"""Fault-tolerant checkpointing: sharded .npz chunks + atomic manifest.

Layout:
    <dir>/step_<N>/shard_<host>.npz     one file per host (its local shards)
    <dir>/step_<N>/MANIFEST.json        written LAST (atomic rename) — a
                                        step directory without a manifest is
                                        incomplete and ignored on resume.

`latest_step` + `restore` give crash-safe auto-resume; `save` prunes old
steps (keep_last).  DeltaGrad's TrainingHistory has `state_dict()` /
`from_state_dict()` and rides along under the "extra" key, so *retraining*
jobs are preemption-safe too.
"""

from __future__ import annotations

import json
import os
import pickle
import shutil
import tempfile
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten_with_names(tree) -> Dict[str, np.ndarray]:
    flat = {}

    def visit(path, leaf):
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
        return leaf

    jax.tree_util.tree_map_with_path(visit, tree)
    return flat


def save(
    directory: str,
    step: int,
    state: Any,
    extra: Optional[Dict[str, Any]] = None,
    host_id: int = 0,
    n_hosts: int = 1,
    keep_last: int = 3,
) -> str:
    """Write a checkpoint; returns the step directory path."""
    step_dir = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(step_dir, exist_ok=True)
    flat = _flatten_with_names(state)
    shard_path = os.path.join(step_dir, f"shard_{host_id:05d}.npz")
    tmp = shard_path + ".tmp"
    with open(tmp, "wb") as f:  # np.savez would append .npz to a bare path
        np.savez(f, **flat)
    os.replace(tmp, shard_path)
    if extra is not None:
        with open(os.path.join(step_dir, "extra.pkl.tmp"), "wb") as f:
            pickle.dump(extra, f)
        os.replace(os.path.join(step_dir, "extra.pkl.tmp"),
                   os.path.join(step_dir, "extra.pkl"))
    if host_id == 0:
        manifest = {
            "step": step,
            "n_hosts": n_hosts,
            "keys": sorted(flat.keys()),
            "time": time.time(),
        }
        mtmp = os.path.join(step_dir, "MANIFEST.json.tmp")
        with open(mtmp, "w") as f:
            json.dump(manifest, f)
        os.replace(mtmp, os.path.join(step_dir, "MANIFEST.json"))
        _prune(directory, keep_last)
    return step_dir


def _prune(directory: str, keep_last: int) -> None:
    steps = complete_steps(directory)
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)


def complete_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in sorted(os.listdir(directory)):
        if not name.startswith("step_"):
            continue
        if os.path.exists(os.path.join(directory, name, "MANIFEST.json")):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = complete_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, like: Any, host_id: int = 0) -> Any:
    """Restore into the structure of `like` (a pytree of arrays or shapes)."""
    step_dir = os.path.join(directory, f"step_{step:08d}")
    if not os.path.exists(os.path.join(step_dir, "MANIFEST.json")):
        raise FileNotFoundError(f"incomplete checkpoint: {step_dir}")
    with np.load(os.path.join(step_dir, f"shard_{host_id:05d}.npz")) as data:
        flat = {k: data[k] for k in data.files}

    def rebuild(path, leaf):
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = flat[key]
        return jax.numpy.asarray(arr)

    return jax.tree_util.tree_map_with_path(rebuild, like)


def restore_extra(directory: str, step: int) -> Optional[Dict[str, Any]]:
    path = os.path.join(directory, f"step_{step:08d}", "extra.pkl")
    if not os.path.exists(path):
        return None
    with open(path, "rb") as f:
        return pickle.load(f)
