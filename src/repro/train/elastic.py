"""Elastic scaling: rebuild the mesh when the healthy device count changes.

Policy: the `model` axis is architecture-determined and fixed; elasticity
happens on the data axis (and the pod axis across pods).  A world-size
change therefore maps to `new_data = n_devices // model`, and a checkpoint
written at any data-size restores onto any other (checkpoints are stored
unsharded per-host, and resharding is just placing with new NamedShardings).

The data pipeline stays deterministic across re-meshes because the sampler
is a pure function of (seed, step) — hosts slice `batch_indices(...)` by
their new data-axis coordinate (see data/sampler.py).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


@dataclass
class ElasticDecision:
    ok: bool
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    dropped_batch: int  # global batch rows dropped to stay divisible
    reason: str = ""


def plan_remesh(
    n_devices: int,
    model_parallel: int,
    global_batch: int,
    multi_pod: bool = False,
    pod_size: Optional[int] = None,
) -> ElasticDecision:
    """Compute the new mesh shape after a world-size change."""
    if n_devices % model_parallel != 0:
        return ElasticDecision(False, (), (), 0,
                               f"{n_devices} devices not divisible by "
                               f"model={model_parallel}")
    data = n_devices // model_parallel
    if multi_pod:
        assert pod_size, "pod_size required for multi-pod re-mesh"
        if n_devices % pod_size != 0:
            return ElasticDecision(False, (), (), 0,
                                   "device count not divisible by pod size")
        pods = n_devices // pod_size
        data = pod_size // model_parallel
        shape = (pods, data, model_parallel)
        names = ("pod", "data", "model")
        dp = pods * data
    else:
        shape = (data, model_parallel)
        names = ("data", "model")
        dp = data
    dropped = global_batch % dp
    return ElasticDecision(True, shape, names, dropped)


def build_mesh(decision: ElasticDecision) -> Mesh:
    assert decision.ok, decision.reason
    return jax.make_mesh(decision.mesh_shape, decision.axis_names)


def reshard_state(state, new_shardings):
    """Place a (host-resident or differently-sharded) state pytree onto the
    new mesh. With jax.device_put the runtime moves/reslices as needed."""
    return jax.tree.map(jax.device_put, state, new_shardings)
