"""Straggler detection & mitigation hooks.

On a 1000+ node fleet the slowest host sets the step time.  This module
provides the host-side machinery:

  * `StepTimer` — per-step wall-time EWMA + p95 tracking;
  * `StragglerPolicy` — flags hosts whose step time exceeds
    `tolerance x p50` for `patience` consecutive steps;
  * mitigation actions (framework-level, since scheduling is external):
      - `deadline_skip`: the driver skips the straggler's microbatch
        contribution for the step (gradient re-weighted by contributing
        microbatch count — unbiased under random assignment),
      - `evict`: recommend elastic re-mesh without the flagged host
        (see train/elastic.py).

The dry-run / CPU tests exercise the bookkeeping; the wire protocol for
cross-host agreement is the job scheduler's (GKE/Borg) concern.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional


@dataclass
class StepTimer:
    window: int = 50
    times: Deque[float] = field(default_factory=deque)
    _start: Optional[float] = None

    def start(self) -> None:
        self._start = time.perf_counter()

    def stop(self) -> float:
        assert self._start is not None
        dt = time.perf_counter() - self._start
        self.times.append(dt)
        while len(self.times) > self.window:
            self.times.popleft()
        self._start = None
        return dt

    def percentile(self, q: float) -> float:
        if not self.times:
            return 0.0
        xs = sorted(self.times)
        i = min(len(xs) - 1, int(q * len(xs)))
        return xs[i]


@dataclass
class StragglerPolicy:
    tolerance: float = 1.5  # x median
    patience: int = 3
    _strikes: Dict[int, int] = field(default_factory=dict)

    def observe(self, host_times: Dict[int, float]) -> List[int]:
        """host_id -> step time; returns hosts flagged for mitigation."""
        if not host_times:
            return []
        xs = sorted(host_times.values())
        median = xs[len(xs) // 2]
        flagged = []
        for host, t in host_times.items():
            if median > 0 and t > self.tolerance * median:
                self._strikes[host] = self._strikes.get(host, 0) + 1
            else:
                self._strikes[host] = 0
            if self._strikes.get(host, 0) >= self.patience:
                flagged.append(host)
        return flagged

    def reweight(self, n_contributing: int, n_total: int) -> float:
        """Gradient scale when deadline-skipping stragglers' microbatches."""
        assert 0 < n_contributing <= n_total
        return n_total / n_contributing
