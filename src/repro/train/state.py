"""TrainState: the single pytree the step function transforms."""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array  # () int32


def init_state(params, optimizer) -> TrainState:
    return TrainState(params=params, opt_state=optimizer.init(params),
                      step=jnp.zeros((), jnp.int32))


def state_shapes(state: TrainState):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
