"""Step builders: train_step / serve_step factories shared by the real driver
(launch/train.py) and the multi-pod dry-run (launch/dryrun.py).

`make_train_step` supports gradient accumulation with microbatching: the
global batch is split along axis 0 into `grad_accum` microbatches processed
by a lax.scan; XLA overlaps each microbatch's gradient reduce-scatter with
the next microbatch's compute (verified in the dry-run HLO by
all-reduce-start/done separation).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer
from repro.train.state import TrainState


def make_train_step(
    loss_fn: Callable[[Any, Any], jax.Array],
    optimizer: Optimizer,
    lr_schedule: Callable[[jax.Array], jax.Array],
    grad_accum: int = 1,
    microbatch_sharding: Optional[Callable[[jax.Array], Any]] = None,
    compute_sharding: Optional[Any] = None,
    compute_dtype=None,
    storage_sharding: Optional[Any] = None,
):
    """(state, batch) -> (state, metrics). loss_fn: (params, batch) -> scalar.

    `microbatch_sharding(leaf) -> sharding` re-pins the batch sharding after
    the (grad_accum, B/g, ...) reshape — GSPMD cannot propagate a 16-way
    batch sharding through that reshape and silently replicates the loop
    body's activations otherwise (observed as ~100x collective inflation in
    the dry-run; see EXPERIMENTS.md §Perf iteration 1).
    """

    def single_grad(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def train_step(state: TrainState, batch):
        master = state.params  # fp32, storage-sharded (ZeRO)
        params = master
        if compute_sharding is not None:
            # ZeRO: state is (model, data)-sharded; compute params are
            # model-only (or replicated in pure-DP mode).  This constraint
            # pins ONE hoisted all-gather per step; without it GSPMD
            # implements the data shard as a contraction split ->
            # per-matmul activation all-reduces (~50x more collective
            # bytes, EXPERIMENTS.md §Perf iteration 1).  `compute_dtype`
            # casts BEFORE the constraint so the gather (and the gradient
            # reduce-scatter, whose cotangents inherit the dtype) moves
            # bf16 instead of fp32 — mixed-precision ZeRO; the optimizer
            # still updates the fp32 master copy.
            if compute_dtype is not None:
                params = jax.tree.map(
                    lambda x: x.astype(compute_dtype)
                    if x.dtype == jnp.float32 else x, params)
                if storage_sharding is not None:
                    # pin the bf16 copy to the STORAGE sharding first so the
                    # partitioner cannot hoist the gather above the cast
                    # (i.e. force gather-in-bf16, not gather-fp32-then-cast)
                    params = jax.lax.with_sharding_constraint(
                        params, storage_sharding)
            params = jax.lax.with_sharding_constraint(params,
                                                      compute_sharding)
        if grad_accum == 1:
            loss, grads = single_grad(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)
            if microbatch_sharding is not None:
                micro = jax.tree.map(
                    lambda x: jax.lax.with_sharding_constraint(
                        x, microbatch_sharding(x)), micro)

            def body(carry, mb):
                loss_acc, grads_acc = carry
                loss, grads = single_grad(params, mb)
                return (loss_acc + loss,
                        jax.tree.map(jnp.add, grads_acc, grads)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            from repro.models.scan_config import scan_unroll
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), micro,
                unroll=scan_unroll())
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
        lr = lr_schedule(state.step)
        new_params, new_opt = optimizer.update(master, grads,
                                               state.opt_state, lr)
        metrics = {"loss": loss, "lr": lr}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def make_finetune_runner(loss_fn: Callable[[Any, Any], jax.Array],
                         optimizer: Optimizer, lr: float, steps: int,
                         project_radius: Optional[float] = None):
    """Compiled warm-start fine-tuner: `steps` full-batch `make_train_step`
    updates under one lax.scan — the descent-to-delete inner loop (noisy
    projected fine-tuning from the last checkpoint; core.algorithms).

    `project_radius` adds the projected-GD step the convex analysis assumes:
    after each update the params are radially projected back onto the L2
    ball of that radius (a no-op while the iterates stay inside it).

    Returns ``run(params, batch) -> (params, losses)``; jit-compiled, keyed
    on the params/batch structure, so a serving stream reuses one program.
    """
    step = make_train_step(loss_fn, optimizer,
                           lambda s: jnp.float32(lr))

    def project(params):
        if project_radius is None:
            return params
        sq = sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(params))
        norm = jnp.sqrt(jnp.maximum(sq, 1e-30))
        shrink = jnp.minimum(1.0, project_radius / norm)
        return jax.tree.map(lambda x: x * shrink.astype(x.dtype), params)

    @jax.jit
    def run(params, batch):
        state = TrainState(params, optimizer.init(params),
                           jnp.zeros((), jnp.int32))

        def body(st, _):
            st, metrics = step(st, batch)
            st = TrainState(project(st.params), st.opt_state, st.step)
            return st, metrics["loss"]

        state, losses = jax.lax.scan(body, state, None, length=steps)
        return state.params, losses

    return run


def make_serve_step(decode_fn: Callable):
    """(params, batch, caches) -> (logits, caches)."""

    def serve_step(params, batch, caches):
        return decode_fn(params, batch, caches)

    return serve_step
