"""repro — DeltaGrad (ICML 2020) as a production-grade JAX/TPU framework.

Core: rapid retraining of SGD/GD-trained models after deletion/addition of a
small set of samples, via a cached optimization path and an L-BFGS
quasi-Hessian correction (Wu, Dobriban, Davidson, ICML 2020).
"""

__version__ = "1.0.0"
