"""Multi-device placement: the parameter/input sharding resolver."""
