"""Parameter / input sharding resolver for the (data, model) mesh.

Megatron-style rules driven by leaf PATH + SHAPE only (no per-model tables):

  * column-parallel projections (wq/wk/wv, w_up/w_gate, ...): model
    parallelism on the OUTPUT dim, data-axis FSDP on the input dim;
  * row-parallel projections (wo, w_down, out_proj): the transpose — model
    on the input dim so the pair (column @ row) needs one all-reduce;
  * the stacked layer axis (scan-over-layers models stack every block
    parameter along a leading ``n_units`` axis) is NEVER sharded — it is
    scanned over, and splitting it would serialize the scan's DMA;
  * any dim not divisible by its mesh axis replicates (GSPMD would pad;
    padding a 140-dim head projection 16 ways wastes >10% of the shard);
  * norms / 1-D leaves replicate on model and FSDP-shard on data when
    divisible;
  * embeddings: vocab-sharded on data only (the lm_head matmul wants d_model
    contiguous);
  * MoE routed experts (leaves shaped (E, d_in, d_out) under ``mlp``):
    expert-parallel on the model axis when E divides it, else
    tensor-parallel on (d_in, d_out) with the expert axis replicated.

Pure functions over a `ShardingPlan` (mesh + optional model config), so unit
tests drive them with a fake mesh and no devices.
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Any, Optional, Tuple

from jax.sharding import PartitionSpec as P

_ROW_PARALLEL = ("wo", "w_down", "out_proj")
_NORM_PARENTS = re.compile(r"(^|/)(ln\d*|.*norm)(/|$)")
_STACKED_PREFIX = re.compile(r"^u\d+(/|$)")


@dataclass
class ShardingPlan:
    mesh: Any
    cfg: Optional[Any] = None  # ModelConfig; enables the MoE rules

    def axis_size(self, name: str) -> int:
        names = tuple(self.mesh.axis_names)
        if name not in names:
            return 1
        return int(self.mesh.devices.shape[names.index(name)])


def make_plan(mesh, cfg=None) -> ShardingPlan:
    return ShardingPlan(mesh=mesh, cfg=cfg)


def _fit(plan: ShardingPlan, axis: Optional[str], dim: int) -> Optional[str]:
    """axis if dim divides its mesh size, else replicate."""
    if axis is None:
        return None
    size = plan.axis_size(axis)
    return axis if (size > 1 and dim % size == 0) else None


def _matrix_spec(plan, dims: Tuple[int, ...], row_parallel: bool):
    """Spec for the trailing (..., d_in, d_out) dims of a projection."""
    lead = (None,) * (len(dims) - 2)
    d_in, d_out = dims[-2], dims[-1]
    if row_parallel:
        return lead + (_fit(plan, "model", d_in), _fit(plan, "data", d_out))
    return lead + (_fit(plan, "data", d_in), _fit(plan, "model", d_out))


def spec_for_leaf(plan: ShardingPlan, path: str, shape: Tuple[int, ...]) -> P:
    """PartitionSpec for one parameter leaf, keyed by its path and shape."""
    parts = path.split("/")
    name = parts[-1]
    stacked = bool(_STACKED_PREFIX.match(path))
    dims = tuple(shape[1:]) if stacked else tuple(shape)
    prefix: Tuple[Optional[str], ...] = (None,) if stacked else ()

    def done(spec_dims) -> P:
        return P(*(prefix + tuple(spec_dims)))

    # embeddings: vocab rows FSDP-sharded on data, d_model contiguous
    if name == "embed":
        return done((_fit(plan, "data", dims[0]),) + (None,) * (len(dims) - 1))

    # norms and other vectors: data-FSDP the feature dim when divisible
    if name in ("scale", "bias") or (len(parts) > 1
                                     and _NORM_PARENTS.search("/".join(parts[:-1]))):
        spec = [None] * len(dims)
        if dims:
            spec[-1] = _fit(plan, "data", dims[-1])
        return done(spec)

    # MoE routed experts: (E, d_in, d_out) under an mlp block
    moe = plan.cfg.moe if (plan.cfg is not None
                           and getattr(plan.cfg, "moe", None)) else None
    if (moe is not None and len(dims) == 3 and "mlp" in parts
            and name in ("w_gate", "w_up", "w_down")):
        E = dims[0]
        if plan.axis_size("model") > 1 and E % plan.axis_size("model") == 0:
            # expert-parallel: experts on model, FSDP the widest matmul dim
            return done(("model", None, _fit(plan, "data", dims[-1])))
        # TP fallback: expert axis replicated, usual column/row split
        return done((None,) + _matrix_spec(
            plan, dims[1:], row_parallel=(name in _ROW_PARALLEL)))

    # projections (>= 2 trailing dims): column- or row-parallel
    if len(dims) >= 2:
        return done(_matrix_spec(plan, dims,
                                 row_parallel=(name in _ROW_PARALLEL)))

    # unknown vectors/scalars: replicate
    return done((None,) * len(dims))


def stacked_spec_for_leaf(plan: ShardingPlan, path: str,
                          shape: Tuple[int, ...]) -> P:
    """PartitionSpec for a HISTORY leaf: a per-step parameter leaf stacked
    along a leading time axis ``(T, ...)`` (core/history's stacked tier).

    The TIME axis is never sharded — the replay scan iterates it step by
    step, and splitting it would serialize every `lax.dynamic_slice` into a
    cross-host fetch.  The per-step dims inherit the live parameter's
    placement from `spec_for_leaf`, so the cached path shards exactly like
    the model it caches and per-host HBM drops by the mesh factor."""
    per_step = spec_for_leaf(plan, path, tuple(shape[1:]))
    return P(None, *tuple(per_step))


def history_shardings(plan: ShardingPlan, stacked_tree):
    """NamedSharding pytree for a stacked (T, ...) history pytree."""
    import jax
    from jax.sharding import NamedSharding

    def one(key_path, leaf):
        spec = stacked_spec_for_leaf(plan, _path_str(key_path),
                                     tuple(leaf.shape))
        return NamedSharding(plan.mesh, spec)

    return jax.tree_util.tree_map_with_path(one, stacked_tree)


def stacked_entry_shardings(plan: ShardingPlan, entry_tree):
    """NamedSharding pytree for stacked (L, ...) WINDOWS of one history
    entry (a per-step (w, g)-shaped pytree — shapes WITHOUT the time axis).

    This is `core.store.ShardedStreamer`'s placement driver: every window a
    host/disk-tier shard streams takes exactly the `stacked_spec_for_leaf`
    placement a `ResidentStore` would give the full (T, ...) leaf — the
    window length rides the (never sharded) leading time axis, so the
    per-shard encoded segments the streamer stages line up with the
    resident store's shards and the same per-step all-gather plan serves
    both."""
    import jax
    from jax.sharding import NamedSharding

    def one(key_path, leaf):
        spec = stacked_spec_for_leaf(plan, _path_str(key_path),
                                     (1,) + tuple(leaf.shape))
        return NamedSharding(plan.mesh, spec)

    return jax.tree_util.tree_map_with_path(one, entry_tree)


def batch_pspec(plan: ShardingPlan, shape: Tuple[int, ...]) -> P:
    """Inputs: batch-dim data parallelism when the global batch divides the
    data axis (batch-1 decode shapes replicate)."""
    if not shape:
        return P()
    return P(_fit(plan, "data", shape[0]), *([None] * (len(shape) - 1)))


# --------------------------------------------------------------------------
# Pytree drivers
# --------------------------------------------------------------------------


def _path_str(key_path) -> str:
    import jax

    parts = []
    for k in key_path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def params_shardings(plan: ShardingPlan, params_tree):
    """NamedSharding pytree for a params pytree (arrays or ShapeDtypeStructs)."""
    import jax
    from jax.sharding import NamedSharding

    def one(key_path, leaf):
        spec = spec_for_leaf(plan, _path_str(key_path), tuple(leaf.shape))
        return NamedSharding(plan.mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_tree)


def inputs_shardings(plan: ShardingPlan, specs_tree):
    """NamedSharding pytree for model inputs (batch-leading tensors)."""
    import jax
    from jax.sharding import NamedSharding

    return jax.tree.map(
        lambda s: NamedSharding(plan.mesh, batch_pspec(plan, tuple(s.shape))),
        specs_tree)
