"""Minimal optax-style optimizers (optax is not vendored here).

An Optimizer is (init_fn, update_fn):
    state = init(params)
    new_params, new_state = update(params, grads, state, lr)

SGD (+momentum) is what the DeltaGrad path assumes (plain SGD); AdamW serves
the LM substrate.  All states are pytrees mirroring params, so they shard
with the same NamedShardings (ZeRO-style when params are 2D-sharded).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], Tuple[Any, Any]]
    name: str = "opt"


def sgd(momentum: float = 0.0, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {"step": jnp.zeros((), jnp.int32)}
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(jnp.zeros_like, params),
        }

    def update(params, grads, state, lr):
        if weight_decay:
            grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
        if momentum == 0.0:
            new_params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
            return new_params, {"step": state["step"] + 1}
        mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
        new_params = jax.tree.map(lambda p, m: p - lr * m, params, mu)
        return new_params, {"step": state["step"] + 1, "mu": mu}

    return Optimizer(init, update, name="sgd")


def adamw(
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
) -> Optimizer:
    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(jnp.zeros_like, params),
            "v": jax.tree.map(jnp.zeros_like, params),
        }

    def update(params, grads, state, lr):
        if grad_clip:
            gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                              for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gn, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        step = state["step"] + 1
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g),
                         state["v"], grads)

        def upd(p, mm, vv):
            mhat = mm / bc1
            vhat = vv / bc2
            return p - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"step": step, "m": m, "v": v}

    return Optimizer(init, update, name="adamw")
