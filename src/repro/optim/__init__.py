from repro.optim.optimizers import Optimizer, adamw, sgd  # noqa: F401
from repro.optim.schedules import (  # noqa: F401
    constant,
    cosine_decay,
    piecewise_constant,
    warmup_cosine,
)
