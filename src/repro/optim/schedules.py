"""Learning-rate schedules (pure functions of the step)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def piecewise_constant(points):
    """points: ((from_step, lr), ...) — the paper's MNIST^n schedule."""
    def f(step):
        lr = jnp.float32(points[0][1])
        for start, value in points:
            lr = jnp.where(step >= start, jnp.float32(value), lr)
        return lr
    return f


def cosine_decay(lr: float, total_steps: int, final_frac: float = 0.1):
    def f(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.float32(lr * (final_frac + (1 - final_frac) * cos))
    return f


def warmup_cosine(lr: float, warmup: int, total_steps: int, final_frac: float = 0.1):
    decay = cosine_decay(lr, max(total_steps - warmup, 1), final_frac)
    def f(step):
        warm = lr * (step + 1) / max(warmup, 1)
        return jnp.where(step < warmup, jnp.float32(warm), decay(step - warmup))
    return f
