from repro.roofline.hw import TPU_V5E  # noqa: F401
from repro.roofline.analysis import (  # noqa: F401
    RooflineReport,
    collective_bytes_from_hlo,
    roofline_from_compiled,
)
