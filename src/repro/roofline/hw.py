"""Hardware constants for the roofline model (assignment-specified)."""

from dataclasses import dataclass


@dataclass(frozen=True)
class HwSpec:
    name: str
    peak_flops_bf16: float  # per chip, FLOP/s
    hbm_bw: float  # per chip, B/s
    ici_link_bw: float  # per link, B/s
    hbm_bytes: float  # per chip


TPU_V5E = HwSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_link_bw=50e9,
    hbm_bytes=16 * 1024**3,
)
