"""First-order analytic FLOP / HBM-byte model per (arch x shape) cell.

Why this exists: XLA's `compiled.cost_analysis()` counts `lax.scan` (while
loop) bodies ONCE — for a 64-layer scanned model with 8-way gradient
accumulation it under-reports FLOPs by ~2 orders of magnitude (verified in
tests/test_roofline.py, which also validates THIS model against
cost_analysis() on fully-unrolled small configs).  §Roofline therefore uses:

    FLOPs / HBM bytes  -> this analytic model (matmul-exact, first-order)
    collective bytes   -> loop-aware HLO parse of the compiled module
    memory fit         -> compiled.memory_analysis() of the production module

Conventions: backward pass = 2x forward FLOPs (train = 3x forward);
causal attention averages S/2 context; HBM bytes count parameter,
activation-checkpoint, logits and KV-cache traffic at their storage widths.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.transformer import layout_of


@dataclass
class AnalyticCost:
    flops_global: float  # whole step, all chips
    bytes_global: float
    breakdown: Dict[str, float]


def _attn_ctx(seq: int, causal: bool, window: int) -> float:
    ctx = seq / 2 if causal else seq
    if window:
        ctx = min(ctx, window)
    return ctx


def _block_fwd_flops(kind: str, cfg: ModelConfig, T: float, seq: int,
                     decode: bool) -> float:
    d = cfg.d_model
    if kind in ("attn", "attn_shared"):
        H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        ctx = seq if decode else _attn_ctx(seq, True, cfg.attn_window)
        if cfg.attn_window and decode:
            ctx = min(seq, cfg.attn_window)
        if cfg.attention == "mla":
            m = cfg.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            f = 2 * T * d * m.q_lora_rank + 2 * T * m.q_lora_rank * H * qk
            f += 2 * T * d * (m.kv_lora_rank + m.qk_rope_head_dim)
            f += 2 * T * m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
            f += 2 * T * ctx * H * (qk + m.v_head_dim)
            f += 2 * T * H * m.v_head_dim * d
        else:
            f = 2 * T * d * (H + 2 * Hkv) * dh + 2 * T * d * H * dh
            f += 2 * T * ctx * H * dh * 2
        # mlp
        if cfg.mlp == "moe":
            mo = cfg.moe
            f += 2 * T * d * mo.num_experts  # router
            # capacity-padded dispatch computes E*(C+1) slots (see models/moe)
            cap = max(int(-(-mo.capacity_factor * mo.top_k * T // mo.num_experts)), 1)
            slots = mo.num_experts * (cap + 1)
            f += slots * 6 * d * mo.d_expert
            if mo.num_shared:
                f += 6 * T * d * mo.d_shared + 2 * T * d
        elif cfg.mlp == "swiglu":
            f += 6 * T * d * cfg.d_ff
        elif cfg.mlp in ("relu_sq", "gelu"):
            f += 4 * T * d * cfg.d_ff
        return f
    if kind == "mamba2":
        s = cfg.ssm
        di = s.expand * d
        H = di // s.head_dim
        gn = s.n_groups * s.d_state
        Q = 1 if decode else min(s.chunk, seq)
        f = 2 * T * d * (2 * di + 2 * gn + H)  # in_proj
        f += 2 * T * s.d_conv * (di + 2 * gn)  # conv
        f += 2 * T * Q * s.n_groups * s.d_state  # intra scores
        f += 2 * T * Q * di  # intra att @ x
        f += 4 * T * s.d_state * di  # states build + apply
        f += 2 * T * di * d  # out_proj
        return f
    if kind == "mlstm":
        pf = cfg.xlstm.proj_factor_mlstm
        di = int(pf * d)
        dh = di // cfg.n_heads
        Q = 1 if decode else min(256, seq)
        f = 2 * T * d * di * 2  # up + z
        f += 3 * 2 * T * di * di  # q, k, v
        f += 2 * T * Q * di * 2  # chunk scores + weighted v
        f += 4 * T * di * dh  # carry C q + state update
        f += 2 * T * di * d  # down
        return f
    if kind == "slstm":
        du = int(cfg.xlstm.proj_factor_slstm * d)
        dh = d // cfg.n_heads
        f = 2 * T * d * 4 * d  # w_in
        f += 2 * T * 4 * d * dh  # block-diagonal recurrence
        f += 2 * T * d * 2 * du + 2 * T * du * d  # GeGLU MLP
        return f
    raise ValueError(kind)


def _per_layer_param_bytes(cfg: ModelConfig) -> float:
    from repro.models.registry import count_params

    return float(count_params(cfg))


def analytic_cost(cfg: ModelConfig, shape: ShapeConfig, *, grad_accum: int = 1,
                  n_params: float = 0.0) -> AnalyticCost:
    decode = shape.is_decode
    B = shape.global_batch
    S = shape.seq_len
    T = float(B) * (1 if decode else S)
    seq_ctx = S  # decode context = cache length

    bd: Dict[str, float] = {}
    if cfg.family == "audio":
        # encoder (bidirectional, full ctx) + decoder (causal + cross)
        H, dh, d = cfg.n_heads, cfg.head_dim, cfg.d_model
        if decode:
            enc_f = 0.0
            Tdec = T
            ctx_cross = 1500.0
        else:
            Tenc = float(B) * S
            enc_f = cfg.n_encoder_layers * (
                2 * Tenc * d * (H + 2 * cfg.n_kv_heads) * dh
                + 2 * Tenc * d * H * dh + 2 * Tenc * S * H * dh * 2
                + 4 * Tenc * d * cfg.d_ff)
            Tdec = T
            ctx_cross = float(S)
        self_ctx = seq_ctx if decode else S / 2
        dec_f = cfg.n_layers * (
            2 * Tdec * d * (H + 2 * cfg.n_kv_heads) * dh + 2 * Tdec * d * H * dh
            + 2 * Tdec * self_ctx * H * dh * 2  # self
            + 4 * Tdec * d * H * dh + 2 * Tdec * ctx_cross * H * dh * 2  # cross
            + 4 * Tdec * d * cfg.d_ff)
        head_f = 2 * Tdec * d * cfg.vocab
        bd["encoder"] = enc_f
        bd["decoder"] = dec_f
        bd["head"] = head_f
        fwd = enc_f + dec_f + head_f
    else:
        unit, n_units = layout_of(cfg)
        fwd = 0.0
        for kind in unit:
            f = _block_fwd_flops(kind, cfg, T, seq_ctx, decode) * n_units
            bd[kind] = bd.get(kind, 0.0) + f
            fwd += f
        head_f = 2 * T * cfg.d_model * cfg.vocab
        if decode:
            head_f = 2 * B * cfg.d_model * cfg.vocab
        bd["head"] = head_f
        fwd += head_f

    mult = 3.0 if shape.kind == "train" else 1.0
    flops = fwd * mult
    bd = {k: v * mult for k, v in bd.items()}

    # ---- HBM bytes -----------------------------------------------------
    P = n_params
    d = cfg.d_model
    L_eff = cfg.n_layers + cfg.n_encoder_layers
    bytes_total = 0.0
    if shape.kind == "train":
        # params: bf16 read per microbatch fwd+bwd; grads fp32 w+r;
        # adam m/v read+write + param update rw (fp32 master)
        bytes_total += P * (2.0 * 2 * grad_accum + 4 * 2 + 8 * 2 + 4 * 2)
        # activation checkpoints: carry per layer write (fwd) + read (bwd)
        # + recompute write
        bytes_total += 3 * L_eff * T * d * 2.0
        # logits: fp32 write+read fwd, write bwd (chunked but HBM-resident)
        bytes_total += 3 * T * cfg.vocab * 4.0
        bd["bytes_params"] = P * (2.0 * 2 * grad_accum + 32)
        bd["bytes_acts"] = 3 * L_eff * T * d * 2.0
        bd["bytes_logits"] = 3 * T * cfg.vocab * 4.0
    elif shape.kind == "prefill":
        bytes_total += P * 2.0
        bytes_total += 2 * L_eff * T * d * 2.0
        bytes_total += _cache_bytes(cfg, B, S)  # cache write
        bd["bytes_cache"] = _cache_bytes(cfg, B, S)
    else:  # decode
        bytes_total += P * 2.0  # weights stream once per step
        bytes_total += _cache_bytes(cfg, B, S)  # cache read
        bytes_total += 2 * B * cfg.vocab * 4.0
        bd["bytes_cache"] = _cache_bytes(cfg, B, S)
    bd["bytes_total"] = bytes_total

    return AnalyticCost(flops_global=flops, bytes_global=bytes_total, breakdown=bd)


def _cache_bytes(cfg: ModelConfig, B: int, S: int) -> float:
    """Decode-state bytes touched per step (read)."""
    if cfg.family == "audio":
        kv = cfg.n_layers * 2 * B * S * cfg.n_kv_heads * cfg.head_dim * 2.0
        cross = cfg.n_layers * 2 * B * 1500 * cfg.n_heads * cfg.head_dim * 2.0
        return kv + cross
    unit, n_units = layout_of(cfg)
    total = 0.0
    for kind in unit:
        if kind in ("attn", "attn_shared"):
            s_eff = min(S, cfg.attn_window) if cfg.attn_window else S
            if cfg.attention == "mla":
                m = cfg.mla
                total += n_units * B * s_eff * (m.kv_lora_rank
                                                + m.qk_rope_head_dim) * 2.0
            else:
                total += n_units * 2 * B * s_eff * cfg.n_kv_heads * \
                    cfg.head_dim * 2.0
        elif kind == "mamba2":
            s = cfg.ssm
            di = s.expand * cfg.d_model
            total += n_units * B * (di * s.d_state / s.head_dim * s.head_dim
                                    + (s.d_conv - 1) * (di + 2 * s.n_groups
                                                        * s.d_state)) * 4.0
            total += n_units * B * (di // s.head_dim) * s.head_dim * s.d_state * 4.0
        elif kind == "mlstm":
            di = int(cfg.xlstm.proj_factor_mlstm * cfg.d_model)
            dh = di // cfg.n_heads
            total += n_units * B * cfg.n_heads * dh * dh * 4.0
        elif kind == "slstm":
            total += n_units * 4 * B * cfg.d_model * 4.0
    return total
