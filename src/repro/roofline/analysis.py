"""Roofline terms from a compiled (dry-run) executable.

  compute    = HLO_FLOPs_global   / (chips * peak_FLOP/s)
  memory     = HLO_bytes_global   / (chips * HBM_bw)
  collective = collective_bytes_global / (chips * ICI_link_bw)

`compiled.cost_analysis()` reports the PER-DEVICE partitioned program, so we
multiply by the device count to get globals (the spec formula then divides
by chips again — i.e. the terms are per-chip seconds, which is what a
balanced SPMD program takes).  Collective bytes are not in cost_analysis;
we parse the optimized HLO and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute (skipping
`-done` halves of async pairs so nothing is double-counted).
"""

from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional

from repro.roofline.hw import HwSpec, TPU_V5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_BRACKET_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """`compiled.cost_analysis()` normalized across jax versions: newer
    backends return a per-device LIST of property dicts (possibly empty),
    older ones a single dict.  Always returns a dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _GROUPS_BRACKET_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 2  # unknown -> conservative


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Per-device ICI link bytes of each collective kind in the module.

    Optimized-HLO `as_text()` prints operands as bare %names, so we work
    from the RESULT shape plus the replica-group size S, with the standard
    ring-algorithm serialization volumes per participating device:

        all-gather:          (S-1)/S * result_bytes
        reduce-scatter:      (S-1)   * result_bytes   (input = S * result)
        all-reduce:          2(S-1)/S * result_bytes
        all-to-all:          (S-1)/S * result_bytes
        collective-permute:  result_bytes

    `-done` halves of async pairs are skipped (the `-start` carries the
    shape), so nothing is double-counted.
    """
    totals: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s+(\(?[a-z0-9].*?)\s+([a-z0-9-]+)\(", stripped)
        if not m:
            continue
        op = m.group(2)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op == c + "-start":
                kind = c
                break
        if kind is None:
            continue
        result = m.group(1)
        size = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(result))
        s = _group_size(stripped)
        if kind == "all-gather":
            vol = size * (s - 1) / s
        elif kind == "reduce-scatter":
            vol = size * (s - 1)
        elif kind == "all-reduce":
            vol = size * 2 * (s - 1) / s
        elif kind == "all-to-all":
            vol = size * (s - 1) / s
        else:  # collective-permute
            vol = size
        totals[kind] += vol
    return {k: int(v) for k, v in totals.items()}


# --------------------------------------------------------------------------
# Loop-aware collective accounting.
#
# jax.lax.scan lowers to an HLO while loop, and XLA's cost/byte analyses (and
# a naive text scan) count the body ONCE instead of trip_count times.  We
# parse the module's computation graph, recover each while's trip count from
# the constant in its condition computation, and multiply every collective
# found inside a body by the product of enclosing trip counts.
# --------------------------------------------------------------------------

_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s+\(.*\)\s*->.*\{$")
_WHILE_RE = re.compile(r"while\(.*?\),\s*condition=%([\w.\-]+),\s*body=%([\w.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply)=%([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    current = None
    entry = None
    for line in hlo_text.splitlines():
        s = line.strip()
        m = _COMP_HEADER_RE.match(s)
        if m and s.endswith("{"):
            current = m.group(1)
            comps[current] = []
            if s.startswith("ENTRY"):
                entry = current
            continue
        if s == "}":
            current = None
            continue
        if current is not None:
            comps[current].append(s)
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    consts = [int(c) for ln in cond_lines for c in _CONST_RE.findall(ln)]
    consts = [c for c in consts if c > 0]
    return max(consts) if consts else 1


def _line_collective_bytes(stripped: str) -> Dict[str, float]:
    out: Dict[str, float] = {}
    m = re.search(r"=\s+(\(?[a-z0-9].*?)\s+([a-z0-9-]+)\(", stripped)
    if not m:
        return out
    op = m.group(2)
    kind = None
    for c in _COLLECTIVES:
        if op == c or op == c + "-start":
            kind = c
            break
    if kind is None:
        return out
    size = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(m.group(1)))
    s = _group_size(stripped)
    if kind == "all-gather":
        vol = size * (s - 1) / s
    elif kind == "reduce-scatter":
        vol = size * (s - 1)
    elif kind == "all-reduce":
        vol = size * 2 * (s - 1) / s
    elif kind == "all-to-all":
        vol = size * (s - 1) / s
    else:
        vol = size
    out[kind] = vol
    return out


def collective_bytes_loop_aware(hlo_text: str) -> Dict[str, int]:
    """Per-device link bytes with while-loop trip counts applied."""
    comps = _split_computations(hlo_text)
    if "__entry__" not in comps:
        return collective_bytes_from_hlo(hlo_text)

    # whiles per computation: (cond, body)
    whiles: Dict[str, List] = {}
    calls: Dict[str, List[str]] = {}
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        whiles[name] = []
        calls[name] = []
        for ln in lines:
            for cond, body in _WHILE_RE.findall(ln):
                whiles[name].append((cond, body))
            calls[name].extend(_CALL_RE.findall(ln))

    entry_lines = comps["__entry__"]
    entry_name = None
    for name, lines in comps.items():
        if name != "__entry__" and lines is entry_lines:
            entry_name = name
            break

    mult: Dict[str, float] = {entry_name: 1.0}
    import collections as _c

    queue = _c.deque([entry_name])
    seen = set()
    while queue:
        cur = queue.popleft()
        if cur in seen:
            continue
        seen.add(cur)
        base = mult.get(cur, 1.0)
        for cond, body in whiles.get(cur, []):
            tc = _trip_count(comps.get(cond, []))
            mult[body] = max(mult.get(body, 0.0), base * tc)
            mult[cond] = max(mult.get(cond, 0.0), base * tc)
            queue.append(body)
            queue.append(cond)
        for callee in calls.get(cur, []):
            if callee in comps:
                mult[callee] = max(mult.get(callee, 0.0), base)
                queue.append(callee)

    totals: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 1.0 if name == entry_name else 0.0)
        if m <= 0:
            continue
        for ln in lines:
            for kind, vol in _line_collective_bytes(ln).items():
                totals[kind] += vol * m
    return {k: int(v) for k, v in totals.items()}


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_global: float
    bytes_global: float
    collective_bytes_global: float
    collective_breakdown: Dict[str, int]
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    raw_hlo_flops_per_device: float = 0.0  # cost_analysis verbatim (loop
    raw_hlo_bytes_per_device: float = 0.0  # bodies counted once — see model.py)
    model_flops: float = 0.0
    usefulness: float = 0.0  # MODEL_FLOPS / HLO_FLOPs
    peak_memory_per_device: float = 0.0
    note: str = ""
    variant: str = "baseline"

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def roofline_fraction(self) -> float:
        """How much of the bound time is the useful-compute time."""
        t_useful = self.model_flops / max(self.flops_global, 1.0) * self.t_compute
        return t_useful / max(self.bound_time, 1e-30)

    def to_json(self) -> str:
        return json.dumps(asdict(self))


def roofline_from_compiled(
    compiled,
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    n_devices: int,
    model_flops: float = 0.0,
    hw: HwSpec = TPU_V5E,
    hlo_text: Optional[str] = None,
    note: str = "",
    variant: str = "baseline",
    analytic_flops: Optional[float] = None,
    analytic_bytes: Optional[float] = None,
) -> RooflineReport:
    cost = cost_analysis_dict(compiled)
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes_loop_aware(text)
    coll_dev = float(sum(coll.values()))

    # cost_analysis counts while bodies once; prefer the validated analytic
    # model when supplied (see roofline/model.py + tests/test_roofline.py).
    flops_g = analytic_flops if analytic_flops else flops_dev * n_devices
    bytes_g = analytic_bytes if analytic_bytes else bytes_dev * n_devices
    coll_g = coll_dev * n_devices

    t_compute = flops_g / (n_devices * hw.peak_flops_bf16)
    t_memory = bytes_g / (n_devices * hw.hbm_bw)
    t_collective = coll_g / (n_devices * hw.ici_link_bw)

    dominant = max(
        (("compute", t_compute), ("memory", t_memory), ("collective", t_collective)),
        key=lambda kv: kv[1],
    )[0]

    peak_mem = 0.0
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            peak_mem = float(
                getattr(ma, "temp_size_in_bytes", 0)
                + getattr(ma, "argument_size_in_bytes", 0)
                + getattr(ma, "output_size_in_bytes", 0)
                - getattr(ma, "alias_size_in_bytes", 0)
            )
    except Exception:
        pass

    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        n_devices=n_devices,
        flops_global=flops_g,
        bytes_global=bytes_g,
        raw_hlo_flops_per_device=flops_dev,
        raw_hlo_bytes_per_device=bytes_dev,
        collective_bytes_global=coll_g,
        collective_breakdown=coll,
        t_compute=t_compute,
        t_memory=t_memory,
        t_collective=t_collective,
        dominant=dominant,
        model_flops=model_flops,
        usefulness=(model_flops / flops_g) if flops_g else 0.0,
        peak_memory_per_device=peak_mem,
        note=note,
        variant=variant,
    )
