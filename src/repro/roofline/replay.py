"""Analytic roofline for DeltaGrad replay spans.

`roofline.model` prices transformer training steps; this module prices
the REPLAY step the unlearning engine actually runs — the
L-BFGS-corrected update of Algorithm 1/3 — so the span tracer
(`repro.obs.trace`) can attach a predicted cost to every scanned replay
segment and the exported trace carries measured-vs-roofline ratios.

Per approximate (corrected) step over P parameters with a changed-row
block of width r (the schedule's pow2 pad) and an m-pair history ring:

    FLOPs:  changed-row gradient (fwd+bwd over r examples, first-order
            matmul-exact for the linear family: ~6·r·P), the masked
            compact two-loop correction (~8·m·P), and the fused update
            arithmetic (~10·P);
    bytes:  the streamed history entry (w_t, g_t) in and the rewritten
            (w, g) out (4·P·dtype), the stacked pair ring (4·m·P·dtype),
            the changed-row features (r·P·dtype), and the parameter
            carry (2·P·dtype).

The prediction is ``max(flops / peak, bytes / bw)`` on the given
`HwSpec` — a LOWER BOUND on wall time ("as fast as the hardware
allows"), so the measured/predicted ratio reads as distance from the
roofline: ~1 means the scan is hardware-bound, ≫1 means dispatch/host
overheads dominate (the expected regime for CPU CI runs).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.roofline.hw import TPU_V5E, HwSpec

__all__ = ["ReplayCost", "replay_step_cost", "scan_segment_cost"]


@dataclass(frozen=True)
class ReplayCost:
    """Roofline prediction for a replay span."""

    flops: float
    hbm_bytes: float
    t_compute: float
    t_memory: float

    @property
    def pred_s(self) -> float:
        return max(self.t_compute, self.t_memory)

    @property
    def bound(self) -> str:
        return "compute" if self.t_compute >= self.t_memory else "memory"


def replay_step_cost(n_params: int, r_changed: int, m_history: int,
                     momentum: bool = False, dtype_bytes: int = 4,
                     hw: HwSpec = TPU_V5E) -> ReplayCost:
    """Cost of ONE corrected replay step (see the module docstring)."""
    P = float(max(1, n_params))
    r = float(max(1, r_changed))
    m = float(max(0, m_history))
    flops = 6.0 * r * P + 8.0 * m * P + 10.0 * P
    if momentum:
        flops += 4.0 * P
    hbm = dtype_bytes * (4.0 * P        # (w_t, g_t) in, rewritten out
                         + 4.0 * m * P  # stacked dW/dG pair ring
                         + r * P        # changed-row feature block
                         + 2.0 * P)     # parameter carry in/out
    return ReplayCost(flops=flops, hbm_bytes=hbm,
                      t_compute=flops / hw.peak_flops_bf16,
                      t_memory=hbm / hw.hbm_bw)


def scan_segment_cost(n_params: int, steps: int, r_changed: int,
                      m_history: int, momentum: bool = False,
                      dtype_bytes: int = 4,
                      hw: HwSpec = TPU_V5E) -> ReplayCost:
    """Cost of a scanned segment of ``steps`` corrected replay steps."""
    one = replay_step_cost(n_params, r_changed, m_history,
                           momentum=momentum, dtype_bytes=dtype_bytes,
                           hw=hw)
    s = float(max(1, steps))
    return ReplayCost(flops=one.flops * s, hbm_bytes=one.hbm_bytes * s,
                      t_compute=one.t_compute * s,
                      t_memory=one.t_memory * s)
