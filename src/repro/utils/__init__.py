from repro.utils.tree import (  # noqa: F401
    tree_add,
    tree_axpy,
    tree_lincomb,
    tree_norm,
    tree_scale,
    tree_sub,
    tree_vdot,
    tree_zeros_like,
)
