"""Pytree vector-space helpers.

DeltaGrad's L-BFGS machinery only needs inner products and linear
combinations of parameter-shaped objects, so the whole core operates on
pytrees directly.  This keeps the algorithm sharding-transparent: a pytree of
`NamedSharding`-placed arrays flows through unchanged, and `tree_vdot`
reductions lower to per-shard partial dots + a psum inserted by the compiler.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(s, a):
    return jax.tree.map(lambda x: s * x, a)


def tree_axpy(s, x, y):
    """y + s * x (pytree AXPY)."""
    return jax.tree.map(lambda xi, yi: yi + s * xi, x, y)


def tree_vdot(a, b):
    """Full-precision inner product <a, b> over every leaf."""
    leaves_a = jax.tree.leaves(a)
    leaves_b = jax.tree.leaves(b)
    parts = [
        jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32))
        for x, y in zip(leaves_a, leaves_b)
    ]
    return jnp.sum(jnp.stack(parts))


def tree_norm(a):
    return jnp.sqrt(tree_vdot(a, a))


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_lincomb(coeffs, trees: Sequence):
    """sum_k coeffs[k] * trees[k]; coeffs is a 1-D array or list of scalars."""
    assert len(trees) > 0
    out = tree_scale(coeffs[0], trees[0])
    for k in range(1, len(trees)):
        out = tree_axpy(coeffs[k], trees[k], out)
    return out


def tree_all_finite(a) -> jax.Array:
    leaves = jax.tree.leaves(a)
    ok = jnp.array(True)
    for x in leaves:
        ok = jnp.logical_and(ok, jnp.all(jnp.isfinite(x)))
    return ok


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)
