"""Public wrapper: layout adaptation + padding for the flash kernel.

Model code uses (B, S, H, D) activations; the kernel wants (B, H, S, D).
On CPU this runs in interpret mode (tests); on TPU it compiles natively.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, block_q: int = 128, block_k: int = 128,
              interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Sk, Hkv, D) -> (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    _, Sk, Hkv, _ = k.shape
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    # pad sequence dims to block multiples (extra kv columns are masked by
    # causality only if causal; for exactness we pad q and slice back, and
    # pad kv with -inf-free zeros that the causal mask excludes when
    # Sq == Sk; non-causal callers must pass aligned shapes).
    Sqp = -(-Sq // bq) * bq
    Skp = -(-Sk // bk) * bk
    assert causal or (Sqp == Sq and Skp == Sk), \
        "non-causal requires block-aligned shapes"
    qt = jnp.pad(q, ((0, 0), (0, Sqp - Sq), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    kt = jnp.pad(k, ((0, 0), (0, Skp - Sk), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    vt = jnp.pad(v, ((0, 0), (0, Skp - Sk), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    o = flash_attention(qt, kt, vt, causal=causal, block_q=bq, block_k=bk,
                        interpret=interpret)
    return o.transpose(0, 2, 1, 3)[:, :Sq]
