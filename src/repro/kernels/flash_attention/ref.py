"""Dense-softmax oracle for the flash attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, Hkv, Sk, D); GQA via H = Hkv * G."""
    B, H, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, Sq, D).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32))
    s = s / np.sqrt(D)
    if causal:
        mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, D).astype(q.dtype)
