"""Causal GQA flash attention (forward) — Pallas TPU.

Online-softmax tiling (Dao et al., adapted to the TPU memory hierarchy):
grid = (B*H, Sq/BQ, Sk/BK); the innermost grid dimension is sequential on
TPU, so the (m, l, acc) running state lives in VMEM scratch across the
Sk/BK iterations of one (batch-head, q-block).  Block shapes keep the MXU
dims 128-aligned: q tile (BQ, D), kv tiles (BK, D), scores (BQ, BK).

GQA: kv blocks are indexed with h // (H/Hkv), so KV tiles are re-read per
q-head group (VMEM-resident; HBM reads stay O(Sk * D) per kv head with
pipelining).  Fully-masked causal blocks short-circuit via pl.when (the
block grid is data-independent, so this costs a predicate, not a branch).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, block_q: int, block_k: int,
               n_k_blocks: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = True
    if causal:
        # kv block strictly after the last q row of this q block -> skip
        run = ik * block_k <= iq * block_q + block_q - 1

    @pl.when(run)
    def _body():
        q = q_ref[0, ...].astype(jnp.float32) * scale  # (BQ, D)
        k = k_ref[0, ...].astype(jnp.float32)  # (BK, D)
        v = v_ref[0, ...].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            q_pos = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            k_pos = ik * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev = m_scr[...]  # (BQ, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == n_k_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, ...] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, Hkv, Sk, D). Sq % block_q == 0 etc."""
    B, H, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert H % Hkv == 0 and Sq % block_q == 0 and Sk % block_k == 0
    G = H // Hkv
    n_k_blocks = Sk // block_k
    grid = (B * H, Sq // block_q, n_k_blocks)
    scale = 1.0 / np.sqrt(D)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, n_k_blocks=n_k_blocks)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D),
                         lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, iq, ik, g=G: (bh // g, ik, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, iq, ik, g=G: (bh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D),
                               lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q.reshape(B * H, Sq, D),
      k.reshape(B * Hkv, Sk, D),
      v.reshape(B * Hkv, Sk, D)).reshape(B, H, Sq, D)
