"""Jit'd wrapper for the fused DeltaGrad update (padding + scalar packing)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fused_update.kernel import deltagrad_update


@partial(jax.jit, static_argnames=("interpret", "tile"))
def update(w, g_cached, bv, g_changed, lr, n, dB, sign, *,
           interpret: bool = False, tile: int = 512):
    """Flat-vector fused update; arbitrary p (pads to tile)."""
    p = w.shape[-1]
    pp = -(-p // tile) * tile

    def prep(x):
        return jnp.pad(x.reshape(1, -1), ((0, 0), (0, pp - p)))

    scalars = jnp.stack([jnp.float32(lr), jnp.float32(n), jnp.float32(dB),
                         jnp.float32(sign)]).reshape(1, 4)
    out = deltagrad_update(prep(w), prep(g_cached), prep(bv), prep(g_changed),
                           scalars, interpret=interpret, tile=tile)
    return out[0, :p]
