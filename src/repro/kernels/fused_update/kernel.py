"""Fused DeltaGrad leave-r-out update — Pallas TPU.

The approx-step update touches four parameter-sized arrays
(w, cached gradient, Bv correction, changed-sample gradient).  Unfused, XLA
may schedule this as several elementwise passes (plus fp32 upcasts); fused
it is one HBM read per operand and one write — strictly memory-bound, so
the kernel's value is the guaranteed single pass + fp32 math at bf16
storage.  Scalars travel in SMEM via a (1, 4) operand.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 4096


def _upd_kernel(w_ref, g_ref, bv_ref, gc_ref, s_ref, out_ref):
    s = s_ref[...]  # (1, 4): lr, n, dB, sign
    lr, n, dB, sign = s[0, 0], s[0, 1], s[0, 2], s[0, 3]
    denom = jnp.maximum(n - sign * dB, 1.0)
    num = n * (g_ref[...].astype(jnp.float32) + bv_ref[...].astype(jnp.float32))
    num = num - sign * dB * gc_ref[...].astype(jnp.float32)
    out_ref[...] = (w_ref[...].astype(jnp.float32)
                    - lr * num / denom).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "tile"))
def deltagrad_update(w, g_cached, bv, g_changed, scalars, *,
                     interpret: bool = False, tile: int = TILE):
    """All tensors (1, p) with p % tile == 0; scalars (1, 4)."""
    _, p = w.shape
    grid = (p // tile,)
    spec = pl.BlockSpec((1, tile), lambda i: (0, i))
    return pl.pallas_call(
        _upd_kernel,
        grid=grid,
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, 4), lambda i: (0, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((1, p), w.dtype),
        interpret=interpret,
    )(w, g_cached, bv, g_changed, scalars)
