"""Oracle for the fused leave-r-out DeltaGrad parameter update."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def deltagrad_update_ref(w, g_cached, bv, g_changed, lr, n, dB, sign):
    """w - lr/(n - sign*dB) * ( n*(g_cached + bv) - sign*dB*g_changed ).

    Paper eq. (2)/(S7): sign=+1 deletion, sign=-1 addition.  All array args
    share w's shape; lr/n/dB/sign are scalars.
    """
    f32 = jnp.float32
    denom = jnp.maximum(n - sign * dB, 1.0)
    num = n * (g_cached.astype(f32) + bv.astype(f32)) \
        - sign * dB * g_changed.astype(f32)
    return (w.astype(f32) - lr * num / denom).astype(w.dtype)
