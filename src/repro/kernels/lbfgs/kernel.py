"""Pallas TPU kernels for the DeltaGrad L-BFGS hot path.

The paper's own Discussion (§4.2) flags the L-BFGS correction as the
GPU-underutilizing part: a chain of (m x p) GEMV-like contractions plus a
rank-2m AXPY, each re-streaming the history from HBM.  On TPU we fuse:

  * `multidot`     — ONE pass over (dW, dG, v) emitting ALL reduction terms
                     (dW dW^T, dW dG^T, dW v, dG v).  Naively these are
                     2m^2 + 2m separate dot products = 2m+1 HBM reads of the
                     (m, p) history; fused it is exactly one read.
  * `rank_update`  — ONE pass computing sigma*v - a dW - b dG (the Bv
                     correction), again one read instead of 2m+1.

Both stream p in lane-aligned VMEM tiles (TILE_P multiple of 128; the m axis
is padded to 8 sublanes by the caller via ops.py) and accumulate partial
results into revisited output blocks (TPU grid is sequential over the p
tiles, so the accumulation pattern is the standard Pallas reduction idiom).
The O(m^3) compact solve stays in XLA (m <= 8).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


TILE_P = 2048  # f32 lanes: 8 sublanes x 128 lanes x 2 -> 8KB per (8, 2048) tile


def _multidot_kernel(dw_ref, dg_ref, v_ref, sw_ref, sy_ref, wv_ref, gv_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        sw_ref[...] = jnp.zeros_like(sw_ref)
        sy_ref[...] = jnp.zeros_like(sy_ref)
        wv_ref[...] = jnp.zeros_like(wv_ref)
        gv_ref[...] = jnp.zeros_like(gv_ref)

    dw = dw_ref[...].astype(jnp.float32)  # (m, TILE_P)
    dg = dg_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)  # (1, TILE_P)
    sw_ref[...] += jax.lax.dot_general(
        dw, dw, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    sy_ref[...] += jax.lax.dot_general(
        dw, dg, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    wv_ref[...] += jax.lax.dot_general(
        dw, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    gv_ref[...] += jax.lax.dot_general(
        dg, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret", "tile_p"))
def multidot(dW: jax.Array, dG: jax.Array, v: jax.Array, *,
             interpret: bool = False, tile_p: int = TILE_P):
    """dW, dG: (m, p) with p % tile_p == 0 and m % 8 == 0; v: (1, p)."""
    m, p = dW.shape
    grid = (p // tile_p,)
    out_shapes = (
        jax.ShapeDtypeStruct((m, m), jnp.float32),  # sw
        jax.ShapeDtypeStruct((m, m), jnp.float32),  # sy
        jax.ShapeDtypeStruct((m, 1), jnp.float32),  # wv
        jax.ShapeDtypeStruct((m, 1), jnp.float32),  # gv
    )
    full = lambda i: (0, 0)  # noqa: E731 — revisit the same output block
    return pl.pallas_call(
        _multidot_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, tile_p), lambda i: (0, i)),
            pl.BlockSpec((m, tile_p), lambda i: (0, i)),
            pl.BlockSpec((1, tile_p), lambda i: (0, i)),
        ],
        out_specs=(
            pl.BlockSpec((m, m), full),
            pl.BlockSpec((m, m), full),
            pl.BlockSpec((m, 1), full),
            pl.BlockSpec((m, 1), full),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(dW, dG, v)


def _rank_update_kernel(dw_ref, dg_ref, v_ref, coef_ref, out_ref):
    dw = dw_ref[...].astype(jnp.float32)  # (m, TILE_P)
    dg = dg_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)  # (1, TILE_P)
    coefs = coef_ref[...]  # (3, m): rows = a, b, (sigma, pad...)
    a = coefs[0:1, :]  # (1, m)
    b = coefs[1:2, :]
    sigma = coefs[2, 0]
    out = sigma * v
    out -= jax.lax.dot_general(a, dw, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    out -= jax.lax.dot_general(b, dg, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "tile_p"))
def rank_update(dW: jax.Array, dG: jax.Array, v: jax.Array, coefs: jax.Array,
                *, interpret: bool = False, tile_p: int = TILE_P):
    """out (1, p) = sigma*v - a dW - b dG; coefs: (3, m) packed [a; b; sigma]."""
    m, p = dW.shape
    grid = (p // tile_p,)
    return pl.pallas_call(
        _rank_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, tile_p), lambda i: (0, i)),
            pl.BlockSpec((m, tile_p), lambda i: (0, i)),
            pl.BlockSpec((1, tile_p), lambda i: (0, i)),
            pl.BlockSpec((3, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_p), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, p), v.dtype),
        interpret=interpret,
    )(dW, dG, v, coefs)
