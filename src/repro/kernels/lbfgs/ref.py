"""Pure-jnp oracles for the fused L-BFGS kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def multidot_ref(dW: jax.Array, dG: jax.Array, v: jax.Array):
    """All Gram/dot terms of the compact L-BFGS system in one logical pass.

    dW, dG: (m, p); v: (p,).
    Returns sw (m,m) = dW dW^T, sy (m,m) = dW dG^T, wv (m,) = dW v,
    gv (m,) = dG v.
    """
    f32 = jnp.float32
    dWf, dGf, vf = dW.astype(f32), dG.astype(f32), v.astype(f32)
    return dWf @ dWf.T, dWf @ dGf.T, dWf @ vf, dGf @ vf


def rank_update_ref(dW: jax.Array, dG: jax.Array, v: jax.Array,
                    a: jax.Array, b: jax.Array, sigma: jax.Array) -> jax.Array:
    """Bv = sigma * v - a @ dW - b @ dG  (rank-2m correction)."""
    f32 = jnp.float32
    out = (sigma.astype(f32) * v.astype(f32)
           - a.astype(f32) @ dW.astype(f32)
           - b.astype(f32) @ dG.astype(f32))
    return out.astype(v.dtype)
