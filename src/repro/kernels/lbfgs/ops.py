"""Jit'd public wrappers: padding + compact solve around the Pallas kernels.

`lbfgs_hvp_fused(dW, dG, v)` == `repro.core.lbfgs.lbfgs_hvp_stacked` but with
the two parameter-dimension passes fused (one HBM read each).  On CPU (tests)
pass interpret=True; on TPU the kernels compile natively.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lbfgs import compact_coeffs
from repro.kernels.lbfgs import kernel as K


def _pad_m(x: jax.Array, m_pad: int) -> jax.Array:
    m = x.shape[0]
    if m == m_pad:
        return x
    return jnp.pad(x, ((0, m_pad - m), (0, 0)))


def _pad_p(x: jax.Array, p_pad: int) -> jax.Array:
    p = x.shape[-1]
    if p == p_pad:
        return x
    return jnp.pad(x, ((0, 0), (0, p_pad - p)))


@partial(jax.jit, static_argnames=("interpret", "tile_p"))
def multidot(dW, dG, v, *, interpret: bool = False, tile_p: int = 512):
    """Gram terms with arbitrary (m, p); pads to kernel alignment."""
    m, p = dW.shape
    m_pad = max(8, int(np.ceil(m / 8)) * 8)
    p_pad = int(np.ceil(p / tile_p)) * tile_p
    dWp = _pad_p(_pad_m(dW, m_pad), p_pad)
    dGp = _pad_p(_pad_m(dG, m_pad), p_pad)
    vp = _pad_p(v.reshape(1, -1), p_pad)
    sw, sy, wv, gv = K.multidot(dWp, dGp, vp, interpret=interpret,
                                tile_p=tile_p)
    return sw[:m, :m], sy[:m, :m], wv[:m, 0], gv[:m, 0]


@partial(jax.jit, static_argnames=("interpret", "tile_p"))
def rank_update(dW, dG, v, a, b, sigma, *, interpret: bool = False,
                tile_p: int = 512):
    m, p = dW.shape
    m_pad = max(8, int(np.ceil(m / 8)) * 8)
    p_pad = int(np.ceil(p / tile_p)) * tile_p
    dWp = _pad_p(_pad_m(dW, m_pad), p_pad)
    dGp = _pad_p(_pad_m(dG, m_pad), p_pad)
    vp = _pad_p(v.reshape(1, -1), p_pad)
    coefs = jnp.zeros((3, m_pad), jnp.float32)
    coefs = coefs.at[0, :m].set(a.astype(jnp.float32))
    coefs = coefs.at[1, :m].set(b.astype(jnp.float32))
    coefs = coefs.at[2, 0].set(sigma.astype(jnp.float32))
    out = K.rank_update(dWp, dGp, vp, coefs, interpret=interpret,
                        tile_p=tile_p)
    return out[0, :p]


@partial(jax.jit, static_argnames=("interpret", "tile_p"))
def lbfgs_hvp_fused(dW, dG, v, *, interpret: bool = False, tile_p: int = 512):
    """B v in two fused HBM passes + an O(m^3) XLA solve."""
    sw, sy, wv, gv = multidot(dW, dG, v, interpret=interpret, tile_p=tile_p)
    c = compact_coeffs(sw, sy, wv, gv)
    return rank_update(dW, dG, v, c.a, c.b, c.sigma, interpret=interpret,
                       tile_p=tile_p)
