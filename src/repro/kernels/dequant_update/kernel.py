"""Fused dequantize + DeltaGrad update — Pallas TPU.

The streamed history store can ship ENCODED windows to device (int8 q with
a per-step scale, or a bf16 residual, optionally against a per-key-window
keyframe base — see `core.history.DeltaCodec`).  These kernels read the
encoded leaf directly and dequantize in registers fused with the hot-loop
elementwise work, so the scan consumes compressed bytes without ever
materializing an f32 copy of a window:

  * ``dequant_deltagrad_update`` — the leave-r-out approx step where the
    cached gradient operand stays encoded,
  * ``dequant_sub`` — ``v = w - w_t`` (the L-BFGS direction input) where
    the cached parameter operand stays encoded.

Decode math is exactly ``q.astype(f32) * scale (+ base)`` — the same
expression and association the jnp decode paths in `core.store` use — so
kernel-mode and fetch-mode replays agree bitwise.  Scalars travel in a
(1, N) operand like `fused_update`; the keyframe base, when present, is a
fifth full-width operand streamed alongside w.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 4096


def _upd_math(w, g, bv, gc, lr, n, dB, sign):
    denom = jnp.maximum(n - sign * dB, 1.0)
    num = n * (g + bv.astype(jnp.float32)) - sign * dB * gc.astype(jnp.float32)
    return w.astype(jnp.float32) - lr * num / denom


def _dq_upd_kernel(w_ref, q_ref, bv_ref, gc_ref, s_ref, out_ref):
    s = s_ref[...]  # (1, 5): lr, n, dB, sign, scale
    g = q_ref[...].astype(jnp.float32) * s[0, 4]
    out = _upd_math(w_ref[...], g, bv_ref[...], gc_ref[...],
                    s[0, 0], s[0, 1], s[0, 2], s[0, 3])
    out_ref[...] = out.astype(out_ref.dtype)


def _dq_upd_base_kernel(w_ref, q_ref, bv_ref, gc_ref, b_ref, s_ref, out_ref):
    s = s_ref[...]
    g = q_ref[...].astype(jnp.float32) * s[0, 4] \
        + b_ref[...].astype(jnp.float32)
    out = _upd_math(w_ref[...], g, bv_ref[...], gc_ref[...],
                    s[0, 0], s[0, 1], s[0, 2], s[0, 3])
    out_ref[...] = out.astype(out_ref.dtype)


def _dq_sub_kernel(w_ref, q_ref, s_ref, out_ref):
    x = q_ref[...].astype(jnp.float32) * s_ref[0, 0]
    out_ref[...] = (w_ref[...].astype(jnp.float32) - x).astype(out_ref.dtype)


def _dq_sub_base_kernel(w_ref, q_ref, b_ref, s_ref, out_ref):
    x = q_ref[...].astype(jnp.float32) * s_ref[0, 0] \
        + b_ref[...].astype(jnp.float32)
    out_ref[...] = (w_ref[...].astype(jnp.float32) - x).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "tile"))
def dequant_deltagrad_update(w, q, bv, g_changed, scalars, base=None, *,
                             interpret: bool = False, tile: int = TILE):
    """All tensors (1, p) with p % tile == 0; scalars (1, 5)."""
    _, p = w.shape
    grid = (p // tile,)
    spec = pl.BlockSpec((1, tile), lambda i: (0, i))
    sspec = pl.BlockSpec((1, 5), lambda i: (0, 0))
    out_shape = jax.ShapeDtypeStruct((1, p), w.dtype)
    if base is None:
        return pl.pallas_call(
            _dq_upd_kernel, grid=grid,
            in_specs=[spec, spec, spec, spec, sspec],
            out_specs=spec, out_shape=out_shape, interpret=interpret,
        )(w, q, bv, g_changed, scalars)
    return pl.pallas_call(
        _dq_upd_base_kernel, grid=grid,
        in_specs=[spec, spec, spec, spec, spec, sspec],
        out_specs=spec, out_shape=out_shape, interpret=interpret,
    )(w, q, bv, g_changed, base, scalars)


@functools.partial(jax.jit, static_argnames=("interpret", "tile"))
def dequant_sub(w, q, scalars, base=None, *,
                interpret: bool = False, tile: int = TILE):
    """(1, p) tensors, p % tile == 0; scalars (1, 1): the dequant scale."""
    _, p = w.shape
    grid = (p // tile,)
    spec = pl.BlockSpec((1, tile), lambda i: (0, i))
    sspec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    out_shape = jax.ShapeDtypeStruct((1, p), w.dtype)
    if base is None:
        return pl.pallas_call(
            _dq_sub_kernel, grid=grid,
            in_specs=[spec, spec, sspec],
            out_specs=spec, out_shape=out_shape, interpret=interpret,
        )(w, q, scalars)
    return pl.pallas_call(
        _dq_sub_base_kernel, grid=grid,
        in_specs=[spec, spec, spec, sspec],
        out_specs=spec, out_shape=out_shape, interpret=interpret,
    )(w, q, base, scalars)
