"""Pure-jnp oracles for the fused dequant + DeltaGrad update kernels."""

from __future__ import annotations

import jax.numpy as jnp


def dequant_ref(q, scale, base=None):
    """``q * scale (+ base)`` in f32 — THE decode expression.

    Every read path (per-entry decode, stacked-window decode, in-scan
    slice decode, Pallas kernel) uses this exact association, which is
    what makes kernel-mode and fetch-mode replays bitwise identical."""
    x = q.astype(jnp.float32) * jnp.float32(scale)
    if base is not None:
        x = x + base.astype(jnp.float32)
    return x


def dequant_update_ref(w, q, bv, g_changed, lr, n, dB, sign, scale,
                       base=None):
    """`fused_update.ref.deltagrad_update_ref` with the cached-gradient
    operand supplied encoded (dequantized on the fly)."""
    f32 = jnp.float32
    g = dequant_ref(q, scale, base)
    denom = jnp.maximum(n - sign * dB, 1.0)
    num = n * (g + bv.astype(f32)) - sign * dB * g_changed.astype(f32)
    return (w.astype(f32) - lr * num / denom).astype(w.dtype)


def dequant_sub_ref(w, q, scale, base=None):
    """``v = w - dequant(w_t)`` — the L-BFGS direction input."""
    return (w.astype(jnp.float32) - dequant_ref(q, scale, base)
            ).astype(w.dtype)
