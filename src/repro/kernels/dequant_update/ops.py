"""Jit'd wrappers for the fused dequant kernels (padding + scalar packing)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.dequant_update import kernel


def _prep(x, pp, p):
    return jnp.pad(x.reshape(1, -1), ((0, 0), (0, pp - p)))


@partial(jax.jit, static_argnames=("interpret", "tile"))
def dequant_update(w, q, bv, g_changed, lr, n, dB, sign, scale, base=None, *,
                   interpret: bool = False, tile: int = 512):
    """Flat-vector fused dequant + update; arbitrary p (pads to tile)."""
    p = w.shape[-1]
    pp = -(-p // tile) * tile
    scalars = jnp.stack([jnp.float32(lr), jnp.float32(n), jnp.float32(dB),
                         jnp.float32(sign), jnp.float32(scale)]).reshape(1, 5)
    out = kernel.dequant_deltagrad_update(
        _prep(w, pp, p), _prep(q, pp, p), _prep(bv, pp, p),
        _prep(g_changed, pp, p), scalars,
        None if base is None else _prep(base, pp, p),
        interpret=interpret, tile=tile)
    return out[0, :p]


@partial(jax.jit, static_argnames=("interpret", "tile"))
def dequant_sub(w, q, scale, base=None, *,
                interpret: bool = False, tile: int = 512):
    """Flat-vector ``w - dequant(q)``; arbitrary p (pads to tile)."""
    p = w.shape[-1]
    pp = -(-p // tile) * tile
    scalars = jnp.float32(scale).reshape(1, 1)
    out = kernel.dequant_sub(
        _prep(w, pp, p), _prep(q, pp, p), scalars,
        None if base is None else _prep(base, pp, p),
        interpret=interpret, tile=tile)
    return out[0, :p]
