# Pallas TPU kernels for the compute hot-spots (each with ops.py jit wrapper
# and ref.py pure-jnp oracle; validated with interpret=True on CPU):
#   lbfgs/           fused multidot + rank-2m update (the paper's L-BFGS
#                    correction path — single-pass HBM streaming)
#   flash_attention/ causal GQA flash attention (train/prefill hot-spot)
#   fused_update/    leave-r-out DeltaGrad parameter update (elementwise)
#   dequant_update/  fused dequant + update / dequant + subtract over the
#                    ENCODED streamed history (int8/bf16, keyframe deltas)
