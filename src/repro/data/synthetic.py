"""Synthetic data generators (paper-scale stand-ins for MNIST/covtype/RCV1/HIGGS
and LM token streams).  All deterministic in the seed."""

from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.data.dataset import Dataset


def binary_classification(
    n: int, d: int, seed: int = 0, margin: float = 1.0, noise: float = 0.25
) -> Dataset:
    """Linearly-separable-ish binary labels in {0, 1} (RCV1/HIGGS stand-in)."""
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=(d,)) / np.sqrt(d)
    x = rng.normal(size=(n, d)).astype(np.float32)
    logits = margin * (x @ w_true) + noise * rng.normal(size=(n,))
    y = (logits > 0).astype(np.int32)
    return Dataset({"x": x, "y": y})


def multiclass_classification(
    n: int, d: int, num_classes: int, seed: int = 0, noise: float = 0.5
) -> Dataset:
    """Gaussian class blobs (MNIST/covtype stand-in)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(num_classes, d)).astype(np.float32)
    y = rng.integers(0, num_classes, size=n).astype(np.int32)
    x = centers[y] + noise * rng.normal(size=(n, d)).astype(np.float32)
    return Dataset({"x": x.astype(np.float32), "y": y})


def token_stream(n_docs: int, seq_len: int, vocab: int, seed: int = 0) -> Dataset:
    """Synthetic LM corpus: each row is one document of `seq_len` token ids.

    Tokens follow a per-document bigram chain so the LM objective has
    learnable structure (deleting documents measurably moves the model).
    """
    rng = np.random.default_rng(seed)
    tokens = np.empty((n_docs, seq_len), dtype=np.int32)
    for i in range(n_docs):
        shift = rng.integers(1, vocab)
        t = rng.integers(0, vocab)
        for j in range(seq_len):
            tokens[i, j] = t
            t = (t + shift + rng.integers(0, 3)) % vocab
    return Dataset({"tokens": tokens})


def train_test_split(ds: Dataset, test_frac: float, seed: int = 0) -> Tuple[Dataset, Dataset]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(ds.n)
    n_test = int(ds.n * test_frac)
    test_idx, train_idx = perm[:n_test], perm[n_test:]
    return (
        Dataset({k: v[train_idx] for k, v in ds.columns.items()}),
        Dataset({k: v[test_idx] for k, v in ds.columns.items()}),
    )
