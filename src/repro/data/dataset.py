"""In-memory dataset with deletion/addition bookkeeping.

A Dataset is a dict of equal-leading-dimension arrays ("columns", e.g.
``{"x": (n, d), "y": (n,)}``).  Deletion never re-indexes: removed rows keep
their original index and are masked out at batch-assembly time, which is what
makes DeltaGrad's schedule replay well-defined.
"""

from __future__ import annotations

from typing import Dict, Iterable, Optional, Sequence

import numpy as np


class Dataset:
    def __init__(self, columns: Dict[str, np.ndarray]):
        assert columns, "empty dataset"
        sizes = {k: len(v) for k, v in columns.items()}
        assert len(set(sizes.values())) == 1, f"ragged columns: {sizes}"
        self.columns = {k: np.asarray(v) for k, v in columns.items()}
        self.n = next(iter(sizes.values()))
        self.removed = np.zeros(self.n, dtype=bool)

    # -- core access ---------------------------------------------------------

    def take(self, idx: np.ndarray) -> Dict[str, np.ndarray]:
        return {k: v[idx] for k, v in self.columns.items()}

    def device_columns(self, capacity: Optional[int] = None):
        """Columns uploaded to device once (cached; refreshed after append).

        The replay engine gathers minibatches with on-device `jnp.take`
        inside `lax.scan`, so the host never materializes per-step batches.

        `capacity` (>= n) pads the leading dimension with zero rows so the
        uploaded shape — and with it every compiled program keyed on it —
        stays put while the dataset grows underneath (the online engine
        passes a pow2-bucketed capacity, so an addition stream re-traces
        O(log #adds) times instead of once per append).  Padding rows are
        never gathered: schedules only index rows < n."""
        import jax.numpy as jnp

        cap = self.n if capacity is None else int(capacity)
        assert cap >= self.n, (cap, self.n)
        if (getattr(self, "_device_cols", None) is None
                or self._device_n != self.n
                or getattr(self, "_device_cap", None) != cap):

            def upload(v):
                if cap > len(v):
                    pad = np.zeros((cap - len(v),) + v.shape[1:],
                                   dtype=v.dtype)
                    v = np.concatenate([v, pad])
                return jnp.asarray(v)

            self._device_cols = {k: upload(v) for k, v in self.columns.items()}
            self._device_n = self.n
            self._device_cap = cap
        return self._device_cols

    def __len__(self) -> int:
        return self.n

    @property
    def n_remaining(self) -> int:
        return int(self.n - self.removed.sum())

    @property
    def remaining_indices(self) -> np.ndarray:
        return np.nonzero(~self.removed)[0]

    @property
    def removed_indices(self) -> np.ndarray:
        return np.nonzero(self.removed)[0]

    # -- mutation ------------------------------------------------------------

    def delete(self, idx: Iterable[int]) -> np.ndarray:
        idx = np.asarray(list(idx), dtype=np.int64)
        already = self.removed[idx]
        if already.any():
            raise ValueError(f"rows already deleted: {idx[already]}")
        self.removed[idx] = True
        return idx

    def undelete(self, idx: Iterable[int]) -> np.ndarray:
        idx = np.asarray(list(idx), dtype=np.int64)
        self.removed[idx] = False
        return idx

    def append(self, rows: Dict[str, np.ndarray]) -> np.ndarray:
        """Physically append new rows; returns their indices."""
        m = len(next(iter(rows.values())))
        for k in self.columns:
            self.columns[k] = np.concatenate([self.columns[k], np.asarray(rows[k])])
        self.removed = np.concatenate([self.removed, np.zeros(m, dtype=bool)])
        new_idx = np.arange(self.n, self.n + m, dtype=np.int64)
        self.n += m
        return new_idx

    # -- batch assembly for the DeltaGrad engine ------------------------------

    def padded_batch(self, idx: np.ndarray, pad_to: int):
        """(columns, weights) with rows gathered by `idx`, padded to `pad_to`.

        Padding repeats row 0 with weight 0 so shapes are static under jit.
        Weights are 1.0 for live (non-removed... caller decides) rows.
        """
        k = len(idx)
        assert k <= pad_to, (k, pad_to)
        pad = np.zeros(pad_to - k, dtype=np.int64)
        full_idx = np.concatenate([idx, pad])
        weights = np.concatenate(
            [np.ones(k, dtype=np.float32), np.zeros(pad_to - k, dtype=np.float32)]
        )
        return self.take(full_idx), weights

    def split_batch(self, idx: np.ndarray, removed_set: Optional[np.ndarray] = None):
        """Split a replayed batch into (kept_idx, removed_idx) against the
        deletion mask (or an explicit removed index set)."""
        if removed_set is None:
            mask = self.removed[idx]
        else:
            mask = np.isin(idx, removed_set)
        return idx[~mask], idx[mask]


def subset(ds: Dataset, idx: Sequence[int]) -> Dataset:
    out = Dataset({k: v[np.asarray(idx)] for k, v in ds.columns.items()})
    return out
