from repro.data.dataset import Dataset  # noqa: F401
from repro.data.sampler import batch_indices, addition_mask  # noqa: F401
