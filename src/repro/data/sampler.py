"""Deterministic, replayable minibatch schedule.

DeltaGrad's SGD analysis (paper §A.1.2) *assumes the retraining run sees the
same minibatch sequence as the original run*: "We assume that the minibatch
randomness of w^{U,S} and w^{I,S} is the same as w^S."  We therefore make the
schedule a pure function of ``(seed, step)`` — independent of process state,
host count, or restarts — so replay holds across checkpoint resumes and mesh
changes.  Indices always refer to the ORIGINAL dataset numbering; deletion is
applied by masking at use time, never by re-indexing.
"""

from __future__ import annotations

import numpy as np


def batch_indices(seed: int, step: int, n: int, batch_size: int) -> np.ndarray:
    """Minibatch for `step`: `batch_size` draws without replacement from [0, n).

    Pure function of (seed, step, n, batch_size). When batch_size >= n this
    is deterministic full-batch GD (identity order).
    """
    if batch_size >= n:
        return np.arange(n, dtype=np.int64)
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    return rng.choice(n, size=batch_size, replace=False).astype(np.int64)


def addition_mask(seed: int, step: int, n: int, batch_size: int, n_added: int) -> np.ndarray:
    """Which of the `n_added` new samples join the minibatch at `step`.

    Each added sample independently joins with probability batch_size/n —
    matching the inclusion probability of original samples, which is what the
    paper's addition experiments simulate.  Pure function of its arguments.
    """
    if batch_size >= n:
        return np.ones(n_added, dtype=bool)
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 0x5EED]))
    return rng.random(n_added) < (batch_size / float(n))
