"""Deterministic, replayable minibatch schedule.

DeltaGrad's SGD analysis (paper §A.1.2) *assumes the retraining run sees the
same minibatch sequence as the original run*: "We assume that the minibatch
randomness of w^{U,S} and w^{I,S} is the same as w^S."  We therefore make the
schedule a pure function of ``(seed, step)`` — independent of process state,
host count, or restarts — so replay holds across checkpoint resumes and mesh
changes.  Indices always refer to the ORIGINAL dataset numbering; deletion is
applied by masking at use time, never by re-indexing.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


def batch_indices(seed: int, step: int, n: int, batch_size: int) -> np.ndarray:
    """Minibatch for `step`: `batch_size` draws without replacement from [0, n).

    Pure function of (seed, step, n, batch_size). When batch_size >= n this
    is deterministic full-batch GD (identity order).
    """
    if batch_size >= n:
        return np.arange(n, dtype=np.int64)
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    return rng.choice(n, size=batch_size, replace=False).astype(np.int64)


def addition_mask(seed: int, step: int, n: int, batch_size: int, n_added: int) -> np.ndarray:
    """Which of the `n_added` new samples join the minibatch at `step`.

    Each added sample independently joins with probability batch_size/n —
    matching the inclusion probability of original samples, which is what the
    paper's addition experiments simulate.  Pure function of its arguments.
    """
    if batch_size >= n:
        return np.ones(n_added, dtype=bool)
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 0x5EED]))
    return rng.random(n_added) < (batch_size / float(n))


# --------------------------------------------------------------------------
# Vectorized schedule precomputation (the replay engine's input)
# --------------------------------------------------------------------------


def batch_indices_all(seed: int, steps: int, n: int, batch_size: int) -> np.ndarray:
    """The full (steps, B) minibatch schedule, row t == batch_indices(seed, t).

    One upfront pass replaces per-step host sampling on the replay hot path;
    each row still uses the per-step SeedSequence stream so the result is
    bit-identical to the incremental sampler.
    """
    B = min(batch_size, n)
    out = np.empty((steps, B), dtype=np.int64)
    for t in range(steps):
        out[t] = batch_indices(seed, t, n, batch_size)
    return out


def addition_mask_all(seed: int, steps: int, n: int, batch_size: int,
                      n_added: int) -> np.ndarray:
    """(steps, n_added) bool; row t == addition_mask(seed, t, ...).

    Column j is PREFIX-STABLE in n_added: the per-step SeedSequence stream is
    read sequentially, so sample j's joins are independent of how many samples
    were added after it.  The online engine relies on this to grow one wide
    (T, capacity) mask across an addition stream instead of resampling per
    request."""
    out = np.empty((steps, n_added), dtype=bool)
    for t in range(steps):
        out[t] = addition_mask(seed, t, n, batch_size, n_added)
    return out


@dataclass
class ReplaySchedule:
    """Device-ready replay plan for one retraining run (all arrays numpy;
    the engine uploads them once and never touches the host per step).

    Shapes: T = steps, B = effective batch size, R = changed-sample pad.

      idx          (T, B)  int64  replayed original minibatch indices
      kept_w       (T, B)  f32    1.0 where the row survives the edit
                                  (delete: not in the removed set; add: all 1)
      changed_idx  (T, R)  int64  changed rows present in batch t, padded
      changed_w    (T, R)  f32    validity mask for changed_idx
      dB           (T,)    f32    |changed ∩ batch_t|   (add: #joining rows)
      kept         (T,)    f32    |surviving rows of batch_t|
      lr           (T,)    f32    learning rate at t
    """

    idx: np.ndarray
    kept_w: np.ndarray
    changed_idx: np.ndarray
    changed_w: np.ndarray
    dB: np.ndarray
    kept: np.ndarray
    lr: np.ndarray
    mode: str
    r_pad: int

    @property
    def steps(self) -> int:
        return self.idx.shape[0]

    @property
    def batch(self) -> int:
        return self.idx.shape[1]


def build_schedule(
    seed: int,
    steps: int,
    n: int,
    batch_size: int,
    changed_idx: np.ndarray,
    mode: str,
    r_pad: int,
    lr_at,
    idx_all: Optional[np.ndarray] = None,
    live_mask: Optional[np.ndarray] = None,
) -> ReplaySchedule:
    """Precompute every per-step quantity DeltaGrad replay needs.

    `changed_idx` are removed rows (delete) or appended rows (add); overlap
    masks come from one vectorized `np.isin` over the (T, B) index matrix
    instead of per-step set logic.  `live_mask` (length >= n bool, True =
    still present) masks rows deleted by EARLIER online requests out of the
    replayed batches (Algorithm 3's n-k bookkeeping); `idx_all` lets callers
    reuse an already-sampled schedule across requests.
    """
    assert mode in ("delete", "add")
    changed_idx = np.asarray(changed_idx, dtype=np.int64)
    idx = batch_indices_all(seed, steps, n, batch_size) if idx_all is None \
        else idx_all
    T, B = idx.shape

    if live_mask is not None:
        live = live_mask[idx]  # (T, B) rows surviving previous requests
    else:
        live = np.ones((T, B), dtype=bool)

    if mode == "delete":
        overlap = np.isin(idx, changed_idx) & live  # (T, B)
        kept_mask = live & ~overlap
        # changed rows, padded to R, preserving within-batch order
        changed_rows = np.zeros((T, r_pad), dtype=np.int64)
        changed_w = np.zeros((T, r_pad), dtype=np.float32)
        for t in np.nonzero(overlap.any(axis=1))[0]:
            rows = idx[t][overlap[t]][:r_pad]
            changed_rows[t, : len(rows)] = rows
            changed_w[t, : len(rows)] = 1.0
        dB = overlap.sum(axis=1).astype(np.float32)
    else:
        joins = addition_mask_all(seed, steps, n, batch_size, len(changed_idx))
        kept_mask = live
        changed_rows = np.zeros((T, r_pad), dtype=np.int64)
        changed_w = np.zeros((T, r_pad), dtype=np.float32)
        for t in np.nonzero(joins.any(axis=1))[0]:
            rows = changed_idx[joins[t]][:r_pad]
            changed_rows[t, : len(rows)] = rows
            changed_w[t, : len(rows)] = 1.0
        dB = joins.sum(axis=1).astype(np.float32)

    assert dB.max(initial=0.0) <= r_pad, (
        f"removal_pad={r_pad} smaller than max per-batch overlap {dB.max()}")
    lr = np.asarray([lr_at(t) for t in range(T)], dtype=np.float32)
    return ReplaySchedule(
        idx=idx,
        kept_w=kept_mask.astype(np.float32),
        changed_idx=changed_rows,
        changed_w=changed_w,
        dB=dB,
        kept=kept_mask.sum(axis=1).astype(np.float32),
        lr=lr,
        mode=mode,
        r_pad=r_pad,
    )


def _pow2(x: int) -> int:
    return 1 << max(0, (x - 1)).bit_length()


def build_online_schedule(
    seed: int,
    steps: int,
    n: int,
    batch_size: int,
    req,
    op: str,
    lr_at,
    live: np.ndarray,
    added_ids: np.ndarray,
    joins: Optional[np.ndarray],
    add_pad: int,
    idx_all: Optional[np.ndarray] = None,
    r_pad: Optional[int] = None,
) -> ReplaySchedule:
    """Replay plan for ONE online request — a single row or a COALESCED
    GROUP of rows served as one replay (Algorithm 3, Appendix C.2; group
    deletion is the paper's Algorithm-1 index-set semantics applied to the
    current rewritten path).

    The replayed batch is extended with one column per row appended by
    earlier addition requests: columns ``[0, B)`` hold the original
    minibatch schedule, columns ``[B, B + add_pad)`` hold ``added_ids``
    (padding columns point at row 0 with weight 0).  ``kept_w`` marks
    POST-request membership — the request rows always ride the ``changed``
    block, so ``kept`` is the post-request effective batch size and the
    PRE-request size is ``kept + dB`` for deletions (resp. ``kept`` pre /
    ``kept + dB`` post for additions).

    Args:
      req:       row id of the request, or a sequence of row ids for a
                 coalesced group (original or previously-added rows for
                 delete; rows already appended to the dataset for add —
                 add groups take the next len(req) join-mask columns).
      op:        "delete" | "add".
      live:      bool per row id (original and added), False once deleted by
                 an earlier request — Algorithm 3's n-k bookkeeping.
      added_ids: (A,) rows appended by earlier ADD requests, arrival order
                 (join-mask column j belongs to added_ids[j]).
      joins:     (T, >= A [+K for op=="add"]) precomputed addition_mask_all
                 columns; None only when no adds are involved.
      add_pad:   padded width of the added-column block (>= A; pow2 so the
                 compiled segment shapes are stable across a stream).
      idx_all:   reusable (T, B) original schedule (request-invariant).
      r_pad:     padded width of the changed-row block (defaults to the next
                 pow2 of the group size, so burst sizes bucket into O(log)
                 distinct compiled shapes instead of one per size).
    """
    assert op in ("delete", "add")
    reqs = np.atleast_1d(np.asarray(req, dtype=np.int64))
    K = len(reqs)
    assert K >= 1 and len(set(reqs.tolist())) == K, (
        f"group request must name distinct rows, got {reqs}")
    if r_pad is None:
        r_pad = _pow2(K)
    added_ids = np.asarray(added_ids, dtype=np.int64)
    A = len(added_ids)
    assert add_pad >= A, (add_pad, A)
    idx = batch_indices_all(seed, steps, n, batch_size) if idx_all is None \
        else idx_all
    T, B = idx.shape

    kept_orig = live[idx].copy()  # (T, B) originals surviving earlier requests
    changed_rows = np.zeros((T, r_pad), dtype=np.int64)
    changed_w = np.zeros((T, r_pad), dtype=np.float32)
    drop_cols: set = set()
    if op == "delete":
        col_of = {int(r): j for j, r in enumerate(added_ids)}
        req_orig = np.asarray([r for r in reqs if int(r) not in col_of],
                              dtype=np.int64)
        # (r, per-step presence) for group rows that were added earlier —
        # their membership comes from their join columns, not the schedule
        pres_added = []
        for r in reqs:
            j = col_of.get(int(r))
            if j is not None:
                drop_cols.add(j)
                pres_added.append((int(r), joins[:, j] & bool(live[r])))
        hit = (np.isin(idx, req_orig) & kept_orig) if len(req_orig) \
            else np.zeros_like(kept_orig)
        kept_orig &= ~hit
        rows_any = hit.any(axis=1)
        for _, p in pres_added:
            rows_any |= p
        for t in np.nonzero(rows_any)[0]:
            rows = idx[t][hit[t]].tolist() \
                + [r for r, p in pres_added if p[t]]
            assert len(rows) <= r_pad, (
                f"r_pad={r_pad} smaller than per-batch overlap {len(rows)}")
            changed_rows[t, : len(rows)] = rows
            changed_w[t, : len(rows)] = 1.0
    else:
        assert joins is not None and joins.shape[1] >= A + K
        changed_rows[:, :K] = reqs  # constant: the new rows themselves
        changed_w[:, :K] = joins[:, A:A + K].astype(np.float32)
    dB = changed_w.sum(axis=1)

    if add_pad:
        add_cols = np.zeros((T, add_pad), dtype=np.float32)
        add_rows = np.zeros(add_pad, dtype=np.int64)
        add_rows[:A] = added_ids
        for j in range(A):
            if j in drop_cols or not live[added_ids[j]]:
                continue  # deleted rows (and the request rows) drop out
            add_cols[:, j] = joins[:, j]
        idx_ext = np.concatenate(
            [idx, np.broadcast_to(add_rows, (T, add_pad))], axis=1)
        kept_w = np.concatenate([kept_orig.astype(np.float32), add_cols],
                                axis=1)
    else:
        idx_ext = idx
        kept_w = kept_orig.astype(np.float32)

    lr = np.asarray([lr_at(t) for t in range(T)], dtype=np.float32)
    return ReplaySchedule(
        idx=idx_ext,
        kept_w=kept_w,
        changed_idx=changed_rows,
        changed_w=changed_w,
        dB=dB.astype(np.float32),
        kept=kept_w.sum(axis=1).astype(np.float32),
        lr=lr,
        mode=op,
        r_pad=r_pad,
    )
