"""Limited-memory BFGS quasi-Hessian, compact representation.

DeltaGrad (Algorithm 1, line "L-BFGS") needs the product ``B_t v`` of a
quasi-Hessian with ``v = w^I_t - w_t``, where ``B_t`` is the BFGS matrix
built from the last ``m`` parameter/gradient difference pairs

    dw_k = w^I_{j_k} - w_{j_k},     dg_k = grad(w^I_{j_k}) - grad(w_{j_k}).

We use the compact representation of Byrd, Nocedal & Schnabel (1994),
Theorem 2.3 (the paper's Algorithm 2): with ``S = [dw_0 .. dw_{m-1}]``,
``Y = [dg_0 .. dg_{m-1}]`` and ``B_0 = sigma I``,

    B v = sigma v - [sigma S, Y] M^{-1} [sigma S^T v; Y^T v],
    M   = [[sigma S^T S, L], [L^T, -D]],

where ``D = diag(S^T Y)`` and ``L`` is the strictly-lower part of ``S^T Y``.
Only m x m Gram matrices and two length-m dot vectors touch the full
parameter dimension, so the operator is O(mp) + O(m^3).

Two equivalent backends are provided:
  * stacked   — ``dW, dG: (m, p)`` matrices (kernel-friendly; the Pallas
                ``lbfgs_multidot`` / ``lbfgs_rank_update`` kernels accelerate
                exactly these contractions),
  * pytree    — lists of parameter pytrees (sharding-transparent; used by the
                distributed engine).

A dense recursive oracle (paper eq. (S11)/(S12)) is included for testing.
"""

from __future__ import annotations

from typing import List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_lincomb, tree_scale, tree_vdot


class CompactCoeffs(NamedTuple):
    """Coefficients of the rank-2m correction: Bv = sigma*v - dW^T a - dG^T b."""

    sigma: jax.Array  # scalar
    a: jax.Array  # (m,) coefficients on the dW rows (already include sigma)
    b: jax.Array  # (m,) coefficients on the dG rows


def compact_coeffs(
    sw: jax.Array, sy: jax.Array, wv: jax.Array, gv: jax.Array
) -> CompactCoeffs:
    """Solve the 2m x 2m compact system.

    Args:
      sw: (m, m) Gram matrix  S^T S  (sw[i, j] = <dw_i, dw_j>).
      sy: (m, m) cross matrix S^T Y  (sy[i, j] = <dw_i, dg_j>).
      wv: (m,)   S^T v.
      gv: (m,)   Y^T v.
    """
    m = sw.shape[0]
    diag_sy = jnp.diag(sy)
    # B_0 = sigma I with sigma from the most recent pair (paper Alg. 2 line 21).
    sigma = diag_sy[-1] / jnp.where(sw[-1, -1] == 0, 1.0, sw[-1, -1])
    ell = jnp.tril(sy, k=-1)  # L_ij = <dw_i, dg_j>, i > j
    dmat = jnp.diag(diag_sy)
    top = jnp.concatenate([sigma * sw, ell], axis=1)
    bot = jnp.concatenate([ell.T, -dmat], axis=1)
    mid = jnp.concatenate([top, bot], axis=0)  # (2m, 2m)
    rhs = jnp.concatenate([sigma * wv, gv])  # (2m,)
    q = jnp.linalg.solve(mid, rhs)
    return CompactCoeffs(sigma=sigma, a=sigma * q[:m], b=q[m:])


def valid_pair_mask(count: jax.Array, m: int) -> jax.Array:
    """(m,) bool mask for a newest-last ring holding ``min(count, m)`` pairs.

    The engine's device ring appends by shifting left, so with ``count``
    admitted pairs the valid slots are the trailing ``min(count, m)`` rows.
    """
    return jnp.arange(m) >= (m - jnp.minimum(count, m))


def ring_valid_mask(dWs) -> jax.Array:
    """(m,) bool — derive ring occupancy FROM the ring: slot i holds an
    admitted pair iff its dw row is nonzero anywhere.

    Sound because admission requires ``<dw, dw> > 0`` (a zero dw can never
    be admitted) and empty slots of the zeros-initialized shift-append ring
    are exact zeros.  Deriving the mask on device means no separate count
    state crosses program boundaries — the fused explicit step's program is
    untouched, which keeps full-ring replays bitwise identical to the
    unmasked path.  The per-leaf any() reduces trailing axes shard-locally
    (boolean OR — associativity-safe under any reduction order)."""
    nz = [jnp.any(w != 0, axis=tuple(range(1, w.ndim)))
          for w in jax.tree.leaves(dWs)]
    valid = nz[0]
    for x in nz[1:]:
        valid = jnp.logical_or(valid, x)
    return valid


def compact_coeffs_masked(
    sw: jax.Array, sy: jax.Array, wv: jax.Array, gv: jax.Array, valid: jax.Array
) -> CompactCoeffs:
    """``compact_coeffs`` over a partially-filled ring.

    Requires invalid ring slots to be EXACT zeros (the device ring
    guarantees this: slots start at zero and rejected pairs never write).
    Then every Gram entry touching an invalid slot is already 0.0, and the
    2m x 2m system block-decouples: placing a 1 on the diagonal of invalid
    rows makes those rows ``e_i`` with a zero rhs, so their coefficients
    solve to exactly 0 and the valid sub-block is untouched.  With all m
    slots valid the mask is all-False and ``jnp.where`` returns ``mid``
    verbatim — bitwise identical to the unmasked solve.

    ``count == 0`` degenerates gracefully: ``sigma = 0/1 = 0`` (zero ring
    slots) and ``q = 0``, so the resulting operator is ``B v = 0`` — the
    exact leave-one-out estimate when ``w^I = w`` (the only way the first
    explicit step's pair is rejected).
    """
    m = sw.shape[0]
    diag_sy = jnp.diag(sy)
    sigma = diag_sy[-1] / jnp.where(sw[-1, -1] == 0, 1.0, sw[-1, -1])
    ell = jnp.tril(sy, k=-1)
    dmat = jnp.diag(diag_sy)
    top = jnp.concatenate([sigma * sw, ell], axis=1)
    bot = jnp.concatenate([ell.T, -dmat], axis=1)
    mid = jnp.concatenate([top, bot], axis=0)  # (2m, 2m)
    valid2 = jnp.concatenate([valid, valid])
    invalid_diag = jnp.eye(2 * m, dtype=bool) & ~valid2[None, :]
    mid = jnp.where(invalid_diag, 1.0, mid)
    rhs = jnp.concatenate([sigma * wv, gv])  # (2m,)
    q = jnp.linalg.solve(mid, rhs)
    return CompactCoeffs(sigma=sigma, a=sigma * q[:m], b=q[m:])


# --------------------------------------------------------------------------
# Stacked (m, p) backend
# --------------------------------------------------------------------------


def gram_terms_stacked(dW: jax.Array, dG: jax.Array, v: jax.Array):
    """All reduction terms in one logical pass over the (m, p) history.

    Returns (sw, sy, wv, gv). This is the contraction the Pallas
    ``lbfgs_multidot`` kernel fuses into a single HBM read of dW, dG, v.
    """
    f32 = jnp.float32
    dWf, dGf, vf = dW.astype(f32), dG.astype(f32), v.astype(f32)
    sw = dWf @ dWf.T
    sy = dWf @ dGf.T
    wv = dWf @ vf
    gv = dGf @ vf
    return sw, sy, wv, gv


def lbfgs_hvp_stacked(dW: jax.Array, dG: jax.Array, v: jax.Array) -> jax.Array:
    """B v with history stacked as (m, p) rows (oldest first)."""
    sw, sy, wv, gv = gram_terms_stacked(dW, dG, v)
    c = compact_coeffs(sw, sy, wv, gv)
    return (c.sigma * v - c.a @ dW - c.b @ dG).astype(v.dtype)


# --------------------------------------------------------------------------
# Pytree backend (sharding-transparent)
# --------------------------------------------------------------------------


def gram_terms_pytree(dws: Sequence, dgs: Sequence, v):
    m = len(dws)
    sw = jnp.stack(
        [jnp.stack([tree_vdot(dws[i], dws[j]) for j in range(m)]) for i in range(m)]
    )
    sy = jnp.stack(
        [jnp.stack([tree_vdot(dws[i], dgs[j]) for j in range(m)]) for i in range(m)]
    )
    wv = jnp.stack([tree_vdot(dws[i], v) for i in range(m)])
    gv = jnp.stack([tree_vdot(dgs[i], v) for i in range(m)])
    return sw, sy, wv, gv


def lbfgs_hvp_pytree(dws: Sequence, dgs: Sequence, v):
    """B v where history entries and v are parameter pytrees."""
    sw, sy, wv, gv = gram_terms_pytree(dws, dgs, v)
    c = compact_coeffs(sw, sy, wv, gv)
    out = tree_scale(c.sigma, v)
    out = tree_lincomb(jnp.concatenate([jnp.ones((1,)), -c.a, -c.b]),
                       [out] + list(dws) + list(dgs))
    return out


# --------------------------------------------------------------------------
# Stacked-pytree backend: every leaf carries a leading history axis m.
# This is the jit-fused path the DeltaGrad engine uses (one XLA program for
# Gram terms + solve + rank-2m update).
# --------------------------------------------------------------------------


def _pair_gram(a, b):
    """(m, ...) x (m, ...) -> (m, m), contracting ALL trailing axes.

    Implemented with a multi-axis dot_general (NOT reshape(m, -1) @ ...):
    a reshape collapses sharded parameter dims into one unshardable axis and
    forces GSPMD to all-gather the whole history buffer — measured 33 GB of
    gathers per DeltaGrad step at 1.8B params (EXPERIMENTS.md §Perf,
    deltagrad-step iteration 1).  dot_general keeps each shard's partial
    product local and psums only the (m, m) scalars.
    """
    axes = tuple(range(1, a.ndim))
    return jax.lax.dot_general(
        a.astype(jnp.float32), b.astype(jnp.float32),
        ((axes, axes), ((), ())), preferred_element_type=jnp.float32)


def _vec_dot(a, x):
    """(m, ...) x (...) -> (m,), contracting all of x's axes shard-locally."""
    axes_a = tuple(range(1, a.ndim))
    axes_x = tuple(range(x.ndim))
    return jax.lax.dot_general(
        a.astype(jnp.float32), x.astype(jnp.float32),
        ((axes_a, axes_x), ((), ())), preferred_element_type=jnp.float32)


def gram_terms_stacked_pytree(dWs, dGs, v):
    """dWs/dGs: pytrees whose leaves are stacked (m, ...); v: plain pytree."""
    wl = jax.tree.leaves(dWs)
    gl = jax.tree.leaves(dGs)
    vl = jax.tree.leaves(v)
    sw = sum(_pair_gram(w, w) for w in wl)
    sy = sum(_pair_gram(w, g) for w, g in zip(wl, gl))
    wv = sum(_vec_dot(w, x) for w, x in zip(wl, vl))
    gv = sum(_vec_dot(g, x) for g, x in zip(gl, vl))
    return sw, sy, wv, gv


def lbfgs_hvp_stacked_pytree(dWs, dGs, v, masked: bool = False):
    """B v with history stacked along a leading axis of every leaf.

    With ``masked=True`` the ring may be PARTIALLY filled: empty slots must
    be exact zeros (the engine's zeros-initialized shift-append ring), the
    occupancy mask is derived from the ring via `ring_valid_mask`, and the
    masked solve matches the occupied-pair operator — bitwise identical to
    the unmasked solve once the ring is full."""
    sw, sy, wv, gv = gram_terms_stacked_pytree(dWs, dGs, v)
    if masked:
        c = compact_coeffs_masked(sw, sy, wv, gv, ring_valid_mask(dWs))
    else:
        c = compact_coeffs(sw, sy, wv, gv)

    def upd(x, w, g):
        shape = (-1,) + (1,) * (x.ndim)
        a = c.a.reshape(shape)
        b = c.b.reshape(shape)
        return (c.sigma * x - jnp.sum(a * w, axis=0) - jnp.sum(b * g, axis=0)).astype(
            x.dtype
        )

    return jax.tree.map(upd, v, dWs, dGs)


# --------------------------------------------------------------------------
# Dense recursive oracle (paper eq. (S11)-(S12)) — tests only
# --------------------------------------------------------------------------


def bfgs_matrix_recursive(
    dW: jax.Array, dG: jax.Array, sigma: Optional[jax.Array] = None
) -> jax.Array:
    """Explicitly build B by the recursive BFGS update (S11) from B0 = sigma I.

    O(m p^2) — for unit tests with small p only.
    """
    m, p = dW.shape
    if sigma is None:
        sigma = (dG[-1] @ dW[-1]) / (dW[-1] @ dW[-1])
    B = sigma * jnp.eye(p, dtype=jnp.float32)
    for k in range(m):
        s = dW[k].astype(jnp.float32)
        y = dG[k].astype(jnp.float32)
        Bs = B @ s
        B = B - jnp.outer(Bs, Bs) / (s @ Bs) + jnp.outer(y, y) / (y @ s)
    return B


# --------------------------------------------------------------------------
# History ring buffer with curvature admission (Algorithm 4 guard hook)
# --------------------------------------------------------------------------


@jax.jit
def _stack_pairs(dws, dgs):
    """Stack m (dw, dg) pytree pairs along a new leading axis in ONE
    dispatch (the un-jitted per-leaf jnp.stack calls showed up as ~half the
    host overhead of an online request)."""
    return (jax.tree.map(lambda *xs: jnp.stack(xs), *dws),
            jax.tree.map(lambda *xs: jnp.stack(xs), *dgs))


class LbfgsBuffer:
    """Fixed-capacity ring buffer of (dw, dg) pytree pairs.

    Admission implements the convexity check DeltaGrad uses for non-convex
    models (paper Appendix C.3): a pair enters the buffer only if
    ``<dg, dw> >= curvature_eps * <dw, dw>`` — for strongly convex objectives
    this always holds with ``curvature_eps <= mu``.
    """

    def __init__(self, capacity: int, curvature_eps: float = 0.0):
        assert capacity >= 1
        self.capacity = capacity
        self.curvature_eps = float(curvature_eps)
        self._dws: List = []
        self._dgs: List = []
        self._stacked_cache = None  # invalidated on add()
        self.rejected = 0
        self.admitted = 0

    def __len__(self) -> int:
        return len(self._dws)

    @property
    def dws(self) -> List:
        return list(self._dws)

    @property
    def dgs(self) -> List:
        return list(self._dgs)

    def add(self, dw, dg) -> bool:
        """Returns True if the pair was admitted."""
        curv = float(tree_vdot(dg, dw))
        ss = float(tree_vdot(dw, dw))
        return self.add_pair(dw, dg, curv, ss)

    def add_pair(self, dw, dg, curv: float, ss: float) -> bool:
        """`add` with the admission inner products precomputed — the engine's
        fused explicit step evaluates them on-device and syncs once."""
        if ss <= 0.0 or curv < self.curvature_eps * ss:
            self.rejected += 1
            return False
        self._dws.append(dw)
        self._dgs.append(dg)
        if len(self._dws) > self.capacity:
            self._dws.pop(0)
            self._dgs.pop(0)
        self._stacked_cache = None
        self.admitted += 1
        return True

    def hvp(self, v):
        """B v. Requires at least one admitted pair."""
        if not self._dws:
            raise ValueError("LbfgsBuffer.hvp called with no admitted pairs")
        return lbfgs_hvp_pytree(self._dws, self._dgs, v)

    def stacked(self):
        """(dWs, dGs) with every leaf stacked along a new leading axis.

        Cached between add() calls — approx steps between two explicit steps
        reuse the same stacked buffers without re-dispatching the stacks.
        """
        if not self._dws:
            raise ValueError("LbfgsBuffer.stacked called with no admitted pairs")
        if self._stacked_cache is None:
            self._stacked_cache = _stack_pairs(tuple(self._dws),
                                               tuple(self._dgs))
        return self._stacked_cache

    def clear(self) -> None:
        self._dws.clear()
        self._dgs.clear()
        self._stacked_cache = None
