"""Optimization-path cache — the information DeltaGrad records during training.

DeltaGrad needs, for every original training step ``t``:
  * the parameters ``w_t``,
  * the (mini-)batch mean gradient ``g_t = (1/|B_t|) sum_{i in B_t} grad F_i(w_t)``,
  * enough metadata to *replay the exact minibatch schedule* (seed, batch
    size, dataset size, learning-rate schedule).

Storage tiers (per-entry, selectable):
  * ``stacked`` — ONE device pytree per quantity with a leading time axis
    (``w[t] == Ws_leaf[t]``).  This is the replay engine's native format:
    approx segments run under ``jax.lax.scan`` and read entries with
    ``lax.dynamic_slice`` without any host round-trip (see core/engine.py),
  * ``device`` — per-entry JAX arrays (sharded exactly like the live
    parameters; right choice on a TPU mesh where each host holds 1/N of
    every entry),
  * ``host``   — entries are pulled to host numpy (paper's choice; frees HBM),
  * ``disk``   — chunked ``.npz`` spill with an in-memory LRU window (long
    training runs; participates in checkpoint/restart).

Any tier can produce the stacked view on demand via ``stacked_view()``
(cached; invalidated by ``append``/``overwrite``) and be bulk-rewritten from
it via ``replace_from_stacked`` — the online engine edits the stacked arrays
functionally during a request and flushes after each request.

Optional compression codecs trade cache size for a tiny, quantifiable
perturbation of the cached path (bf16: 2x; int8 + per-leaf scale: ~4x) —
DeltaGrad's correction is first-order in the cache error, and the
``bench_hyperparams`` benchmark measures the effect.

Choosing a tier — the HBM math
------------------------------

The cache stores TWO pytrees per step (w_t and g_t), so with ``P`` model
bytes (f32 params) and ``T`` recorded steps:

  =========  =======================  ==================================
  tier       device bytes             when to pick it
  =========  =======================  ==================================
  stacked    ``2*T*P``                default — replay runs fastest; fits
                                      whenever 2*T*P is small next to HBM
                                      (1k steps of a 10M-param model =
                                      80 GB… too big; of a 100k-param
                                      model = 800 MB… fine)
  stacked    ``2*T*P / mesh``         same, placed on a mesh via
  + mesh                              `core.store.PlacementPolicy`: each
                                      device keeps 1/mesh of every sharded
                                      leaf, gathered one step at a time
  device     ``2*T*P``                per-entry arrays; only when entries
                                      must keep a custom per-leaf sharding
  host       ``~2*L*P`` (window)      paper's choice — frees HBM; served
                                      to the compiled scan in ``L``-step
                                      double-buffered windows by
                                      `core.store.SegmentStreamer`
                                      (host RAM pays ``2*T*P / ratio``,
                                      codec ratio 1/2/4 for f32/bf16/int8)
  host       ``~2*L*P / mesh``        the COMPOSED tier
  + mesh     (shard window)           (`core.store.ShardedStreamer`) — the
                                      only fit when the path exceeds any
                                      single host's HBM *and* any single
                                      device: each mesh shard streams only
                                      its `stacked_spec_for_leaf` slice of
                                      every window, so per-DEVICE bytes
                                      are ~2 windows of the shard and
                                      per-HOST RAM is the encoded path
                                      (``2*T*P / ratio``) plus one window
                                      of staged slices; the shard_map
                                      scan all-gathers one step at a time
  disk       ``~2*L*P`` (window)      longest runs; host RAM ~0, entries
                                      spill to ``spill_dir`` .npz
                                      (``spill_dir="auto"`` → a fresh
                                      tempdir, removed with the process;
                                      ``spill_window=L`` batches one .npz
                                      per stream window so a window costs
                                      one IO burst instead of L);
                                      also composes with a mesh placement
                                      exactly like host + mesh
  host/disk  ``~2*L*P / ratio``       delta+int8 codec (``delta_int8``):
  + delta    (encoded window)         entry t is stored as an int8
                                      residual against an immutable
                                      per-key-window keyframe base, so
                                      the slowly-drifting path costs
                                      ~2.5 B/param/step instead of 8
                                      (f32) or ~2 (plain int8) — and the
                                      residuals quantize far better
                                      because DeltaGrad's own premise
                                      (w_t, g_t change slowly) makes
                                      them small
  decode-in  encoded bytes stay       ``stream_decode="kernel"`` (auto
  -kernel    resident; dequant runs   for lossy codecs): the streamers
             in registers             ship ENCODED windows to device and
                                      the replay scan dequantizes per
                                      step in registers (Pallas
                                      ``kernels/dequant_update`` on TPU,
                                      XLA-fused jnp elsewhere) — HBM
                                      high-water drops by the codec
                                      ratio and no f32 window copy is
                                      ever materialized
  =========  =======================  ==================================

Bytes per param per step, both quantities (w_t and g_t) included:

  ==========  ==============================================
  codec       bytes/param/step (stored form)
  ==========  ==============================================
  f32         8
  bf16        4
  int8        ~2   (+ one f32 scale per leaf per entry)
  delta_bf16  ~4   (+ 8/key_interval for keyframe bases)
  delta_int8  ~2   + 8/key_interval ≈ 2.5 at key_interval=16
  ==========  ==============================================

Codecs apply to host/disk (re-encoded per entry); ``stacked`` rejects
lossy codecs by construction (it stores what the engine produced).

At transformer-LM scale the table stops being hypothetical.  Worked rows
(``models.registry.count_params`` gives P exactly):

  ==========================  ========  ===================================
  model                       P         bytes/step — f32 8 B vs delta ~2.5
  ==========================  ========  ===================================
  bench_lm --quick (2 layers  2.4 M     19 MB/step f32 → a 16-step path is
  of internlm2-1.8b blocks,             306 MB resident; delta_int8 holds
  vocab 8k, d_model 128)                it at ~77 MB with streamed windows
  internlm2-1.8b (full)       1.9 B     15 GB/step f32 — a 1k-step path is
                                        ~15 TB: no single tier fits, only
                                        host+mesh (`ShardedStreamer`) with
                                        ``delta_int8`` (~4.7 TB host RAM
                                        across the fleet, ~2 encoded shard
                                        windows per device) is in range
  ==========================  ========  ===================================

`benchmarks/bench_lm.py` measures the quick row end to end (HBM
high-water, encoded bytes, exact streamed-vs-resident parity) on per-layer
pytree histories; `examples/unlearn_lm.py` is the API quickstart.

Delta encoding (``delta_int8`` / ``delta_bf16``) uses a FIXED per-window
keyframe base rather than chaining t against t-1: entry ``t`` stores a
quantized residual against the first entry of its key window
(``t // key_interval``), captured once and immutable afterwards.  Chained
deltas would ripple on every online rewrite and lose O(1) random access
(the replay needs arbitrary entries every explicit step); a fixed base
keeps windows independently decodable, keeps overwrites local to one
entry, and still captures the time-axis redundancy DeltaGrad guarantees.
"""

from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# Codecs
# --------------------------------------------------------------------------


class Codec:
    name = "f32"

    def encode(self, tree):
        return jax.tree.map(np.asarray, jax.device_get(tree))

    def decode(self, stored):
        return jax.tree.map(jnp.asarray, stored)

    def decode_stacked(self, stored):
        """Decode a WINDOW of encoded entries stacked along a leading axis
        (one upload per window — `core.store.SegmentStreamer`'s read path).
        Must agree elementwise with per-entry `decode`."""
        return jax.tree.map(jnp.asarray, stored)


class F32Codec(Codec):
    name = "f32"


class BF16Codec(Codec):
    name = "bf16"

    def encode(self, tree):
        tree = jax.device_get(tree)
        return jax.tree.map(lambda x: np.asarray(x, dtype=jnp.bfloat16), tree)

    def decode(self, stored):
        return jax.tree.map(lambda x: jnp.asarray(x, dtype=jnp.float32), stored)

    def decode_stacked(self, stored):
        return jax.tree.map(lambda x: jnp.asarray(x, dtype=jnp.float32),
                            stored)


class Int8Codec(Codec):
    """Symmetric per-leaf absmax int8 quantization."""

    name = "int8"

    def encode(self, tree):
        tree = jax.device_get(tree)

        def enc(x):
            x = np.asarray(x, dtype=np.float32)
            scale = np.max(np.abs(x)) / 127.0 if x.size else 1.0
            scale = scale if scale > 0 else 1.0
            q = np.clip(np.round(x / scale), -127, 127).astype(np.int8)
            return {"q": q, "scale": np.float32(scale)}

        return jax.tree.map(enc, tree)

    def decode(self, stored):
        def dec(d):
            return jnp.asarray(d["q"], dtype=jnp.float32) * d["scale"]

        return jax.tree.map(dec, stored, is_leaf=lambda x: isinstance(x, dict) and "q" in x)

    def decode_stacked(self, stored):
        """Stacked window form: q is (L, ...) int8, scale is (L,) — one
        per-entry scale broadcast over the entry's dims."""

        def dec(d):
            q = jnp.asarray(d["q"], dtype=jnp.float32)
            scale = jnp.asarray(d["scale"], dtype=jnp.float32)
            return q * scale.reshape((-1,) + (1,) * (q.ndim - 1))

        return jax.tree.map(dec, stored,
                            is_leaf=lambda x: isinstance(x, dict) and "q" in x)


class DeltaCodec(Codec):
    """Time-axis delta wrapper: store entry t as ``inner(x_t - base)``.

    ``base`` is the f32 keyframe of entry t's key window (the first entry
    written in window ``t // key_interval``), captured once by
    `TrainingHistory` and immutable afterwards — overwrites re-encode
    against the SAME base, so rewrites never ripple and any entry decodes
    in O(1) from (residual, base).  The decode contract is exactly

        x_t == inner_decode(residual) + base     (elementwise, f32)

    which `core.store` reuses verbatim for stacked windows and in-kernel
    dequant, so per-entry, windowed, and fused-kernel reads are bitwise
    identical.  The base lives OUTSIDE the stored entry (the history and
    the streamers pass it in), so encode/decode without a base raise."""

    inner_cls: type = Int8Codec
    name = "delta_int8"
    key_interval = 16

    def __init__(self):
        self.inner = self.inner_cls()

    def _need_base(self, op):
        raise ValueError(
            f"codec {self.name!r} stores residuals against a per-key-window "
            f"keyframe base; {op} needs the base passed explicitly (use "
            "encode_delta/decode_delta, or go through TrainingHistory which "
            "manages the bases)")

    def encode(self, tree):
        self._need_base("encode()")

    def decode(self, stored):
        self._need_base("decode()")

    def decode_stacked(self, stored):
        self._need_base("decode_stacked()")

    def make_base(self, tree):
        """Immutable f32 host copy used as the key window's keyframe."""
        tree = jax.device_get(tree)
        return jax.tree.map(lambda x: np.array(x, dtype=np.float32), tree)

    def encode_delta(self, tree, base):
        tree = jax.device_get(tree)
        resid = jax.tree.map(
            lambda x, b: np.asarray(x, dtype=np.float32) - b, tree, base)
        return self.inner.encode(resid)

    def decode_delta(self, stored, base):
        resid = self.inner.decode(stored)
        return jax.tree.map(lambda r, b: r + jnp.asarray(b), resid, base)


class DeltaInt8Codec(DeltaCodec):
    inner_cls = Int8Codec
    name = "delta_int8"


class DeltaBF16Codec(DeltaCodec):
    inner_cls = BF16Codec
    name = "delta_bf16"


CODECS = {"f32": F32Codec, "bf16": BF16Codec, "int8": Int8Codec,
          "delta_int8": DeltaInt8Codec, "delta_bf16": DeltaBF16Codec}


# --------------------------------------------------------------------------
# History
# --------------------------------------------------------------------------


@dataclass
class HistoryMeta:
    """Everything needed to replay the original training run."""

    n: int  # dataset size during original training
    batch_size: int  # B (== n for deterministic GD)
    seed: int  # sampler seed
    steps: int  # T
    lr_schedule: Tuple[Tuple[int, float], ...]  # piecewise-constant (from_step, lr)
    l2: float = 0.0
    # beyond-paper: heavy-ball momentum (paper covers plain SGD; with
    # momentum every replay — batch or online — reconstructs its own
    # velocity from vel_0 = 0 using the corrected gradients, so the cache
    # stores plain gradients only — see core/engine.py and tests)
    momentum: float = 0.0
    extra: Dict[str, Any] = field(default_factory=dict)

    def lr_at(self, t: int) -> float:
        lr = self.lr_schedule[0][1]
        for start, value in self.lr_schedule:
            if t >= start:
                lr = value
        return lr


class TrainingHistory:
    """Per-step (w_t, g_t) cache with tiered storage."""

    def __init__(
        self,
        meta: HistoryMeta,
        tier: str = "device",
        codec: str = "f32",
        spill_dir: Optional[str] = None,
        lru_window: int = 64,
        spill_window: int = 0,
    ):
        if tier not in ("stacked", "device", "host", "disk"):
            raise ValueError(
                f"unknown history tier {tier!r}; pick one of 'stacked' "
                "(device-resident, fastest replay), 'device' (per-entry "
                "arrays), 'host' (entries offloaded to host RAM, streamed "
                "to the scan per segment), or 'disk' (.npz spill under "
                "spill_dir) — see the tier-selection guide in "
                "repro/core/history.py")
        if codec not in CODECS:
            raise ValueError(f"unknown codec {codec!r}; pick one of "
                             f"{sorted(CODECS)}")
        # compression codecs apply where entries are re-encoded (host/disk);
        # stacked storage keeps what the engine produced, uncompressed
        # (the pre-existing device tier also ignores codecs, kept permissive
        # for backwards compatibility)
        if codec != "f32" and tier == "stacked":
            raise ValueError(
                f"codec={codec!r} has no effect on tier='stacked': stacked "
                "storage keeps the exact arrays the recording scan "
                "produced.  Use tier='host' (or 'disk') to store the path "
                f"{codec}-compressed — the SegmentStreamer still serves it "
                "to the compiled scan — or drop the codec")
        self.meta = meta
        self.tier = tier
        self.codec: Codec = CODECS[codec]()
        self.lru_window = lru_window
        self._params: List[Any] = []
        self._grads: List[Any] = []
        self._disk_paths: List[Optional[str]] = []
        self._stacked: Optional[Tuple[Any, Any]] = None  # (Ws, Gs), T leading
        self._stacked_len: int = 0
        # overwrite()s against stacked storage buffered here (t -> (w, g));
        # folded into ONE batched scatter on the next stacked read, so a
        # per-step rewrite loop costs O(T*P) total, not O(T^2*P)
        self._pending_over: Dict[int, Tuple[Any, Any]] = {}
        self.final_params = None
        if tier == "disk":
            if spill_dir is None:
                raise ValueError(
                    "tier='disk' spills every history entry to .npz files "
                    "and needs somewhere to put them: pass "
                    "spill_dir=<directory> (created if missing), or "
                    "spill_dir='auto' to opt into a fresh temporary "
                    "directory (removed when the process exits)")
            if spill_dir == "auto":
                import atexit
                import shutil
                import tempfile
                spill_dir = tempfile.mkdtemp(prefix="repro_history_")
                atexit.register(shutil.rmtree, spill_dir,
                                ignore_errors=True)
            os.makedirs(spill_dir, exist_ok=True)
        self.spill_dir = spill_dir
        # delta codecs: immutable f32 keyframes, kwid -> (base_w, base_g)
        self._bases: Dict[int, Tuple[Any, Any]] = {}
        # disk tier, windowed spill: one .npz per spill_window steps
        self.spill_window = max(0, int(spill_window)) if tier == "disk" else 0
        self._win_paths: List[str] = []
        self._spill_buf: List[Tuple[Any, Any]] = []  # not-yet-flushed entries
        self._spill_flushed = 0  # steps already on disk
        self._win_cache: Optional[Tuple[int, List[Tuple[Any, Any]]]] = None
        self._win_dirty = False
        self.io_read_s = 0.0  # cumulative spill IO wall time
        self.io_write_s = 0.0

    def __len__(self) -> int:
        return self._stacked_len + len(self._params)

    # -- delta-codec keyframe bases ------------------------------------------

    @property
    def is_delta(self) -> bool:
        return isinstance(self.codec, DeltaCodec)

    @property
    def key_interval(self) -> int:
        return self.codec.key_interval if self.is_delta else 0

    def base_entry(self, kwid: int) -> Tuple[Any, Any]:
        """(base_w, base_g) f32 keyframes of key window `kwid`."""
        return self._bases[kwid]

    def _base_for(self, t: int, params=None, grad=None) -> Tuple[Any, Any]:
        kwid = t // self.codec.key_interval
        if kwid not in self._bases:
            if params is None:
                raise KeyError(
                    f"no keyframe base for key window {kwid} (entry {t})")
            self._bases[kwid] = (self.codec.make_base(params),
                                 self.codec.make_base(grad))
        return self._bases[kwid]

    def _encode_pair(self, t: int, params, grad):
        if self.is_delta:
            bp, bg = self._base_for(t, params, grad)
            return (self.codec.encode_delta(params, bp),
                    self.codec.encode_delta(grad, bg))
        return self.codec.encode(params), self.codec.encode(grad)

    def _decode_pair(self, t: int, enc_p, enc_g):
        if self.is_delta:
            bp, bg = self._base_for(t)
            return (self.codec.decode_delta(enc_p, bp),
                    self.codec.decode_delta(enc_g, bg))
        return self.codec.decode(enc_p), self.codec.decode(enc_g)

    # -- write path --------------------------------------------------------

    def append(self, params, grad) -> None:
        t = len(self._params)
        if self._stacked_is_storage:
            # buffered; merged into the stacked arrays on the next read
            self._params.append(params)
            self._grads.append(grad)
        elif self.tier == "device":
            self._params.append(params)
            self._grads.append(grad)
            self._stacked = None
        else:
            enc_p, enc_g = self._encode_pair(t, params, grad)
            self._stacked = None
            if self.tier == "host":
                self._params.append(enc_p)
                self._grads.append(enc_g)
            elif self.spill_window > 1:  # disk, one .npz per window
                flat_p, tdef = jax.tree.flatten(enc_p)
                self._treedef = tdef
                self._params.append(None)
                self._grads.append(None)
                self._spill_buf.append((enc_p, enc_g))
                self._flush_spill()  # no-op until a window is complete
            else:  # disk, legacy one .npz per step
                path = os.path.join(self.spill_dir, f"step_{t:07d}.npz")
                flat_p, tdef = jax.tree.flatten(enc_p)
                flat_g, _ = jax.tree.flatten(enc_g)
                t0 = time.perf_counter()
                np.savez(path, n_p=len(flat_p), *flat_p, *flat_g)
                self.io_write_s += time.perf_counter() - t0
                self._params.append(None)
                self._grads.append(None)
                self._treedef = tdef
                self._disk_paths.append(path)

    # -- windowed disk spill (one .npz per spill_window steps) ---------------

    def _win_path(self, wid: int) -> str:
        return os.path.join(self.spill_dir, f"win_{wid:07d}.npz")

    def _write_win(self, wid: int, entries: List[Tuple[Any, Any]]) -> None:
        per_entry: List[List[Any]] = []
        n_p = 0
        for enc_p, enc_g in entries:
            flat_p, _ = jax.tree.flatten(enc_p)
            flat_g, _ = jax.tree.flatten(enc_g)
            n_p = len(flat_p)
            per_entry.append(flat_p + flat_g)
        # one member per LEAF stacked over the window's steps, not one per
        # leaf per step: npz overhead (zip entry + .npy header) is per
        # member, and encoded trees double the leaf count (q + scale) —
        # per-step members would cost more than the int8 payload saves
        stacked = [np.stack([np.asarray(row[i]) for row in per_entry])
                   for i in range(2 * n_p)]
        t0 = time.perf_counter()
        np.savez(self._win_path(wid), n_p=n_p,
                 t0=wid * self.spill_window, steps=len(entries), *stacked)
        self.io_write_s += time.perf_counter() - t0

    def _flush_spill(self, everything: bool = False) -> None:
        """Write buffered appends as window files — complete windows only,
        unless `everything` (finalize) also flushes the partial tail.  A
        partial window rewritten later (appends resumed after finalize)
        merges with the entries already on disk."""
        W = self.spill_window
        while self._spill_buf:
            wid = self._spill_flushed // W
            off = self._spill_flushed % W
            take = min(W - off, len(self._spill_buf))
            if not everything and off + take < W:
                return  # keep the partial tail buffered
            entries = (list(self._load_win(wid)) if off else []) \
                + self._spill_buf[:take]
            self._write_win(wid, entries)
            if wid >= len(self._win_paths):
                self._win_paths.append(self._win_path(wid))
            self._win_cache = (wid, entries)
            self._win_dirty = False
            self._spill_flushed += take
            self._spill_buf = self._spill_buf[take:]

    def _flush_win_cache(self) -> None:
        """Write back a dirty cached window (deferred overwrite commit)."""
        if self._win_cache is not None and self._win_dirty:
            wid, entries = self._win_cache
            self._write_win(wid, entries)
        self._win_dirty = False

    def _load_win(self, wid: int) -> List[Tuple[Any, Any]]:
        if self._win_cache is not None and self._win_cache[0] == wid:
            return self._win_cache[1]
        self._flush_win_cache()
        t0 = time.perf_counter()
        with np.load(self._win_paths[wid]) as data:
            n_p = int(data["n_p"])
            steps = int(data["steps"])
            stacked = [data[f"arr_{i}"] for i in range(2 * n_p)]
        self.io_read_s += time.perf_counter() - t0
        entries = []
        for e in range(steps):
            flat = [s[e] for s in stacked]
            entries.append((jax.tree.unflatten(self._treedef, flat[:n_p]),
                            jax.tree.unflatten(self._treedef, flat[n_p:])))
        self._win_cache = (wid, entries)
        self._win_dirty = False
        return entries

    def finalize(self, final_params) -> None:
        self.final_params = final_params
        # drain buffered writes (one batched scatter) so the pending dict
        # never outlives the run/request that produced it
        self._merge_pending()
        if self.spill_window > 1:
            self._flush_spill(everything=True)
            self._flush_win_cache()

    # -- stacked tier / view -------------------------------------------------

    def set_stacked(self, Ws, Gs, final_params=None) -> None:
        """Adopt (Ws, Gs) — pytrees with a leading time axis — as the cache.

        This is the zero-copy hand-off from the engine's recording scan: the
        arrays the scan collected ARE the history.  For the ``stacked`` and
        ``device`` tiers the stacked arrays become the storage (one device
        buffer — no per-entry slice copies); host/disk re-encode per entry."""
        T = jax.tree.leaves(Ws)[0].shape[0]
        if self.tier in ("stacked", "device"):
            self._stacked = (Ws, Gs)
            self._stacked_len = T
            self._params, self._grads = [], []
            self._pending_over = {}
        else:
            for i in range(T):
                self.append(jax.tree.map(lambda x: x[i], Ws),
                            jax.tree.map(lambda x: x[i], Gs))
        if final_params is not None:
            self.finalize(final_params)

    @property
    def _stacked_is_storage(self) -> bool:
        """True when `_stacked` IS the backing store (the stacked tier, or a
        device-tier history adopted via set_stacked/replace_from_stacked) —
        as opposed to the derived cache other tiers hold transiently."""
        return self.tier == "stacked" or self._stacked_len > 0

    def _merge_pending(self) -> None:
        """Stacked storage: fold buffered append()s and overwrite()s into the
        stacked arrays (one concatenate + one batched scatter)."""
        if not self._stacked_is_storage:
            return
        if self._params:
            new_w = jax.tree.map(lambda *xs: jnp.stack(xs), *self._params)
            new_g = jax.tree.map(lambda *xs: jnp.stack(xs), *self._grads)
            if self._stacked is None:
                self._stacked = (new_w, new_g)
            else:
                Ws, Gs = self._stacked
                self._stacked = (
                    jax.tree.map(lambda a, b: jnp.concatenate([a, b]), Ws, new_w),
                    jax.tree.map(lambda a, b: jnp.concatenate([a, b]), Gs, new_g),
                )
            self._stacked_len += len(self._params)
            self._params, self._grads = [], []
        if self._pending_over:
            ts = jnp.asarray(list(self._pending_over.keys()))
            vals = list(self._pending_over.values())
            up_w = jax.tree.map(lambda *xs: jnp.stack(xs), *[v[0] for v in vals])
            up_g = jax.tree.map(lambda *xs: jnp.stack(xs), *[v[1] for v in vals])
            Ws, Gs = self._stacked
            self._stacked = (
                jax.tree.map(lambda x, u: x.at[ts].set(u), Ws, up_w),
                jax.tree.map(lambda x, u: x.at[ts].set(u), Gs, up_g),
            )
            self._pending_over = {}

    def stacked_view(self):
        """(Ws, Gs) with every leaf stacked along a leading time axis.

        Free for the stacked tier; built once and cached for the others
        (invalidated by append/overwrite)."""
        if self._stacked_is_storage:
            self._merge_pending()
            if self._stacked is None:
                raise ValueError("stacked_view() on an empty history")
            return self._stacked
        if self._stacked is None:
            T = len(self)
            entries = [self.entry(t) for t in range(T)]
            Ws = jax.tree.map(lambda *xs: jnp.stack(xs), *[e[0] for e in entries])
            Gs = jax.tree.map(lambda *xs: jnp.stack(xs), *[e[1] for e in entries])
            if self.tier == "device" and not self._multi_device():
                # adopt as storage: keeping the per-entry arrays alongside
                # would double device memory for the whole path.  Skipped on
                # a mesh — the device tier's contract is entries sharded like
                # the live params, and jnp.stack'd copies would not be.
                self.set_stacked(Ws, Gs)
            else:
                self._stacked = (Ws, Gs)
        return self._stacked

    def _multi_device(self) -> bool:
        for tree in self._params[:1]:
            for leaf in jax.tree.leaves(tree):
                sharding = getattr(leaf, "sharding", None)
                if sharding is not None and len(getattr(
                        sharding, "device_set", ())) > 1:
                    return True
        return False

    def replace_from_stacked(self, Ws, Gs, final_params=None) -> None:
        """Bulk-rewrite the whole cache from edited stacked arrays (the online
        engine's end-of-request flush); pass `final_params` to finalize the
        post-request model in the same call."""
        if self.tier == "stacked" or (self.tier == "device"
                                      and not self._multi_device()):
            self._params, self._grads = [], []
            self._stacked = (Ws, Gs)
            self._stacked_len = jax.tree.leaves(Ws)[0].shape[0]
            self._pending_over = {}
        else:
            T = len(self)
            self._stacked = None
            for t in range(T):
                self.overwrite(t, jax.tree.map(lambda x: x[t], Ws),
                               jax.tree.map(lambda x: x[t], Gs))
            # do NOT cache (Ws, Gs) here: under a lossy codec the raw arrays
            # would diverge from what entry() decodes back; let stacked_view()
            # rebuild from the encoded entries so both read paths agree
        if final_params is not None:
            self.finalize(final_params)

    # -- read path ----------------------------------------------------------

    def _load_disk(self, t: int):
        if self.spill_window > 1:
            if t >= self._spill_flushed:  # still buffered, not yet on disk
                return self._spill_buf[t - self._spill_flushed]
            wid, off = divmod(t, self.spill_window)
            return self._load_win(wid)[off]
        t0 = time.perf_counter()
        with np.load(self._disk_paths[t]) as data:
            n_p = int(data["n_p"])
            arrays = [data[f"arr_{i}"] for i in range(2 * n_p)]
        self.io_read_s += time.perf_counter() - t0
        p = jax.tree.unflatten(self._treedef, arrays[:n_p])
        g = jax.tree.unflatten(self._treedef, arrays[n_p:])
        return p, g

    def entry(self, t: int):
        """(w_t, g_t) decoded back to device arrays."""
        if self._stacked_is_storage:
            if t in self._pending_over:  # not yet scattered — serve directly
                return self._pending_over[t]
            if self._params:
                self._merge_pending()
            if self._stacked is None or not 0 <= t < self._stacked_len:
                raise IndexError(f"history entry {t} of {len(self)}")
            Ws, Gs = self._stacked
            return (jax.tree.map(lambda x: x[t], Ws),
                    jax.tree.map(lambda x: x[t], Gs))
        if self.tier == "device":
            return self._params[t], self._grads[t]
        if self.tier == "host":
            return self._decode_pair(t, self._params[t], self._grads[t])
        p, g = self._load_disk(t)
        return self._decode_pair(t, p, g)

    def encoded_entry(self, t: int):
        """(w_t, g_t) in STORED form — no codec decode, no device upload.

        Offload tiers only: this is `core.store.SegmentStreamer`'s read
        path (it stacks a whole window of encoded entries, ships them in
        one copy, and decodes on device)."""
        assert self.tier in ("host", "disk"), self.tier
        if self.tier == "host":
            return self._params[t], self._grads[t]
        return self._load_disk(t)

    def params_at(self, t: int):
        return self.entry(t)[0]

    def grad_at(self, t: int):
        return self.entry(t)[1]

    # -- in-place rewrite (online deletion, Algorithm 3) --------------------

    def overwrite(self, t: int, params, grad) -> None:
        if self._stacked_is_storage:
            if self._params:
                self._merge_pending()  # appends first, to fix the length
            if self._stacked is None or not 0 <= t < self._stacked_len:
                raise IndexError(f"history entry {t} of {len(self)}")
            self._pending_over[t] = (params, grad)
            return
        self._stacked = None
        if self.tier == "device":
            self._params[t] = params
            self._grads[t] = grad
            return
        if self.tier == "host":
            self._params[t], self._grads[t] = self._encode_pair(t, params,
                                                                grad)
            return
        # disk: re-encode against the same (immutable) base — a delta
        # rewrite stays local to this entry, no ripple into neighbours
        enc_p, enc_g = self._encode_pair(t, params, grad)
        if self.spill_window > 1:
            if t >= self._spill_flushed:
                self._spill_buf[t - self._spill_flushed] = (enc_p, enc_g)
                return
            wid, off = divmod(t, self.spill_window)
            entries = self._load_win(wid)
            entries[off] = (enc_p, enc_g)
            self._win_dirty = True  # written back on window change/finalize
            return
        flat_p, _ = jax.tree.flatten(enc_p)
        flat_g, _ = jax.tree.flatten(enc_g)
        t0 = time.perf_counter()
        np.savez(self._disk_paths[t], n_p=len(flat_p), *flat_p, *flat_g)
        self.io_write_s += time.perf_counter() - t0

    # -- checkpoint integration ---------------------------------------------

    def state_dict(self) -> Dict[str, Any]:
        if self.spill_window > 1:
            self._flush_spill(everything=True)
            self._flush_win_cache()
        state = {
            "meta": self.meta,
            "tier": self.tier,
            "codec": self.codec.name,
            "params": [jax.device_get(p) for p in self._params],
            "grads": [jax.device_get(g) for g in self._grads],
            "final_params": jax.device_get(self.final_params),
            "disk_paths": list(self._disk_paths),
        }
        if self._bases:
            state["bases"] = dict(self._bases)
        if self.spill_window > 1:
            state["spill_window"] = self.spill_window
            state["win_paths"] = list(self._win_paths)
            state["spill_flushed"] = self._spill_flushed
        if self._stacked_is_storage and self._stacked is not None:
            self._merge_pending()
            state["params"], state["grads"] = [], []
            state["stacked"] = jax.device_get(self._stacked)
        return state

    @classmethod
    def from_state_dict(cls, state: Dict[str, Any], spill_dir: Optional[str] = None):
        h = cls(state["meta"], tier=state["tier"], codec=state["codec"],
                spill_dir=spill_dir or "/tmp/repro_history",
                spill_window=state.get("spill_window", 0))
        h._params = state["params"]
        h._grads = state["grads"]
        h._disk_paths = state["disk_paths"]
        h.final_params = state["final_params"]
        h._bases = dict(state.get("bases", {}))
        if state.get("spill_window", 0) > 1:
            h._win_paths = list(state.get("win_paths", []))
            h._spill_flushed = int(state.get("spill_flushed", 0))
        if state.get("stacked") is not None:
            Ws, Gs = state["stacked"]
            h.set_stacked(jax.tree.map(jnp.asarray, Ws),
                          jax.tree.map(jnp.asarray, Gs))
        if h.tier == "disk" and state["final_params"] is not None:
            # disk reads unflatten with the ENCODED treedef (set during
            # recording); rebuild it from a zero probe shaped like params
            probe = jax.tree.map(lambda x: np.zeros((), np.float32),
                                 state["final_params"])
            inner = h.codec.inner if h.is_delta else h.codec
            h._treedef = jax.tree.structure(inner.encode(probe))
        return h

    def nbytes(self) -> int:
        total = 0
        trees = list(self._params) + list(self._grads)
        if self._stacked is not None and self._stacked_is_storage:
            trees += list(self._stacked)
        for bp, bg in self._bases.values():  # keyframes are host RAM too
            trees += [bp, bg]
        for tree in trees:
            if tree is None:
                continue
            for leaf in jax.tree.leaves(tree):
                total += np.asarray(leaf).nbytes
        return total

    def disk_nbytes(self) -> int:
        """Bytes currently occupied by the disk spill (0 for other tiers)."""
        paths = [p for p in self._disk_paths if p] + list(self._win_paths)
        return sum(os.path.getsize(p) for p in paths if os.path.exists(p))
