"""Optimization-path cache — the information DeltaGrad records during training.

DeltaGrad needs, for every original training step ``t``:
  * the parameters ``w_t``,
  * the (mini-)batch mean gradient ``g_t = (1/|B_t|) sum_{i in B_t} grad F_i(w_t)``,
  * enough metadata to *replay the exact minibatch schedule* (seed, batch
    size, dataset size, learning-rate schedule).

Storage tiers (per-entry, selectable):
  * ``device`` — entries stay as JAX arrays (sharded exactly like the live
    parameters; right choice on a TPU mesh where each host holds 1/N of
    every entry),
  * ``host``   — entries are pulled to host numpy (paper's choice; frees HBM),
  * ``disk``   — chunked ``.npz`` spill with an in-memory LRU window (long
    training runs; participates in checkpoint/restart).

Optional compression codecs trade cache size for a tiny, quantifiable
perturbation of the cached path (bf16: 2x; int8 + per-leaf scale: ~4x) —
DeltaGrad's correction is first-order in the cache error, and the
``bench_hyperparams`` benchmark measures the effect.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# Codecs
# --------------------------------------------------------------------------


class Codec:
    name = "f32"

    def encode(self, tree):
        return jax.tree.map(np.asarray, jax.device_get(tree))

    def decode(self, stored):
        return jax.tree.map(jnp.asarray, stored)


class F32Codec(Codec):
    name = "f32"


class BF16Codec(Codec):
    name = "bf16"

    def encode(self, tree):
        tree = jax.device_get(tree)
        return jax.tree.map(lambda x: np.asarray(x, dtype=jnp.bfloat16), tree)

    def decode(self, stored):
        return jax.tree.map(lambda x: jnp.asarray(x, dtype=jnp.float32), stored)


class Int8Codec(Codec):
    """Symmetric per-leaf absmax int8 quantization."""

    name = "int8"

    def encode(self, tree):
        tree = jax.device_get(tree)

        def enc(x):
            x = np.asarray(x, dtype=np.float32)
            scale = np.max(np.abs(x)) / 127.0 if x.size else 1.0
            scale = scale if scale > 0 else 1.0
            q = np.clip(np.round(x / scale), -127, 127).astype(np.int8)
            return {"q": q, "scale": np.float32(scale)}

        return jax.tree.map(enc, tree)

    def decode(self, stored):
        def dec(d):
            return jnp.asarray(d["q"], dtype=jnp.float32) * d["scale"]

        return jax.tree.map(dec, stored, is_leaf=lambda x: isinstance(x, dict) and "q" in x)


CODECS = {"f32": F32Codec, "bf16": BF16Codec, "int8": Int8Codec}


# --------------------------------------------------------------------------
# History
# --------------------------------------------------------------------------


@dataclass
class HistoryMeta:
    """Everything needed to replay the original training run."""

    n: int  # dataset size during original training
    batch_size: int  # B (== n for deterministic GD)
    seed: int  # sampler seed
    steps: int  # T
    lr_schedule: Tuple[Tuple[int, float], ...]  # piecewise-constant (from_step, lr)
    l2: float = 0.0
    # beyond-paper: heavy-ball momentum (paper covers plain SGD; with
    # momentum the retraining path maintains its own velocity from the
    # corrected gradients — see core/deltagrad.py and tests)
    momentum: float = 0.0
    extra: Dict[str, Any] = field(default_factory=dict)

    def lr_at(self, t: int) -> float:
        lr = self.lr_schedule[0][1]
        for start, value in self.lr_schedule:
            if t >= start:
                lr = value
        return lr


class TrainingHistory:
    """Per-step (w_t, g_t) cache with tiered storage."""

    def __init__(
        self,
        meta: HistoryMeta,
        tier: str = "device",
        codec: str = "f32",
        spill_dir: Optional[str] = None,
        lru_window: int = 64,
    ):
        assert tier in ("device", "host", "disk")
        self.meta = meta
        self.tier = tier
        self.codec: Codec = CODECS[codec]()
        self.spill_dir = spill_dir
        self.lru_window = lru_window
        self._params: List[Any] = []
        self._grads: List[Any] = []
        self._disk_paths: List[Optional[str]] = []
        self.final_params = None
        if tier == "disk":
            assert spill_dir is not None, "disk tier requires spill_dir"
            os.makedirs(spill_dir, exist_ok=True)

    def __len__(self) -> int:
        return len(self._params)

    # -- write path --------------------------------------------------------

    def append(self, params, grad) -> None:
        t = len(self._params)
        if self.tier == "device":
            self._params.append(params)
            self._grads.append(grad)
        else:
            enc_p = self.codec.encode(params)
            enc_g = self.codec.encode(grad)
            if self.tier == "host":
                self._params.append(enc_p)
                self._grads.append(enc_g)
            else:  # disk
                path = os.path.join(self.spill_dir, f"step_{t:07d}.npz")
                flat_p, tdef = jax.tree.flatten(enc_p)
                flat_g, _ = jax.tree.flatten(enc_g)
                np.savez(path, n_p=len(flat_p), *flat_p, *flat_g)
                self._params.append(None)
                self._grads.append(None)
                self._treedef = tdef
                self._disk_paths.append(path)

    def finalize(self, final_params) -> None:
        self.final_params = final_params

    # -- read path ----------------------------------------------------------

    def _load_disk(self, t: int):
        with np.load(self._disk_paths[t]) as data:
            n_p = int(data["n_p"])
            arrays = [data[f"arr_{i}"] for i in range(2 * n_p)]
        p = jax.tree.unflatten(self._treedef, arrays[:n_p])
        g = jax.tree.unflatten(self._treedef, arrays[n_p:])
        return p, g

    def entry(self, t: int):
        """(w_t, g_t) decoded back to device arrays."""
        if self.tier == "device":
            return self._params[t], self._grads[t]
        if self.tier == "host":
            return self.codec.decode(self._params[t]), self.codec.decode(self._grads[t])
        p, g = self._load_disk(t)
        return self.codec.decode(p), self.codec.decode(g)

    def params_at(self, t: int):
        return self.entry(t)[0]

    def grad_at(self, t: int):
        return self.entry(t)[1]

    # -- in-place rewrite (online deletion, Algorithm 3) --------------------

    def overwrite(self, t: int, params, grad) -> None:
        if self.tier == "device":
            self._params[t] = params
            self._grads[t] = grad
        elif self.tier == "host":
            self._params[t] = self.codec.encode(params)
            self._grads[t] = self.codec.encode(grad)
        else:
            enc_p = self.codec.encode(params)
            enc_g = self.codec.encode(grad)
            flat_p, _ = jax.tree.flatten(enc_p)
            flat_g, _ = jax.tree.flatten(enc_g)
            np.savez(self._disk_paths[t], n_p=len(flat_p), *flat_p, *flat_g)

    # -- checkpoint integration ---------------------------------------------

    def state_dict(self) -> Dict[str, Any]:
        return {
            "meta": self.meta,
            "tier": self.tier,
            "codec": self.codec.name,
            "params": [jax.device_get(p) for p in self._params],
            "grads": [jax.device_get(g) for g in self._grads],
            "final_params": jax.device_get(self.final_params),
            "disk_paths": list(self._disk_paths),
        }

    @classmethod
    def from_state_dict(cls, state: Dict[str, Any], spill_dir: Optional[str] = None):
        h = cls(state["meta"], tier=state["tier"], codec=state["codec"],
                spill_dir=spill_dir or "/tmp/repro_history")
        h._params = state["params"]
        h._grads = state["grads"]
        h._disk_paths = state["disk_paths"]
        h.final_params = state["final_params"]
        return h

    def nbytes(self) -> int:
        total = 0
        for tree in self._params + self._grads:
            if tree is None:
                continue
            for leaf in jax.tree.leaves(tree):
                total += np.asarray(leaf).nbytes
        return total
