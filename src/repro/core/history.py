"""Optimization-path cache — the information DeltaGrad records during training.

DeltaGrad needs, for every original training step ``t``:
  * the parameters ``w_t``,
  * the (mini-)batch mean gradient ``g_t = (1/|B_t|) sum_{i in B_t} grad F_i(w_t)``,
  * enough metadata to *replay the exact minibatch schedule* (seed, batch
    size, dataset size, learning-rate schedule).

Storage tiers (per-entry, selectable):
  * ``stacked`` — ONE device pytree per quantity with a leading time axis
    (``w[t] == Ws_leaf[t]``).  This is the replay engine's native format:
    approx segments run under ``jax.lax.scan`` and read entries with
    ``lax.dynamic_slice`` without any host round-trip (see core/engine.py),
  * ``device`` — per-entry JAX arrays (sharded exactly like the live
    parameters; right choice on a TPU mesh where each host holds 1/N of
    every entry),
  * ``host``   — entries are pulled to host numpy (paper's choice; frees HBM),
  * ``disk``   — chunked ``.npz`` spill with an in-memory LRU window (long
    training runs; participates in checkpoint/restart).

Any tier can produce the stacked view on demand via ``stacked_view()``
(cached; invalidated by ``append``/``overwrite``) and be bulk-rewritten from
it via ``replace_from_stacked`` — the online engine edits the stacked arrays
functionally during a request and flushes after each request.

Optional compression codecs trade cache size for a tiny, quantifiable
perturbation of the cached path (bf16: 2x; int8 + per-leaf scale: ~4x) —
DeltaGrad's correction is first-order in the cache error, and the
``bench_hyperparams`` benchmark measures the effect.

Choosing a tier — the HBM math
------------------------------

The cache stores TWO pytrees per step (w_t and g_t), so with ``P`` model
bytes (f32 params) and ``T`` recorded steps:

  =========  =======================  ==================================
  tier       device bytes             when to pick it
  =========  =======================  ==================================
  stacked    ``2*T*P``                default — replay runs fastest; fits
                                      whenever 2*T*P is small next to HBM
                                      (1k steps of a 10M-param model =
                                      80 GB… too big; of a 100k-param
                                      model = 800 MB… fine)
  stacked    ``2*T*P / mesh``         same, placed on a mesh via
  + mesh                              `core.store.PlacementPolicy`: each
                                      device keeps 1/mesh of every sharded
                                      leaf, gathered one step at a time
  device     ``2*T*P``                per-entry arrays; only when entries
                                      must keep a custom per-leaf sharding
  host       ``~2*L*P`` (window)      paper's choice — frees HBM; served
                                      to the compiled scan in ``L``-step
                                      double-buffered windows by
                                      `core.store.SegmentStreamer`
                                      (host RAM pays ``2*T*P / ratio``,
                                      codec ratio 1/2/4 for f32/bf16/int8)
  host       ``~2*L*P / mesh``        the COMPOSED tier
  + mesh     (shard window)           (`core.store.ShardedStreamer`) — the
                                      only fit when the path exceeds any
                                      single host's HBM *and* any single
                                      device: each mesh shard streams only
                                      its `stacked_spec_for_leaf` slice of
                                      every window, so per-DEVICE bytes
                                      are ~2 windows of the shard and
                                      per-HOST RAM is the encoded path
                                      (``2*T*P / ratio``) plus one window
                                      of staged slices; the shard_map
                                      scan all-gathers one step at a time
  disk       ``~2*L*P`` (window)      longest runs; host RAM ~0, entries
                                      spill to ``spill_dir`` .npz
                                      (``spill_dir="auto"`` → a fresh
                                      tempdir, removed with the process);
                                      also composes with a mesh placement
                                      exactly like host + mesh
  =========  =======================  ==================================

Codecs apply to host/disk (re-encoded per entry); ``stacked`` rejects
lossy codecs by construction (it stores what the engine produced).
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# Codecs
# --------------------------------------------------------------------------


class Codec:
    name = "f32"

    def encode(self, tree):
        return jax.tree.map(np.asarray, jax.device_get(tree))

    def decode(self, stored):
        return jax.tree.map(jnp.asarray, stored)

    def decode_stacked(self, stored):
        """Decode a WINDOW of encoded entries stacked along a leading axis
        (one upload per window — `core.store.SegmentStreamer`'s read path).
        Must agree elementwise with per-entry `decode`."""
        return jax.tree.map(jnp.asarray, stored)


class F32Codec(Codec):
    name = "f32"


class BF16Codec(Codec):
    name = "bf16"

    def encode(self, tree):
        tree = jax.device_get(tree)
        return jax.tree.map(lambda x: np.asarray(x, dtype=jnp.bfloat16), tree)

    def decode(self, stored):
        return jax.tree.map(lambda x: jnp.asarray(x, dtype=jnp.float32), stored)

    def decode_stacked(self, stored):
        return jax.tree.map(lambda x: jnp.asarray(x, dtype=jnp.float32),
                            stored)


class Int8Codec(Codec):
    """Symmetric per-leaf absmax int8 quantization."""

    name = "int8"

    def encode(self, tree):
        tree = jax.device_get(tree)

        def enc(x):
            x = np.asarray(x, dtype=np.float32)
            scale = np.max(np.abs(x)) / 127.0 if x.size else 1.0
            scale = scale if scale > 0 else 1.0
            q = np.clip(np.round(x / scale), -127, 127).astype(np.int8)
            return {"q": q, "scale": np.float32(scale)}

        return jax.tree.map(enc, tree)

    def decode(self, stored):
        def dec(d):
            return jnp.asarray(d["q"], dtype=jnp.float32) * d["scale"]

        return jax.tree.map(dec, stored, is_leaf=lambda x: isinstance(x, dict) and "q" in x)

    def decode_stacked(self, stored):
        """Stacked window form: q is (L, ...) int8, scale is (L,) — one
        per-entry scale broadcast over the entry's dims."""

        def dec(d):
            q = jnp.asarray(d["q"], dtype=jnp.float32)
            scale = jnp.asarray(d["scale"], dtype=jnp.float32)
            return q * scale.reshape((-1,) + (1,) * (q.ndim - 1))

        return jax.tree.map(dec, stored,
                            is_leaf=lambda x: isinstance(x, dict) and "q" in x)


CODECS = {"f32": F32Codec, "bf16": BF16Codec, "int8": Int8Codec}


# --------------------------------------------------------------------------
# History
# --------------------------------------------------------------------------


@dataclass
class HistoryMeta:
    """Everything needed to replay the original training run."""

    n: int  # dataset size during original training
    batch_size: int  # B (== n for deterministic GD)
    seed: int  # sampler seed
    steps: int  # T
    lr_schedule: Tuple[Tuple[int, float], ...]  # piecewise-constant (from_step, lr)
    l2: float = 0.0
    # beyond-paper: heavy-ball momentum (paper covers plain SGD; with
    # momentum every replay — batch or online — reconstructs its own
    # velocity from vel_0 = 0 using the corrected gradients, so the cache
    # stores plain gradients only — see core/engine.py and tests)
    momentum: float = 0.0
    extra: Dict[str, Any] = field(default_factory=dict)

    def lr_at(self, t: int) -> float:
        lr = self.lr_schedule[0][1]
        for start, value in self.lr_schedule:
            if t >= start:
                lr = value
        return lr


class TrainingHistory:
    """Per-step (w_t, g_t) cache with tiered storage."""

    def __init__(
        self,
        meta: HistoryMeta,
        tier: str = "device",
        codec: str = "f32",
        spill_dir: Optional[str] = None,
        lru_window: int = 64,
    ):
        if tier not in ("stacked", "device", "host", "disk"):
            raise ValueError(
                f"unknown history tier {tier!r}; pick one of 'stacked' "
                "(device-resident, fastest replay), 'device' (per-entry "
                "arrays), 'host' (entries offloaded to host RAM, streamed "
                "to the scan per segment), or 'disk' (.npz spill under "
                "spill_dir) — see the tier-selection guide in "
                "repro/core/history.py")
        if codec not in CODECS:
            raise ValueError(f"unknown codec {codec!r}; pick one of "
                             f"{sorted(CODECS)}")
        # compression codecs apply where entries are re-encoded (host/disk);
        # stacked storage keeps what the engine produced, uncompressed
        # (the pre-existing device tier also ignores codecs, kept permissive
        # for backwards compatibility)
        if codec != "f32" and tier == "stacked":
            raise ValueError(
                f"codec={codec!r} has no effect on tier='stacked': stacked "
                "storage keeps the exact arrays the recording scan "
                "produced.  Use tier='host' (or 'disk') to store the path "
                f"{codec}-compressed — the SegmentStreamer still serves it "
                "to the compiled scan — or drop the codec")
        self.meta = meta
        self.tier = tier
        self.codec: Codec = CODECS[codec]()
        self.lru_window = lru_window
        self._params: List[Any] = []
        self._grads: List[Any] = []
        self._disk_paths: List[Optional[str]] = []
        self._stacked: Optional[Tuple[Any, Any]] = None  # (Ws, Gs), T leading
        self._stacked_len: int = 0
        # overwrite()s against stacked storage buffered here (t -> (w, g));
        # folded into ONE batched scatter on the next stacked read, so a
        # per-step rewrite loop costs O(T*P) total, not O(T^2*P)
        self._pending_over: Dict[int, Tuple[Any, Any]] = {}
        self.final_params = None
        if tier == "disk":
            if spill_dir is None:
                raise ValueError(
                    "tier='disk' spills every history entry to .npz files "
                    "and needs somewhere to put them: pass "
                    "spill_dir=<directory> (created if missing), or "
                    "spill_dir='auto' to opt into a fresh temporary "
                    "directory (removed when the process exits)")
            if spill_dir == "auto":
                import atexit
                import shutil
                import tempfile
                spill_dir = tempfile.mkdtemp(prefix="repro_history_")
                atexit.register(shutil.rmtree, spill_dir,
                                ignore_errors=True)
            os.makedirs(spill_dir, exist_ok=True)
        self.spill_dir = spill_dir

    def __len__(self) -> int:
        return self._stacked_len + len(self._params)

    # -- write path --------------------------------------------------------

    def append(self, params, grad) -> None:
        t = len(self._params)
        if self._stacked_is_storage:
            # buffered; merged into the stacked arrays on the next read
            self._params.append(params)
            self._grads.append(grad)
        elif self.tier == "device":
            self._params.append(params)
            self._grads.append(grad)
            self._stacked = None
        else:
            enc_p = self.codec.encode(params)
            enc_g = self.codec.encode(grad)
            self._stacked = None
            if self.tier == "host":
                self._params.append(enc_p)
                self._grads.append(enc_g)
            else:  # disk
                path = os.path.join(self.spill_dir, f"step_{t:07d}.npz")
                flat_p, tdef = jax.tree.flatten(enc_p)
                flat_g, _ = jax.tree.flatten(enc_g)
                np.savez(path, n_p=len(flat_p), *flat_p, *flat_g)
                self._params.append(None)
                self._grads.append(None)
                self._treedef = tdef
                self._disk_paths.append(path)

    def finalize(self, final_params) -> None:
        self.final_params = final_params
        # drain buffered writes (one batched scatter) so the pending dict
        # never outlives the run/request that produced it
        self._merge_pending()

    # -- stacked tier / view -------------------------------------------------

    def set_stacked(self, Ws, Gs, final_params=None) -> None:
        """Adopt (Ws, Gs) — pytrees with a leading time axis — as the cache.

        This is the zero-copy hand-off from the engine's recording scan: the
        arrays the scan collected ARE the history.  For the ``stacked`` and
        ``device`` tiers the stacked arrays become the storage (one device
        buffer — no per-entry slice copies); host/disk re-encode per entry."""
        T = jax.tree.leaves(Ws)[0].shape[0]
        if self.tier in ("stacked", "device"):
            self._stacked = (Ws, Gs)
            self._stacked_len = T
            self._params, self._grads = [], []
            self._pending_over = {}
        else:
            for i in range(T):
                self.append(jax.tree.map(lambda x: x[i], Ws),
                            jax.tree.map(lambda x: x[i], Gs))
        if final_params is not None:
            self.finalize(final_params)

    @property
    def _stacked_is_storage(self) -> bool:
        """True when `_stacked` IS the backing store (the stacked tier, or a
        device-tier history adopted via set_stacked/replace_from_stacked) —
        as opposed to the derived cache other tiers hold transiently."""
        return self.tier == "stacked" or self._stacked_len > 0

    def _merge_pending(self) -> None:
        """Stacked storage: fold buffered append()s and overwrite()s into the
        stacked arrays (one concatenate + one batched scatter)."""
        if not self._stacked_is_storage:
            return
        if self._params:
            new_w = jax.tree.map(lambda *xs: jnp.stack(xs), *self._params)
            new_g = jax.tree.map(lambda *xs: jnp.stack(xs), *self._grads)
            if self._stacked is None:
                self._stacked = (new_w, new_g)
            else:
                Ws, Gs = self._stacked
                self._stacked = (
                    jax.tree.map(lambda a, b: jnp.concatenate([a, b]), Ws, new_w),
                    jax.tree.map(lambda a, b: jnp.concatenate([a, b]), Gs, new_g),
                )
            self._stacked_len += len(self._params)
            self._params, self._grads = [], []
        if self._pending_over:
            ts = jnp.asarray(list(self._pending_over.keys()))
            vals = list(self._pending_over.values())
            up_w = jax.tree.map(lambda *xs: jnp.stack(xs), *[v[0] for v in vals])
            up_g = jax.tree.map(lambda *xs: jnp.stack(xs), *[v[1] for v in vals])
            Ws, Gs = self._stacked
            self._stacked = (
                jax.tree.map(lambda x, u: x.at[ts].set(u), Ws, up_w),
                jax.tree.map(lambda x, u: x.at[ts].set(u), Gs, up_g),
            )
            self._pending_over = {}

    def stacked_view(self):
        """(Ws, Gs) with every leaf stacked along a leading time axis.

        Free for the stacked tier; built once and cached for the others
        (invalidated by append/overwrite)."""
        if self._stacked_is_storage:
            self._merge_pending()
            if self._stacked is None:
                raise ValueError("stacked_view() on an empty history")
            return self._stacked
        if self._stacked is None:
            T = len(self)
            entries = [self.entry(t) for t in range(T)]
            Ws = jax.tree.map(lambda *xs: jnp.stack(xs), *[e[0] for e in entries])
            Gs = jax.tree.map(lambda *xs: jnp.stack(xs), *[e[1] for e in entries])
            if self.tier == "device" and not self._multi_device():
                # adopt as storage: keeping the per-entry arrays alongside
                # would double device memory for the whole path.  Skipped on
                # a mesh — the device tier's contract is entries sharded like
                # the live params, and jnp.stack'd copies would not be.
                self.set_stacked(Ws, Gs)
            else:
                self._stacked = (Ws, Gs)
        return self._stacked

    def _multi_device(self) -> bool:
        for tree in self._params[:1]:
            for leaf in jax.tree.leaves(tree):
                sharding = getattr(leaf, "sharding", None)
                if sharding is not None and len(getattr(
                        sharding, "device_set", ())) > 1:
                    return True
        return False

    def replace_from_stacked(self, Ws, Gs, final_params=None) -> None:
        """Bulk-rewrite the whole cache from edited stacked arrays (the online
        engine's end-of-request flush); pass `final_params` to finalize the
        post-request model in the same call."""
        if self.tier == "stacked" or (self.tier == "device"
                                      and not self._multi_device()):
            self._params, self._grads = [], []
            self._stacked = (Ws, Gs)
            self._stacked_len = jax.tree.leaves(Ws)[0].shape[0]
            self._pending_over = {}
        else:
            T = len(self)
            self._stacked = None
            for t in range(T):
                self.overwrite(t, jax.tree.map(lambda x: x[t], Ws),
                               jax.tree.map(lambda x: x[t], Gs))
            # do NOT cache (Ws, Gs) here: under a lossy codec the raw arrays
            # would diverge from what entry() decodes back; let stacked_view()
            # rebuild from the encoded entries so both read paths agree
        if final_params is not None:
            self.finalize(final_params)

    # -- read path ----------------------------------------------------------

    def _load_disk(self, t: int):
        with np.load(self._disk_paths[t]) as data:
            n_p = int(data["n_p"])
            arrays = [data[f"arr_{i}"] for i in range(2 * n_p)]
        p = jax.tree.unflatten(self._treedef, arrays[:n_p])
        g = jax.tree.unflatten(self._treedef, arrays[n_p:])
        return p, g

    def entry(self, t: int):
        """(w_t, g_t) decoded back to device arrays."""
        if self._stacked_is_storage:
            if t in self._pending_over:  # not yet scattered — serve directly
                return self._pending_over[t]
            if self._params:
                self._merge_pending()
            if self._stacked is None or not 0 <= t < self._stacked_len:
                raise IndexError(f"history entry {t} of {len(self)}")
            Ws, Gs = self._stacked
            return (jax.tree.map(lambda x: x[t], Ws),
                    jax.tree.map(lambda x: x[t], Gs))
        if self.tier == "device":
            return self._params[t], self._grads[t]
        if self.tier == "host":
            return self.codec.decode(self._params[t]), self.codec.decode(self._grads[t])
        p, g = self._load_disk(t)
        return self.codec.decode(p), self.codec.decode(g)

    def encoded_entry(self, t: int):
        """(w_t, g_t) in STORED form — no codec decode, no device upload.

        Offload tiers only: this is `core.store.SegmentStreamer`'s read
        path (it stacks a whole window of encoded entries, ships them in
        one copy, and decodes on device)."""
        assert self.tier in ("host", "disk"), self.tier
        if self.tier == "host":
            return self._params[t], self._grads[t]
        return self._load_disk(t)

    def params_at(self, t: int):
        return self.entry(t)[0]

    def grad_at(self, t: int):
        return self.entry(t)[1]

    # -- in-place rewrite (online deletion, Algorithm 3) --------------------

    def overwrite(self, t: int, params, grad) -> None:
        if self._stacked_is_storage:
            if self._params:
                self._merge_pending()  # appends first, to fix the length
            if self._stacked is None or not 0 <= t < self._stacked_len:
                raise IndexError(f"history entry {t} of {len(self)}")
            self._pending_over[t] = (params, grad)
            return
        self._stacked = None
        if self.tier == "device":
            self._params[t] = params
            self._grads[t] = grad
        elif self.tier == "host":
            self._params[t] = self.codec.encode(params)
            self._grads[t] = self.codec.encode(grad)
        else:
            enc_p = self.codec.encode(params)
            enc_g = self.codec.encode(grad)
            flat_p, _ = jax.tree.flatten(enc_p)
            flat_g, _ = jax.tree.flatten(enc_g)
            np.savez(self._disk_paths[t], n_p=len(flat_p), *flat_p, *flat_g)

    # -- checkpoint integration ---------------------------------------------

    def state_dict(self) -> Dict[str, Any]:
        state = {
            "meta": self.meta,
            "tier": self.tier,
            "codec": self.codec.name,
            "params": [jax.device_get(p) for p in self._params],
            "grads": [jax.device_get(g) for g in self._grads],
            "final_params": jax.device_get(self.final_params),
            "disk_paths": list(self._disk_paths),
        }
        if self._stacked_is_storage and self._stacked is not None:
            self._merge_pending()
            state["params"], state["grads"] = [], []
            state["stacked"] = jax.device_get(self._stacked)
        return state

    @classmethod
    def from_state_dict(cls, state: Dict[str, Any], spill_dir: Optional[str] = None):
        h = cls(state["meta"], tier=state["tier"], codec=state["codec"],
                spill_dir=spill_dir or "/tmp/repro_history")
        h._params = state["params"]
        h._grads = state["grads"]
        h._disk_paths = state["disk_paths"]
        h.final_params = state["final_params"]
        if state.get("stacked") is not None:
            Ws, Gs = state["stacked"]
            h.set_stacked(jax.tree.map(jnp.asarray, Ws),
                          jax.tree.map(jnp.asarray, Gs))
        return h

    def nbytes(self) -> int:
        total = 0
        trees = list(self._params) + list(self._grads)
        if self._stacked is not None and self._stacked_is_storage:
            trees += list(self._stacked)
        for tree in trees:
            if tree is None:
                continue
            for leaf in jax.tree.leaves(tree):
                total += np.asarray(leaf).nbytes
        return total
