# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.

# Importing core.algorithms registers the built-in unlearning algorithms
# (deltagrad, descent_to_delete, retrain_oracle) with the registry that
# `UnlearnerConfig.algorithm` selects from.
from repro.core.algorithms import (  # noqa: F401
    ALGORITHMS,
    Certificate,
    DescentToDeleteConfig,
    UnlearningAlgorithm,
    available_algorithms,
    get_algorithm,
    register,
)
