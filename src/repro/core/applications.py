"""Paper §5 applications built on the DeltaGrad engine.

§5.4 data valuation (leave-one-out influence), §5.5 jackknife bias
reduction, §5.6 cross-conformal prediction.  Each retrains with DeltaGrad
instead of from scratch — that is the paper's point: these procedures need
MANY retrainings on (n-1)- or (n-n/K)-sized subsets.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Sequence

import jax
import numpy as np

from repro.core.deltagrad import DeltaGradConfig, Objective, deltagrad_retrain
from repro.core.history import TrainingHistory
from repro.data.dataset import Dataset
from repro.utils.tree import tree_norm, tree_sub


def leave_one_out_models(
    objective: Objective,
    history: TrainingHistory,
    ds: Dataset,
    indices: Sequence[int],
    cfg: DeltaGradConfig,
) -> List[Any]:
    """w^{I}_{-i} for each i — the workhorse of §5.4/§5.5."""
    out = []
    for i in indices:
        params, _ = deltagrad_retrain(
            objective, history, ds, np.array([i]), cfg, mode="delete"
        )
        out.append(params)
    return out


def data_values(
    objective: Objective,
    history: TrainingHistory,
    ds: Dataset,
    indices: Sequence[int],
    cfg: DeltaGradConfig,
) -> np.ndarray:
    """Influence of each sample = ||w_{-i} - w*|| (Cook-style deletion
    diagnostics, §5.4)."""
    w_star = history.final_params
    vals = []
    for params in leave_one_out_models(objective, history, ds, indices, cfg):
        vals.append(float(tree_norm(tree_sub(params, w_star))))
    return np.asarray(vals)


def jackknife_bias_correct(
    estimator: Callable[[Any], np.ndarray],
    objective: Objective,
    history: TrainingHistory,
    ds: Dataset,
    cfg: DeltaGradConfig,
    indices: Sequence[int] = None,
) -> Dict[str, np.ndarray]:
    """Quenouille jackknife (§5.5): f_jack = f_n - (n-1)(mean_i f_{-i} - f_n).

    `estimator` maps model params to the statistic of interest.  `indices`
    defaults to all n leave-one-out fits (pass a subsample for speed).
    """
    n = ds.n_remaining
    if indices is None:
        indices = ds.remaining_indices
    f_n = np.asarray(estimator(history.final_params))
    f_loo = [
        np.asarray(estimator(p))
        for p in leave_one_out_models(objective, history, ds, indices, cfg)
    ]
    bias = (n - 1) * (np.mean(f_loo, axis=0) - f_n)
    return {"estimate": f_n, "bias": bias, "corrected": f_n - bias}


@dataclass
class ConformalSet:
    lower: np.ndarray
    upper: np.ndarray
    coverage_level: float


def cross_conformal(
    objective: Objective,
    history: TrainingHistory,
    ds: Dataset,
    predict_fn: Callable[[Any, np.ndarray], np.ndarray],
    x_test: np.ndarray,
    K: int = 5,
    alpha: float = 0.1,
    cfg: DeltaGradConfig = None,
    seed: int = 0,
) -> ConformalSet:
    """Vovk cross-conformal predictive intervals (§5.6).

    Splits the data into K folds; for each fold, DeltaGrad-deletes the fold
    and computes out-of-fold residuals; the interval at x is the alpha-
    calibrated union of f_{-S_k}(x) ± R_i.
    """
    cfg = cfg or DeltaGradConfig()
    rng = np.random.default_rng(seed)
    idx = rng.permutation(ds.n)
    folds = np.array_split(idx, K)
    all_centers, all_res = [], []
    for fold in folds:
        params, _ = deltagrad_retrain(objective, history, ds, fold, cfg, mode="delete")
        preds = predict_fn(params, ds.columns["x"][fold])
        res = np.abs(ds.columns["y"][fold].astype(np.float64) - preds)
        centers = predict_fn(params, x_test)
        all_centers.append(centers)
        all_res.extend(res.tolist())
    all_res = np.sort(np.asarray(all_res))
    q = all_res[min(len(all_res) - 1, int(np.ceil((1 - alpha) * (len(all_res) + 1))))]
    centers = np.stack(all_centers)  # (K, n_test)
    return ConformalSet(
        lower=centers.min(0) - q,
        upper=centers.max(0) + q,
        coverage_level=1 - 2 * alpha - 2 * K / ds.n,
    )
