"""Pluggable unlearning algorithms behind one certified-deletion engine.

The serving stack (`core.session.UnlearnerSession` and everything above
it — `core.api`, `launch/serve.py`, the benches) is algorithm-agnostic:
requests flow through the SAME submit/coalesce/flush/save/restore surface
no matter which algorithm answers them.  This module is the seam: the
`UnlearningAlgorithm` protocol, a registry, and three implementations —

  * ``deltagrad``          — the paper's Algorithm 3 engine
                             (`core.online.OnlineEngine`: L-BFGS-corrected
                             replay over the cached training path), with a
                             Laplace ε-certificate from the paper's δ0 bound
                             (§5.1 / App. B.1);
  * ``descent_to_delete``  — noisy projected fine-tuning from the last
                             checkpoint (Neel, Roth & Sharifi-Malvajerdi
                             2020): I full-batch gradient steps on the
                             post-deletion objective, Gaussian noise at
                             publication, with the (ε, δ) certificate from
                             the contraction bound ρ^I (||w−w*||+Δ);
  * ``retrain_oracle``     — exact retraining (BaseL, paper eq. (1)/(S6)):
                             the online engine with an ALL-EXPLICIT plan
                             computes exact current-objective gradients at
                             every replayed step, which IS full retraining
                             on the modified dataset under the original
                             schedule — served through the same engine so
                             mixed delete/add streams, coalesced groups,
                             and snapshots all work unchanged.  Its
                             certificate is exact (ε = 0, bound = 0).

Protocol (the session drives exactly this surface):

    algo = get_algorithm(name)(objective, dataset, config)
    algo.prepare(history, params, params0)     # after fit()/restore()
    stats = algo.apply(op, rows, coalesce=..)  # -> [RetrainStats]
    noised, cert = algo.publish(key)           # certified release
    algo.certificate()                         # -> Certificate (no noise)
    algo.state_dict() / algo.load_state(...)   # snapshot round-trip

Certificates are COMPARABLE across algorithms: every one reports the
mechanism, the certified deviation bound ``||w_alg − w_retrain||`` its
analysis guarantees, and the per-coordinate noise scale that ε (and δ)
buy at that bound.  All bounds assume the strongly-convex regularized
setting (PrivacyConfig.mu > 0); see `core.session` for the selection
guide and convexity caveats.
"""

from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deltagrad import Objective, RetrainStats
from repro.core.engine import _next_pow2
from repro.core.online import OnlineEngine
from repro.core.privacy import (PrivacyConfig, gaussian_publish,
                                gaussian_sigma, laplace_publish, num_params)
from repro.core.store import PlacementPolicy
from repro.data.dataset import Dataset
from repro.optim.optimizers import sgd
from repro.train.loop import make_finetune_runner

# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

ALGORITHMS: Dict[str, Type["UnlearningAlgorithm"]] = {}


def register(name: str):
    """Class decorator: `@register("name")` adds an algorithm to the
    registry (and stamps `cls.name`) so sessions can select it by string."""

    def deco(cls):
        cls.name = name
        ALGORITHMS[name] = cls
        return cls

    return deco


def get_algorithm(name: str) -> Type["UnlearningAlgorithm"]:
    try:
        return ALGORITHMS[name]
    except KeyError:
        raise ValueError(
            f"unknown unlearning algorithm {name!r}; registered: "
            f"{', '.join(available_algorithms())}") from None


def available_algorithms() -> List[str]:
    return sorted(ALGORITHMS)


# --------------------------------------------------------------------------
# Certificates
# --------------------------------------------------------------------------


@dataclass
class Certificate:
    """What a published model promises.

    bound is the certified L2 deviation ``||w_alg − w_retrain*||`` the
    algorithm's analysis guarantees against the exact-retraining optimum;
    noise_scale is the per-coordinate noise the mechanism adds so that the
    release is ε-(or (ε, δ)-)indistinguishable from publishing the
    retrained model through the same mechanism."""

    algorithm: str
    mechanism: str  # "laplace" | "gaussian" | "exact"
    eps: float
    delta: float
    bound: float
    noise_scale: float
    removals: int

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


# --------------------------------------------------------------------------
# Protocol
# --------------------------------------------------------------------------


class UnlearningAlgorithm:
    """Base class every registered algorithm implements.

    Construction is cheap (no compilation, no device work); `prepare()`
    binds the trained state after `fit()`/`restore()`.  `apply()` serves
    one planner group — the ONLY mutation path, so the session's
    bookkeeping and the algorithm's never diverge."""

    name = "base"

    def __init__(self, objective: Objective, dataset: Dataset, config):
        self.objective = objective
        self.ds = dataset
        self.config = config  # the owning UnlearnerConfig
        self.history = None
        self.params0 = None
        self._params = None
        self._compile_time_s = 0.0
        self._removals = 0

    @property
    def compile_time_s(self) -> float:
        return self._compile_time_s

    @compile_time_s.setter
    def compile_time_s(self, value: float) -> None:
        self._compile_time_s = float(value)

    # -- lifecycle ---------------------------------------------------------

    def prepare(self, history, params, params0) -> "UnlearningAlgorithm":
        """Bind the cached training run (history), the trained/current
        params, and the init params; idempotent."""
        self.history = history
        self._params = params
        self.params0 = params0
        self._prepared()
        return self

    def _prepared(self) -> None:  # optional hook
        pass

    @property
    def privacy(self) -> PrivacyConfig:
        p = getattr(self.config, "privacy", None)
        return p if p is not None else PrivacyConfig()

    # -- serving surface ---------------------------------------------------

    def apply(self, op: str, rows: Sequence[int],
              coalesce: bool = True) -> List[RetrainStats]:
        """Serve one planner group (`op` in {"delete", "add"}): one entry
        per replay — a single entry for a coalesced group, len(rows)
        entries for a serial group."""
        raise NotImplementedError

    @property
    def params(self):
        return self._params

    @property
    def added(self) -> List[int]:
        """Rows appended after the cached run that the algorithm has
        absorbed (the session validates add requests against this)."""
        return []

    @property
    def live(self) -> np.ndarray:
        """Liveness over the dataset's rows (drivers sample from it)."""
        return ~np.asarray(self.ds.removed, dtype=bool)

    def begin_plan(self, n_adds: int) -> None:
        """Called once per flush with the plan's TOTAL add count so the
        algorithm can size capacity before any group executes."""

    def warmup(self, specs=("delete",)) -> float:
        """Pre-compile the serving programs; returns compile seconds."""
        return self.compile_time_s

    # -- certified publication --------------------------------------------

    def certificate(self, eps: Optional[float] = None,
                    delta: Optional[float] = None) -> Certificate:
        raise NotImplementedError

    def publish(self, key: jax.Array, params: Any = None,
                eps: Optional[float] = None,
                delta: Optional[float] = None):
        """(noised_params, Certificate): release the current (or given)
        model through the algorithm's mechanism, randomness drawn ONLY
        from `key` (deterministic replays under the session PRNG key)."""
        params = self.params if params is None else params
        cert = self.certificate(eps=eps, delta=delta)
        if cert.mechanism == "laplace":
            out = laplace_publish(key, params, cert.eps, cert.bound)
        elif cert.mechanism == "gaussian":
            out = gaussian_publish(key, params, cert.noise_scale)
        else:  # exact — publishing the model itself is the guarantee
            out = params
        return out, cert

    # -- snapshot ----------------------------------------------------------

    @property
    def descriptor(self) -> Dict[str, Any]:
        return {"algorithm": self.name}

    def state_dict(self) -> Dict[str, Any]:
        return {"removals": int(self._removals)}

    def load_state(self, state: Dict[str, Any], params) -> None:
        self._removals = int(state.get("removals", 0))
        self._params = params


# --------------------------------------------------------------------------
# DeltaGrad (the paper's engine) and the exact-retraining oracle
# --------------------------------------------------------------------------


@register("deltagrad")
class DeltaGradAlgorithm(UnlearningAlgorithm):
    """Algorithm 3 replay with L-BFGS corrections — wraps the session's one
    `core.online.OnlineEngine` and preserves its exact call sequence
    (request_group for coalesced groups, per-row request otherwise), so
    replay results are identical to driving the engine directly."""

    def __init__(self, objective, dataset, config):
        super().__init__(objective, dataset, config)
        self._engine: Optional[OnlineEngine] = None

    def _engine_cfg(self):
        return self.config.deltagrad

    def engine(self, placement: Optional[PlacementPolicy] = None
               ) -> OnlineEngine:
        if self._engine is None:
            self._engine = OnlineEngine(
                self.objective, self.history, self.ds, self._engine_cfg(),
                placement=placement
                if placement is not None else self.config.placement)
        elif placement is not None:
            raise RuntimeError(
                "the session's engine already exists; placement must be "
                "chosen before the first request (pass it to the first "
                "engine() call or set config.placement)")
        return self._engine

    def apply(self, op, rows, coalesce=True):
        engine = self.engine()
        if coalesce and len(rows) > 1:
            stats = [engine.request_group(op, rows)]
        else:
            stats = [engine.request(op, r) for r in rows]
        if op == "delete":
            self._removals += len(rows)
        self._params = engine.params
        return stats

    @property
    def params(self):
        return self._engine.params if self._engine is not None \
            else self._params

    @property
    def added(self):
        return self._engine.added if self._engine is not None else []

    @property
    def live(self):
        if self._engine is not None:
            return self._engine.live
        return super().live

    def begin_plan(self, n_adds: int) -> None:
        engine = self.engine()
        engine.add_capacity = max(engine.add_capacity,
                                  len(engine.added) + n_adds)

    @property
    def compile_time_s(self) -> float:
        if self._engine is not None:
            return self._engine.compile_time_s
        return self._compile_time_s

    @compile_time_s.setter
    def compile_time_s(self, value: float) -> None:
        self._compile_time_s = float(value)

    def warmup(self, specs=("delete",)) -> float:
        engine = self.engine()
        if engine.impl == "scan":
            engine._warmup(tuple(specs))
        return self.compile_time_s

    def certificate(self, eps=None, delta=None) -> Certificate:
        pv = self.privacy
        eps = pv.eps if eps is None else float(eps)
        meta = self.history.meta
        r = self._removals
        if r == 0:
            bound = 0.0
        else:
            bound = pv.constants(lr=meta.lr_at(0), n=meta.n, r=r,
                                 l2=self.objective.l2).delta0()
        p = num_params(self.params)
        scale = float(np.sqrt(p)) * bound / eps
        # Laplace mechanism: pure ε-indistinguishability, δ = 0
        return Certificate(algorithm=self.name, mechanism="laplace",
                           eps=eps, delta=0.0, bound=bound,
                           noise_scale=scale, removals=r)

    def state_dict(self):
        state = super().state_dict()
        state["engine"] = (self._engine.state_dict()
                           if self._engine is not None else None)
        return state

    def load_state(self, state, params):
        super().load_state(state, params)
        if state.get("engine") is not None:
            engine = self.engine()
            engine.load_state(state["engine"])
            engine.params = params


@register("retrain_oracle")
class RetrainOracleAlgorithm(DeltaGradAlgorithm):
    """Exact retraining (BaseL) behind the serving surface.

    Uses the online engine with an ALL-EXPLICIT step plan (burn_in past the
    last step): every replayed step evaluates the exact gradient of the
    CURRENT (post-request) objective at the current iterate, which is
    precisely eq. (1)/(S6) retraining from w_0 under the original schedule
    — while inheriting the engine's mixed delete/add bookkeeping, group
    coalescing, path rewrite, and snapshot state for free.  No L-BFGS
    correction is ever consulted (there are no approx steps).

    Caveat: with momentum histories the replay reconstructs velocity from
    0 like every other path here — exactness is relative to the repo's
    BaseL semantics (plain SGD, the paper's optimizer, is exact-exact)."""

    def _engine_cfg(self):
        dg = self.config.deltagrad
        return dataclasses.replace(dg, burn_in=self.history.meta.steps + 1,
                                   period=1)

    def certificate(self, eps=None, delta=None) -> Certificate:
        # retraining IS the reference: zero deviation, nothing to hide
        eps = 0.0 if eps is None else float(eps)
        return Certificate(algorithm=self.name, mechanism="exact",
                           eps=0.0, delta=0.0, bound=0.0, noise_scale=0.0,
                           removals=self._removals)


# --------------------------------------------------------------------------
# Descent-to-delete (noisy projected fine-tuning)
# --------------------------------------------------------------------------


@dataclass
class DescentToDeleteConfig:
    """Knobs for the `descent_to_delete` algorithm (Neel et al. 2020).

    finetune_steps is I, the full-batch gradient steps per request group;
    lr=None resolves to 2/(mu+L), the contraction-optimal step size;
    project_radius adds the projected-GD step the analysis assumes (None
    disables — fine whenever iterates stay in the ball anyway)."""

    finetune_steps: int = 5
    lr: Optional[float] = None
    project_radius: Optional[float] = None


@register("descent_to_delete")
class DescentToDeleteAlgorithm(UnlearningAlgorithm):
    """Noisy projected fine-tuning from the last checkpoint.

    Each request group updates liveness, then runs I compiled full-batch
    gradient steps (`train.loop.make_finetune_runner` over
    `Objective.weighted_mean_loss` with the live-row weight vector) from
    the CURRENT params — warm-started, never from scratch.  Publication
    adds Gaussian noise calibrated to the certified deviation bound, which
    contracts geometrically per group:

        bound <- rho^I * (bound + 2 c2 |group| / (mu n_live)),
        rho = (kappa - 1) / (kappa + 1),  kappa = L / mu

    (strongly-convex contraction of gradient descent at lr = 2/(mu+L) plus
    the optimum's sensitivity to the group's rows).  Cost per group is
    I full-batch gradients — independent of the training length T, which
    is why it beats the retrain oracle's T-step replay on wall-clock."""

    def __init__(self, objective, dataset, config):
        super().__init__(objective, dataset, config)
        self._live: Optional[np.ndarray] = None
        self._added: List[int] = []
        self._bound = 0.0
        self._base_n = dataset.n
        self._row_cap = dataset.n
        self._runner = None

    # -- resolved hyperparameters -----------------------------------------

    @property
    def d2d(self) -> DescentToDeleteConfig:
        d = getattr(self.config, "descent", None)
        return d if d is not None else DescentToDeleteConfig()

    def _mu_L(self):
        pv = self.privacy
        mu = pv.resolve_mu(self.objective.l2)
        L = max(float(pv.L), mu)
        return mu, L

    def _lr(self) -> float:
        if self.d2d.lr is not None:
            return float(self.d2d.lr)
        mu, L = self._mu_L()
        return 2.0 / (mu + L)

    def _prepared(self):
        # the original/appended boundary is the CACHED RUN's n, not ds.n
        # at instantiation: submit() appends add payloads eagerly, and the
        # algorithm is created lazily at first flush — possibly after
        if self.history is not None:
            self._base_n = int(self.history.meta.n)
        if self._live is None:
            self._live = ~np.asarray(self.ds.removed, dtype=bool).copy()

    # -- serving -----------------------------------------------------------

    @property
    def added(self):
        return list(self._added)

    @property
    def live(self):
        self._prepared()
        return self._live

    def _grow_live(self):
        if len(self._live) < self.ds.n:
            grown = np.ones(self.ds.n, dtype=bool)
            grown[:len(self._live)] = self._live
            self._live = grown

    def _weights(self, cap: int) -> jax.Array:
        w = np.zeros(cap, dtype=np.float32)
        lv = self._live[:self._base_n]
        w[:self._base_n][lv] = 1.0
        for r in self._added:
            if self._live[r]:
                w[r] = 1.0
        return jnp.asarray(w)

    def _get_runner(self):
        if self._runner is None:
            loss = (lambda p, b:
                    self.objective.weighted_mean_loss(p, b[0], b[1]))
            self._runner = make_finetune_runner(
                loss, sgd(), self._lr(), int(self.d2d.finetune_steps),
                project_radius=self.d2d.project_radius)
        return self._runner

    def _cols(self):
        if self.ds.n > self._row_cap:
            self._row_cap = self._base_n + _next_pow2(self.ds.n
                                                      - self._base_n)
        return self.ds.device_columns(capacity=self._row_cap)

    def apply(self, op, rows, coalesce=True):
        self._prepared()
        self._grow_live()
        rows = [int(r) for r in rows]
        if op == "delete":
            for r in rows:
                assert self._live[r], f"row {r} already deleted"
                self._live[r] = False
                self.ds.removed[r] = True
            self._removals += len(rows)
        else:
            for r in rows:
                assert self._base_n <= r < self.ds.n, (
                    "add requests name rows appended after the cached run")
            self._added.extend(rows)
        n_live = int(self._live[:self._base_n].sum()
                     + sum(self._live[r] for r in self._added))
        I = int(self.d2d.finetune_steps)
        mu, L = self._mu_L()
        kappa = L / mu
        rho = ((kappa - 1.0) / (kappa + 1.0)) ** I
        sens = 2.0 * self.privacy.c2 * len(rows) / (mu * max(n_live, 1))
        self._bound = rho * (self._bound + sens)

        t0 = time.perf_counter()
        batch = (self._cols(), self._weights(self._row_cap))
        self._params, _losses = self._get_runner()(self._params, batch)
        stats = RetrainStats(
            explicit_steps=I,
            grad_examples=I * n_live,
            grad_examples_baseline=int(
                self.history.meta.steps
                * min(self.history.meta.batch_size, n_live)),
            wall_time_s=time.perf_counter() - t0,
        )
        stats.extra["finetune_bound"] = self._bound
        # one entry whether or not the group coalesced: the fine-tune IS
        # the group correction (serial replays would change nothing — the
        # objective after the last row lands is all that matters)
        return [stats]

    def begin_plan(self, n_adds: int) -> None:
        if n_adds:  # size the bucketed capacity before the first group
            self._row_cap = max(self._row_cap,
                                self._base_n
                                + _next_pow2(self.ds.n - self._base_n
                                             + n_adds))

    def warmup(self, specs=("delete",)) -> float:
        self._prepared()
        t0 = time.perf_counter()
        batch = (self._cols(), self._weights(self._row_cap))
        out, _ = self._get_runner()(self._params, batch)
        jax.block_until_ready(out)
        self.compile_time_s = time.perf_counter() - t0
        return self.compile_time_s

    # -- certification -----------------------------------------------------

    def certificate(self, eps=None, delta=None) -> Certificate:
        pv = self.privacy
        eps = pv.eps if eps is None else float(eps)
        delta = pv.delta if delta is None else float(delta)
        scale = gaussian_sigma(self._bound, eps, delta) if self._bound \
            else 0.0
        return Certificate(algorithm=self.name, mechanism="gaussian",
                           eps=eps, delta=delta, bound=self._bound,
                           noise_scale=scale, removals=self._removals)

    # -- snapshot ----------------------------------------------------------

    def state_dict(self):
        self._prepared()
        state = super().state_dict()
        state.update({
            "live": np.asarray(self._live, dtype=bool).copy(),
            "added": list(self._added),
            "bound": float(self._bound),
            "base_n": int(self._base_n),
            "row_cap": int(self._row_cap),
        })
        return state

    def load_state(self, state, params):
        super().load_state(state, params)
        self._live = np.asarray(state["live"], dtype=bool).copy()
        self._added = list(state["added"])
        self._bound = float(state["bound"])
        self._base_n = int(state["base_n"])
        self._row_cap = max(int(state["row_cap"]), self.ds.n)
