"""DeltaGrad — Algorithm 1 (batch deletion/addition, GD and SGD).

Reference: Wu, Dobriban, Davidson, "DeltaGrad: Rapid retraining of machine
learning models", ICML 2020.  Notation follows the paper:

  w_t    — cached original iterates            (TrainingHistory)
  g_t    — cached (mini-)batch mean gradients  (TrainingHistory)
  w^I_t  — DeltaGrad ("incrementally updated") iterates
  w^U_t  — exact retraining iterates ("BaseL", eq. (1)/(S6))

This module holds the OBJECTIVE abstraction and the public entry points;
the execution itself — vectorized schedule precomputation, scanned approx
segments, stacked-history reads, the Pallas fused update — lives in
`core.engine` (see its module docstring for the phase-by-phase mapping to
the paper's Algorithms 1/3).  `DeltaGradConfig(impl="python")` selects the
pre-refactor per-step loop, kept as the parity oracle.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Re-exported so existing imports (`from repro.core.deltagrad import ...`)
# keep working after the engine extraction.
from repro.core.engine import (  # noqa: F401
    DeltaGradConfig,
    RetrainStats,
    _approx_gradient,
    _approx_update,
    _momentum_apply,
    _next_pow2,
    _sgd_apply,
    _tree_zeros,
    run_baseline,
    run_replay,
    run_training,
)
from repro.core.history import HistoryMeta, TrainingHistory
from repro.data.dataset import Dataset


# --------------------------------------------------------------------------
# Objective
# --------------------------------------------------------------------------


@dataclass(eq=False)  # eq=False -> hashable by id, so jit caches persist
class Objective:
    """Per-example loss; the engine derives every gradient flavor from it.

    per_example_loss(params, batch_columns) -> (k,) losses, one per row.
    l2: coefficient of the (lambda/2)||w||^2 term included in every F_i
        (the paper's regularized objectives).
    """

    per_example_loss: Callable[[Any, Dict[str, jax.Array]], jax.Array]
    l2: float = 0.0

    def weighted_mean_loss(self, params, batch, weights):
        losses = self.per_example_loss(params, batch)
        denom = jnp.maximum(jnp.sum(weights), 1.0)
        data_term = jnp.sum(losses * weights) / denom
        if self.l2:
            sq = sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(params))
            return data_term + 0.5 * self.l2 * sq
        return data_term

    def make_grad_fn(self):
        """(params, batch, weights) -> mean gradient over weighted rows.

        Cached per Objective instance: repeated retraining calls (jackknife,
        conformal folds, online streams) must reuse compiled code."""
        if not hasattr(self, "_grad_fn"):
            self._grad_fn = jax.jit(jax.grad(self.weighted_mean_loss))
        return self._grad_fn

    def make_value_grad_fn(self):
        if not hasattr(self, "_vg_fn"):
            self._vg_fn = jax.jit(jax.value_and_grad(self.weighted_mean_loss))
        return self._vg_fn

    @classmethod
    def from_model(cls, model, *, remat: bool = False,
                   loss_chunk: Optional[int] = None, l2: float = 0.0,
                   attn_impl: Optional[str] = None) -> "Objective":
        """Build an Objective from a `models.registry.Model`.

        The model's ``loss_fn(params, batch) -> ()`` is a mean loss over a
        batch (masked token cross-entropy for LMs); the engine needs a
        per-EXAMPLE loss, so this vmaps the model loss over singleton
        slices of each batch column — row i's loss is exactly the model's
        mean loss on the batch ``{k: col[i:i+1]}``.  This replaces the
        hand-rolled inline vmap every LM caller used to write.

        remat / loss_chunk are forwarded to ``loss_fn`` (activation
        rematerialization and chunked cross-entropy — the memory knobs at
        real model scale).  ``attn_impl`` pins the attention
        implementation (`models.attention_config`) for every trace of
        this objective: ``"flash"`` routes the Pallas flash kernel onto
        the replay forward where shapes allow.
        """
        kw: Dict[str, Any] = {"remat": remat}
        if loss_chunk is not None:
            kw["loss_chunk"] = loss_chunk

        def per_example_loss(params, batch):
            from repro.models.attention_config import use_attention_impl

            def one(row):
                return model.loss_fn(
                    params, jax.tree.map(lambda c: c[None], row), **kw)

            with use_attention_impl(attn_impl):
                return jax.vmap(one)(batch)

        return cls(per_example_loss=per_example_loss, l2=l2)


# --------------------------------------------------------------------------
# Entry points (thin frontends over core.engine)
# --------------------------------------------------------------------------


def sgd_train_with_cache(
    objective: Objective,
    params0,
    ds: Dataset,
    meta: HistoryMeta,
    tier: str = "device",
    codec: str = "f32",
    spill_dir: Optional[str] = None,
    impl: str = "scan",
    window: int = 0,
    spill_window: Optional[int] = None,
) -> Tuple[Any, TrainingHistory]:
    """Train w_t by plain SGD (the paper's optimizer), caching (w_t, g_t)."""
    return run_training(objective, params0, ds, meta, tier=tier, codec=codec,
                        spill_dir=spill_dir, impl=impl, window=window,
                        spill_window=spill_window)


def baseline_retrain(
    objective: Objective,
    ds: Dataset,
    meta: HistoryMeta,
    params0,
    changed_idx: np.ndarray,
    mode: str = "delete",
    impl: str = "scan",
) -> Tuple[Any, RetrainStats]:
    """BaseL: exact retraining from scratch on the modified dataset,
    replaying the original schedule (paper eq. (1) / (S6))."""
    return run_baseline(objective, ds, meta, params0, changed_idx, mode=mode,
                        impl=impl)


def deltagrad_retrain(
    objective: Objective,
    history: TrainingHistory,
    ds: Dataset,
    changed_idx: np.ndarray,
    cfg: DeltaGradConfig,
    mode: str = "delete",
    params0=None,
    placement=None,
    store=None,
) -> Tuple[Any, RetrainStats]:
    """Algorithm 1 (GD + SGD unified; GD == SGD with batch_size >= n).

    `placement` (a `core.store.PlacementPolicy`) shards the replay across a
    mesh; `store` reuses a prebuilt `core.store.HistoryStore` (and its
    compiled-program cache) across calls."""
    return run_replay(objective, history, ds, changed_idx, cfg, mode=mode,
                      params0=params0, placement=placement, store=store)
