"""DeltaGrad — Algorithm 1 (batch deletion/addition, GD and SGD).

Reference: Wu, Dobriban, Davidson, "DeltaGrad: Rapid retraining of machine
learning models", ICML 2020.  Notation follows the paper:

  w_t    — cached original iterates            (TrainingHistory)
  g_t    — cached (mini-)batch mean gradients  (TrainingHistory)
  w^I_t  — DeltaGrad ("incrementally updated") iterates   (this module)
  w^U_t  — exact retraining iterates ("BaseL", eq. (1)/(S6))

Per retraining step t the engine replays the original minibatch B_t
(`data.sampler` is a pure function of (seed, step)) and either

  EXPLICIT  (t <= j0, or (t - j0) % T0 == 0, or Algorithm-4 guard fired):
      evaluate the full-batch gradient at w^I_t exactly, record the pair
      (dw, dg) = (w^I_t - w_t, g^I_t - g_t), step with the exact
      leave-r-out gradient;

  APPROX    (otherwise):
      g^I_t ~= g_t + B_t (w^I_t - w_t)   with B_t the L-BFGS quasi-Hessian,
      evaluate gradients only on the <= r removed (added) samples present in
      B_t, and apply the leave-r-out (add-r) update — paper eq. (2)/(S7):

        delete: w -= lr/(B-dB) * ( B * g^I_t - sum_{i in R cap B_t} grad F_i(w) )
        add:    w -= lr/(B+dA) * ( B * g^I_t + sum_{i in A_t}       grad F_i(w) )

All shapes are static under jit (padded batches + 0/1 weights), so the whole
retraining run uses two compiled programs regardless of how r varies.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.history import HistoryMeta, TrainingHistory
from repro.core.lbfgs import LbfgsBuffer, lbfgs_hvp_stacked_pytree
from repro.data.dataset import Dataset
from repro.data.sampler import addition_mask, batch_indices
from repro.utils.tree import tree_all_finite, tree_norm, tree_sub


# --------------------------------------------------------------------------
# Objective
# --------------------------------------------------------------------------


@dataclass(eq=False)  # eq=False -> hashable by id, so jit caches persist
class Objective:
    """Per-example loss; the engine derives every gradient flavor from it.

    per_example_loss(params, batch_columns) -> (k,) losses, one per row.
    l2: coefficient of the (lambda/2)||w||^2 term included in every F_i
        (the paper's regularized objectives).
    """

    per_example_loss: Callable[[Any, Dict[str, jax.Array]], jax.Array]
    l2: float = 0.0

    def weighted_mean_loss(self, params, batch, weights):
        losses = self.per_example_loss(params, batch)
        denom = jnp.maximum(jnp.sum(weights), 1.0)
        data_term = jnp.sum(losses * weights) / denom
        if self.l2:
            sq = sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(params))
            return data_term + 0.5 * self.l2 * sq
        return data_term

    def make_grad_fn(self):
        """(params, batch, weights) -> mean gradient over weighted rows.

        Cached per Objective instance: repeated retraining calls (jackknife,
        conformal folds, online streams) must reuse compiled code."""
        if not hasattr(self, "_grad_fn"):
            self._grad_fn = jax.jit(jax.grad(self.weighted_mean_loss))
        return self._grad_fn

    def make_value_grad_fn(self):
        if not hasattr(self, "_vg_fn"):
            self._vg_fn = jax.jit(jax.value_and_grad(self.weighted_mean_loss))
        return self._vg_fn


# --------------------------------------------------------------------------
# Config / stats
# --------------------------------------------------------------------------


@dataclass
class DeltaGradConfig:
    period: int = 5  # T0 — explicit gradient every T0 steps
    burn_in: int = 10  # j0 — initial explicit steps
    history_size: int = 2  # m — L-BFGS memory
    curvature_eps: float = 0.0  # pair admission threshold (Alg. 4 guard)
    guard: bool = False  # enable non-convex fallback checks
    guard_norm_clip: float = 1e4  # fallback if ||Bv|| > clip * ||v||
    removal_pad: int = 0  # 0 → auto (next pow2 of max per-batch overlap)

    def is_explicit(self, t: int) -> bool:
        if t <= self.burn_in:
            return True
        return (t - self.burn_in) % self.period == 0


@dataclass
class RetrainStats:
    explicit_steps: int = 0
    approx_steps: int = 0
    guard_fallbacks: int = 0
    skipped_steps: int = 0  # empty effective batch (paper: no update)
    pairs_rejected: int = 0
    grad_examples: int = 0  # per-example gradient evaluations (DeltaGrad)
    grad_examples_baseline: int = 0  # what BaseL would have paid
    wall_time_s: float = 0.0
    extra: Dict[str, Any] = field(default_factory=dict)

    @property
    def theoretical_speedup(self) -> float:
        return self.grad_examples_baseline / max(self.grad_examples, 1)


# --------------------------------------------------------------------------
# Original training with path caching
# --------------------------------------------------------------------------


def sgd_train_with_cache(
    objective: Objective,
    params0,
    ds: Dataset,
    meta: HistoryMeta,
    tier: str = "device",
    codec: str = "f32",
    spill_dir: Optional[str] = None,
) -> Tuple[Any, TrainingHistory]:
    """Train w_t by plain SGD (the paper's optimizer), caching (w_t, g_t)."""
    history = TrainingHistory(meta, tier=tier, codec=codec, spill_dir=spill_dir)
    grad_fn = objective.make_grad_fn()
    params = params0
    vel = _tree_zeros(params0) if meta.momentum else None
    ones = np.ones(min(meta.batch_size, meta.n), dtype=np.float32)
    for t in range(meta.steps):
        idx = batch_indices(meta.seed, t, meta.n, meta.batch_size)
        batch = ds.take(idx)
        g = grad_fn(params, batch, ones)
        history.append(params, g)
        if meta.momentum:
            params, vel = _momentum_apply(params, vel, g,
                                          jnp.float32(meta.lr_at(t)),
                                          jnp.float32(meta.momentum))
        else:
            params = _sgd_apply(params, g, jnp.float32(meta.lr_at(t)))
    history.finalize(params)
    return params, history


def baseline_retrain(
    objective: Objective,
    ds: Dataset,
    meta: HistoryMeta,
    params0,
    changed_idx: np.ndarray,
    mode: str = "delete",
) -> Tuple[Any, RetrainStats]:
    """BaseL: exact retraining from scratch on the modified dataset,
    replaying the original schedule (paper eq. (1) / (S6))."""
    assert mode in ("delete", "add")
    changed_idx = np.asarray(changed_idx, dtype=np.int64)
    changed_set = set(changed_idx.tolist())
    grad_fn = objective.make_grad_fn()
    params = params0
    vel = _tree_zeros(params0) if meta.momentum else None
    stats = RetrainStats()
    t0 = time.perf_counter()
    B = min(meta.batch_size, meta.n)
    n_add = len(changed_idx) if mode == "add" else 0
    pad_to = B + (n_add if mode == "add" else 0)
    for t in range(meta.steps):
        idx = batch_indices(meta.seed, t, meta.n, meta.batch_size)
        if mode == "delete":
            keep = ~np.isin(idx, changed_idx)
            eff = idx[keep]
        else:
            joins = addition_mask(meta.seed, t, meta.n, meta.batch_size, n_add)
            eff = np.concatenate([idx, changed_idx[joins]])
        if len(eff) == 0:
            stats.skipped_steps += 1
            continue
        batch, weights = ds.padded_batch(eff, pad_to)
        g = grad_fn(params, batch, weights)
        if meta.momentum:
            params, vel = _momentum_apply(params, vel, g,
                                          jnp.float32(meta.lr_at(t)),
                                          jnp.float32(meta.momentum))
        else:
            params = _sgd_apply(params, g, jnp.float32(meta.lr_at(t)))
        stats.grad_examples += len(eff)
    stats.wall_time_s = time.perf_counter() - t0
    stats.explicit_steps = meta.steps
    del changed_set
    return params, stats


# --------------------------------------------------------------------------
# DeltaGrad retraining
# --------------------------------------------------------------------------


def _next_pow2(x: int) -> int:
    return 1 << max(0, (x - 1)).bit_length()


# Module-level jits shared across all retraining calls (no per-call closures
# -> no recompiles; B/dB/clip are traced scalars, only `sign` is static).


@partial(jax.jit, static_argnames=("sign",))
def _approx_update(params, w_t, g_t, dWs, dGs, g_changed, lr, B, dB, clip,
                   sign: int):
    v = tree_sub(params, w_t)
    bv = lbfgs_hvp_stacked_pytree(dWs, dGs, v)
    denom = jnp.maximum(B - sign * dB, 1.0)

    def step(p, gt, b, gc):
        g_apx = gt + b  # approximates full-batch mean grad at params
        num = B * g_apx - sign * dB * gc
        return p - lr * num / denom

    new = jax.tree.map(step, params, g_t, bv, g_changed)
    bn = tree_norm(bv)
    vn = tree_norm(v)
    ok = jnp.logical_and(tree_all_finite(new), bn <= clip * vn)
    return new, ok


@jax.jit
def _sgd_apply(p, g, lr):
    return jax.tree.map(lambda a, b: a - lr * b, p, g)


@jax.jit
def _momentum_apply(p, vel, g, lr, mom):
    """Heavy-ball: vel <- mom*vel + g; p <- p - lr*vel. Returns (p, vel)."""
    vel = jax.tree.map(lambda v, b: mom * v + b, vel, g)
    return jax.tree.map(lambda a, v: a - lr * v, p, vel), vel


@partial(jax.jit, static_argnames=("sign",))
def _approx_gradient(params, w_t, g_t, dWs, dGs, g_changed, B, dB, clip,
                     sign: int):
    """The leave-r-out gradient ESTIMATE (paper eq. (2) numerator/denom),
    without applying it — used by the momentum extension."""
    v = tree_sub(params, w_t)
    bv = lbfgs_hvp_stacked_pytree(dWs, dGs, v)
    denom = jnp.maximum(B - sign * dB, 1.0)
    g_est = jax.tree.map(
        lambda gt, b, gc: (B * (gt + b) - sign * dB * gc) / denom,
        g_t, bv, g_changed)
    ok = jnp.logical_and(tree_all_finite(g_est),
                         tree_norm(bv) <= clip * tree_norm(v))
    return g_est, ok


@jax.jit
def _tree_zeros(p):
    return jax.tree.map(jnp.zeros_like, p)


def deltagrad_retrain(
    objective: Objective,
    history: TrainingHistory,
    ds: Dataset,
    changed_idx: np.ndarray,
    cfg: DeltaGradConfig,
    mode: str = "delete",
    params0=None,
) -> Tuple[Any, RetrainStats]:
    """Algorithm 1 (GD + SGD unified; GD == SGD with batch_size >= n)."""
    assert mode in ("delete", "add")
    meta = history.meta
    changed_idx = np.asarray(changed_idx, dtype=np.int64)
    r = len(changed_idx)
    n, B = meta.n, min(meta.batch_size, meta.n)
    grad_fn = objective.make_grad_fn()
    buffer = LbfgsBuffer(cfg.history_size, curvature_eps=cfg.curvature_eps)

    r_pad = cfg.removal_pad or _next_pow2(max(1, min(r, B)))
    n_add = r if mode == "add" else 0
    clip = jnp.float32(cfg.guard_norm_clip)
    mom = jnp.float32(meta.momentum) if meta.momentum else None

    params = params0 if params0 is not None else history.params_at(0)
    vel = _tree_zeros(params) if meta.momentum else None
    stats = RetrainStats()
    t0 = time.perf_counter()

    for t in range(meta.steps):
        idx = batch_indices(meta.seed, t, n, meta.batch_size)
        if mode == "delete":
            kept_idx, changed_in = ds.split_batch(idx, removed_set=changed_idx)
        else:
            joins = addition_mask(meta.seed, t, n, meta.batch_size, n_add)
            kept_idx, changed_in = idx, changed_idx[joins]
        dB = len(changed_in)
        k = len(kept_idx)
        lr = jnp.float32(meta.lr_at(t))
        stats.grad_examples_baseline += (k if mode == "delete" else k + dB)

        if mode == "delete" and k == 0:
            stats.skipped_steps += 1  # paper §3: B - dB_t == 0 → no update
            continue

        explicit = cfg.is_explicit(t)
        w_t, g_t = history.entry(t)

        if not explicit and len(buffer) == 0:
            explicit = True  # nothing to approximate with yet

        if not explicit:
            # ---- approx step: gradients only on the changed samples --------
            if dB > 0:
                cb, cw = ds.padded_batch(changed_in, r_pad)
                g_changed = grad_fn(params, cb, cw)
                stats.grad_examples += dB
            else:
                g_changed = _tree_zeros(params)
            dWs, dGs = buffer.stacked()
            sign = 1 if mode == "delete" else -1
            if mom is not None:
                g_est, ok = _approx_gradient(
                    params, w_t, g_t, dWs, dGs, g_changed,
                    jnp.float32(B), jnp.float32(dB), clip, sign)
                if cfg.guard and not bool(ok):
                    stats.guard_fallbacks += 1
                    explicit = True
                else:
                    params, vel = _momentum_apply(params, vel, g_est, lr, mom)
                    stats.approx_steps += 1
            else:
                new_params, ok = _approx_update(
                    params, w_t, g_t, dWs, dGs, g_changed, lr,
                    jnp.float32(B), jnp.float32(dB), clip, sign
                )
                if cfg.guard and not bool(ok):
                    stats.guard_fallbacks += 1
                    explicit = True  # fall through to the explicit branch
                else:
                    params = new_params
                    stats.approx_steps += 1

        if explicit:
            # ---- explicit step: full-batch gradient at w^I_t ---------------
            kb, kw = ds.padded_batch(kept_idx, B if mode == "delete" else B + n_add)
            g_kept = grad_fn(params, kb, kw)
            if dB > 0:
                cb, cw = ds.padded_batch(changed_in, r_pad)
                g_changed = grad_fn(params, cb, cw)
            else:
                g_changed = _tree_zeros(params)
            stats.grad_examples += k + dB

            if mode == "delete":
                # mean over the ORIGINAL batch (pair definition, §A.1.2)
                g_full = jax.tree.map(
                    lambda a, b: (k * a + dB * b) / float(B), g_kept, g_changed
                )
                g_step = g_kept  # mean over kept == leave-r-out update
            else:
                g_full = g_kept  # original batch == kept in add mode
                g_step = jax.tree.map(
                    lambda a, b: (B * a + dB * b) / float(B + dB), g_kept, g_changed
                )

            dw = tree_sub(params, w_t)
            dg = tree_sub(g_full, g_t)
            if not buffer.add(dw, dg):
                stats.pairs_rejected += 1
            if mom is not None:
                params, vel = _momentum_apply(params, vel, g_step, lr, mom)
            else:
                params = _sgd_apply(params, g_step, lr)
            stats.explicit_steps += 1

    stats.wall_time_s = time.perf_counter() - t0
    stats.extra["buffer_admitted"] = buffer.admitted
    stats.extra["buffer_rejected"] = buffer.rejected
    return params, stats
