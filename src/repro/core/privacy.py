"""ε-approximate deletion via the Laplace mechanism (paper §5.1, App. B.1).

DeltaGrad guarantees ``||w^{I*} - w^{U*}|| <= delta_0`` (Theorem 7 constants);
adding iid Laplace(delta/eps) noise per coordinate with ``delta >= sqrt(p) *
delta_0`` makes the released DeltaGrad model an ε-approximate deletion in the
sense of Definition 3 (the log-density ratio between noised-DeltaGrad and
noised-exact-retrain is bounded by eps).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass
class DeletionBoundConstants:
    """Problem constants entering the paper's delta_0 bound (App. B.1)."""

    mu: float  # strong convexity
    L: float  # smoothness
    c0: float  # Hessian Lipschitz constant
    c2: float  # per-sample gradient bound
    lr: float  # eta
    n: int
    r: int
    m: int = 2  # L-BFGS history
    c1: float = 0.2  # strong-independence constant (paper: ~0.2 on MNIST)

    def delta0(self) -> float:
        """Upper bound on ||w^{U*} - w^{I*}|| — paper §5.1 display equation."""
        n, r = float(self.n), float(self.r)
        M1 = 2.0 * self.c2 / self.mu
        e = (self.L * (self.L + 1.0)) / (self.mu * 1.0)  # K1~O(1) absorbed in c1
        A = self.c0 * math.sqrt(self.m) * ((1.0 + e) ** self.m - 1.0) / self.c1 + self.c0
        denom_c = 0.5 * self.mu - (r / (n - r)) * self.mu - self.c0 * M1 * r / (2.0 * n)
        if denom_c <= 0:
            raise ValueError(
                "r/n too large for the privacy bound (denominator <= 0); "
                "the epsilon-approximate-deletion guarantee needs r << n"
            )
        num = (M1 * r / (n - r)) * (A * M1 * (r / n) / (0.5 - r / n))
        return num / (self.lr * denom_c ** 2)


def num_params(params: Any) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def laplace_publish(key: jax.Array, params: Any, eps: float, delta0: float):
    """Add iid Laplace(delta/eps) noise per coordinate, delta = sqrt(p)*delta0."""
    p = num_params(params)
    scale = math.sqrt(p) * delta0 / eps
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(key, len(leaves))
    noised = [
        leaf + scale * jax.random.laplace(k, leaf.shape, dtype=jnp.float32)
        for leaf, k in zip(leaves, keys)
    ]
    return jax.tree.unflatten(treedef, noised)


def empirical_epsilon(w_i: Any, w_u: Any, eps: float, delta0: float, p: int) -> float:
    """Achieved log-density-ratio bound: eps * ||w_I - w_U||_1 / (sqrt(p)*delta0).

    <= eps whenever the theoretical bound holds; diagnostic for experiments.
    """
    l1 = 0.0
    for a, b in zip(jax.tree.leaves(w_i), jax.tree.leaves(w_u)):
        l1 += float(jnp.sum(jnp.abs(a - b)))
    return eps * l1 / (math.sqrt(p) * delta0)
