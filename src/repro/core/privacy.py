"""ε-approximate deletion via the Laplace mechanism (paper §5.1, App. B.1).

DeltaGrad guarantees ``||w^{I*} - w^{U*}|| <= delta_0`` (Theorem 7 constants);
adding iid Laplace(delta/eps) noise per coordinate with ``delta >= sqrt(p) *
delta_0`` makes the released DeltaGrad model an ε-approximate deletion in the
sense of Definition 3 (the log-density ratio between noised-DeltaGrad and
noised-exact-retrain is bounded by eps).

This module also carries the Gaussian mechanism used by the
descent-to-delete algorithm (Neel et al. 2020): there the deviation bound
is an L2 ball, so calibrated Gaussian noise gives (ε, δ)-indistinguishability
from the retrained-and-noised release.

Both publishers are ONE compiled tree-map (`jax.jit` keyed on the params
treedef/shapes), sample per-leaf from independent split keys, and preserve
every leaf's dtype exactly — the published model is a drop-in replacement
for the private one.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclass
class DeletionBoundConstants:
    """Problem constants entering the paper's delta_0 bound (App. B.1)."""

    mu: float  # strong convexity
    L: float  # smoothness
    c0: float  # Hessian Lipschitz constant
    c2: float  # per-sample gradient bound
    lr: float  # eta
    n: int
    r: int
    m: int = 2  # L-BFGS history
    c1: float = 0.2  # strong-independence constant (paper: ~0.2 on MNIST)

    def delta0(self) -> float:
        """Upper bound on ||w^{U*} - w^{I*}|| — paper §5.1 display equation."""
        n, r = float(self.n), float(self.r)
        M1 = 2.0 * self.c2 / self.mu
        e = (self.L * (self.L + 1.0)) / (self.mu * 1.0)  # K1~O(1) absorbed in c1
        A = self.c0 * math.sqrt(self.m) * ((1.0 + e) ** self.m - 1.0) / self.c1 + self.c0
        denom_c = 0.5 * self.mu - (r / (n - r)) * self.mu - self.c0 * M1 * r / (2.0 * n)
        if denom_c <= 0:
            raise ValueError(
                "r/n too large for the privacy bound (denominator <= 0); "
                "the epsilon-approximate-deletion guarantee needs r << n"
            )
        num = (M1 * r / (n - r)) * (A * M1 * (r / n) / (0.5 - r / n))
        return num / (self.lr * denom_c ** 2)


@dataclass
class PrivacyConfig:
    """Certified-deletion knobs shared by every registered algorithm.

    eps/delta are the published guarantee targets; mu/L/c0/c2/c1 are the
    objective's regularity constants (strong convexity, smoothness, Hessian
    Lipschitz, per-sample gradient bound, strong independence).  ``mu=None``
    resolves to the objective's l2 coefficient — the only convexity the
    regularized losses guarantee unconditionally.
    """

    eps: float = 1.0
    delta: float = 1e-5  # Gaussian-mechanism delta (Laplace uses delta=0)
    mu: Optional[float] = None
    L: float = 1.0
    c0: float = 1.0
    c2: float = 1.0
    c1: float = 0.2
    m: int = 2

    def resolve_mu(self, l2: float) -> float:
        mu = self.mu if self.mu is not None else l2
        if mu <= 0:
            raise ValueError(
                "privacy bounds need strong convexity: set PrivacyConfig.mu "
                "or use an l2-regularized objective")
        return float(mu)

    def constants(self, lr: float, n: int, r: int,
                  l2: float = 0.0) -> DeletionBoundConstants:
        return DeletionBoundConstants(
            mu=self.resolve_mu(l2), L=self.L, c0=self.c0, c2=self.c2,
            lr=float(lr), n=int(n), r=int(r), m=self.m, c1=self.c1)


def num_params(params: Any) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


@partial(jax.jit, static_argnames=("dist",))
def _noise_publish(key: jax.Array, params: Any, scale: jax.Array,
                   *, dist: str):
    """ONE compiled publisher: per-leaf independent keys, leaf-dtype noise.

    The additions happen in each leaf's own dtype so the published pytree's
    structure AND dtypes match the input exactly (an f64 head next to f32
    features stays f64)."""
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(key, len(leaves))
    sampler = jax.random.laplace if dist == "laplace" else jax.random.normal
    noised = [
        leaf + (scale.astype(leaf.dtype)
                * sampler(k, leaf.shape, dtype=leaf.dtype))
        for leaf, k in zip(leaves, keys)
    ]
    return jax.tree.unflatten(treedef, noised)


def laplace_publish(key: jax.Array, params: Any, eps: float, delta0: float):
    """Add iid Laplace(delta/eps) noise per coordinate, delta = sqrt(p)*delta0.

    jit-compatible and deterministic under `key`: the whole publication is
    one compiled tree-map (reused across calls with the same param shapes),
    and all randomness flows from the caller's key — no module-level state."""
    p = num_params(params)
    scale = jnp.float32(math.sqrt(p) * delta0 / eps)
    return _noise_publish(key, params, scale, dist="laplace")


def gaussian_sigma(bound: float, eps: float, delta: float) -> float:
    """Gaussian-mechanism noise scale for an L2 sensitivity `bound`:
    sigma = bound * sqrt(2 ln(1.25/delta)) / eps (Dwork & Roth Thm A.1)."""
    if not 0 < delta < 1:
        raise ValueError(f"gaussian mechanism needs 0 < delta < 1, got {delta}")
    return float(bound) * math.sqrt(2.0 * math.log(1.25 / delta)) / float(eps)


def gaussian_publish(key: jax.Array, params: Any, sigma: float):
    """Add iid N(0, sigma^2) noise per coordinate (descent-to-delete's
    publication step); same compiled one-tree-map/dtype-preserving contract
    as `laplace_publish`."""
    return _noise_publish(key, params, jnp.float32(sigma), dist="gaussian")


def empirical_epsilon(w_i: Any, w_u: Any, eps: float, delta0: float, p: int) -> float:
    """Achieved log-density-ratio bound: eps * ||w_I - w_U||_1 / (sqrt(p)*delta0).

    <= eps whenever the theoretical bound holds; diagnostic for experiments.
    """
    l1 = 0.0
    for a, b in zip(jax.tree.leaves(w_i), jax.tree.leaves(w_u)):
        l1 += float(jnp.sum(jnp.abs(a - b)))
    return eps * l1 / (math.sqrt(p) * delta0)
