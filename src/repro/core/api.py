"""Compatibility facade over `core.session.UnlearnerSession`.

The PRIMARY serving surface is the session + request-plan API
(`core/session.py`): typed `UnlearnRequest`s are `submit()`-ed to an
`UnlearnerSession` and come back as lazy `RequestHandle`s; a coalescing
planner merges bursts of same-op requests into one group replay; sessions
snapshot/restore through `train/checkpoint`.

    from repro.core.session import UnlearnerSession, UnlearnerConfig
    sess = UnlearnerSession(objective, params0, dataset, UnlearnerConfig())
    sess.fit()
    h = sess.delete([3, 17, 256])   # lazy handle; ONE coalesced replay
    h.result().stats                # force (flush + block)
    sess.stream_delete([5, 9])      # serial Algorithm-3 semantics
    sess.save(ckpt_dir)             # restorable mid-stream snapshot

`Unlearner` below keeps the pre-session method zoo alive as a THIN shim:
every call — batch `delete()`/`add()` AND the `stream_*` methods — routes
through the session's single `OnlineEngine`, which rewrites the cached
path after each replay.  That closes the old footgun where a batch
`delete()`/`add()` after a `stream_*` call silently reset the engine
(dropping liveness and added-row join state): interleaving batch and
stream requests is now well-defined, with no state loss in either
direction.

Migration from the pre-session `Unlearner`:

  * `unl.delete(idx)` / `unl.add(rows)`  →  `sess.delete(idx).result()` /
    `sess.add(data=rows).result()` — now ONE group replay that also
    rewrites the cached path (previously a batch replay that left the
    cache stale).  Each returns `UnlearnResponse` whose `.stats` is a list
    (one entry for the coalesced replay).
  * `unl.stream_delete/stream_add/stream`  →  `sess.stream_delete(...)` /
    `sess.stream_add(...)` / `sess.serve_stream(pairs)` — unchanged
    serial semantics, same `OnlineStats`.
  * `unl.params`  →  `sess.params` (forces pending requests, blocks) or
    `handle.params` for a specific request.
  * new: `sess.submit(...)` + `flush()` for explicit request plans,
    `sess.save(dir)` / `UnlearnerSession.restore(dir, objective)`.

Registry-name entry points (LM-scale surface):

  * hand-rolled `Objective(per_example_loss=...)` over a transformer
    loss  →  `Objective.from_model(model, remat=..., loss_chunk=...)` —
    builds the per-example vmap internally (bitwise-identical to the
    hand-rolled version) and threads the attention-impl switch
    (`attn_impl="flash"` / `"flash_interpret"`) through the trace.
  * `models.registry.build(cfg)` + manual session wiring  →
    `UnlearnerSession.from_config("internlm2-1.8b", data,
    reduced=dict(...), config=UnlearnerConfig(...))` — one call from a
    registry name (see `configs/registry.py` for names) to a fitted-ready
    session; the built `Model` hangs off `sess.model`.
  * `model.objective(remat=..., loss_chunk=...)` is the instance-method
    spelling of `Objective.from_model` for when you already hold a
    `Model`.
  * CLI: `launch/serve.py --model <name>` and
    `benchmarks/bench_lm.py --model <name>` resolve the same registry
    names (with `--quick`-style reductions applied on top).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

# Re-exports: the historical import site for these names.
from repro.core.deltagrad import (  # noqa: F401
    DeltaGradConfig,
    Objective,
    RetrainStats,
    baseline_retrain,
    deltagrad_retrain,
    sgd_train_with_cache,
)
from repro.core.online import OnlineEngine, OnlineStats  # noqa: F401
from repro.core.session import (  # noqa: F401
    RequestHandle,
    UnlearnerConfig,
    UnlearnerSession,
    UnlearnRequest,
    UnlearnResponse,
)
from repro.data.dataset import Dataset


class Unlearner:
    """Thin compatibility shim — every method delegates to one
    `UnlearnerSession` (see the module docstring for the mapping)."""

    def __init__(
        self,
        objective: Objective,
        params0,
        dataset: Dataset,
        config: UnlearnerConfig,
    ):
        self.session = UnlearnerSession(objective, params0, dataset, config)

    # -- session state passthrough ------------------------------------------

    @property
    def objective(self) -> Objective:
        return self.session.objective

    @property
    def dataset(self) -> Dataset:
        return self.session.dataset

    @property
    def config(self) -> UnlearnerConfig:
        return self.session.config

    @property
    def params0(self):
        return self.session.params0

    @property
    def history(self):
        return self.session.history

    @property
    def params(self):
        """Current model (forces pending session work, blocks)."""
        return self.session.params

    @property
    def log(self) -> List[Dict]:
        return self.session.log

    @property
    def _online(self) -> Optional[OnlineEngine]:
        """The session's engine (None until the first request) — batch and
        stream requests share it, so nothing here ever silently resets."""
        return self.session._engine

    # -- phase 1 -------------------------------------------------------------

    def fit(self):
        return self.session.fit()

    # -- phase 2: batch requests — ONE coalesced group replay each -----------

    def delete(self, indices) -> RetrainStats:
        import time

        t0 = time.perf_counter()
        resp = self.session.delete(list(indices)).result()
        stats = resp.stats[0]
        stats.wall_time_s = time.perf_counter() - t0
        return stats

    def add(self, rows: Dict[str, np.ndarray]) -> RetrainStats:
        import time

        t0 = time.perf_counter()
        resp = self.session.add(data=rows).result()
        stats = resp.stats[0]
        stats.wall_time_s = time.perf_counter() - t0
        return stats

    # -- phase 2': online request streams (serial Algorithm 3) ---------------

    def stream_delete(self, requests: Sequence[int]) -> OnlineStats:
        return self.session.stream_delete(list(requests))

    def stream_add(self, rows: Dict[str, np.ndarray]) -> OnlineStats:
        """Append `rows` and insert them one request at a time (Algorithm 3
        add-mode: each joins the replayed batches via the deterministic
        addition mask, rewriting history after every request)."""
        return self.session.stream_add(rows)

    def stream(self, requests: Sequence) -> OnlineStats:
        """Mixed online stream: `requests` are ("delete"|"add", row) pairs;
        add rows must already be appended (e.g. via `dataset.append`)."""
        for r in requests:
            if not isinstance(r, (tuple, list)):
                raise TypeError(
                    f"stream() takes (op, row) pairs, got {r!r}; use "
                    "stream_delete()/stream_add() for single-op streams")
        return self.session.serve_stream(
            [(op, int(row)) for op, row in requests])

    # -- reference: exact retraining (BaseL) ----------------------------------

    def baseline(self, indices, mode: str = "delete"):
        return self.session.baseline(indices, mode=mode)
