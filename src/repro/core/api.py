"""High-level Unlearner API: train once with caching, then serve an arbitrary
stream of delete/add requests — each answered by DeltaGrad at ~T0x less
gradient work than retraining from scratch.

    unl = Unlearner(objective, params0, dataset, UnlearnerConfig(...))
    unl.fit()
    unl.delete([3, 17, 256])        # batch deletion  (Algorithm 1)
    unl.add({"x": new_x, "y": new_y})
    unl.stream_delete([5, 9, ...])  # online requests (Algorithm 3)
    unl.stream_add({"x": ..., "y": ...})       # online additions
    unl.stream([("delete", 5), ("add", 1001)])  # mixed request stream
    unl.params                      # current model
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.deltagrad import (
    DeltaGradConfig,
    Objective,
    RetrainStats,
    baseline_retrain,
    deltagrad_retrain,
    sgd_train_with_cache,
)
from repro.core.history import HistoryMeta, TrainingHistory
from repro.core.online import OnlineEngine, OnlineStats
from repro.data.dataset import Dataset


@dataclass
class UnlearnerConfig:
    steps: int = 100
    batch_size: int = 1 << 30  # default: deterministic full-batch GD
    lr: float = 0.1
    lr_schedule: Optional[Sequence] = None  # overrides lr if given
    seed: int = 0
    deltagrad: DeltaGradConfig = field(default_factory=DeltaGradConfig)
    # None resolves to "stacked" (the engine's native tier, see core/engine),
    # or to "host" — the codec-honoring offload tier — when history_codec is
    # not "f32" (stacked storage is uncompressed by construction).  An
    # EXPLICIT "stacked" + lossy codec is rejected by TrainingHistory.
    history_tier: Optional[str] = None
    history_codec: str = "f32"
    spill_dir: Optional[str] = None


class Unlearner:
    def __init__(
        self,
        objective: Objective,
        params0: Any,
        dataset: Dataset,
        config: UnlearnerConfig,
    ):
        self.objective = objective
        self.params0 = params0
        self.dataset = dataset
        self.config = config
        self.history: Optional[TrainingHistory] = None
        self.params: Any = params0
        self.log: List[Dict] = []
        # ONE online engine per rewritten history: it owns the stream state
        # (liveness, added-row join columns) that must survive across
        # stream_delete/stream_add/stream calls; reset whenever the cache is
        # rebuilt (fit) or bulk-replayed without a rewrite (delete/add)
        self._online: Optional[OnlineEngine] = None

    # -- phase 1: training with path caching ---------------------------------

    def fit(self) -> Any:
        c = self.config
        tier = c.history_tier
        if tier is None:
            tier = "host" if c.history_codec != "f32" else "stacked"
        meta = HistoryMeta(
            n=self.dataset.n,
            batch_size=min(c.batch_size, self.dataset.n),
            seed=c.seed,
            steps=c.steps,
            lr_schedule=tuple(c.lr_schedule) if c.lr_schedule else ((0, c.lr),),
            l2=self.objective.l2,
        )
        self.params, self.history = sgd_train_with_cache(
            self.objective,
            self.params0,
            self.dataset,
            meta,
            tier=tier,
            codec=c.history_codec,
            spill_dir=c.spill_dir,
        )
        self._online = None
        return self.params

    def _require_fit(self):
        if self.history is None:
            raise RuntimeError("call fit() before delete/add")

    # -- phase 2: batch requests (Algorithm 1) --------------------------------

    def delete(self, indices) -> RetrainStats:
        self._require_fit()
        idx = np.asarray(list(indices), dtype=np.int64)
        self.params, stats = deltagrad_retrain(
            self.objective, self.history, self.dataset, idx,
            self.config.deltagrad, mode="delete",
        )
        self.dataset.delete(idx)
        self._online = None  # batch replay does not rewrite the cache
        self.log.append({"op": "delete", "idx": idx, "stats": stats})
        return stats

    def add(self, rows: Dict[str, np.ndarray]) -> RetrainStats:
        self._require_fit()
        new_idx = self.dataset.append(rows)
        self.params, stats = deltagrad_retrain(
            self.objective, self.history, self.dataset, new_idx,
            self.config.deltagrad, mode="add",
        )
        self._online = None  # batch replay does not rewrite the cache
        self.log.append({"op": "add", "idx": new_idx, "stats": stats})
        return stats

    # -- phase 2': online request streams (Algorithm 3) -----------------------

    def _online_engine(self) -> OnlineEngine:
        if self._online is None:
            self._online = OnlineEngine(
                self.objective, self.history, self.dataset,
                self.config.deltagrad)
        return self._online

    def _serve_stream(self, requests, mode: Optional[str]) -> OnlineStats:
        import time

        import jax

        engine = self._online_engine()
        for r in requests:
            if mode is None and not isinstance(r, (tuple, list)):
                raise TypeError(
                    f"stream() takes (op, row) pairs, got {r!r}; use "
                    "stream_delete()/stream_add() for single-op streams")
        ops = [(r if isinstance(r, (tuple, list)) else (mode, r))
               for r in requests]
        # size the add-column block once for the whole stream so the padded
        # schedule width (and every compiled shape) stays put
        n_adds = sum(1 for op, _ in ops if op == "add")
        engine.add_capacity = max(engine.add_capacity,
                                  len(engine.added) + n_adds)
        stats = OnlineStats(compile_time_s=engine.compile_time_s)
        t0 = time.perf_counter()
        for op, row in ops:
            stats.per_request.append(engine.request(op, int(row)))
        # steady-state scan requests enqueue device work without syncing;
        # block so wall_time_s measures compute, not dispatch
        jax.block_until_ready(engine.params)
        stats.wall_time_s = time.perf_counter() - t0
        self.params = engine.params
        return stats

    def stream_delete(self, requests: Sequence[int]) -> OnlineStats:
        self._require_fit()
        stats = self._serve_stream(list(requests), "delete")
        self.log.append({"op": "stream_delete", "idx": list(requests), "stats": stats})
        return stats

    def stream_add(self, rows: Dict[str, np.ndarray]) -> OnlineStats:
        """Append `rows` and insert them one request at a time (Algorithm 3
        add-mode: each joins the replayed batches via the deterministic
        addition mask, rewriting history after every request)."""
        self._require_fit()
        new_idx = self.dataset.append(rows)
        stats = self._serve_stream(new_idx.tolist(), "add")
        self.log.append({"op": "stream_add", "idx": new_idx, "stats": stats})
        return stats

    def stream(self, requests: Sequence) -> OnlineStats:
        """Mixed online stream: `requests` are ("delete"|"add", row) pairs;
        add rows must already be appended (e.g. via `dataset.append`)."""
        self._require_fit()
        stats = self._serve_stream(list(requests), None)
        self.log.append({"op": "stream", "idx": list(requests), "stats": stats})
        return stats

    # -- reference: exact retraining (BaseL) ----------------------------------

    def baseline(self, indices, mode: str = "delete"):
        self._require_fit()
        idx = np.asarray(list(indices), dtype=np.int64)
        return baseline_retrain(
            self.objective, self.dataset, self.history.meta, self.params0, idx, mode
        )
