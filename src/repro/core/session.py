"""UnlearnerSession — the request-plan serving surface for DeltaGrad.

The paper's headline use case is answering *streams* of deletion/addition
requests far cheaper than retraining; follow-up work (Descent-to-Delete,
Neel et al. 2020; Mahadevan & Mathioudakis 2021) frames unlearning
explicitly as an online service.  This module is that service's API:

    sess = UnlearnerSession(objective, params0, dataset, UnlearnerConfig())
    sess.fit()                              # train once, caching the path
    h = sess.delete([3, 17, 256])           # returns a lazy RequestHandle
    sess.add(data={"x": new_x, "y": new_y})
    h.result().stats                        # force: flush + block
    sess.save("ckpt/"); UnlearnerSession.restore("ckpt/", objective)

Design:

  * REQUEST PLAN.  `submit()` enqueues typed `UnlearnRequest`s and returns
    lightweight `RequestHandle`s that resolve lazily — nothing executes
    (and nothing host-syncs) until a handle is forced via `.result()` /
    `.params`, or `flush()` runs.  Batch and stream semantics are unified:
    every request — bursty or one-at-a-time — is served by the session's
    ONE `core.online.OnlineEngine`, which rewrites the cached path after
    each replay, so interleaving "batch" deletes with "online" streams is
    well-defined instead of silently discarding engine state (the
    pre-session `Unlearner` footgun).

  * COALESCING PLANNER.  At flush, maximal runs of adjacent same-op
    requests with ``coalesce=True`` merge into ONE engine replay using the
    paper's group-deletion semantics (Algorithm 1 with an index set,
    applied to the current rewritten path): K pending deletes cost one
    T-step replay instead of K.  Serving-semantics contract: the coalesced
    result is the GROUP correction for the K rows — it approximates the
    same leave-K-out model as K sequential Algorithm-3 single-request
    corrections, but is not bitwise the serial composition (both land
    within the method's approximation error of exact retraining; the
    serial path remains available via ``coalesce=False`` and the
    ``stream_*`` helpers, and scan-vs-python parity holds for either).
    Changed-row blocks pad to the next pow2 of the burst size, so burst
    sizes bucket into O(log) distinct compiled shapes.

  * BUCKETED ADD CAPACITY.  The engine uploads device columns at a
    pow2-bucketed row capacity (`Dataset.device_columns(capacity=...)`),
    so an addition stream that outgrows the staged pool re-traces O(log
    #adds) times instead of once per appended row.

  * SNAPSHOT/RESTORE.  `save()` writes params through `train/checkpoint`
    (sharded .npz + atomic manifest) with the `TrainingHistory` state (all
    tiers), dataset columns + deletion mask, the ALGORITHM DESCRIPTOR
    (name + algorithm state, e.g. the engine's liveness/added-row
    order/capacities/L-BFGS ring, or descent-to-delete's contraction
    bound) and the session PRNG key in the checkpoint's extra payload.
    `restore()` rebuilds a session that serves the next request — and the
    next certified `publish()` — with results identical to the
    uninterrupted one.  Objectives hold code, not state, so the caller
    passes the objective to `restore()`.

ALGORITHM SELECTION (``UnlearnerConfig.algorithm``) — every entry in
`core.algorithms`'s registry serves through this same session surface:

  * ``"deltagrad"`` (default) — the paper's Algorithm 3: L-BFGS-corrected
    replay of the cached path.  Per-request cost ~ the explicit steps'
    gradients only; answers track exact retraining to within the paper's
    approximation error.  Choose it when requests trickle in and the
    cached path is warm — it is the low-latency path this repo exists
    for.  Certificate: Laplace ε-approximate deletion from the §5.1 δ0
    bound (δ = 0); the bound needs r ≪ n and strong convexity, and
    `certificate()` raises once cumulative removals push δ0's
    denominator negative.
  * ``"descent_to_delete"`` — noisy projected fine-tuning (Neel et al.
    2020): I full-batch steps from the current params per request group,
    Gaussian noise at publication.  Cost is independent of the training
    length T, so it wins on wall-clock whenever T-step replay (or
    retraining) is the alternative and a weaker, (ε, δ)-style guarantee
    with contraction bound ρ^I(bound + Δ) suffices.  Needs strong
    convexity for the contraction (κ = L/μ finite).
  * ``"retrain_oracle"`` — exact retraining served through the same
    engine (all-explicit plan).  The reference everything else is
    certified against: ε = 0, bound = 0, publish is the identity.  Use
    it for ground truth, audits, and small problems where exactness is
    cheap.

  Certificate semantics: `certificate()` reports (mechanism, ε, δ,
  bound, noise_scale) where `bound` certifies ``||w_alg − w_retrain*||``
  under the algorithm's analysis; `publish()` draws the calibrated noise
  deterministically from the session-held PRNG key (split per call, so a
  restored session publishes bitwise-identically).  ALL bounds assume
  the strongly-convex regularized setting — for non-convex objectives
  the numbers are diagnostics, not guarantees (the paper's guard only
  protects the replay's stability, not the certificate).

SERVING TIER.  For multi-caller traffic — per-tenant admission control,
SLA-class deadlines instead of the single ``max_pending``/``max_delay_s``
pair, cross-tenant batching, and seeded load generation — put
`repro.serve.ServingScheduler` in front of the session (the serving guide
lives in ``repro/serve/__init__.py``).  The session-level auto-flush
policy remains for single-caller use; `AutoFlushTimer` is deprecated in
favor of `repro.serve.SessionFlushClock`.

`core.api.Unlearner` is a thin compatibility shim over this class.
"""

from __future__ import annotations

import threading
import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.algorithms import (Certificate, DescentToDeleteConfig,
                                   UnlearningAlgorithm, get_algorithm)
from repro.core.deltagrad import (DeltaGradConfig, Objective, RetrainStats,
                                  baseline_retrain, sgd_train_with_cache)
from repro.core.history import HistoryMeta, TrainingHistory
from repro.core.online import OnlineEngine, OnlineStats
from repro.core.privacy import PrivacyConfig
from repro.core.store import PlacementPolicy
from repro.data.dataset import Dataset
from repro.train import checkpoint as ckpt


@dataclass
class UnlearnerConfig:
    steps: int = 100
    batch_size: int = 1 << 30  # default: deterministic full-batch GD
    lr: float = 0.1
    lr_schedule: Optional[Sequence] = None  # overrides lr if given
    seed: int = 0
    momentum: float = 0.0  # heavy-ball (beyond-paper; see HistoryMeta)
    deltagrad: DeltaGradConfig = field(default_factory=DeltaGradConfig)
    # None resolves to "stacked" (the engine's native tier, see core/engine),
    # or to "host" — the codec-honoring offload tier — when history_codec is
    # not "f32" (stacked storage is uncompressed by construction).  An
    # EXPLICIT "stacked" + lossy codec is rejected by TrainingHistory.
    # host/disk tiers are served to the compiled scan by
    # core.store.SegmentStreamer (device holds ~2 windows, never the path).
    history_tier: Optional[str] = None
    history_codec: str = "f32"
    spill_dir: Optional[str] = None
    # mesh placement for the cached path + replay (core.store.PlacementPolicy
    # — plain data, so save()/restore() round-trips it and the restoring
    # host rebuilds the mesh lazily); None = single-device
    placement: Optional["PlacementPolicy"] = None
    # auto-flush policy: bound how long a submitted request can sit pending
    # under continuous load.  max_pending: flush when that many requests are
    # queued (the coalescing planner then serves them as one burst);
    # max_delay_s: flush when the OLDEST pending request has waited this
    # long (checked at submit and via session.poll()).  None disables.
    max_pending: Optional[int] = None
    max_delay_s: Optional[float] = None
    # which registered unlearning algorithm serves requests — see the
    # module docstring's selection guide and core/algorithms.py
    algorithm: str = "deltagrad"
    # certified-deletion constants (ε/δ targets + regularity constants);
    # None resolves to PrivacyConfig() defaults at certificate time
    privacy: Optional[PrivacyConfig] = None
    # descent-to-delete knobs (finetune steps, lr, projection radius)
    descent: Optional[DescentToDeleteConfig] = None


@dataclass
class UnlearnRequest:
    """One typed unlearning request.

    op:       "delete" | "add".
    rows:     row ids — original or previously-added rows for delete;
              already-appended rows for add (filled in automatically when
              `data` is given).
    data:     add payload (dict of columns); appended to the dataset at
              submit time so later requests can reference the new rows.
    coalesce: True → the planner may merge this request with adjacent
              same-op requests into ONE group replay; False → serve each
              row as its own Algorithm-3 replay (paper-exact
              single-request semantics), never merged.
    """

    op: str
    rows: Optional[Sequence[int]] = None
    data: Optional[Dict[str, np.ndarray]] = None
    coalesce: bool = True


@dataclass
class UnlearnResponse:
    """Resolved outcome of one request.

    stats holds one `RetrainStats` per replay that served the request — a
    single entry when the request was coalesced into (or was itself) one
    group replay, len(rows) entries for a serial (`coalesce=False`)
    request.  `group_size` is the total number of rows the replay(s)
    coalesced (> len(request.rows) when neighbors merged in).
    `dispatch_s` is host dispatch time for the whole group; `params` is
    the post-request model (a device value — NOT host-synced; forcing a
    handle blocks on it).

    MIGRATION NOTE — ``stats.extra``: the untyped per-replay dict
    (``impl``, ``store``, ``windows``, ``hbm_high_water``, ...) remains
    for backward compatibility, but it is no longer the primary
    observability surface.  The engine, store, queue, and monitor now
    publish typed counters/gauges/histograms into the
    `repro.obs.metrics` registry (``get_registry().snapshot()``, JSONL
    and Prometheus exporters) and emit `repro.obs.trace` spans with
    roofline predicted-vs-measured cost — new consumers should read
    those (the full name contract is the table in ``repro/obs``)
    instead of string-keying into ``extra``."""

    request: UnlearnRequest
    stats: List[RetrainStats]
    group_size: int
    dispatch_s: float
    params: Any = None


class AutoFlushTimer:
    """DEPRECATED shim — the global auto-flush timer is superseded by the
    serving tier (`repro.serve`): `ServingScheduler` for per-SLA-class
    deadlines, or `SessionFlushClock` for the degenerate one-class case
    this timer implemented.  Constructing it warns and returns a
    `SessionFlushClock` (same ``ticks``/``last_error``/``interval_s``/
    ``stop()`` surface), so existing callers keep working."""

    def __new__(cls, session: "UnlearnerSession",
                interval_s: Optional[float] = None):
        warnings.warn(
            "core.session.AutoFlushTimer is deprecated; use "
            "repro.serve.SessionFlushClock (one default SLA class) or "
            "repro.serve.ServingScheduler (per-class deadlines)",
            DeprecationWarning, stacklevel=2)
        from repro.serve.scheduler import SessionFlushClock
        return SessionFlushClock(session, interval_s=interval_s)


class RequestHandle:
    """Lazy handle returned by `UnlearnerSession.submit`.

    Holding a handle costs nothing: the request executes when the session
    flushes (explicitly, or because some handle was forced).  `.result()`
    forces the flush and blocks until this request's post-request params
    are on host — the only sync point in the serving path."""

    def __init__(self, session: "UnlearnerSession", ticket: int,
                 request: UnlearnRequest):
        self._session = session
        self._ticket = ticket
        self.request = request

    @property
    def done(self) -> bool:
        """True once the request has been served (it may still be
        executing asynchronously on the device)."""
        return self._ticket in self._session._responses

    def result(self, block: bool = True) -> UnlearnResponse:
        resp = self._session._resolve(self._ticket)
        if block:
            jax.block_until_ready(resp.params)
        return resp

    @property
    def params(self):
        """Post-request model (forces resolution, blocks)."""
        return self.result().params

    @property
    def stats(self) -> List[RetrainStats]:
        return self.result(block=False).stats


def plan_requests(pending: List[Tuple[int, UnlearnRequest]]
                  ) -> List[List[Tuple[int, UnlearnRequest]]]:
    """The coalescing planner: partition pending requests, in submission
    order, into serving groups.  Maximal runs of adjacent same-op requests
    with ``coalesce=True`` merge into one group (one engine replay);
    ``coalesce=False`` requests form singleton groups and break runs, so
    an explicitly-serial request is never reordered past a burst."""
    groups: List[List[Tuple[int, UnlearnRequest]]] = []
    for ticket, req in pending:
        if (groups and req.coalesce
                and groups[-1][0][1].coalesce
                and groups[-1][0][1].op == req.op):
            groups[-1].append((ticket, req))
        else:
            groups.append([(ticket, req)])
    return groups


class UnlearnerSession:
    """Request-plan serving session over one cached training run."""

    def __init__(
        self,
        objective: Objective,
        params0: Any,
        dataset: Dataset,
        config: UnlearnerConfig,
    ):
        self.objective = objective
        self.params0 = params0
        self.dataset = dataset
        self.config = config
        self.history: Optional[TrainingHistory] = None
        self.log: List[Dict] = []
        self._trained_params: Any = params0
        self._algorithm: Optional[UnlearningAlgorithm] = None
        self._prng_key: Optional[jax.Array] = None
        self._pending: List[Tuple[int, UnlearnRequest]] = []
        self._responses: Dict[int, UnlearnResponse] = {}
        self._failed: Dict[int, Exception] = {}
        self._tickets = 0
        # responses pin their post-request params (a device pytree) so
        # handles can be forced later; bound how many stay live — beyond
        # this, the oldest resolve to a clear "evicted" error instead of
        # leaking device memory on fire-and-forget submitters
        self.max_responses = 256
        # auto-flush bookkeeping (config.max_pending / max_delay_s); the
        # lock serializes submit/flush/poll so an `AutoFlushTimer` thread
        # can drive the deadline next to a submitting foreground thread
        self._lock = threading.RLock()
        self._oldest_pending_ts: Optional[float] = None
        self._autoflush_timer: Optional[Any] = None  # SessionFlushClock
        self.autoflush_count = 0
        self.autoflush_reasons: Dict[str, int] = {"max_pending": 0,
                                                  "max_delay_s": 0}
        # set by from_config(): the registry Model backing this session's
        # objective (None when the objective was hand-built)
        self.model: Optional[Any] = None

    @classmethod
    def from_config(
        cls,
        name: str,
        dataset: Dataset,
        *,
        reduced: Optional[Dict[str, Any]] = None,
        config: Optional[UnlearnerConfig] = None,
        l2: float = 0.0,
        remat: bool = False,
        loss_chunk: Optional[int] = None,
        attn_impl: Optional[str] = None,
        init_seed: int = 1,
    ) -> "UnlearnerSession":
        """Build a session from a registry model name.

        ``name`` is a `configs.registry` key (e.g. ``"internlm2-1.8b"``);
        ``reduced`` — if given — is a dict of `ModelConfig.reduced`
        overrides producing a CI-sized variant of the same architecture.
        The model's loss becomes the session objective via
        `Objective.from_model` (remat / loss_chunk / attn_impl are
        forwarded), initial params come from ``model.init(init_seed)``,
        and the built `models.registry.Model` is kept on ``session.model``
        for scoring/decoding next to the unlearning surface.
        """
        from repro.configs.registry import get_config
        from repro.models.registry import build

        model_cfg = get_config(name)
        if reduced is not None:
            model_cfg = model_cfg.reduced(**reduced)
        model = build(model_cfg)
        objective = Objective.from_model(
            model, remat=remat, loss_chunk=loss_chunk, l2=l2,
            attn_impl=attn_impl)
        sess = cls(objective, model.init(init_seed), dataset,
                   config or UnlearnerConfig())
        sess.model = model
        return sess

    # -- phase 1: training with path caching --------------------------------

    def fit(self) -> Any:
        if self._pending:
            raise RuntimeError(
                "flush() or resolve pending requests before refitting")
        c = self.config
        tier = c.history_tier
        if tier is None:
            tier = "host" if c.history_codec != "f32" else "stacked"
        meta = HistoryMeta(
            n=self.dataset.n,
            batch_size=min(c.batch_size, self.dataset.n),
            seed=c.seed,
            steps=c.steps,
            lr_schedule=tuple(c.lr_schedule) if c.lr_schedule else ((0, c.lr),),
            l2=self.objective.l2,
            momentum=c.momentum,
        )
        self._trained_params, self.history = sgd_train_with_cache(
            self.objective,
            self.params0,
            self.dataset,
            meta,
            tier=tier,
            codec=c.history_codec,
            spill_dir=c.spill_dir,
            window=c.deltagrad.stream_window,
        )
        self._algorithm = None
        return self._trained_params

    def _require_fit(self):
        if self.history is None:
            raise RuntimeError("call fit() (or restore()) before serving")

    # -- algorithm / engine / current model ---------------------------------

    @property
    def algorithm(self) -> UnlearningAlgorithm:
        """The session's ONE serving algorithm (created lazily from
        ``config.algorithm`` via the `core.algorithms` registry, bound to
        the cached run by `prepare()`)."""
        self._require_fit()
        if self._algorithm is None:
            cls = get_algorithm(self.config.algorithm)
            self._algorithm = cls(self.objective, self.dataset, self.config)
            self._algorithm.prepare(self.history, self._trained_params,
                                    self.params0)
        return self._algorithm

    @property
    def _engine(self) -> Optional[OnlineEngine]:
        """The algorithm's online engine, when it has one (deltagrad /
        retrain_oracle); None before the first request and for
        engine-less algorithms.  Kept as a property because drivers and
        tests reach for the engine's liveness/added state directly."""
        if self._algorithm is None:
            return None
        return getattr(self._algorithm, "_engine", None)

    def engine(self, placement: Optional[PlacementPolicy] = None
               ) -> OnlineEngine:
        """The session's online engine (created lazily; owns liveness,
        added-row join columns, and the rewritten cached path — served
        through a `core.store.HistoryStore`).  Only engine-backed
        algorithms (deltagrad, retrain_oracle) have one.

        `placement` overrides ``config.placement`` for the engine's store
        on FIRST creation (mesh-sharded resident replay); after that the
        engine — and its placement — is fixed for the session's life."""
        algo = self.algorithm
        if not hasattr(algo, "engine"):
            raise RuntimeError(
                f"algorithm {algo.name!r} does not serve through an "
                "OnlineEngine; use session.algorithm directly")
        return algo.engine(placement=placement)

    def warmup(self, specs=("delete",)) -> float:
        """Pre-compile the request programs; `specs` entries are op names
        or ``(op, group_size)`` pairs (group sizes bucket to pow2, so warm
        the bucket the serving bursts will hit).  Returns compile time."""
        return self.algorithm.warmup(tuple(specs))

    @property
    def params(self):
        """Current model — forces every pending request and blocks."""
        self.flush()
        p = self._algorithm.params if self._algorithm is not None \
            else self._trained_params
        jax.block_until_ready(p)
        return p

    # -- certified publication ----------------------------------------------

    def _next_key(self) -> jax.Array:
        """Split one use-key off the session-held PRNG key (created from
        ``config.seed`` on first use; save()/restore() round-trips it, so
        a restored session's next publish is bitwise-identical)."""
        if self._prng_key is None:
            self._prng_key = jax.random.PRNGKey(self.config.seed)
        self._prng_key, key = jax.random.split(self._prng_key)
        return key

    def certificate(self, eps: Optional[float] = None,
                    delta: Optional[float] = None) -> Certificate:
        """The serving algorithm's current deletion certificate — no
        noise is drawn and no state changes."""
        self.flush()
        return self.algorithm.certificate(eps=eps, delta=delta)

    def publish(self, eps: Optional[float] = None,
                delta: Optional[float] = None):
        """(params, Certificate): certified release of the current model
        through the algorithm's mechanism, with noise drawn from the
        session PRNG key (one split per publish)."""
        with self._lock:
            params = self.params  # flush + block
            return self.algorithm.publish(self._next_key(), params,
                                          eps=eps, delta=delta)

    # -- phase 2: the request plan ------------------------------------------

    def submit(self, request: Optional[UnlearnRequest] = None, *,
               op: Optional[str] = None,
               rows: Optional[Sequence[int]] = None,
               data: Optional[Dict[str, np.ndarray]] = None,
               coalesce: bool = True) -> RequestHandle:
        """Enqueue one request; returns a lazy `RequestHandle`.

        Nothing executes until the session flushes.  Add payloads (`data`)
        ARE appended to the dataset here, so their row ids are assigned at
        submission time and later requests may delete them.  Serialized
        against `flush()`/`poll()` (and so against an `AutoFlushTimer`)
        by the session lock."""
        with self._lock:
            return self._submit_locked(request, op=op, rows=rows,
                                       data=data, coalesce=coalesce)

    def _submit_locked(self, request, *, op, rows, data,
                       coalesce) -> RequestHandle:
        self._require_fit()
        if request is None:
            request = UnlearnRequest(op=op, rows=rows, data=data,
                                     coalesce=coalesce)
        if request.op not in ("delete", "add"):
            raise ValueError(f"op must be 'delete' or 'add', got "
                             f"{request.op!r}")
        if request.op == "add" and request.data is not None \
                and request.rows is None:
            request.rows = self.dataset.append(request.data).tolist()
        if not request.rows:
            raise ValueError("request names no rows")
        request.rows = [int(r) for r in request.rows]
        if len(set(request.rows)) != len(request.rows):
            raise ValueError(f"duplicate rows in request: {request.rows}")
        if request.op == "delete":
            pending_del = {r for _, q in self._pending if q.op == "delete"
                           for r in q.rows}
            for r in request.rows:
                if not 0 <= r < self.dataset.n:
                    raise ValueError(f"row {r} out of range")
                if self.dataset.removed[r] or r in pending_del:
                    raise ValueError(f"row {r} already deleted (or has a "
                                     "pending delete)")
        else:
            pending_add = {r for _, q in self._pending if q.op == "add"
                           for r in q.rows}
            already = (set(self._algorithm.added)
                       if self._algorithm is not None else set())
            base_n = self.history.meta.n
            for r in request.rows:
                if not base_n <= r < self.dataset.n:
                    raise ValueError(
                        "add requests name rows appended AFTER the cached "
                        f"training run (expected {base_n} <= row < "
                        f"{self.dataset.n}, got {r}) — an original row "
                        "would be double-counted")
                if r in already or r in pending_add:
                    raise ValueError(f"row {r} already added (or has a "
                                     "pending add)")
        ticket = self._tickets
        self._tickets += 1
        if not self._pending:
            self._oldest_pending_ts = time.monotonic()
        self._pending.append((ticket, request))
        handle = RequestHandle(self, ticket, request)
        self._maybe_autoflush()
        return handle

    # -- deadline/size-triggered auto-flush ---------------------------------

    def _maybe_autoflush(self) -> bool:
        """Flush when the pending queue trips the configured size or
        staleness bound.  Size is checked on every submit; the deadline is
        checked at submit time AND via `poll()` (call it between arrivals
        — e.g. from the serving loop's idle tick) so a lull after a burst
        cannot park requests past ``max_delay_s``."""
        c = self.config
        reason = None
        if (c.max_pending is not None and c.max_pending > 0
                and len(self._pending) >= c.max_pending):
            reason = "max_pending"
        elif (c.max_delay_s is not None and self._pending
              and time.monotonic() - self._oldest_pending_ts
              >= c.max_delay_s):
            reason = "max_delay_s"
        if reason is None:
            return False
        self.autoflush_count += 1
        self.autoflush_reasons[reason] += 1
        try:
            self.flush()
        except Exception:
            # a POLICY-triggered flush must not propagate a failing
            # group's error out of submit() — the caller would lose the
            # handle for the request it just enqueued.  flush() already
            # recorded the failing tickets in _failed (their handles
            # resolve to the error) and requeued the groups behind them.
            pass
        return True

    def poll(self) -> bool:
        """Deadline tick for continuous-load serving: flushes (returning
        True) iff pending work has outstayed ``config.max_delay_s``.
        Call it from the load loop's idle tick, or let
        `start_autoflush_timer()` drive it from a daemon thread."""
        with self._lock:
            return self._maybe_autoflush()

    def start_autoflush_timer(self, interval_s: Optional[float] = None):
        """DEPRECATED: drive the ``max_delay_s`` deadline from a daemon
        tick thread.  This now routes through the serving tier — it
        returns a `repro.serve.SessionFlushClock` (one default SLA class
        whose deadline is ``max_delay_s``; same ``ticks``/``stop()``
        surface as the old `AutoFlushTimer`).  New code should construct
        `repro.serve.ServingScheduler` for per-class deadlines, admission
        control, and cross-tenant batching.  Starting a new clock stops
        the previous one."""
        warnings.warn(
            "session.start_autoflush_timer() is deprecated; serve through "
            "repro.serve.ServingScheduler (SLA-class deadlines) or create "
            "repro.serve.SessionFlushClock directly",
            DeprecationWarning, stacklevel=2)
        if self.config.max_delay_s is None:
            raise ValueError(
                "start_autoflush_timer() needs config.max_delay_s — there "
                "is no deadline for the timer to enforce")
        from repro.serve.scheduler import SessionFlushClock
        if self._autoflush_timer is not None:
            self._autoflush_timer.stop()
        self._autoflush_timer = SessionFlushClock(self, interval_s=interval_s)
        return self._autoflush_timer

    @property
    def pending_age_s(self) -> float:
        """Seconds the OLDEST pending request has been waiting (0 if none):
        the staleness the auto-flush policy bounds."""
        if not self._pending or self._oldest_pending_ts is None:
            return 0.0
        return time.monotonic() - self._oldest_pending_ts

    @property
    def pending_count(self) -> int:
        """Number of submitted-but-unserved requests (len is atomic under
        CPython, so this is safe to read without the lock — the serving
        executor polls it between flush rounds)."""
        return len(self._pending)

    def pending_requests(self) -> List[Tuple[int, UnlearnRequest]]:
        """Snapshot of the pending set as ``(ticket, request)`` pairs, in
        submission order — what the coalescing planner would group at the
        next flush.  The serving tier uses this (plus `pending_count`) to
        decide whether a snapshot can proceed and to account pending add
        rows against staged device capacity."""
        with self._lock:
            return list(self._pending)

    def try_flush(self) -> Optional[List[UnlearnResponse]]:
        """Non-blocking variant of `flush()`: serve the pending set IF
        the session lock is immediately available, else return None
        without waiting.  Part of the serving-tier session surface
        (alongside `poll` and `pending_requests`) for callers driving the
        session from their own event loop, where a flush attempt must
        never park behind a foreground submitter (or another flush)
        holding the lock.  The threaded serving path does not need it:
        `ServingScheduler`'s executor is the session's only writer there
        and uses plain `flush()`."""
        if not self._lock.acquire(blocking=False):
            return None
        try:
            return self._flush_locked()
        finally:
            self._lock.release()

    def delete(self, rows: Sequence[int], coalesce: bool = True
               ) -> RequestHandle:
        return self.submit(op="delete", rows=list(rows), coalesce=coalesce)

    def add(self, data: Optional[Dict[str, np.ndarray]] = None,
            rows: Optional[Sequence[int]] = None, coalesce: bool = True
            ) -> RequestHandle:
        return self.submit(op="add", rows=rows, data=data, coalesce=coalesce)

    def _resolve(self, ticket: int) -> UnlearnResponse:
        if ticket not in self._responses and ticket not in self._failed:
            self.flush()
        if ticket in self._failed:
            err = self._failed[ticket]
            raise RuntimeError(
                f"request {ticket} was not served: {err}") from err
        return self._responses[ticket]

    def _record(self, ticket: int, resp: UnlearnResponse) -> None:
        self._responses[ticket] = resp
        while len(self._responses) > self.max_responses:
            old = next(iter(self._responses))  # oldest (insertion order)
            del self._responses[old]
            self._failed[old] = RuntimeError(
                "response evicted (more than max_responses unread "
                "responses); force handles promptly or raise "
                "session.max_responses")

    def flush(self) -> List[UnlearnResponse]:
        """Serve every pending request through the coalescing planner.

        Replays are DISPATCHED, not synced: device work queues up and
        `dispatch_s` measures host time only; blocking happens when a
        handle (or `.params`) is forced."""
        with self._lock:
            return self._flush_locked()

    def _flush_locked(self) -> List[UnlearnResponse]:
        if not self._pending:
            return []
        algo = self.algorithm
        pending, self._pending = self._pending, []
        ts0, self._oldest_pending_ts = self._oldest_pending_ts, None
        # size the add-column block for the whole plan once so the padded
        # schedule width (and every compiled shape) stays put across it
        n_adds = sum(len(q.rows) for _, q in pending if q.op == "add")
        algo.begin_plan(n_adds)
        out: List[UnlearnResponse] = []
        groups = plan_requests(pending)
        for gi, group in enumerate(groups):
            op = group[0][1].op
            rows = [r for _, q in group for r in q.rows]
            t0 = time.perf_counter()
            try:
                stats = algo.apply(op, rows,
                                   coalesce=group[0][1].coalesce)
            except Exception as e:
                # the failing group's handles resolve to this error; groups
                # after it go back on the queue (ahead of anything submitted
                # later) so their handles stay servable
                for ticket, _ in group:
                    self._failed[ticket] = e
                self._pending = [tr for g in groups[gi + 1:] for tr in g] \
                    + self._pending
                if self._pending:
                    # keep the ORIGINAL enqueue clock: requeued requests
                    # were already waiting, and restarting the clock would
                    # let them silently outstay max_delay_s
                    self._oldest_pending_ts = ts0 or time.monotonic()
                raise
            dispatch_s = time.perf_counter() - t0
            for ticket, req in group:
                resp = UnlearnResponse(request=req, stats=stats,
                                       group_size=len(rows),
                                       dispatch_s=dispatch_s,
                                       params=algo.params)
                self._record(ticket, resp)
                out.append(resp)
            self.log.append({"op": op, "rows": rows,
                             "coalesced": len(stats) == 1 and len(rows) > 1,
                             "stats": stats})
        return out

    # -- streams (serial Algorithm-3 semantics; the paper's request model) ---

    def serve_stream(self, ops: Sequence[Tuple[str, int]]) -> OnlineStats:
        """Serve ``(op, row)`` pairs one replay per row (never coalesced),
        returning aggregate `OnlineStats`; wall_time_s covers dispatch +
        the final device sync, with compile cost reported separately."""
        self._require_fit()
        self.flush()  # drain older pending work outside this stream's timer
        algo = self.algorithm
        handles = [self.submit(op=op, rows=[int(row)], coalesce=False)
                   for op, row in ops]
        stats = OnlineStats(compile_time_s=algo.compile_time_s)
        t0 = time.perf_counter()
        self.flush()
        jax.block_until_ready(algo.params)
        stats.wall_time_s = time.perf_counter() - t0
        for h in handles:
            stats.per_request.extend(h.stats)
        return stats

    def stream_delete(self, rows: Sequence[int]) -> OnlineStats:
        return self.serve_stream([("delete", int(r)) for r in rows])

    def stream_add(self, data: Dict[str, np.ndarray]) -> OnlineStats:
        new_idx = self.dataset.append(data)
        return self.serve_stream([("add", int(r)) for r in new_idx])

    # -- reference: exact retraining (BaseL) ---------------------------------

    def baseline(self, indices, mode: str = "delete"):
        self._require_fit()
        idx = np.asarray(list(indices), dtype=np.int64)
        return baseline_retrain(
            self.objective, self.dataset, self.history.meta, self.params0,
            idx, mode)

    # -- snapshot / restore --------------------------------------------------

    def save(self, directory: str, step: Optional[int] = None,
             pending: str = "drain") -> str:
        """Write a restorable snapshot through `train/checkpoint`.

        ``pending`` picks the snapshot-under-load semantics, and both
        choices are deterministic: ``"drain"`` (default) flushes every
        pending request first, so the snapshot is always a consistent
        between-requests state — restoring it and serving the remainder of
        a request stream is identical to the uninterrupted session;
        ``"refuse"`` raises `RuntimeError` while anything is pending, for
        callers that must not absorb the drain latency inside save().
        Params ride as the checkpoint's sharded pytree; `TrainingHistory`
        (any tier), the dataset (columns + deletion mask), and the
        algorithm descriptor (e.g. the engine's liveness/added-row
        order/capacities/L-BFGS ring) ride in the extra payload.  Returns
        the step dir.  Holds the session lock for the whole write so a
        concurrent submitter or flush clock cannot mutate state between
        the flush and the state_dict reads."""
        if pending not in ("drain", "refuse"):
            raise ValueError(
                f"pending must be 'drain' or 'refuse', got {pending!r}")
        with self._lock:
            if pending == "refuse" and self._pending:
                raise RuntimeError(
                    f"save(pending='refuse') with {len(self._pending)} "
                    "pending request(s); flush() first or use "
                    "pending='drain'")
            return self._save_locked(directory, step)

    def _save_locked(self, directory: str, step: Optional[int]) -> str:
        self._require_fit()
        self.flush()
        params = self._algorithm.params if self._algorithm is not None \
            else self._trained_params
        jax.block_until_ready(params)
        step = self._tickets if step is None else int(step)
        extra = {
            "format": 2,
            "config": self.config,
            "params0": jax.device_get(self.params0),
            "history": self.history.state_dict(),
            "dataset": {
                "columns": {k: np.asarray(v)
                            for k, v in self.dataset.columns.items()},
                "removed": np.asarray(self.dataset.removed, dtype=bool).copy(),
            },
            # the algorithm descriptor: which algorithm served this
            # session plus its full serving state, so restore() rebuilds
            # the SAME algorithm mid-stream (format 1 snapshots carried a
            # bare deltagrad engine state under "engine")
            "algorithm": ({
                "name": self._algorithm.name,
                "state": self._algorithm.state_dict(),
            } if self._algorithm is not None else None),
            "prng_key": (np.asarray(jax.device_get(self._prng_key))
                         if self._prng_key is not None else None),
            "tickets": self._tickets,
        }
        return ckpt.save(directory, step, params, extra=extra)

    @classmethod
    def restore(cls, directory: str, objective: Objective,
                step: Optional[int] = None,
                spill_dir: Optional[str] = None) -> "UnlearnerSession":
        """Rebuild a session from `save()` output; the next request served
        is identical to what the uninterrupted session would have served.
        `objective` is code, not state — pass the same objective the saved
        session was built with."""
        if step is None:
            step = ckpt.latest_step(directory)
            if step is None:
                raise FileNotFoundError(
                    f"no complete checkpoint under {directory}")
        extra = ckpt.restore_extra(directory, step)
        history = TrainingHistory.from_state_dict(extra["history"],
                                                  spill_dir=spill_dir)
        ds = Dataset(extra["dataset"]["columns"])
        ds.removed = np.asarray(extra["dataset"]["removed"],
                                dtype=bool).copy()
        params = ckpt.restore(directory, step, like=history.final_params)
        params0 = extra.get("params0")
        if params0 is not None:
            params0 = jax.tree.map(jax.numpy.asarray, params0)
        sess = cls(objective, params0=params0, dataset=ds,
                   config=extra["config"])
        sess.history = history
        sess._trained_params = params
        sess._tickets = int(extra.get("tickets", 0))
        key = extra.get("prng_key")
        if key is not None:
            sess._prng_key = jax.numpy.asarray(np.asarray(key))
        algo_desc = extra.get("algorithm")
        if algo_desc is not None:
            if algo_desc["name"] != sess.config.algorithm:
                raise ValueError(
                    f"snapshot was served by {algo_desc['name']!r} but the "
                    f"restored config selects {sess.config.algorithm!r}")
            sess.algorithm.load_state(algo_desc["state"], params)
        elif extra.get("engine") is not None:  # format 1 (pre-registry)
            engine = sess.engine()
            engine.load_state(extra["engine"])
            engine.params = params
        return sess
