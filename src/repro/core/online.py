"""DeltaGrad online deletion/addition — paper Algorithm 3 (Appendix C.2).

Requests arrive one at a time (GDPR-style streams).  After each request the
optimization-path cache is REWRITTEN in place so the next request corrects
the *previous DeltaGrad path* rather than the original training run:

  explicit steps:  w_t <- w^I_t,  g_t <- exact mean gradient of the current
                   (post-deletion) objective at w^I_t;
  approx steps:    w_t <- w^I_t,  g_t <- g^a_t, the approximated gradient
                   (paper eq. (S62)) — this is what keeps per-request cost
                   independent of how many requests came before.

The minibatch schedule is always replayed against the ORIGINAL dataset
numbering; cumulative deletions shrink each batch's effective size
``B_t(k) = B - |batch_t ∩ R_k|`` (paper's n-k bookkeeping).

Deletion streams run on the compiled engine (`core.engine.run_online_request`):
per request, approx segments execute under `lax.scan` against the stacked
history and the rewrite pairs are written back with
`lax.dynamic_update_slice`; the storage flush is an O(1) pointer swap after
each request.  Addition streams, offload tiers (host/disk) and
`impl="python"` use the pre-refactor loop below.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deltagrad import (DeltaGradConfig, Objective, RetrainStats,
                                  _next_pow2, _sgd_apply, _tree_zeros)
from repro.core.engine import _approx_math, run_online_request
from repro.core.history import TrainingHistory
from repro.core.lbfgs import LbfgsBuffer, lbfgs_hvp_stacked_pytree
from repro.data.dataset import Dataset
from repro.data.sampler import batch_indices, batch_indices_all
from repro.utils.tree import tree_all_finite, tree_norm, tree_sub


@partial(jax.jit, static_argnames=("sign",))
def _online_approx_update(params, w_t, g_t, dWs, dGs, g_one, lr, b_eff, has,
                          clip, sign: int):
    """One fused approx step; also returns g^a (eq. S62) for the rewrite."""
    v = tree_sub(params, w_t)
    bv = lbfgs_hvp_stacked_pytree(dWs, dGs, v)
    # gradient of the post-request objective at params
    g_new = _approx_math(g_t, bv, g_one, b_eff, has, sign)
    new_params = jax.tree.map(lambda p, g: p - lr * g, params, g_new)
    ok = jnp.logical_and(
        tree_all_finite(new_params),
        tree_norm(bv) <= clip * tree_norm(v),
    )
    return new_params, g_new, ok


@dataclass
class OnlineStats:
    per_request: List[RetrainStats] = field(default_factory=list)
    wall_time_s: float = 0.0

    @property
    def grad_examples(self) -> int:
        return sum(s.grad_examples for s in self.per_request)

    @property
    def grad_examples_baseline(self) -> int:
        return sum(s.grad_examples_baseline for s in self.per_request)

    @property
    def theoretical_speedup(self) -> float:
        return self.grad_examples_baseline / max(self.grad_examples, 1)


def online_deltagrad(
    objective: Objective,
    history: TrainingHistory,
    ds: Dataset,
    requests: Sequence[int],
    cfg: DeltaGradConfig,
    mode: str = "delete",
) -> Tuple[Any, OnlineStats]:
    """Process deletion (or addition) requests sequentially, rewriting history.

    For mode == "add", `requests` are indices of rows already appended to `ds`
    (ds.n > history.meta.n); each request inserts one of them into the replayed
    batches with the deterministic `addition_mask` of `data.sampler` — here,
    for single-sample requests, the sample simply joins every batch with
    probability B/n via the same hash (handled by treating it as a deleted
    sample of the *future* run and running the add-update).
    """
    assert mode in ("delete", "add")
    # Algorithm 3 rewrites the cache assuming plain-SGD replay; a heavy-ball
    # path would need per-request velocity reconstruction (ROADMAP item) —
    # silently applying SGD to a momentum-cached path diverges unboundedly
    assert not history.meta.momentum, (
        "online_deltagrad does not support momentum-trained histories yet")
    if mode == "add" or cfg.impl == "python" \
            or history.tier in ("host", "disk"):
        return _online_python(objective, history, ds, requests, cfg, mode)

    meta = history.meta
    grad_fn = objective.make_grad_fn()
    cols = ds.device_columns()
    idx_all = batch_indices_all(meta.seed, meta.steps, meta.n,
                                meta.batch_size)
    # the (T, B) index matrix and lr vector never change across requests —
    # upload them once
    static_dev = (jnp.asarray(idx_all, jnp.int32),
                  jnp.asarray([meta.lr_at(t) for t in range(meta.steps)],
                              jnp.float32))
    live = np.ones(meta.n, dtype=bool)
    W, G = history.stacked_view()
    params = history.final_params
    stats = OnlineStats()
    t_start = time.perf_counter()

    for req in requests:
        req = int(req)
        params, W, G, rstat = run_online_request(
            grad_fn, history, W, G, cols, req, cfg, live, idx_all,
            static_dev=static_dev)
        # flush per request (O(1) pointer swap for stacked/device storage)
        # so dataset bookkeeping and the rewritten cache never diverge even
        # if a later request dies mid-stream
        history.replace_from_stacked(W, G)
        history.finalize(params)
        live[req] = False
        ds.removed[req] = True
        stats.per_request.append(rstat)

    jax.block_until_ready(params)
    stats.wall_time_s = time.perf_counter() - t_start
    return params, stats


def _online_python(objective, history, ds, requests, cfg, mode):
    """Pre-refactor per-step loop: additions, disk tier, parity oracle."""
    meta = history.meta
    grad_fn = objective.make_grad_fn()
    B = min(meta.batch_size, meta.n)
    r_pad = 1  # single-sample requests
    add_pad = _next_pow2(len(list(requests))) if mode == "add" else 0
    batch_pad = B + add_pad

    clip = jnp.float32(cfg.guard_norm_clip)

    removed_so_far: List[int] = []
    added_so_far: List[int] = []
    params = history.final_params
    stats = OnlineStats()
    t_start = time.perf_counter()

    for req in requests:
        req = int(req)
        buffer = LbfgsBuffer(cfg.history_size, curvature_eps=cfg.curvature_eps)
        params = history.params_at(0)
        rstat = RetrainStats()

        for t in range(meta.steps):
            idx = batch_indices(meta.seed, t, meta.n, meta.batch_size)
            # rows already gone from previous requests are masked out of the
            # replayed batch; the cached g_t already excludes them.
            live = idx[~np.isin(idx, removed_so_far)] if removed_so_far else idx
            if mode == "delete":
                in_batch = req in set(live.tolist())
                base = live  # batch the cached (pre-request) path used
            else:
                from repro.data.sampler import addition_mask

                n_new = len(added_so_far) + 1
                joins = addition_mask(meta.seed, t, meta.n, meta.batch_size, n_new)
                in_batch = bool(joins[-1])
                prev_added = np.asarray(added_so_far, dtype=np.int64)[joins[:-1]]
                base = np.concatenate([live, prev_added])
            eff_prev = len(base)
            has = 1.0 if in_batch else 0.0
            lr = jnp.float32(meta.lr_at(t))
            rstat.grad_examples_baseline += eff_prev - (1 if (mode == "delete" and in_batch) else 0)

            if mode == "delete" and in_batch and eff_prev <= 1:
                rstat.skipped_steps += 1
                continue

            explicit = cfg.is_explicit(t) or len(buffer) == 0
            w_t, g_t = history.entry(t)

            if not explicit:
                if in_batch:
                    cb, cw = ds.padded_batch(np.array([req]), r_pad)
                    g_one = grad_fn(params, cb, cw)
                    rstat.grad_examples += 1
                else:
                    g_one = _tree_zeros(params)
                dWs, dGs = buffer.stacked()
                sign = 1 if mode == "delete" else -1
                new_params, g_new, ok = _online_approx_update(
                    params, w_t, g_t, dWs, dGs, g_one, lr,
                    jnp.float32(eff_prev), jnp.float32(has), clip, sign,
                )
                if cfg.guard and not bool(ok):
                    rstat.guard_fallbacks += 1
                    explicit = True
                else:
                    history.overwrite(t, params, g_new)
                    params = new_params
                    rstat.approx_steps += 1

            if explicit:
                if mode == "delete":
                    cur = base[base != req]
                else:
                    cur = np.concatenate([base, np.array([req], dtype=np.int64)]) \
                        if in_batch else base
                kb, kw = ds.padded_batch(cur, batch_pad)
                g_cur = grad_fn(params, kb, kw)  # mean grad, post-request batch
                rstat.grad_examples += len(cur)
                # pair: gradient over the PRE-request batch at params
                if in_batch:
                    cb, cw = ds.padded_batch(np.array([req]), r_pad)
                    g_one = grad_fn(params, cb, cw)
                    if mode == "delete":
                        g_prev = jax.tree.map(
                            lambda a, b: (len(cur) * a + b) / eff_prev, g_cur, g_one
                        )
                    else:
                        g_prev = jax.tree.map(
                            lambda a, b: ((len(cur)) * a - b) / eff_prev, g_cur, g_one
                        )
                else:
                    g_prev = g_cur
                dw = tree_sub(params, w_t)
                dg = tree_sub(g_prev, g_t)
                buffer.add(dw, dg)
                history.overwrite(t, params, g_cur)
                params = _sgd_apply(params, g_cur, lr)
                rstat.explicit_steps += 1

        if mode == "delete":
            removed_so_far.append(req)
            ds.removed[req] = True
        else:
            added_so_far.append(req)
        history.finalize(params)
        stats.per_request.append(rstat)

    stats.wall_time_s = time.perf_counter() - t_start
    return params, stats
