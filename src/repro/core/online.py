"""DeltaGrad online deletion/addition — paper Algorithm 3 (Appendix C.2).

Requests arrive one at a time (GDPR-style streams).  After each request the
optimization-path cache is REWRITTEN in place so the next request corrects
the *previous DeltaGrad path* rather than the original training run:

  explicit steps:  w_t <- w^I_t,  g_t <- exact mean gradient of the current
                   (post-request) objective at w^I_t;
  approx steps:    w_t <- w^I_t,  g_t <- g^a_t, the approximated gradient
                   (paper eq. (S62)) — this is what keeps per-request cost
                   independent of how many requests came before.

The minibatch schedule is always replayed against the ORIGINAL dataset
numbering; cumulative deletions shrink each batch's effective size
``B_t(k) = B - |batch_t ∩ R_k|`` (paper's n-k bookkeeping), and rows
appended by earlier ADDITION requests extend each batch through their
precomputed, prefix-stable join masks (``data.sampler``).  Heavy-ball
histories are supported: each request reconstructs the velocity from
``vel_0 = 0`` while replaying, so the cache keeps storing plain gradients.

`OnlineEngine` owns the stream state (liveness over original AND added
rows, added-row join masks, the request-invariant device schedule) and
serves every request flavor — delete or add, single row or a COALESCED
GROUP of rows (`request_group`, one replay for K requests — the
session planner's batching primitive), SGD or momentum — through
`core.engine.run_online_request`: approx segments execute under `lax.scan`
against the history served by a `core.store.HistoryStore` — fully resident
(stacked/device tiers, optionally mesh-sharded with psum-reduced
per-example gradients) or streamed per segment window from the offload
tiers (host/disk) — and rewrites land in batched flushes through
`store.commit` (an O(1) pointer swap for resident storage, a codec
write-back for streamed).  `impl="python"` selects
`_online_request_python`, a per-step oracle driving the SAME precomputed
`ReplaySchedule` through the same jitted step math, kept as the parity
reference.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deltagrad import (DeltaGradConfig, Objective, RetrainStats,
                                  _next_pow2, _tree_zeros)
from repro.core.engine import (SKIP, EXPLICIT, _online_approx_step,
                               _online_explicit_math, _ring_append,
                               _scan_pred, build_plan, run_online_request)
from repro.core.history import TrainingHistory
from repro.core.store import (HistoryStore, PlacementPolicy,
                              make_psum_grad_fn)
from repro.data.dataset import Dataset
from repro.data.sampler import (ReplaySchedule, addition_mask_all,
                                batch_indices_all, build_online_schedule)
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


@dataclass
class OnlineStats:
    per_request: List[RetrainStats] = field(default_factory=list)
    wall_time_s: float = 0.0
    # first-request trace/compile cost, measured by the engine's warm-up
    # request (0.0 when warm-up is off) — kept OUT of wall_time_s so stream
    # throughput numbers aren't dominated by tracing
    compile_time_s: float = 0.0

    @property
    def grad_examples(self) -> int:
        return sum(s.grad_examples for s in self.per_request)

    @property
    def grad_examples_baseline(self) -> int:
        return sum(s.grad_examples_baseline for s in self.per_request)

    @property
    def theoretical_speedup(self) -> float:
        return self.grad_examples_baseline / max(self.grad_examples, 1)


Request = Union[int, Tuple[str, int]]


class OnlineEngine:
    """Persistent Algorithm-3 request engine over one cached training run.

    Owns everything that outlives a single request: the replayed (T, B)
    index matrix (uploaded once), liveness over original and added rows,
    the added-row join masks (grown prefix-stably as adds arrive), and the
    extended index matrix whose add-columns pad to powers of two so the
    compiled segment shapes stay stable across a stream.  Both backends
    serve requests from the same `build_online_schedule` output, so they
    see identical per-step row sets, weights, and learning rates.
    """

    def __init__(self, objective: Objective, history: TrainingHistory,
                 ds: Dataset, cfg: DeltaGradConfig, warmup=False,
                 add_capacity: int = 0,
                 placement: Optional[PlacementPolicy] = None,
                 store: Optional[HistoryStore] = None):
        self.objective = objective
        self.history = history
        self.ds = ds
        self.cfg = cfg
        # preallocated add-column block: sizing it for the expected number
        # of additions up front keeps the extended-schedule width (and so
        # every compiled segment shape) constant across the stream
        self.add_capacity = int(add_capacity)
        self.grad_fn = objective.make_grad_fn()
        meta = history.meta
        # every tier runs the compiled path: offload tiers stream segment
        # windows through core.store.SegmentStreamer; only an explicit
        # impl="python" selects the per-step oracle
        self.impl = "python" if cfg.impl == "python" else "scan"
        self.idx_all = batch_indices_all(meta.seed, meta.steps, meta.n,
                                         meta.batch_size)
        # Rows already deleted (by an earlier online stream over this same
        # rewritten history, or by a batch replay) stay masked out of the
        # replayed batches: the schedule then matches the CURRENT dataset.
        # If the cache was not rewritten for some of those deletions (batch
        # `deltagrad_retrain` does not rewrite), the first requests' explicit
        # steps serve as catch-up corrections — they evaluate exact
        # current-objective gradients and rewrite the cache toward
        # consistency, which is how Algorithm 3 absorbs any cache/objective
        # mismatch.  Rows added by an EARLIER engine instance cannot be
        # recovered from the dataset alone; reuse one OnlineEngine per
        # rewritten history (as `core.api.Unlearner` does) to keep their
        # join columns alive.
        self.live = ~np.asarray(ds.removed, dtype=bool)
        self.added: List[int] = []
        self._joins = None  # (T, capacity) bool, prefix-stable columns
        self.params = history.final_params
        self.compile_time_s = 0.0
        # the last served request's L-BFGS pair ring — snapshot state only
        # (every request rebuilds its ring from the rewritten path)
        self.last_ring = None
        # pow2-bucketed device-row capacity: appends within the bucket keep
        # every compiled shape put; outgrowing it bumps to the next pow2,
        # so an addition stream re-traces O(log #adds) times, not per add
        self._base_n = ds.n
        self._row_cap = ds.n + (_next_pow2(self.add_capacity)
                                if self.add_capacity else 0)
        self.store: Optional[HistoryStore] = None
        self._seg_grad_fn = None
        if self.impl == "scan":
            self.store = store if store is not None else HistoryStore.create(
                history, placement=placement, window=cfg.stream_window,
                decode=cfg.stream_decode)
            runner = self.store.sharded_replay()
            if runner is not None:
                self._seg_grad_fn = make_psum_grad_fn(
                    objective, runner.placement.data_axis)
            self._lr_dev = jnp.asarray(
                [meta.lr_at(t) for t in range(meta.steps)], jnp.float32)
            self._idx_dev = None  # uploaded lazily, re-used across requests
            self._idx_ver = None  # (len(added), width) of the upload
            if warmup:  # True, or an iterable of op flavors to precompile
                self._warmup(("delete",) if warmup is True else tuple(warmup))

    # -- stream state ------------------------------------------------------

    @property
    def _add_pad(self) -> int:
        need = max(len(self.added), self.add_capacity)
        return _next_pow2(need) if need else 0

    def _ensure_joins(self, n_cols: int) -> None:
        if n_cols and (self._joins is None
                       or self._joins.shape[1] < n_cols):
            meta = self.history.meta
            self._joins = addition_mask_all(
                meta.seed, meta.steps, meta.n, meta.batch_size,
                _next_pow2(n_cols))

    def _schedule(self, op: str, rows: Sequence[int]) -> ReplaySchedule:
        meta = self.history.meta
        K = len(rows)
        self._ensure_joins(len(self.added) + (K if op == "add" else 0))
        if op == "delete":
            # per-step changed count is bounded by the minibatch overlap
            # (<= B original rows) plus the group's previously-added rows,
            # so cap the pad like the batch path's min(r, B) — a K >> B
            # group must not widen every step's changed block to K
            n_added_in = len(set(rows) & set(self.added)) if self.added \
                else 0
            r_eff = min(K, min(meta.batch_size, meta.n) + n_added_in)
        else:
            r_eff = K  # add groups carry all K rows in the changed block
        return build_online_schedule(
            meta.seed, meta.steps, meta.n, meta.batch_size, rows, op,
            meta.lr_at, self.live, np.asarray(self.added, np.int64),
            self._joins, self._add_pad, idx_all=self.idx_all,
            r_pad=_next_pow2(r_eff))

    def _cols(self):
        """Device columns at the bucketed row capacity (see `_row_cap`).

        The cap honors a RAISED ``add_capacity`` (e.g. `begin_plan` sizing
        a whole flush, or the serving tier pre-staging its admission
        budget) — not just rows already appended — so staging happens once
        up front instead of as a mid-flush retrace on the first add
        burst.  Admission-side accounting (`repro.serve`) counts pending
        adds against this same bucket, padding included."""
        need = max(len(self.added), self.add_capacity)
        cap = self._base_n + (_next_pow2(need) if need else 0)
        if cap > self._row_cap:
            self._row_cap = cap
        if self.ds.n > self._row_cap:
            self._row_cap = self._base_n + _next_pow2(self.ds.n
                                                      - self._base_n)
        return self.ds.device_columns(capacity=self._row_cap)

    def _static_dev(self, sched: ReplaySchedule):
        """(idx, lr) on device, re-uploaded only when the added set grows or
        the padded schedule width changes (e.g. add_capacity was raised)."""
        key = (len(self.added), sched.idx.shape[1])
        if self._idx_ver != key or self._idx_dev is None:
            self._idx_dev = jnp.asarray(sched.idx, jnp.int32)
            self._idx_ver = key
        return self._idx_dev, self._lr_dev

    def _warmup(self, ops=("delete",)) -> None:
        """Trace + compile the request programs on throwaway requests (one
        per flavor the stream will serve — the compiled programs key on the
        request sign AND the pow2-bucketed group width, so `ops` entries
        are op names or ``(op, group_size)`` pairs).

        `run_online_request` with ``commit=False`` never lands its rewrites,
        so discarding its outputs leaves no trace; the measured time is the
        first-request compile cost reported as
        `OnlineStats.compile_time_s`."""
        live_rows = np.flatnonzero(self.live[:self.history.meta.n])
        if live_rows.size == 0:
            return
        t0 = time.perf_counter()
        with obs_trace.span("online.warmup", ops=len(ops)):
            for spec in ops:
                op, k = spec if isinstance(spec, tuple) else (spec, 1)
                k = int(min(k, live_rows.size))
                # existing live rows stand in for appended ones in add mode:
                # the schedule only needs gatherable row ids + the next free
                # join-mask columns
                sched = self._schedule(op, [int(r) for r in live_rows[:k]])
                out = run_online_request(self.grad_fn, self.store,
                                         self._cols(), sched, self.cfg,
                                         static_dev=self._static_dev(sched),
                                         seg_grad_fn=self._seg_grad_fn,
                                         commit=False)
                jax.block_until_ready(out[0])
        self.compile_time_s = time.perf_counter() - t0
        obs_metrics.get_registry().gauge(
            "online.compile_time_s", unit="s",
            owner="core.online").set(self.compile_time_s)

    # -- request serving ---------------------------------------------------

    def request(self, op: str, row: int) -> RetrainStats:
        """Serve one delete/add request, rewriting history + bookkeeping."""
        return self.request_group(op, [int(row)])

    def request_group(self, op: str, rows: Sequence[int]) -> RetrainStats:
        """Serve a COALESCED group of same-op requests as ONE replay.

        Group deletion applies the paper's index-set semantics (Algorithm 1
        with R = `rows`) to the current rewritten path, rewriting history
        once; group addition joins every new row through its own mask
        column in the same single replay.  K sequential replays collapse to
        one — per-request cost drops ~Kx — at the price of a path that is
        the GROUP correction, not the composition of K single-request
        corrections (both approximate the same leave-R-out model; see
        core.session for the serving-semantics contract)."""
        assert op in ("delete", "add"), op
        rows = [int(r) for r in rows]
        assert len(rows) == len(set(rows)), f"duplicate rows in {rows}"
        if max(rows) >= len(self.live):  # dataset grew since construction
            grown = np.ones(self.ds.n, dtype=bool)
            grown[:len(self.live)] = self.live
            self.live = grown
        if op == "delete":
            for row in rows:
                assert self.live[row], f"row {row} already deleted"
        else:
            for row in rows:
                assert self.history.meta.n <= row < self.ds.n, (
                    "add requests name rows appended AFTER the cached "
                    f"training run (expected {self.history.meta.n} <= row < "
                    f"{self.ds.n}, got {row}) — an original row would be "
                    "double-counted")
                assert row not in self.added, f"row {row} already added"
        sched = self._schedule(op, rows)

        # whole-replay roofline lower bound (None — and not computed —
        # while tracing is off); the tracer stamps the measured wall and
        # ratio onto the span at exit
        pred = _scan_pred(
            sum(x.size for x in jax.tree.leaves(self.params)),
            self.history.meta.steps, sched.r_pad, self.cfg.history_size,
            bool(self.history.meta.momentum)) if obs_trace.enabled() \
            else None
        with obs_trace.span("online.request", op=op, k=len(rows),
                            pred_s=pred):
            if self.impl == "scan":
                # the store commits the rewrites into the history per
                # request (O(1) pointer swap for resident storage, codec
                # write-back for streamed tiers) so dataset bookkeeping and
                # the rewritten cache never diverge even if a later request
                # dies mid-stream
                params, rstat = run_online_request(
                    self.grad_fn, self.store, self._cols(), sched, self.cfg,
                    static_dev=self._static_dev(sched),
                    seg_grad_fn=self._seg_grad_fn)
            else:
                params, rstat = _online_request_python(
                    self.grad_fn, self.history, self.ds, sched, self.cfg)
                self.history.finalize(params)
        ring = rstat.extra.pop("lbfgs_ring", None)
        if ring is not None:
            self.last_ring = ring

        if op == "delete":
            for row in rows:
                self.live[row] = False
                self.ds.removed[row] = True
        else:
            self.added.extend(rows)
        self.params = params
        return rstat

    # -- snapshot / restore (core.session.save/restore) --------------------

    def state_dict(self) -> Dict[str, Any]:
        """Stream state that cannot be rebuilt from the dataset alone:
        liveness over original AND added rows, the added-row arrival order
        (join-mask column assignment), staged capacities, and the last
        request's L-BFGS pair ring (recorded for completeness — rings are
        rebuilt from the rewritten path on every request, so restore does
        not feed it back into the math)."""
        state = {
            "live": np.asarray(self.live, dtype=bool).copy(),
            "added": list(self.added),
            "add_capacity": int(self.add_capacity),
            "base_n": int(self._base_n),
            "row_cap": int(self._row_cap),
            "lbfgs_ring": None,
        }
        if self.last_ring is not None:
            state["lbfgs_ring"] = jax.device_get(self.last_ring)
        return state

    def load_state(self, state: Dict[str, Any]) -> None:
        self.live = np.asarray(state["live"], dtype=bool).copy()
        self.added = list(state["added"])
        self.add_capacity = int(state["add_capacity"])
        self._base_n = int(state.get("base_n", self.ds.n))
        self._row_cap = max(int(state.get("row_cap", self.ds.n)), self.ds.n)
        ring = state.get("lbfgs_ring")
        self.last_ring = (jax.tree.map(jnp.asarray, ring)
                          if ring is not None else None)
        self._joins = None
        self._ensure_joins(len(self.added))
        if self.impl == "scan":
            self._idx_dev = self._idx_ver = None


def online_deltagrad(
    objective: Objective,
    history: TrainingHistory,
    ds: Dataset,
    requests: Sequence[Request],
    cfg: DeltaGradConfig,
    mode: str = "delete",
    warmup: bool = False,
    placement: Optional[PlacementPolicy] = None,
) -> Tuple[Any, OnlineStats]:
    """Process deletion/addition requests sequentially, rewriting history.

    `requests` is either a sequence of row indices (all treated as `mode`)
    or a sequence of ``(op, row)`` pairs for mixed delete/add streams.  For
    additions, rows must already be appended to `ds` (``ds.n`` >
    ``history.meta.n``); each joins the replayed batches through the
    deterministic `addition_mask` of `data.sampler`, matching the inclusion
    probability of original samples.  `warmup=True` runs (and times) a
    throwaway first request so `OnlineStats.compile_time_s` absorbs the
    trace/compile cost and `wall_time_s` measures the warm stream only.
    """
    assert mode in ("delete", "add")
    requests = list(requests)
    ops = [r[0] if isinstance(r, (tuple, list)) else mode for r in requests]
    n_adds = ops.count("add")
    engine = OnlineEngine(objective, history, ds, cfg,
                          warmup=sorted(set(ops)) if warmup else False,
                          add_capacity=n_adds, placement=placement)
    stats = OnlineStats(compile_time_s=engine.compile_time_s)
    t_start = time.perf_counter()
    for r in requests:
        op, row = r if isinstance(r, (tuple, list)) else (mode, r)
        t_req = time.perf_counter()
        rstat = engine.request(op, int(row))
        # host-side dispatch wall per request (no added device sync:
        # compile happens synchronously at trace time, so a cache-miss
        # first request shows up here and bench_online can report it
        # separately from the steady per-request cost)
        rstat.extra["dispatch_wall_s"] = time.perf_counter() - t_req
        stats.per_request.append(rstat)
    jax.block_until_ready(engine.params)
    stats.wall_time_s = time.perf_counter() - t_start
    return engine.params, stats


def _online_request_python(grad_fn, history, ds, sched: ReplaySchedule,
                           cfg) -> Tuple[Any, RetrainStats]:
    """Per-step oracle: one request driven from the host over the SAME
    precomputed schedule and jitted step math as the scan path (additions,
    momentum, offload tiers, and the parity reference)."""
    meta = history.meta
    op = sched.mode
    sign = 1 if op == "delete" else -1
    momentum = bool(meta.momentum)
    plan = build_plan(cfg, sched, online=True)
    params = history.params_at(0)
    vel = _tree_zeros(params) if momentum else None
    mom = jnp.float32(meta.momentum)
    clip = jnp.float32(cfg.guard_norm_clip)
    stats = RetrainStats()
    # zeros-initialized device pair ring, mirroring the scan path's
    # `_ring_append` / masked-solve semantics exactly (the same jitted
    # admission + compact solve, with slot occupancy derived FROM the ring,
    # so parity holds at ANY fill level — including a partially-filled
    # ring during burn-in)
    dWs = jax.tree.map(
        lambda x: jnp.zeros((cfg.history_size,) + x.shape, x.dtype), params)
    dGs = dWs
    ring_started = False
    eps = jnp.float32(cfg.curvature_eps)

    def changed_grad(t):
        has = jnp.float32(1.0 if sched.dB[t] > 0 else 0.0)
        g = grad_fn(params, ds.take(sched.changed_idx[t]),
                    jnp.asarray(sched.changed_w[t]))
        return jax.tree.map(lambda x: has * x, g)

    for t in range(meta.steps):
        code = plan[t]
        if code == SKIP:
            stats.skipped_steps += 1
            continue
        kept = jnp.float32(sched.kept[t])
        dB = jnp.float32(sched.dB[t])
        lr = jnp.float32(meta.lr_at(t))
        w_t, g_t = history.entry(t)
        explicit = code == EXPLICIT or not ring_started
        g_one = None

        if not explicit:
            g_one = changed_grad(t)
            stats.grad_examples += int(sched.dB[t])
            new_p, new_vel, g_new, ok = _online_approx_step(
                params, vel, w_t, g_t, dWs, dGs, g_one, lr, kept, dB, clip,
                mom, sign=sign, momentum=momentum)
            if cfg.guard and not bool(ok):
                stats.guard_fallbacks += 1
                explicit = True  # g_one is reused — true cost kept + dB
            else:
                history.overwrite(t, params, g_new)
                params, vel = new_p, new_vel
                stats.approx_steps += 1

        if explicit:
            g_base = grad_fn(params, ds.take(sched.idx[t]),
                             jnp.asarray(sched.kept_w[t]))
            if g_one is None:
                g_one = changed_grad(t)
                stats.grad_examples += int(sched.dB[t])
            stats.grad_examples += int(sched.kept[t])
            p_in = params
            params, vel, g_cur, dw, dg, admit = _online_explicit_math(
                params, vel, w_t, g_t, g_base, g_one, lr, kept, dB, mom,
                sign=sign, momentum=momentum)
            dWs, dGs = _ring_append(dWs, dGs, dw, dg, admit, eps)
            ring_started = True
            history.overwrite(t, p_in, g_cur)
            stats.explicit_steps += 1

    base = sched.kept.astype(np.int64)
    if op == "add":
        base = base + sched.dB.astype(np.int64)
    stats.grad_examples_baseline = int(base.sum())
    if ring_started:  # see run_online_request: snapshot state for sessions
        stats.extra["lbfgs_ring"] = (dWs, dGs)
    return params, stats
