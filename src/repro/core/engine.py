"""Unified compiled replay engine — the DeltaGrad hot path as one program.

Architecture (mapping to Wu et al., ICML 2020):

  Phase 0  SCHEDULE      `data.sampler.build_schedule` precomputes the whole
                         minibatch replay plan — (T, B) batch indices,
                         removal/addition overlap masks, per-step learning
                         rates — in one vectorized pass, then uploads it to
                         the device once.  This is the paper's "replay the
                         same minibatch sequence" assumption (§A.1.2) made a
                         data structure.

  Phase 1  RECORD        `run_training` — Algorithm 1's original SGD run,
                         executed as a single `jax.lax.scan`; the scan's
                         stacked outputs (w_t, g_t) ARE the optimization-path
                         cache (TrainingHistory's ``stacked`` tier), so
                         caching costs one device buffer instead of T host
                         round-trips.

  Phase 2  REPLAY        `run_replay` — Algorithm 1's retraining loop.
                         Explicit steps (t <= j0, or every T0) stay host-
                         driven because they mutate the L-BFGS pair buffer
                         with curvature admission (Algorithm 4's check).
                         Every maximal run of approx steps between two
                         explicit steps executes as ONE `lax.scan` whose body
                         reads (w_t, g_t) from the stacked history with
                         `lax.dynamic_slice`, evaluates gradients only on the
                         <= r changed rows present in B_t (the paper's eq.
                         (2)/(S7) update), applies the quasi-Hessian
                         correction B_t(w^I_t - w_t) via the compact L-BFGS
                         operator (Algorithm 2), and resolves the Algorithm-4
                         guard on-device with `lax.cond` — guard outcomes
                         come back as one stacked flag vector read once at
                         the end, never as a per-step blocking `bool()`.

  Phase 2' ONLINE        `run_online_request` — Algorithm 3 (Appendix C.2)
                         for BOTH request flavors (single-sample deletion and
                         addition) and both optimizers (plain SGD and
                         heavy-ball, whose velocity is reconstructed per
                         request inside the scan carry from vel_0 = 0): the
                         same segment scan additionally emits the rewritten
                         (w_t <- w^I_t, g_t <- g^a_t) pairs.  Rewrites —
                         including the explicit steps' — defer to ONE jitted
                         assembly + `lax.dynamic_update_slice` per contiguous
                         region per request, and once the L-BFGS buffer fills
                         the pair ring lives on device (where-gated
                         shift-append), so a steady request runs with zero
                         mid-request host syncs and per-request cost stays
                         independent of how many requests came before.
                         Addition requests extend the replayed batch with one
                         precomputed join-mask column per added row
                         (`data.sampler.build_online_schedule`); join
                         decisions are device arrays, never per-step host
                         calls.

  Phase 3  KERNEL        The non-momentum approx update is routed through
                         the Pallas ``kernels/fused_update`` op on TPU (one
                         HBM pass over the four parameter-sized operands);
                         CPU and tests use the numerically identical
                         ``ref.py`` oracle (or the kernel's interpret mode)
                         on the same flattened operands.

Where the history bytes live is `core.store`'s concern: stacked/device
tiers replay fully resident (optionally sharded across a mesh, with the
segment scans run under ``shard_map`` and per-example gradients
psum-reduced), host/disk tiers stream double-buffered segment windows to
the same compiled scans — and the two COMPOSE: a mesh-placed host/disk
tier streams per-shard encoded window segments (`ShardedStreamer`), the
scans consuming them under shard_map exactly like the resident sharded
path (window-granular gather source, same per-step all-gather plan).
Execution backends: ``impl="scan"`` (this
module's compiled path, all tiers) and ``impl="python"`` (the pre-refactor
per-step loop, kept as the parity oracle).  Numerics and counters
are identical between the two backends, guard ON or OFF.  The two
divergences documented after the engine refactor are resolved: (1) a scanned
segment that reports a guard fallback is re-run split at the first fallback
step, which then executes as a host explicit step and ADMITS its L-BFGS pair
exactly like the python loop (the cost is one host sync per scanned segment
when the guard is enabled — guard-off runs still sync nothing until the end);
(2) fallback steps charge their true `grad_examples` cost kept+dB in both
backends — the python loop now reuses the changed-row gradient it computed
in the rejected approx attempt instead of re-evaluating (and re-charging)
it in the explicit branch.

Frontends: `core.deltagrad.{sgd_train_with_cache, baseline_retrain,
deltagrad_retrain}` and `core.online.online_deltagrad` are thin wrappers
over this module.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.core.history import HistoryMeta, TrainingHistory
from repro.core.lbfgs import LbfgsBuffer, lbfgs_hvp_stacked_pytree
from repro.core.store import (EncodedLeaf, HistoryStore, auto_window,
                              entry_at, is_encoded_window,
                              make_psum_grad_fn, pad_schedule_batch)
from repro.data.dataset import Dataset
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.roofline.replay import scan_segment_cost
from repro.data.sampler import (ReplaySchedule, addition_mask,
                                batch_indices, batch_indices_all,
                                build_schedule)
from repro.utils.tree import (tree_all_finite, tree_norm, tree_sub,
                              tree_vdot)


# --------------------------------------------------------------------------
# Config / stats (the public dataclasses; re-exported by core.deltagrad)
# --------------------------------------------------------------------------


@dataclass
class DeltaGradConfig:
    period: int = 5  # T0 — explicit gradient every T0 steps
    burn_in: int = 10  # j0 — initial explicit steps
    history_size: int = 2  # m — L-BFGS memory
    curvature_eps: float = 0.0  # pair admission threshold (Alg. 4 guard)
    guard: bool = False  # enable non-convex fallback checks
    guard_norm_clip: float = 1e4  # fallback if ||Bv|| > clip * ||v||
    removal_pad: int = 0  # 0 → auto (next pow2 of max per-batch overlap)
    impl: str = "scan"  # "scan" (compiled engine) | "python" (legacy loop)
    fused: str = "auto"  # "auto" | "pallas" | "interpret" | "ref"
    # steps per device-resident window when the history lives on an offload
    # tier (served by core.store.SegmentStreamer); 0 → auto
    stream_window: int = 0
    # streamed-window read path: "kernel" keeps windows ENCODED on device
    # and the scan dequantizes per step, "fetch" decodes each window to
    # f32 on arrival, "auto" → kernel for every non-f32 codec
    stream_decode: str = "auto"

    def is_explicit(self, t: int) -> bool:
        if t <= self.burn_in:
            return True
        return (t - self.burn_in) % self.period == 0


@dataclass
class RetrainStats:
    explicit_steps: int = 0
    approx_steps: int = 0
    guard_fallbacks: int = 0
    skipped_steps: int = 0  # empty effective batch (paper: no update)
    pairs_rejected: int = 0
    grad_examples: int = 0  # per-example gradient evaluations (DeltaGrad)
    grad_examples_baseline: int = 0  # what BaseL would have paid
    wall_time_s: float = 0.0
    extra: Dict[str, Any] = field(default_factory=dict)

    @property
    def theoretical_speedup(self) -> float:
        return self.grad_examples_baseline / max(self.grad_examples, 1)


def _scan_pred(n_params: int, steps: int, r: int, m: int,
               momentum: bool) -> Optional[float]:
    """Roofline-predicted cost (seconds) for a scanned replay segment —
    attached as ``pred_s`` to ``replay.scan`` spans so the exported trace
    carries measured-vs-roofline ratios.  Returns None (and computes
    nothing) while tracing is disabled, keeping the tracer-off hot path
    free of the prediction arithmetic."""
    if not obs_trace.enabled():
        return None
    return scan_segment_cost(n_params, steps, r, m, momentum=momentum).pred_s


def _publish_replay_metrics(stats: "RetrainStats", store) -> None:
    """Publish one finished replay's counters into the process-wide
    `repro.obs.metrics` registry (see the contract table in `repro.obs`)."""
    reg = obs_metrics.get_registry()
    own = "core.engine"
    reg.counter("engine.replays", owner=own).inc()
    reg.counter("engine.explicit_steps", owner=own).inc(stats.explicit_steps)
    reg.counter("engine.approx_steps", owner=own).inc(stats.approx_steps)
    reg.counter("engine.guard_fallbacks",
                owner=own).inc(stats.guard_fallbacks)
    reg.counter("engine.grad_examples", owner=own).inc(stats.grad_examples)
    hw = store.hbm_high_water() if store is not None else 0
    if hw:
        reg.gauge("store.hbm_high_water_bytes", unit="B",
                  owner="core.store").set_max(hw)


# --------------------------------------------------------------------------
# Step plan
# --------------------------------------------------------------------------

SKIP, EXPLICIT, APPROX = 0, 1, 2


def _next_pow2(x: int) -> int:
    return 1 << max(0, (x - 1)).bit_length()


def build_plan(cfg: DeltaGradConfig, sched: ReplaySchedule,
               online: bool = False) -> np.ndarray:
    """Per-step execution codes.  SKIP (empty effective batch, paper §3)
    takes precedence over the explicit/approx cadence.  Batch mode skips any
    emptied batch; online mode mirrors Algorithm 3's condition exactly — skip
    only when the REQUEST row sits in a batch whose other rows are all gone
    (kept == 0 and dB > 0); request-absent empty batches still execute, as
    degenerate no-op/l2-only steps, matching the python oracle."""
    T = sched.steps
    codes = np.full(T, APPROX, dtype=np.int8)
    for t in range(T):
        if cfg.is_explicit(t):
            codes[t] = EXPLICIT
    if sched.mode == "delete":
        empty = sched.kept <= 0
        codes[empty & (sched.dB > 0) if online else empty] = SKIP
    return codes


class DeviceSchedule(NamedTuple):
    """`ReplaySchedule` uploaded to the device once per retraining run."""

    idx: jax.Array  # (T, B) i32
    kept_w: jax.Array  # (T, B) f32
    changed_idx: jax.Array  # (T, R) i32
    changed_w: jax.Array  # (T, R) f32
    dB: jax.Array  # (T,) f32
    kept: jax.Array  # (T,) f32
    lr: jax.Array  # (T,) f32


def to_device(sched: ReplaySchedule, idx=None, lr=None) -> DeviceSchedule:
    """Upload a schedule; pass already-uploaded `idx`/`lr` to reuse them
    (they are request-invariant across an online stream)."""
    return DeviceSchedule(
        idx=jnp.asarray(sched.idx, dtype=jnp.int32) if idx is None else idx,
        kept_w=jnp.asarray(sched.kept_w),
        changed_idx=jnp.asarray(sched.changed_idx, dtype=jnp.int32),
        changed_w=jnp.asarray(sched.changed_w),
        dB=jnp.asarray(sched.dB),
        kept=jnp.asarray(sched.kept),
        lr=jnp.asarray(sched.lr) if lr is None else lr,
    )


def _gather(cols, rows):
    return {k: c[rows] for k, c in cols.items()}


# --------------------------------------------------------------------------
# Update math (shared by scan bodies, host explicit steps and the python
# oracle — one definition, identical numerics everywhere)
# --------------------------------------------------------------------------


def _sgd_math(p, g, lr):
    return jax.tree.map(lambda a, b: a - lr * b, p, g)


def _momentum_math(p, vel, g, lr, mom):
    """Heavy-ball: vel <- mom*vel + g; p <- p - lr*vel."""
    vel = jax.tree.map(lambda v, b: mom * v + b, vel, g)
    return jax.tree.map(lambda a, v: a - lr * v, p, vel), vel


@jax.jit
def _sgd_apply(p, g, lr):
    return _sgd_math(p, g, lr)


@jax.jit
def _momentum_apply(p, vel, g, lr, mom):
    return _momentum_math(p, vel, g, lr, mom)


@jax.jit
def _tree_zeros(p):
    return jax.tree.map(jnp.zeros_like, p)


def _resolve_fused(fused: str) -> str:
    assert fused in ("auto", "pallas", "interpret", "ref"), fused
    if fused == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return fused


def _run_fused(w, g, b, c, lr, B, dB, s, fused: str):
    from repro.kernels.fused_update.ops import update as fused_op
    from repro.kernels.fused_update.ref import deltagrad_update_ref

    if fused == "pallas":
        return fused_op(w, g, b, c, lr, B, dB, s)
    if fused == "interpret":
        return fused_op(w, g, b, c, lr, B, dB, s, interpret=True)
    return deltagrad_update_ref(w, g, b, c, lr, B, dB, s)


def _flat_fused_update(params, g_t, bv, g_changed, lr, B, dB, sign: int,
                       fused: str, axis: Optional[str] = None,
                       n_shards: int = 1):
    """Paper eq. (2)/(S7) on the FLATTENED parameter vector, through the
    Pallas fused kernel (TPU), its interpret mode, or the jnp reference —
    all three compute w - lr/(B - sign*dB) * (B*(g_t + Bv) - sign*dB*g_c).

    Inside a shard_map body (`axis` set), the kernel is routed PER SHARD:
    each mesh member along `axis` runs the fused op on its 1/n_shards tile
    of the flattened vector and the tiles all-gather back — the update is
    elementwise, so the split is exact."""
    w, unravel = ravel_pytree(params)
    g, _ = ravel_pytree(g_t)
    b, _ = ravel_pytree(bv)
    c, _ = ravel_pytree(g_changed)
    s = jnp.float32(sign)
    if axis is not None and n_shards > 1:
        p = w.shape[0]
        pp = -(-p // n_shards) * n_shards
        ps = pp // n_shards
        i = jax.lax.axis_index(axis)

        def cut(x):
            return jax.lax.dynamic_slice(jnp.pad(x, (0, pp - p)),
                                         (i * ps,), (ps,))

        out = _run_fused(cut(w), cut(g), cut(b), cut(c), lr, B, dB, s,
                         fused)
        out = jax.lax.all_gather(out, axis, axis=0, tiled=True)[:p]
    else:
        out = _run_fused(w, g, b, c, lr, B, dB, s, fused)
    return unravel(out)


def _enc_slice_args(leaf: EncodedLeaf, i):
    """(q, scale, base) of step ``i`` of one encoded window leaf, flattened
    for the `kernels.dequant_update` ops (scale is per (leaf, step), which
    is why the fused dequant kernels route PER LEAF)."""
    q = leaf.q[i].reshape(-1)
    scale = leaf.scale[i] if leaf.scale is not None else jnp.float32(1.0)
    base = None if leaf.base is None \
        else leaf.base[leaf.kidx[i]].reshape(-1)
    return q, scale, base


def _dequant_sub_tree(params, W, i, fused: str):
    """``v = params - w_t`` with the cached parameter operand consumed
    ENCODED — the `dequant_sub` Pallas kernel dequantizes in registers, so
    no f32 copy of w_t is ever materialized."""
    from repro.kernels.dequant_update.ops import dequant_sub

    def one(p, leaf):
        if not isinstance(leaf, EncodedLeaf):
            return p - leaf[i]
        q, scale, base = _enc_slice_args(leaf, i)
        out = dequant_sub(p.reshape(-1), q, scale, base,
                          interpret=fused == "interpret")
        return out.reshape(p.shape)

    return jax.tree.map(one, params, W)


def _dequant_fused_update(params, G, i, bv, g_changed, lr, B, dB, sign: int,
                          fused: str):
    """The non-momentum approx update with the cached gradient operand
    consumed ENCODED — `dequant_update` fuses the dequant with the
    leave-r-out step, per leaf (per-leaf scales)."""
    from repro.kernels.dequant_update.ops import dequant_update

    def one(p, leaf, b, c):
        if not isinstance(leaf, EncodedLeaf):
            denom = jnp.maximum(B - sign * dB, 1.0)
            return p - lr * (B * (leaf[i] + b) - sign * dB * c) / denom
        q, scale, base = _enc_slice_args(leaf, i)
        out = dequant_update(p.reshape(-1), q, b.reshape(-1), c.reshape(-1),
                             lr, B, dB, sign, scale, base,
                             interpret=fused == "interpret")
        return out.reshape(p.shape)

    return jax.tree.map(one, params, G, bv, g_changed)


def _approx_math(g_t, bv, g_changed, B, dB, sign: int):
    """The paper's eq. (2)/(S7) leave-r-out (add-r) gradient estimate
    g^a = (B*(g_t + Bv) - sign*dB*g_c) / max(B - sign*dB, 1) — the ONE
    definition shared by the python oracle, both scan bodies, and the online
    rewrite (there with B = B_t(k), dB = 1{req in batch})."""
    denom = jnp.maximum(B - sign * dB, 1.0)
    return jax.tree.map(
        lambda gt, b, gc: (B * (gt + b) - sign * dB * gc) / denom,
        g_t, bv, g_changed)


@partial(jax.jit, static_argnames=("sign",))
def _approx_update(params, w_t, g_t, dWs, dGs, g_changed, lr, B, dB, clip,
                   sign: int):
    """Legacy tree-math approx step (python oracle path)."""
    v = tree_sub(params, w_t)
    bv = lbfgs_hvp_stacked_pytree(dWs, dGs, v)
    g_est = _approx_math(g_t, bv, g_changed, B, dB, sign)
    new = jax.tree.map(lambda p, g: p - lr * g, params, g_est)
    bn = tree_norm(bv)
    vn = tree_norm(v)
    ok = jnp.logical_and(tree_all_finite(new), bn <= clip * vn)
    return new, ok


@partial(jax.jit, static_argnames=("sign",))
def _approx_gradient(params, w_t, g_t, dWs, dGs, g_changed, B, dB, clip,
                     sign: int):
    """The leave-r-out gradient ESTIMATE (eq. (2) numerator/denominator)
    without applying it — the momentum extension needs the gradient."""
    v = tree_sub(params, w_t)
    bv = lbfgs_hvp_stacked_pytree(dWs, dGs, v)
    g_est = _approx_math(g_t, bv, g_changed, B, dB, sign)
    ok = jnp.logical_and(tree_all_finite(g_est),
                         tree_norm(bv) <= clip * tree_norm(v))
    return g_est, ok


@partial(jax.jit, static_argnames=("sign",))
def _combine_explicit(g_kept, g_changed, k, dB, B, sign: int):
    """(g_full, g_step): the pair-definition gradient over the ORIGINAL
    batch and the leave-r-out / add-r update gradient (paper §A.1.2)."""
    if sign > 0:  # delete
        g_full = jax.tree.map(lambda a, b: (k * a + dB * b) / B,
                              g_kept, g_changed)
        g_step = g_kept
    else:  # add
        g_full = g_kept
        g_step = jax.tree.map(lambda a, b: (B * a + dB * b) / (B + dB),
                              g_kept, g_changed)
    return g_full, g_step


# --------------------------------------------------------------------------
# Phase 1: RECORD — original training as one scan
# --------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("grad_fn", "momentum"))
def _train_scan(params0, vel0, cols, idx, lr, w_ones, mom, *, grad_fn,
                momentum: bool):
    def body(carry, xs):
        params, vel = carry
        rows, lr_t = xs
        g = grad_fn(params, _gather(cols, rows), w_ones)
        if momentum:
            new_p, new_vel = _momentum_math(params, vel, g, lr_t, mom)
        else:
            new_p, new_vel = _sgd_math(params, g, lr_t), vel
        return (new_p, new_vel), (params, g)

    (pT, velT), (Ws, Gs) = jax.lax.scan(body, (params0, vel0), (idx, lr))
    return pT, velT, Ws, Gs


def run_training(
    objective,
    params0,
    ds: Dataset,
    meta: HistoryMeta,
    tier: str = "device",
    codec: str = "f32",
    spill_dir: Optional[str] = None,
    impl: str = "scan",
    window: int = 0,
    spill_window: Optional[int] = None,
) -> Tuple[Any, TrainingHistory]:
    """Train w_t by plain SGD (the paper's optimizer), caching (w_t, g_t).

    ``window`` bounds the recorder's device high-water on offload tiers
    (steps scanned per spill; 0 → the same auto default
    `core.store.SegmentStreamer` uses on the read path).  On the disk
    tier, spills batch ONE .npz per ``spill_window`` steps (None → match
    the stream window; 1 → the legacy one-file-per-step layout, which
    stays readable either way)."""
    grad_fn = objective.make_grad_fn()
    momentum = bool(meta.momentum)
    vel = _tree_zeros(params0) if momentum else None
    B = min(meta.batch_size, meta.n)
    if spill_window is None:
        spill_window = auto_window(meta.steps, window) if tier == "disk" \
            else 0
    history = TrainingHistory(meta, tier=tier, codec=codec,
                              spill_dir=spill_dir, spill_window=spill_window)

    if impl == "python":
        ones = np.ones(B, dtype=np.float32)
        params = params0
        for t in range(meta.steps):
            idx = batch_indices(meta.seed, t, meta.n, meta.batch_size)
            g = grad_fn(params, ds.take(idx), ones)
            history.append(params, g)
            if momentum:
                params, vel = _momentum_apply(params, vel, g,
                                              jnp.float32(meta.lr_at(t)),
                                              jnp.float32(meta.momentum))
            else:
                params = _sgd_apply(params, g, jnp.float32(meta.lr_at(t)))
        history.finalize(params)
        return params, history

    idx_all = batch_indices_all(meta.seed, meta.steps, meta.n, meta.batch_size)
    lrs = np.asarray([meta.lr_at(t) for t in range(meta.steps)], np.float32)
    cols = ds.device_columns()
    idx_dev = jnp.asarray(idx_all, jnp.int32)
    lr_dev = jnp.asarray(lrs)
    ones = jnp.ones((B,), jnp.float32)
    mom = jnp.float32(meta.momentum)

    if tier in ("host", "disk"):
        # offload tiers keep the full path OUT of device memory, but the
        # recorder still runs compiled: scan one WINDOW of steps at a
        # time and spill each window's (Ws, Gs) through the codec — the
        # device never holds more than one window of the path (the read
        # path mirrors this via core.store.SegmentStreamer)
        L = auto_window(meta.steps, window)
        params = params0
        for a in range(0, meta.steps, L):
            b = min(meta.steps, a + L)
            params, vel, Ws, Gs = _train_scan(
                params, vel, cols, idx_dev[a:b], lr_dev[a:b], ones, mom,
                grad_fn=grad_fn, momentum=momentum)
            host_w, host_g = jax.device_get((Ws, Gs))
            for i in range(b - a):
                history.append(jax.tree.map(lambda x: x[i], host_w),
                               jax.tree.map(lambda x: x[i], host_g))
        history.finalize(params)
        return params, history

    params, _, Ws, Gs = _train_scan(
        params0, vel, cols, idx_dev, lr_dev, ones, mom, grad_fn=grad_fn,
        momentum=momentum)
    history.set_stacked(Ws, Gs, final_params=params)
    return params, history


# --------------------------------------------------------------------------
# BaseL: exact retraining from scratch, also one scan
# --------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("grad_fn", "momentum", "mode"))
def _baseline_scan(params0, vel0, cols, sd: DeviceSchedule, mom, *, grad_fn,
                   momentum: bool, mode: str):
    def body(carry, t):
        params, vel = carry
        if mode == "delete":
            batch = _gather(cols, sd.idx[t])
            w = sd.kept_w[t]
        else:
            batch = {k: jnp.concatenate([c[sd.idx[t]], c[sd.changed_idx[t]]])
                     for k, c in cols.items()}
            w = jnp.concatenate([sd.kept_w[t], sd.changed_w[t]])
        g = grad_fn(params, batch, w)
        if momentum:
            new_p, new_vel = _momentum_math(params, vel, g, sd.lr[t], mom)
        else:
            new_p, new_vel = _sgd_math(params, g, sd.lr[t]), vel
        upd = sd.kept[t] > 0 if mode == "delete" else jnp.bool_(True)
        new_p = jax.tree.map(lambda n, o: jnp.where(upd, n, o), new_p, params)
        if momentum:
            new_vel = jax.tree.map(lambda n, o: jnp.where(upd, n, o),
                                   new_vel, vel)
        return (new_p, new_vel), None

    T = sd.idx.shape[0]
    (pT, _), _ = jax.lax.scan(body, (params0, vel0), jnp.arange(T))
    return pT


def run_baseline(
    objective,
    ds: Dataset,
    meta: HistoryMeta,
    params0,
    changed_idx: np.ndarray,
    mode: str = "delete",
    impl: str = "scan",
) -> Tuple[Any, RetrainStats]:
    """BaseL: exact retraining on the modified dataset, replaying the
    original schedule (paper eq. (1) / (S6))."""
    assert mode in ("delete", "add")
    changed_idx = np.asarray(changed_idx, dtype=np.int64)
    grad_fn = objective.make_grad_fn()
    momentum = bool(meta.momentum)
    stats = RetrainStats()
    t0 = time.perf_counter()
    r_pad = _next_pow2(max(1, len(changed_idx)))
    sched = build_schedule(meta.seed, meta.steps, meta.n, meta.batch_size,
                           changed_idx, mode, r_pad, meta.lr_at)

    eff = sched.kept.astype(np.int64) \
        + (sched.dB.astype(np.int64) if mode == "add" else 0)
    nonskip = eff > 0
    stats.grad_examples = int(eff[nonskip].sum())
    stats.skipped_steps = int((~nonskip).sum())
    stats.explicit_steps = meta.steps

    if impl == "python":
        params = params0
        vel = _tree_zeros(params0) if momentum else None
        B = min(meta.batch_size, meta.n)
        n_add = len(changed_idx) if mode == "add" else 0
        pad_to = B + n_add
        for t in range(meta.steps):
            idx = batch_indices(meta.seed, t, meta.n, meta.batch_size)
            if mode == "delete":
                eff_t = idx[~np.isin(idx, changed_idx)]
            else:
                joins = addition_mask(meta.seed, t, meta.n, meta.batch_size,
                                      n_add)
                eff_t = np.concatenate([idx, changed_idx[joins]])
            if len(eff_t) == 0:
                continue
            batch, weights = ds.padded_batch(eff_t, pad_to)
            g = grad_fn(params, batch, weights)
            if momentum:
                params, vel = _momentum_apply(params, vel, g,
                                              jnp.float32(meta.lr_at(t)),
                                              jnp.float32(meta.momentum))
            else:
                params = _sgd_apply(params, g, jnp.float32(meta.lr_at(t)))
        stats.wall_time_s = time.perf_counter() - t0
        return params, stats

    vel = _tree_zeros(params0) if momentum else None
    params = _baseline_scan(params0, vel, ds.device_columns(),
                            to_device(sched), jnp.float32(meta.momentum),
                            grad_fn=grad_fn, momentum=momentum, mode=mode)
    jax.block_until_ready(params)
    stats.wall_time_s = time.perf_counter() - t0
    return params, stats


# --------------------------------------------------------------------------
# Phase 2: REPLAY — Algorithm 1 with scanned approx segments
# --------------------------------------------------------------------------


def _replay_segment_impl(params, vel, t0, off, W, G, cols,
                         sd: DeviceSchedule, dWs, dGs, B, clip, mom, *,
                         grad_fn, sign: int, momentum: bool, fused: str,
                         span: int, gather=None, axis=None,
                         n_shards: int = 1):
    """One approx segment [t0, t0+span) as a single scan.

    Per step: dynamic-slice (w_t, g_t) out of the stacked history WINDOW
    (leaves indexed ``t - off``; ``off`` is 0 for a fully resident path and
    the window start for a streamed one — see `core.store`), gradient on
    the <= R changed rows only, compact L-BFGS correction, fused update.
    The Algorithm-4 guard verdict is DETECTION-only here: the stacked `oks`
    output flags failing steps, and the caller re-runs the segment split at
    the first failure so that step executes as a host explicit step (which
    admits its L-BFGS pair — see `run_replay`).  Steps after a failed guard
    may therefore carry garbage; the caller discards them.

    Under `core.store.ShardedReplay` this same body runs inside shard_map:
    `grad_fn` is the psum-reducing variant (the schedule arrives
    batch-sharded), `gather` all-gathers sharded history leaves one step
    at a time, and (`axis`, `n_shards`) route the fused kernel per shard.

    ENCODED windows (`EncodedLeaf` leaves — the streamers' kernel decode
    mode) dequantize per step inside this scan.  On the default jnp path
    `entry_at` slice-decodes (XLA fuses the elementwise dequant); the
    unsharded non-momentum Pallas path instead routes the encoded leaves
    straight into `kernels.dequant_update` — dequant fused with the
    subtract (v = w - w_t) and with the approx update in registers, no
    f32 window copy anywhere."""
    use_dq = (is_encoded_window(W) and not momentum and axis is None
              and fused in ("pallas", "interpret"))

    def body(carry, t):
        params, vel = carry
        lr, dB, kept = sd.lr[t], sd.dB[t], sd.kept[t]
        has = (dB > 0).astype(jnp.float32)
        g_changed = jax.tree.map(
            lambda x: has * x,
            grad_fn(params, _gather(cols, sd.changed_idx[t]),
                    sd.changed_w[t]))
        if use_dq:
            v = _dequant_sub_tree(params, W, t - off, fused)
        else:
            w_t = entry_at(W, t, off, gather)
            v = tree_sub(params, w_t)
        bv = lbfgs_hvp_stacked_pytree(dWs, dGs, v)
        guard_ok = tree_norm(bv) <= clip * tree_norm(v)
        if momentum:
            g_t = entry_at(G, t, off, gather)
            g_est = _approx_math(g_t, bv, g_changed, B, dB, sign)
            ok = jnp.logical_and(tree_all_finite(g_est), guard_ok)
            new_p, new_vel = _momentum_math(params, vel, g_est, lr, mom)
        elif use_dq:
            new_p = _dequant_fused_update(params, G, t - off, bv, g_changed,
                                          lr, B, dB, sign, fused)
            ok = jnp.logical_and(tree_all_finite(new_p), guard_ok)
            new_vel = vel
        else:
            g_t = entry_at(G, t, off, gather)
            new_p = _flat_fused_update(params, g_t, bv, g_changed, lr, B, dB,
                                       sign, fused, axis=axis,
                                       n_shards=n_shards)
            ok = jnp.logical_and(tree_all_finite(new_p), guard_ok)
            new_vel = vel

        upd = kept > 0 if sign > 0 else jnp.bool_(True)
        new_p = jax.tree.map(lambda n, o: jnp.where(upd, n, o), new_p, params)
        new_vel = jax.tree.map(lambda n, o: jnp.where(upd, n, o), new_vel, vel)
        return (new_p, new_vel), ok

    (params, vel), oks = jax.lax.scan(body, (params, vel),
                                      t0 + jnp.arange(span))
    return params, vel, oks


_replay_segment = partial(jax.jit, static_argnames=(
    "grad_fn", "sign", "momentum", "fused", "span", "gather", "axis",
    "n_shards"))(_replay_segment_impl)


def run_replay(
    objective,
    history: TrainingHistory,
    ds: Dataset,
    changed_idx: np.ndarray,
    cfg: DeltaGradConfig,
    mode: str = "delete",
    params0=None,
    placement=None,
    store: Optional[HistoryStore] = None,
) -> Tuple[Any, RetrainStats]:
    """Algorithm 1 (GD + SGD unified; GD == SGD with batch_size >= n).

    Where the history bytes live is `core.store.HistoryStore`'s problem:
    stacked/device tiers replay fully resident (optionally mesh-sharded —
    pass a `PlacementPolicy` or a prebuilt store), host/disk tiers stream
    device-resident segment windows with prefetch.  Only
    ``cfg.impl="python"`` still selects the per-step oracle loop."""
    assert mode in ("delete", "add")
    if cfg.impl == "python":
        return _run_replay_python(objective, history, ds, changed_idx, cfg,
                                  mode, params0)
    if store is None:
        store = HistoryStore.create(history, placement=placement,
                                    window=cfg.stream_window,
                                    decode=cfg.stream_decode)

    meta = history.meta
    changed_idx = np.asarray(changed_idx, dtype=np.int64)
    r = len(changed_idx)
    B = min(meta.batch_size, meta.n)
    grad_fn = objective.make_grad_fn()
    momentum = bool(meta.momentum)
    sign = 1 if mode == "delete" else -1
    fused = _resolve_fused(cfg.fused)
    r_pad = cfg.removal_pad or _next_pow2(max(1, min(r, B)))
    runner = store.sharded_replay()

    t_start = time.perf_counter()
    with obs_trace.span("replay.schedule_build", steps=meta.steps, r=r):
        sched = build_schedule(meta.seed, meta.steps, meta.n,
                               meta.batch_size, changed_idx, mode, r_pad,
                               meta.lr_at)
        plan = build_plan(cfg, sched)
        sd = to_device(sched)
    if runner is not None:
        sd = pad_schedule_batch(sd, runner.placement.data_size)
        seg_grad_fn = make_psum_grad_fn(objective,
                                        runner.placement.data_axis)
        gather = runner.gather_info()
        axis = runner.placement.data_axis
        n_shards = runner.placement.data_size
    cols = ds.device_columns()
    buffer = LbfgsBuffer(cfg.history_size, curvature_eps=cfg.curvature_eps)

    params = params0 if params0 is not None else history.params_at(0)
    vel = _tree_zeros(params) if momentum else None
    Bf = jnp.float32(B)
    clip = jnp.float32(cfg.guard_norm_clip)
    mom = jnp.float32(meta.momentum)
    stats = RetrainStats()
    T = meta.steps
    seg_oks: List[Tuple[int, int, Any]] = []  # (t0, t1, device flags)

    n_params = (sum(x.size for x in jax.tree.leaves(params))
                if obs_trace.enabled() else 0)

    def scan_segment(p, v, a, b):
        with obs_trace.span(
                "replay.scan", t0=a, t1=b,
                pred_s=_scan_pred(n_params, b - a, r_pad,
                                  cfg.history_size, momentum)):
            W, G, off = store.window(a, b)
            if runner is not None:
                fn = runner.wrap(
                    partial(_replay_segment_impl, grad_fn=seg_grad_fn,
                            sign=sign, momentum=momentum, fused=fused,
                            span=b - a, gather=gather, axis=axis,
                            n_shards=n_shards),
                    key=("replay", b - a, sign, momentum, fused),
                    n_outputs=3)
                return fn(p, v, jnp.int32(a), jnp.int32(off), W, G, cols,
                          sd, dWs, dGs, Bf, clip, mom)
            return _replay_segment(
                p, v, jnp.int32(a), jnp.int32(off), W, G, cols, sd, dWs,
                dGs, Bf, clip, mom, grad_fn=grad_fn, sign=sign,
                momentum=momentum, fused=fused, span=b - a)

    def explicit_step(p, v, tt):
        with obs_trace.span("replay.explicit", t0=tt, steps=1):
            return _host_explicit_step(
                grad_fn, buffer, p, v, tt, store, cols, sd,
                float(sched.kept[tt]), float(sched.dB[tt]), Bf, mom, sign,
                momentum, stats)

    t = 0
    while t < T:
        code = plan[t]
        if code == EXPLICIT or (code == APPROX and len(buffer) == 0):
            params, vel = explicit_step(params, vel, t)
            t += 1
        elif code == SKIP and len(buffer) == 0:
            t += 1
        else:
            t2 = t
            while t2 < T and plan[t2] != EXPLICIT:
                t2 += 1
            while t < t2:
                # a streamed store may cap the scan at its window boundary;
                # resident stores always run the whole segment at once
                b = store.span_end(t, t2)
                dWs, dGs = buffer.stacked()
                p_in, v_in = params, vel
                params, vel, oks = scan_segment(p_in, v_in, t, b)
                if cfg.guard:
                    # segment-splitting retry: one host sync per scanned
                    # segment (guard ON only); if any step tripped the
                    # Algorithm-4 guard, keep the all-ok prefix, run the
                    # tripped step as a host explicit step (admitting its
                    # L-BFGS pair like the python loop), and rescan the rest
                    # with the enlarged buffer.  Split spans stay below the
                    # explicit period, so at most period-2 extra scan
                    # compilations exist per stream — the prefix re-run is
                    # the real cost when fallbacks are dense (ROADMAP: a
                    # lax.while_loop formulation could keep this on device).
                    fell = np.flatnonzero(
                        (plan[t:b] != SKIP) & ~np.asarray(oks))
                    if fell.size:
                        tf = t + int(fell[0])
                        with obs_trace.span("replay.guard_retry", t=tf,
                                            prefix=tf - t):
                            if tf > t:
                                params, vel, oks_p = scan_segment(
                                    p_in, v_in, t, tf)
                                seg_oks.append((t, tf, oks_p))
                            else:
                                params, vel = p_in, v_in
                            stats.guard_fallbacks += 1
                            params, vel = explicit_step(params, vel, tf)
                        t = tf + 1
                        continue
                seg_oks.append((t, b, oks))
                t = b

    # counters resolved once at the end — no per-step host syncs (with the
    # guard enabled, recorded segments are all-ok by construction: fallback
    # steps were peeled off and accounted as host explicit steps above)
    for t0_, t1_, oks in seg_oks:
        nonskip = plan[t0_:t1_] != SKIP
        dB_i = sched.dB[t0_:t1_].astype(np.int64)
        if cfg.guard:
            stats.approx_steps += int((nonskip & np.asarray(oks)).sum())
        else:
            stats.approx_steps += int(nonskip.sum())
        stats.grad_examples += int(dB_i[nonskip].sum())
    stats.skipped_steps = int((plan == SKIP).sum())
    base = sched.kept.astype(np.int64) if mode == "delete" \
        else sched.kept.astype(np.int64) + sched.dB.astype(np.int64)
    stats.grad_examples_baseline = int(base.sum())
    jax.block_until_ready(params)
    stats.wall_time_s = time.perf_counter() - t_start
    stats.extra["buffer_admitted"] = buffer.admitted
    stats.extra["buffer_rejected"] = buffer.rejected
    stats.extra["impl"] = "scan"
    stats.extra["fused"] = fused
    stats.extra["store"] = store.kind
    stats.extra["hbm_high_water"] = store.hbm_high_water()
    stats.extra["segments"] = max(1, len(seg_oks))
    if getattr(store, "windows_fetched", 0):
        stats.extra["windows"] = store.windows_fetched
        stats.extra["host_wait_s"] = store.host_wait_s
        stats.extra["prefetch_depth"] = store.depth_used
        stats.extra["host_stage_high"] = store.host_stage_high
        stats.extra["stream_decode"] = store.decode_mode
        stats.extra["encoded_bytes_high"] = store.enc_bytes_high
        stats.extra["compression_ratio"] = store.compression_ratio
    if history.io_read_s or history.io_write_s:
        # disk-tier spill IO (cumulative; windowed spills batch one .npz
        # per window — see TrainingHistory)
        stats.extra["spill_io_read_s"] = history.io_read_s
        stats.extra["spill_io_write_s"] = history.io_write_s
    if runner is not None:
        stats.extra["mesh"] = runner.placement.describe()
    _publish_replay_metrics(stats, store)
    return params, stats


@partial(jax.jit, static_argnames=("grad_fn", "sign", "momentum"))
def _explicit_step(params, vel, t, w_t, g_t, cols, sd: DeviceSchedule, B,
                   mom, *, grad_fn, sign: int, momentum: bool):
    """The whole explicit step as ONE program: kept + changed gradients
    against the store-served (w_t, g_t) history entry, pair construction
    (with the Algorithm-4 admission inner products), and the parameter
    update.  The host only syncs the two admission scalars — one
    round-trip per explicit step."""
    k, dB, lr = sd.kept[t], sd.dB[t], sd.lr[t]
    g_kept = grad_fn(params, _gather(cols, sd.idx[t]), sd.kept_w[t])
    has = (dB > 0).astype(jnp.float32)
    g_changed = jax.tree.map(
        lambda x: has * x,
        grad_fn(params, _gather(cols, sd.changed_idx[t]), sd.changed_w[t]))
    g_full, g_step = _combine_explicit(g_kept, g_changed, k, dB, B, sign)
    dw = tree_sub(params, w_t)
    dg = tree_sub(g_full, g_t)
    admit = jnp.stack([tree_vdot(dg, dw), tree_vdot(dw, dw)])
    if momentum:
        new_p, new_vel = _momentum_math(params, vel, g_step, lr, mom)
    else:
        new_p, new_vel = _sgd_math(params, g_step, lr), vel
    return new_p, new_vel, dw, dg, admit


def _host_explicit_step(grad_fn, buffer, params, vel, t, store, cols, sd,
                        k, dB, Bf, mom, sign, momentum, stats):
    """One explicit step (host-driven: it mutates the L-BFGS buffer)."""
    w_t, g_t = store.entry(t)
    params, vel, dw, dg, admit = _explicit_step(
        params, vel, t, w_t, g_t, cols, sd, Bf, mom, grad_fn=grad_fn,
        sign=sign, momentum=momentum)
    curv, ss = np.asarray(admit)
    if not buffer.add_pair(dw, dg, float(curv), float(ss)):
        stats.pairs_rejected += 1
    stats.grad_examples += int(k + dB)
    stats.explicit_steps += 1
    return params, vel


def _run_replay_python(objective, history, ds, changed_idx, cfg, mode,
                       params0):
    """The pre-refactor per-step loop, verbatim — parity oracle + disk tier."""
    meta = history.meta
    changed_idx = np.asarray(changed_idx, dtype=np.int64)
    r = len(changed_idx)
    n, B = meta.n, min(meta.batch_size, meta.n)
    grad_fn = objective.make_grad_fn()
    buffer = LbfgsBuffer(cfg.history_size, curvature_eps=cfg.curvature_eps)

    r_pad = cfg.removal_pad or _next_pow2(max(1, min(r, B)))
    n_add = r if mode == "add" else 0
    clip = jnp.float32(cfg.guard_norm_clip)
    mom = jnp.float32(meta.momentum) if meta.momentum else None

    params = params0 if params0 is not None else history.params_at(0)
    vel = _tree_zeros(params) if meta.momentum else None
    stats = RetrainStats()
    t0 = time.perf_counter()

    for t in range(meta.steps):
        idx = batch_indices(meta.seed, t, n, meta.batch_size)
        if mode == "delete":
            kept_idx, changed_in = ds.split_batch(idx, removed_set=changed_idx)
        else:
            joins = addition_mask(meta.seed, t, n, meta.batch_size, n_add)
            kept_idx, changed_in = idx, changed_idx[joins]
        dB = len(changed_in)
        k = len(kept_idx)
        lr = jnp.float32(meta.lr_at(t))
        stats.grad_examples_baseline += (k if mode == "delete" else k + dB)

        if mode == "delete" and k == 0:
            stats.skipped_steps += 1  # paper §3: B - dB_t == 0 → no update
            continue

        explicit = cfg.is_explicit(t)
        w_t, g_t = history.entry(t)
        g_changed = None  # set by the approx attempt; reused on fallback

        if not explicit and len(buffer) == 0:
            explicit = True  # nothing to approximate with yet

        if not explicit:
            # ---- approx step: gradients only on the changed samples --------
            if dB > 0:
                cb, cw = ds.padded_batch(changed_in, r_pad)
                g_changed = grad_fn(params, cb, cw)
                stats.grad_examples += dB
            else:
                g_changed = _tree_zeros(params)
            dWs, dGs = buffer.stacked()
            sign = 1 if mode == "delete" else -1
            if mom is not None:
                g_est, ok = _approx_gradient(
                    params, w_t, g_t, dWs, dGs, g_changed,
                    jnp.float32(B), jnp.float32(dB), clip, sign)
                if cfg.guard and not bool(ok):
                    stats.guard_fallbacks += 1
                    explicit = True
                else:
                    params, vel = _momentum_apply(params, vel, g_est, lr, mom)
                    stats.approx_steps += 1
            else:
                new_params, ok = _approx_update(
                    params, w_t, g_t, dWs, dGs, g_changed, lr,
                    jnp.float32(B), jnp.float32(dB), clip, sign
                )
                if cfg.guard and not bool(ok):
                    stats.guard_fallbacks += 1
                    explicit = True  # fall through to the explicit branch
                else:
                    params = new_params
                    stats.approx_steps += 1

        if explicit:
            # ---- explicit step: full-batch gradient at w^I_t ---------------
            kb, kw = ds.padded_batch(kept_idx,
                                     B if mode == "delete" else B + n_add)
            g_kept = grad_fn(params, kb, kw)
            if g_changed is None:
                # regular explicit step — the changed-row gradient was not
                # evaluated yet; a guard fallback already computed (and
                # charged) it at these same params, so reuse it there and
                # charge this step its true cost k + dB either way.
                if dB > 0:
                    cb, cw = ds.padded_batch(changed_in, r_pad)
                    g_changed = grad_fn(params, cb, cw)
                else:
                    g_changed = _tree_zeros(params)
                stats.grad_examples += dB
            stats.grad_examples += k

            if mode == "delete":
                # mean over the ORIGINAL batch (pair definition, §A.1.2)
                g_full = jax.tree.map(
                    lambda a, b: (k * a + dB * b) / float(B), g_kept, g_changed
                )
                g_step = g_kept  # mean over kept == leave-r-out update
            else:
                g_full = g_kept  # original batch == kept in add mode
                g_step = jax.tree.map(
                    lambda a, b: (B * a + dB * b) / float(B + dB),
                    g_kept, g_changed
                )

            dw = tree_sub(params, w_t)
            dg = tree_sub(g_full, g_t)
            if not buffer.add(dw, dg):
                stats.pairs_rejected += 1
            if mom is not None:
                params, vel = _momentum_apply(params, vel, g_step, lr, mom)
            else:
                params = _sgd_apply(params, g_step, lr)
            stats.explicit_steps += 1

    stats.wall_time_s = time.perf_counter() - t0
    stats.extra["buffer_admitted"] = buffer.admitted
    stats.extra["buffer_rejected"] = buffer.rejected
    stats.extra["impl"] = "python"
    return params, stats


# --------------------------------------------------------------------------
# Phase 2': ONLINE — Algorithm 3 (delete AND add, SGD AND heavy-ball) with
# history rewrite in the scan
# --------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("sign", "momentum"))
def _online_approx_step(params, vel, w_t, g_t, dWs, dGs, g_one, lr, kept, dB,
                        clip, mom, *, sign: int, momentum: bool):
    """One Algorithm-3 approx step — the quasi-Hessian-corrected gradient of
    the post-request objective at params (eq. (S62), with the per-step
    PRE-request batch size kept+dB for deletes / kept for adds), the
    resulting SGD or heavy-ball update, and the guard verdict.

    The pair ring is the zeros-initialized device ring and may be PARTIALLY
    filled during burn-in: the masked compact solve derives slot occupancy
    from the ring itself (`lbfgs.ring_valid_mask`) and is bitwise identical
    to the unmasked solve once the ring is full.

    This is the ONE definition shared verbatim by the scan body and the
    per-step python oracle (`core.online`), which is what makes
    scan-vs-python parity hold to float32 round-off."""
    b_prev = kept + dB if sign > 0 else kept
    v = tree_sub(params, w_t)
    bv = lbfgs_hvp_stacked_pytree(dWs, dGs, v, masked=True)
    g_new = _approx_math(g_t, bv, g_one, b_prev, dB, sign)
    if momentum:
        new_p, new_vel = _momentum_math(params, vel, g_new, lr, mom)
    else:
        new_p, new_vel = _sgd_math(params, g_new, lr), vel
    ok = jnp.logical_and(tree_all_finite(new_p),
                         tree_norm(bv) <= clip * tree_norm(v))
    return new_p, new_vel, g_new, ok


@partial(jax.jit, static_argnames=("sign", "momentum"))
def _online_explicit_math(params, vel, w_t, g_t, g_base, g_one, lr, kept, dB,
                          mom, *, sign: int, momentum: bool):
    """Online explicit-step math shared by the device step and the oracle.

    `g_base` is the gradient over the scheduled kept rows — the POST-request
    batch for deletes, the PRE-request batch for adds; mixing in the request
    row's `g_one` yields the other one.  Returns the updated (params, vel),
    the post-request gradient `g_cur` (the cache rewrite value), and the
    L-BFGS pair built against the PRE-request gradient (paper §A.1.2 pair
    definition carried over to the rewritten path)."""
    has = dB > 0
    denom = jnp.maximum(kept + dB, 1.0)
    mix = jax.tree.map(
        lambda a, b: jnp.where(has, (kept * a + dB * b) / denom, a),
        g_base, g_one)
    g_cur, g_prev = (g_base, mix) if sign > 0 else (mix, g_base)
    dw = tree_sub(params, w_t)
    dg = tree_sub(g_prev, g_t)
    admit = jnp.stack([tree_vdot(dg, dw), tree_vdot(dw, dw)])
    if momentum:
        new_p, new_vel = _momentum_math(params, vel, g_cur, lr, mom)
    else:
        new_p, new_vel = _sgd_math(params, g_cur, lr), vel
    return new_p, new_vel, g_cur, dw, dg, admit


def _online_segment_impl(params, vel, t0, off, W, G, cols,
                         sd: DeviceSchedule, dWs, dGs, clip, mom, *,
                         grad_fn, sign: int, momentum: bool, span: int,
                         gather=None):
    """Online approx segment: like `_replay_segment` but with the per-step
    effective batch size (paper's n-k bookkeeping), the velocity carried in
    the scan state for heavy-ball histories, and the rewrite pairs
    (w_t <- w^I_t, g_t <- g^a_t, eq. (S62)) emitted as stacked scan outputs.
    Guard verdicts are detection-only, as in `_replay_segment`.  History
    leaves are indexed ``t - off`` (window offset for streamed stores) and
    all-gathered per the `gather` plan when sharded across a mesh."""

    def body(carry, t):
        params, vel = carry
        w_t = entry_at(W, t, off, gather)
        g_t = entry_at(G, t, off, gather)
        lr, dB, kept = sd.lr[t], sd.dB[t], sd.kept[t]
        has = (dB > 0).astype(jnp.float32)
        g_one = jax.tree.map(
            lambda x: has * x,
            grad_fn(params, _gather(cols, sd.changed_idx[t]),
                    sd.changed_w[t]))
        new_p, new_vel, g_new, ok = _online_approx_step(
            params, vel, w_t, g_t, dWs, dGs, g_one, lr, kept, dB, clip, mom,
            sign=sign, momentum=momentum)

        if sign > 0:  # Algorithm 3's skip: request emptied the whole batch
            skip = jnp.logical_and(kept <= 0, dB > 0)
        else:
            skip = jnp.bool_(False)
        new_p = jax.tree.map(lambda n, o: jnp.where(skip, o, n), new_p,
                             params)
        new_vel = jax.tree.map(lambda n, o: jnp.where(skip, o, n), new_vel,
                               vel)
        w_wr = jax.tree.map(lambda n, o: jnp.where(skip, o, n), params, w_t)
        g_wr = jax.tree.map(lambda n, o: jnp.where(skip, o, n), g_new, g_t)
        return (new_p, new_vel), (w_wr, g_wr, ok)

    (params, vel), (w_writes, g_writes, oks) = jax.lax.scan(
        body, (params, vel), t0 + jnp.arange(span))
    return params, vel, w_writes, g_writes, oks


_online_segment = partial(jax.jit, static_argnames=(
    "grad_fn", "sign", "momentum", "span", "gather"))(_online_segment_impl)


@partial(jax.jit, static_argnames=("grad_fn", "sign", "momentum"))
def _online_explicit_step(params, vel, t, w_t, g_t, cols,
                          sd: DeviceSchedule, mom, *, grad_fn, sign: int,
                          momentum: bool):
    """Online explicit step fused into one program: kept and changed-row
    gradients against the store-served history entry, the pre/post-request
    gradient pair, and the update.  Only the two L-BFGS admission scalars
    return to the host; the cache rewrite value `g_cur` is handed back so
    the caller can batch it into the end-of-request flush instead of
    scattering per step."""
    kept, dB, lr = sd.kept[t], sd.dB[t], sd.lr[t]
    g_base = grad_fn(params, _gather(cols, sd.idx[t]), sd.kept_w[t])
    has = (dB > 0).astype(jnp.float32)
    g_one = jax.tree.map(
        lambda x: has * x,
        grad_fn(params, _gather(cols, sd.changed_idx[t]), sd.changed_w[t]))
    return _online_explicit_math(params, vel, w_t, g_t, g_base, g_one, lr,
                                 kept, dB, mom, sign=sign, momentum=momentum)


@jax.jit
def _ring_append(dWs, dGs, dw, dg, admit, eps):
    """Where-gated shift-append of the stacked (m, ...) pair ring with the
    admission rule `<dg, dw> >= eps * <dw, dw>` resolved ON DEVICE.  The
    ring starts as exact zeros, so the masked compact solve
    (`lbfgs.compact_coeffs_masked` via `ring_valid_mask`) can consume it at
    ANY fill level — burn-in no longer needs a host-side buffer phase.
    Shared by the fused device step and the python oracle so admission is
    one definition."""
    ok = jnp.logical_and(admit[1] > 0.0, admit[0] >= eps * admit[1])
    dWs = jax.tree.map(
        lambda b, n: jnp.where(
            ok, jnp.concatenate([b[1:], n[None].astype(b.dtype)]), b),
        dWs, dw)
    dGs = jax.tree.map(
        lambda b, n: jnp.where(
            ok, jnp.concatenate([b[1:], n[None].astype(b.dtype)]), b),
        dGs, dg)
    return dWs, dGs


@partial(jax.jit, static_argnames=("grad_fn", "sign", "momentum"))
def _online_explicit_fused(params, vel, t, w_t, g_t, cols,
                           sd: DeviceSchedule, dWs, dGs, eps, mom, *,
                           grad_fn, sign: int, momentum: bool):
    """`_online_explicit_step` with the Algorithm-4 pair admission resolved
    ON DEVICE via `_ring_append` — every explicit step (burn-in included)
    runs this fused program against the zeros-initialized ring, so an
    online request has ZERO mid-request host syncs (guard off).  No fill
    count crosses this program's boundary: occupancy is derived from the
    ring by the masked solve, which keeps this program — and so the
    full-ring replay results — bitwise identical to the pre-masking
    engine."""
    new_p, new_vel, g_cur, dw, dg, admit = _online_explicit_step(
        params, vel, t, w_t, g_t, cols, sd, mom, grad_fn=grad_fn, sign=sign,
        momentum=momentum)
    dWs, dGs = _ring_append(dWs, dGs, dw, dg, admit, eps)
    return new_p, new_vel, g_cur, dWs, dGs


def run_online_request(
    grad_fn,
    store: HistoryStore,
    cols,
    sched: ReplaySchedule,
    cfg: DeltaGradConfig,
    static_dev: Optional[Tuple[jax.Array, jax.Array]] = None,
    seg_grad_fn=None,
    commit: bool = True,
) -> Tuple[Any, RetrainStats]:
    """One online request — a single row or a coalesced GROUP of rows
    (delete or add — `sched.mode`, width `sched.r_pad`) — against the
    current cached path, served through a `core.store.HistoryStore`
    (resident — optionally mesh-sharded — or streamed from an offload
    tier).  Returns (params, stats); rewrites are committed into the store
    (and through it into the history) before returning.

    `sched` comes from `data.sampler.build_online_schedule` (the caller owns
    the stream state: liveness, added rows, join masks).  `static_dev` is
    the request-invariant (idx, lr) pair already on device — pass it so a
    stream uploads the (T, B [+pad]) schedule once, not per request.
    `seg_grad_fn` (default `grad_fn`) is what scanned segments use — the
    psum-reducing variant when the store is mesh-sharded.

    History rewrites are fully deferred: explicit steps hand their (w, g)
    rewrite back instead of scattering per step, segment outputs stay as
    stacked chunks, and each maximal contiguous region of rewrites lands in
    ONE jitted assembly + scatter (resident) or codec write-back (streamed)
    in `store.commit` (sound because every step is visited once and reads
    only its original entry).  Momentum-trained histories replay with the
    heavy-ball velocity reconstructed from vel_0 = 0 in the scan carry; the
    cache keeps storing plain gradients, so each request's reconstruction
    is self-contained (Algorithm 3 with momentum)."""
    meta = store.meta
    op = sched.mode
    sign = 1 if op == "delete" else -1
    momentum = bool(meta.momentum)
    plan = build_plan(cfg, sched, online=True)
    sd = to_device(sched, *(static_dev or (None, None)))
    runner = store.sharded_replay()
    gather = None
    if runner is not None:
        sd = pad_schedule_batch(sd, runner.placement.data_size)
        gather = runner.gather_info()
    if seg_grad_fn is None:
        seg_grad_fn = grad_fn
    params = store.params0()  # w_0 is never rewritten
    n_params = (sum(x.size for x in jax.tree.leaves(params))
                if obs_trace.enabled() else 0)
    vel = _tree_zeros(params) if momentum else None
    clip = jnp.float32(cfg.guard_norm_clip)
    mom = jnp.float32(meta.momentum)
    stats = RetrainStats()
    T = meta.steps
    seg_oks: List[Tuple[int, int, Any]] = []

    # Deferred history rewrites.  Every step t is visited exactly once per
    # request and only ever READS the original entry at t, so nothing needs
    # to land in (W, G) before the request completes: rewrites accumulate as
    # contiguous chunks — explicit-step runs and scanned-segment outputs —
    # and ONE jitted assembly per contiguous region scatters them all
    # (`store.commit`; steady streams compile it once).
    regions: List[Tuple[int, List[str], List, List]] = []
    write_end = -1

    def _region(t):
        if not regions or t != write_end:
            regions.append((t, [], [], []))
        return regions[-1]

    def note_single(t, w, g):
        nonlocal write_end
        _, kinds, pw, pg = _region(t)
        if not kinds or kinds[-1] != "run":
            kinds.append("run")
            pw.append([])
            pg.append([])
        pw[-1].append(w)
        pg[-1].append(g)
        write_end = t + 1

    def note_seg(t, span, w, g):
        nonlocal write_end
        _, kinds, pw, pg = _region(t)
        kinds.append("seg")
        pw.append(w)
        pg.append(g)
        write_end = t + span

    # The L-BFGS pair ring lives ON DEVICE from step 0: a zeros-initialized
    # stacked (m, ...) ring plus an admitted-pair `count`, appended to by the
    # where-gated `_ring_append` inside every fused explicit step and read by
    # scanned segments through the MASKED compact solve
    # (`lbfgs.compact_coeffs_masked` — exact at any fill level, bitwise
    # identical to the unmasked solve once the ring is full).  Burn-in no
    # longer runs a host-side buffer phase, so a request has zero
    # mid-request host syncs even before the ring fills (guard off).
    dWs = jax.tree.map(
        lambda x: jnp.zeros((cfg.history_size,) + x.shape, x.dtype), params)
    dGs = dWs
    ring_started = False  # True once any explicit step ran (plan invariant:
    #                       the first non-skipped step is always explicit)
    eps = jnp.float32(cfg.curvature_eps)

    def do_explicit(params, vel, t, r2):
        nonlocal dWs, dGs, ring_started
        with obs_trace.span("replay.explicit", t0=t, steps=r2 - t):
            for tt in range(t, r2):
                p_in = params
                w_t, g_t = store.entry(tt)
                params, vel, g_cur, dWs, dGs = _online_explicit_fused(
                    params, vel, tt, w_t, g_t, cols, sd, dWs, dGs, eps,
                    mom, grad_fn=grad_fn, sign=sign, momentum=momentum)
                note_single(tt, p_in, g_cur)
        ring_started = True
        stats.grad_examples += int(
            (sched.kept[t:r2] + sched.dB[t:r2]).sum())
        stats.explicit_steps += r2 - t
        return params, vel

    t = 0
    while t < T:
        code = plan[t]
        if code == EXPLICIT or (code == APPROX and not ring_started):
            r2 = t + 1
            if code == EXPLICIT:
                while r2 < T and plan[r2] == EXPLICIT:
                    r2 += 1
            params, vel = do_explicit(params, vel, t, r2)
            t = r2
        elif code == SKIP and not ring_started:
            t += 1  # entry stays as-is; the write region simply breaks here
        else:
            t2 = t
            while t2 < T and plan[t2] != EXPLICIT:
                t2 += 1

            def scan_segment(p, v, a, b, pW, pG):
                with obs_trace.span(
                        "replay.scan", t0=a, t1=b,
                        pred_s=_scan_pred(n_params, b - a, sched.r_pad,
                                          cfg.history_size, momentum)):
                    Wd, Gd, off = store.window(a, b)
                    if runner is not None:
                        fn = runner.wrap(
                            partial(_online_segment_impl,
                                    grad_fn=seg_grad_fn, sign=sign,
                                    momentum=momentum, span=b - a,
                                    gather=gather),
                            key=("online", b - a, sign, momentum),
                            n_outputs=5)
                        return fn(p, v, jnp.int32(a), jnp.int32(off), Wd,
                                  Gd, cols, sd, pW, pG, clip, mom)
                    return _online_segment(
                        p, v, jnp.int32(a), jnp.int32(off), Wd, Gd, cols,
                        sd, pW, pG, clip, mom, grad_fn=seg_grad_fn,
                        sign=sign, momentum=momentum, span=b - a)

            while t < t2:
                b = store.span_end(t, t2)
                pW, pG = dWs, dGs
                p_in, v_in = params, vel
                params, vel, w_wr, g_wr, oks = scan_segment(
                    p_in, v_in, t, b, pW, pG)
                if cfg.guard:
                    # segment-splitting retry (see run_replay): the tripped
                    # step becomes an explicit step that admits its pair and
                    # rewrites the exact post-request gradient; the failed
                    # segment's outputs are never noted, so they are simply
                    # dropped from the flush.
                    fell = np.flatnonzero(
                        (plan[t:b] != SKIP) & ~np.asarray(oks))
                    if fell.size:
                        tf = t + int(fell[0])
                        with obs_trace.span("replay.guard_retry", t=tf,
                                            prefix=tf - t):
                            if tf > t:
                                params, vel, w_wr, g_wr, oks_p = \
                                    scan_segment(p_in, v_in, t, tf, pW, pG)
                                note_seg(t, tf - t, w_wr, g_wr)
                                seg_oks.append((t, tf, oks_p))
                            else:
                                params, vel = p_in, v_in
                            stats.guard_fallbacks += 1
                            params, vel = do_explicit(params, vel, tf,
                                                      tf + 1)
                        t = tf + 1
                        continue
                note_seg(t, b - t, w_wr, g_wr)
                seg_oks.append((t, b, oks))
                t = b

    if commit:
        with obs_trace.span("replay.commit", regions=len(regions)):
            store.commit(regions, final_params=params)

    for t0_, t1_, oks in seg_oks:
        nonskip = plan[t0_:t1_] != SKIP
        if cfg.guard:
            stats.approx_steps += int((nonskip & np.asarray(oks)).sum())
        else:
            stats.approx_steps += int(nonskip.sum())
        stats.grad_examples += int(
            sched.dB[t0_:t1_].astype(np.int64)[nonskip].sum())
    stats.skipped_steps = int((plan == SKIP).sum())
    base = sched.kept.astype(np.int64)
    if op == "add":
        base = base + sched.dB.astype(np.int64)
    stats.grad_examples_baseline = int(base.sum())
    stats.extra["store"] = store.kind
    stats.extra["hbm_high_water"] = store.hbm_high_water()
    if getattr(store, "windows_fetched", 0):
        stats.extra["windows"] = store.windows_fetched
        stats.extra["prefetch_depth"] = store.depth_used
    if runner is not None:
        stats.extra["mesh"] = runner.placement.describe()
    # the end-of-request pair ring, for session snapshots (the ring is
    # rebuilt from the rewritten path on every request, so this is state
    # a snapshot records rather than state the next request consumes);
    # the engine pops it off extra so logged stats stay device-array-free
    if ring_started:
        stats.extra["lbfgs_ring"] = (dWs, dGs)
    _publish_replay_metrics(stats, store)
    return params, stats
