"""Unified compiled replay engine — the DeltaGrad hot path as one program.

Architecture (mapping to Wu et al., ICML 2020):

  Phase 0  SCHEDULE      `data.sampler.build_schedule` precomputes the whole
                         minibatch replay plan — (T, B) batch indices,
                         removal/addition overlap masks, per-step learning
                         rates — in one vectorized pass, then uploads it to
                         the device once.  This is the paper's "replay the
                         same minibatch sequence" assumption (§A.1.2) made a
                         data structure.

  Phase 1  RECORD        `run_training` — Algorithm 1's original SGD run,
                         executed as a single `jax.lax.scan`; the scan's
                         stacked outputs (w_t, g_t) ARE the optimization-path
                         cache (TrainingHistory's ``stacked`` tier), so
                         caching costs one device buffer instead of T host
                         round-trips.

  Phase 2  REPLAY        `run_replay` — Algorithm 1's retraining loop.
                         Explicit steps (t <= j0, or every T0) stay host-
                         driven because they mutate the L-BFGS pair buffer
                         with curvature admission (Algorithm 4's check).
                         Every maximal run of approx steps between two
                         explicit steps executes as ONE `lax.scan` whose body
                         reads (w_t, g_t) from the stacked history with
                         `lax.dynamic_slice`, evaluates gradients only on the
                         <= r changed rows present in B_t (the paper's eq.
                         (2)/(S7) update), applies the quasi-Hessian
                         correction B_t(w^I_t - w_t) via the compact L-BFGS
                         operator (Algorithm 2), and resolves the Algorithm-4
                         guard on-device with `lax.cond` — guard outcomes
                         come back as one stacked flag vector read once at
                         the end, never as a per-step blocking `bool()`.

  Phase 2' ONLINE        `run_online` — Algorithm 3 (Appendix C.2): the same
                         segment scan additionally emits the rewritten
                         (w_t <- w^I_t, g_t <- g^a_t) pairs, which are
                         written back into the stacked history with
                         `lax.dynamic_update_slice`, keeping per-request cost
                         independent of how many requests came before.

  Phase 3  KERNEL        The non-momentum approx update is routed through
                         the Pallas ``kernels/fused_update`` op on TPU (one
                         HBM pass over the four parameter-sized operands);
                         CPU and tests use the numerically identical
                         ``ref.py`` oracle (or the kernel's interpret mode)
                         on the same flattened operands.

Execution backends: ``impl="scan"`` (this module's compiled path) and
``impl="python"`` (the pre-refactor per-step loop, kept verbatim as the
parity oracle and as the fallback for the disk history tier).  Numerics are
identical to the legacy loop for guard-off runs; with the guard ON the scan
path differs in two documented ways on guard-FALLBACK steps only: (1) the
fallback applies the exact leave-r-out update but does not admit an L-BFGS
pair mid-segment (the python loop does), since pair admission is host state;
(2) `grad_examples` charges such steps their true cost kept+dB, where the
python loop re-evaluates the changed-row gradient and charges kept+2*dB.

Frontends: `core.deltagrad.{sgd_train_with_cache, baseline_retrain,
deltagrad_retrain}` and `core.online.online_deltagrad` are thin wrappers
over this module.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.core.history import HistoryMeta, TrainingHistory
from repro.core.lbfgs import LbfgsBuffer, lbfgs_hvp_stacked_pytree
from repro.data.dataset import Dataset
from repro.data.sampler import (ReplaySchedule, addition_mask,
                                batch_indices, batch_indices_all,
                                build_schedule)
from repro.utils.tree import (tree_all_finite, tree_norm, tree_sub,
                              tree_vdot)


# --------------------------------------------------------------------------
# Config / stats (the public dataclasses; re-exported by core.deltagrad)
# --------------------------------------------------------------------------


@dataclass
class DeltaGradConfig:
    period: int = 5  # T0 — explicit gradient every T0 steps
    burn_in: int = 10  # j0 — initial explicit steps
    history_size: int = 2  # m — L-BFGS memory
    curvature_eps: float = 0.0  # pair admission threshold (Alg. 4 guard)
    guard: bool = False  # enable non-convex fallback checks
    guard_norm_clip: float = 1e4  # fallback if ||Bv|| > clip * ||v||
    removal_pad: int = 0  # 0 → auto (next pow2 of max per-batch overlap)
    impl: str = "scan"  # "scan" (compiled engine) | "python" (legacy loop)
    fused: str = "auto"  # "auto" | "pallas" | "interpret" | "ref"

    def is_explicit(self, t: int) -> bool:
        if t <= self.burn_in:
            return True
        return (t - self.burn_in) % self.period == 0


@dataclass
class RetrainStats:
    explicit_steps: int = 0
    approx_steps: int = 0
    guard_fallbacks: int = 0
    skipped_steps: int = 0  # empty effective batch (paper: no update)
    pairs_rejected: int = 0
    grad_examples: int = 0  # per-example gradient evaluations (DeltaGrad)
    grad_examples_baseline: int = 0  # what BaseL would have paid
    wall_time_s: float = 0.0
    extra: Dict[str, Any] = field(default_factory=dict)

    @property
    def theoretical_speedup(self) -> float:
        return self.grad_examples_baseline / max(self.grad_examples, 1)


# --------------------------------------------------------------------------
# Step plan
# --------------------------------------------------------------------------

SKIP, EXPLICIT, APPROX = 0, 1, 2


def _next_pow2(x: int) -> int:
    return 1 << max(0, (x - 1)).bit_length()


def build_plan(cfg: DeltaGradConfig, sched: ReplaySchedule,
               online: bool = False) -> np.ndarray:
    """Per-step execution codes.  SKIP (empty effective batch, paper §3)
    takes precedence over the explicit/approx cadence.  Batch mode skips any
    emptied batch; online mode mirrors Algorithm 3's condition exactly — skip
    only when the REQUEST row sits in a batch whose other rows are all gone
    (kept == 0 and dB > 0); request-absent empty batches still execute, as
    degenerate no-op/l2-only steps, matching the python oracle."""
    T = sched.steps
    codes = np.full(T, APPROX, dtype=np.int8)
    for t in range(T):
        if cfg.is_explicit(t):
            codes[t] = EXPLICIT
    if sched.mode == "delete":
        empty = sched.kept <= 0
        codes[empty & (sched.dB > 0) if online else empty] = SKIP
    return codes


class DeviceSchedule(NamedTuple):
    """`ReplaySchedule` uploaded to the device once per retraining run."""

    idx: jax.Array  # (T, B) i32
    kept_w: jax.Array  # (T, B) f32
    changed_idx: jax.Array  # (T, R) i32
    changed_w: jax.Array  # (T, R) f32
    dB: jax.Array  # (T,) f32
    kept: jax.Array  # (T,) f32
    lr: jax.Array  # (T,) f32


def to_device(sched: ReplaySchedule, idx=None, lr=None) -> DeviceSchedule:
    """Upload a schedule; pass already-uploaded `idx`/`lr` to reuse them
    (they are request-invariant across an online stream)."""
    return DeviceSchedule(
        idx=jnp.asarray(sched.idx, dtype=jnp.int32) if idx is None else idx,
        kept_w=jnp.asarray(sched.kept_w),
        changed_idx=jnp.asarray(sched.changed_idx, dtype=jnp.int32),
        changed_w=jnp.asarray(sched.changed_w),
        dB=jnp.asarray(sched.dB),
        kept=jnp.asarray(sched.kept),
        lr=jnp.asarray(sched.lr) if lr is None else lr,
    )


def _gather(cols, rows):
    return {k: c[rows] for k, c in cols.items()}


# --------------------------------------------------------------------------
# Update math (shared by scan bodies, host explicit steps and the python
# oracle — one definition, identical numerics everywhere)
# --------------------------------------------------------------------------


def _sgd_math(p, g, lr):
    return jax.tree.map(lambda a, b: a - lr * b, p, g)


def _momentum_math(p, vel, g, lr, mom):
    """Heavy-ball: vel <- mom*vel + g; p <- p - lr*vel."""
    vel = jax.tree.map(lambda v, b: mom * v + b, vel, g)
    return jax.tree.map(lambda a, v: a - lr * v, p, vel), vel


@jax.jit
def _sgd_apply(p, g, lr):
    return _sgd_math(p, g, lr)


@jax.jit
def _momentum_apply(p, vel, g, lr, mom):
    return _momentum_math(p, vel, g, lr, mom)


@jax.jit
def _tree_zeros(p):
    return jax.tree.map(jnp.zeros_like, p)


def _resolve_fused(fused: str) -> str:
    assert fused in ("auto", "pallas", "interpret", "ref"), fused
    if fused == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return fused


def _flat_fused_update(params, g_t, bv, g_changed, lr, B, dB, sign: int,
                       fused: str):
    """Paper eq. (2)/(S7) on the FLATTENED parameter vector, through the
    Pallas fused kernel (TPU), its interpret mode, or the jnp reference —
    all three compute w - lr/(B - sign*dB) * (B*(g_t + Bv) - sign*dB*g_c)."""
    from repro.kernels.fused_update.ops import update as fused_op
    from repro.kernels.fused_update.ref import deltagrad_update_ref

    w, unravel = ravel_pytree(params)
    g, _ = ravel_pytree(g_t)
    b, _ = ravel_pytree(bv)
    c, _ = ravel_pytree(g_changed)
    s = jnp.float32(sign)
    if fused == "pallas":
        out = fused_op(w, g, b, c, lr, B, dB, s)
    elif fused == "interpret":
        out = fused_op(w, g, b, c, lr, B, dB, s, interpret=True)
    else:
        out = deltagrad_update_ref(w, g, b, c, lr, B, dB, s)
    return unravel(out)


def _approx_math(g_t, bv, g_changed, B, dB, sign: int):
    """The paper's eq. (2)/(S7) leave-r-out (add-r) gradient estimate
    g^a = (B*(g_t + Bv) - sign*dB*g_c) / max(B - sign*dB, 1) — the ONE
    definition shared by the python oracle, both scan bodies, and the online
    rewrite (there with B = B_t(k), dB = 1{req in batch})."""
    denom = jnp.maximum(B - sign * dB, 1.0)
    return jax.tree.map(
        lambda gt, b, gc: (B * (gt + b) - sign * dB * gc) / denom,
        g_t, bv, g_changed)


@partial(jax.jit, static_argnames=("sign",))
def _approx_update(params, w_t, g_t, dWs, dGs, g_changed, lr, B, dB, clip,
                   sign: int):
    """Legacy tree-math approx step (python oracle path)."""
    v = tree_sub(params, w_t)
    bv = lbfgs_hvp_stacked_pytree(dWs, dGs, v)
    g_est = _approx_math(g_t, bv, g_changed, B, dB, sign)
    new = jax.tree.map(lambda p, g: p - lr * g, params, g_est)
    bn = tree_norm(bv)
    vn = tree_norm(v)
    ok = jnp.logical_and(tree_all_finite(new), bn <= clip * vn)
    return new, ok


@partial(jax.jit, static_argnames=("sign",))
def _approx_gradient(params, w_t, g_t, dWs, dGs, g_changed, B, dB, clip,
                     sign: int):
    """The leave-r-out gradient ESTIMATE (eq. (2) numerator/denominator)
    without applying it — the momentum extension needs the gradient."""
    v = tree_sub(params, w_t)
    bv = lbfgs_hvp_stacked_pytree(dWs, dGs, v)
    g_est = _approx_math(g_t, bv, g_changed, B, dB, sign)
    ok = jnp.logical_and(tree_all_finite(g_est),
                         tree_norm(bv) <= clip * tree_norm(v))
    return g_est, ok


@partial(jax.jit, static_argnames=("sign",))
def _combine_explicit(g_kept, g_changed, k, dB, B, sign: int):
    """(g_full, g_step): the pair-definition gradient over the ORIGINAL
    batch and the leave-r-out / add-r update gradient (paper §A.1.2)."""
    if sign > 0:  # delete
        g_full = jax.tree.map(lambda a, b: (k * a + dB * b) / B,
                              g_kept, g_changed)
        g_step = g_kept
    else:  # add
        g_full = g_kept
        g_step = jax.tree.map(lambda a, b: (B * a + dB * b) / (B + dB),
                              g_kept, g_changed)
    return g_full, g_step


# --------------------------------------------------------------------------
# Phase 1: RECORD — original training as one scan
# --------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("grad_fn", "momentum"))
def _train_scan(params0, vel0, cols, idx, lr, w_ones, mom, *, grad_fn,
                momentum: bool):
    def body(carry, xs):
        params, vel = carry
        rows, lr_t = xs
        g = grad_fn(params, _gather(cols, rows), w_ones)
        if momentum:
            new_p, new_vel = _momentum_math(params, vel, g, lr_t, mom)
        else:
            new_p, new_vel = _sgd_math(params, g, lr_t), vel
        return (new_p, new_vel), (params, g)

    (pT, _), (Ws, Gs) = jax.lax.scan(body, (params0, vel0), (idx, lr))
    return pT, Ws, Gs


def run_training(
    objective,
    params0,
    ds: Dataset,
    meta: HistoryMeta,
    tier: str = "device",
    codec: str = "f32",
    spill_dir: Optional[str] = None,
    impl: str = "scan",
) -> Tuple[Any, TrainingHistory]:
    """Train w_t by plain SGD (the paper's optimizer), caching (w_t, g_t)."""
    grad_fn = objective.make_grad_fn()
    momentum = bool(meta.momentum)
    vel = _tree_zeros(params0) if momentum else None
    B = min(meta.batch_size, meta.n)
    history = TrainingHistory(meta, tier=tier, codec=codec, spill_dir=spill_dir)

    # host/disk tiers exist to keep the full path OUT of device memory, so
    # they record per-entry; the scan recorder would materialize all T
    # entries on device first.
    if impl == "python" or tier in ("host", "disk"):
        ones = np.ones(B, dtype=np.float32)
        params = params0
        for t in range(meta.steps):
            idx = batch_indices(meta.seed, t, meta.n, meta.batch_size)
            g = grad_fn(params, ds.take(idx), ones)
            history.append(params, g)
            if momentum:
                params, vel = _momentum_apply(params, vel, g,
                                              jnp.float32(meta.lr_at(t)),
                                              jnp.float32(meta.momentum))
            else:
                params = _sgd_apply(params, g, jnp.float32(meta.lr_at(t)))
        history.finalize(params)
        return params, history

    idx_all = batch_indices_all(meta.seed, meta.steps, meta.n, meta.batch_size)
    lrs = np.asarray([meta.lr_at(t) for t in range(meta.steps)], np.float32)
    cols = ds.device_columns()
    params, Ws, Gs = _train_scan(
        params0, vel, cols, jnp.asarray(idx_all, jnp.int32),
        jnp.asarray(lrs), jnp.ones((B,), jnp.float32),
        jnp.float32(meta.momentum), grad_fn=grad_fn, momentum=momentum)
    history.set_stacked(Ws, Gs, final_params=params)
    return params, history


# --------------------------------------------------------------------------
# BaseL: exact retraining from scratch, also one scan
# --------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("grad_fn", "momentum", "mode"))
def _baseline_scan(params0, vel0, cols, sd: DeviceSchedule, mom, *, grad_fn,
                   momentum: bool, mode: str):
    def body(carry, t):
        params, vel = carry
        if mode == "delete":
            batch = _gather(cols, sd.idx[t])
            w = sd.kept_w[t]
        else:
            batch = {k: jnp.concatenate([c[sd.idx[t]], c[sd.changed_idx[t]]])
                     for k, c in cols.items()}
            w = jnp.concatenate([sd.kept_w[t], sd.changed_w[t]])
        g = grad_fn(params, batch, w)
        if momentum:
            new_p, new_vel = _momentum_math(params, vel, g, sd.lr[t], mom)
        else:
            new_p, new_vel = _sgd_math(params, g, sd.lr[t]), vel
        upd = sd.kept[t] > 0 if mode == "delete" else jnp.bool_(True)
        new_p = jax.tree.map(lambda n, o: jnp.where(upd, n, o), new_p, params)
        if momentum:
            new_vel = jax.tree.map(lambda n, o: jnp.where(upd, n, o),
                                   new_vel, vel)
        return (new_p, new_vel), None

    T = sd.idx.shape[0]
    (pT, _), _ = jax.lax.scan(body, (params0, vel0), jnp.arange(T))
    return pT


def run_baseline(
    objective,
    ds: Dataset,
    meta: HistoryMeta,
    params0,
    changed_idx: np.ndarray,
    mode: str = "delete",
    impl: str = "scan",
) -> Tuple[Any, RetrainStats]:
    """BaseL: exact retraining on the modified dataset, replaying the
    original schedule (paper eq. (1) / (S6))."""
    assert mode in ("delete", "add")
    changed_idx = np.asarray(changed_idx, dtype=np.int64)
    grad_fn = objective.make_grad_fn()
    momentum = bool(meta.momentum)
    stats = RetrainStats()
    t0 = time.perf_counter()
    r_pad = _next_pow2(max(1, len(changed_idx)))
    sched = build_schedule(meta.seed, meta.steps, meta.n, meta.batch_size,
                           changed_idx, mode, r_pad, meta.lr_at)

    eff = sched.kept.astype(np.int64) \
        + (sched.dB.astype(np.int64) if mode == "add" else 0)
    nonskip = eff > 0
    stats.grad_examples = int(eff[nonskip].sum())
    stats.skipped_steps = int((~nonskip).sum())
    stats.explicit_steps = meta.steps

    if impl == "python":
        params = params0
        vel = _tree_zeros(params0) if momentum else None
        B = min(meta.batch_size, meta.n)
        n_add = len(changed_idx) if mode == "add" else 0
        pad_to = B + n_add
        for t in range(meta.steps):
            idx = batch_indices(meta.seed, t, meta.n, meta.batch_size)
            if mode == "delete":
                eff_t = idx[~np.isin(idx, changed_idx)]
            else:
                joins = addition_mask(meta.seed, t, meta.n, meta.batch_size,
                                      n_add)
                eff_t = np.concatenate([idx, changed_idx[joins]])
            if len(eff_t) == 0:
                continue
            batch, weights = ds.padded_batch(eff_t, pad_to)
            g = grad_fn(params, batch, weights)
            if momentum:
                params, vel = _momentum_apply(params, vel, g,
                                              jnp.float32(meta.lr_at(t)),
                                              jnp.float32(meta.momentum))
            else:
                params = _sgd_apply(params, g, jnp.float32(meta.lr_at(t)))
        stats.wall_time_s = time.perf_counter() - t0
        return params, stats

    vel = _tree_zeros(params0) if momentum else None
    params = _baseline_scan(params0, vel, ds.device_columns(),
                            to_device(sched), jnp.float32(meta.momentum),
                            grad_fn=grad_fn, momentum=momentum, mode=mode)
    jax.block_until_ready(params)
    stats.wall_time_s = time.perf_counter() - t0
    return params, stats


# --------------------------------------------------------------------------
# Phase 2: REPLAY — Algorithm 1 with scanned approx segments
# --------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("grad_fn", "sign", "momentum", "guard",
                                   "fused", "span"))
def _replay_segment(params, vel, t0, W, G, cols, sd: DeviceSchedule, dWs, dGs,
                    B, clip, mom, *, grad_fn, sign: int, momentum: bool,
                    guard: bool, fused: str, span: int):
    """One approx segment [t0, t0+span) as a single scan.

    Per step: dynamic-slice (w_t, g_t) out of the stacked history, gradient
    on the <= R changed rows only, compact L-BFGS correction, fused update.
    The Algorithm-4 guard is a `lax.cond`: the fallback branch applies the
    exact leave-r-out update from the precomputed kept-row weights (it does
    NOT admit an L-BFGS pair — host state; see module docstring)."""

    def body(carry, t):
        params, vel = carry
        w_t = jax.tree.map(lambda x: x[t], W)
        g_t = jax.tree.map(lambda x: x[t], G)
        lr, dB, kept = sd.lr[t], sd.dB[t], sd.kept[t]
        has = (dB > 0).astype(jnp.float32)
        g_changed = jax.tree.map(
            lambda x: has * x,
            grad_fn(params, _gather(cols, sd.changed_idx[t]),
                    sd.changed_w[t]))
        v = tree_sub(params, w_t)
        bv = lbfgs_hvp_stacked_pytree(dWs, dGs, v)
        guard_ok = tree_norm(bv) <= clip * tree_norm(v)
        if momentum:
            g_est = _approx_math(g_t, bv, g_changed, B, dB, sign)
            ok = jnp.logical_and(tree_all_finite(g_est), guard_ok)
            new_p, new_vel = _momentum_math(params, vel, g_est, lr, mom)
        else:
            new_p = _flat_fused_update(params, g_t, bv, g_changed, lr, B, dB,
                                       sign, fused)
            ok = jnp.logical_and(tree_all_finite(new_p), guard_ok)
            new_vel = vel

        if guard:
            def fallback(_):
                g_kept = grad_fn(params, _gather(cols, sd.idx[t]),
                                 sd.kept_w[t])
                if sign > 0:
                    g_step = g_kept
                else:
                    g_step = jax.tree.map(
                        lambda a, b: (B * a + dB * b) / (B + dB),
                        g_kept, g_changed)
                if momentum:
                    return _momentum_math(params, vel, g_step, lr, mom)
                return _sgd_math(params, g_step, lr), vel

            new_p, new_vel = jax.lax.cond(
                ok, lambda _: (new_p, new_vel), fallback, None)

        upd = kept > 0 if sign > 0 else jnp.bool_(True)
        new_p = jax.tree.map(lambda n, o: jnp.where(upd, n, o), new_p, params)
        new_vel = jax.tree.map(lambda n, o: jnp.where(upd, n, o), new_vel, vel)
        return (new_p, new_vel), ok

    (params, vel), oks = jax.lax.scan(body, (params, vel),
                                      t0 + jnp.arange(span))
    return params, vel, oks


def run_replay(
    objective,
    history: TrainingHistory,
    ds: Dataset,
    changed_idx: np.ndarray,
    cfg: DeltaGradConfig,
    mode: str = "delete",
    params0=None,
) -> Tuple[Any, RetrainStats]:
    """Algorithm 1 (GD + SGD unified; GD == SGD with batch_size >= n)."""
    assert mode in ("delete", "add")
    impl = cfg.impl
    if impl == "scan" and history.tier in ("host", "disk"):
        # the offload tiers promise the cache does NOT live on device;
        # stacking it there for the scan would defeat them (ROADMAP: stream
        # segments host->device instead)
        impl = "python"
    if impl == "python":
        return _run_replay_python(objective, history, ds, changed_idx, cfg,
                                  mode, params0)

    meta = history.meta
    changed_idx = np.asarray(changed_idx, dtype=np.int64)
    r = len(changed_idx)
    B = min(meta.batch_size, meta.n)
    grad_fn = objective.make_grad_fn()
    momentum = bool(meta.momentum)
    sign = 1 if mode == "delete" else -1
    fused = _resolve_fused(cfg.fused)
    r_pad = cfg.removal_pad or _next_pow2(max(1, min(r, B)))

    t_start = time.perf_counter()
    sched = build_schedule(meta.seed, meta.steps, meta.n, meta.batch_size,
                           changed_idx, mode, r_pad, meta.lr_at)
    plan = build_plan(cfg, sched)
    sd = to_device(sched)
    cols = ds.device_columns()
    W, G = history.stacked_view()
    buffer = LbfgsBuffer(cfg.history_size, curvature_eps=cfg.curvature_eps)

    params = params0 if params0 is not None else history.params_at(0)
    vel = _tree_zeros(params) if momentum else None
    Bf = jnp.float32(B)
    clip = jnp.float32(cfg.guard_norm_clip)
    mom = jnp.float32(meta.momentum)
    stats = RetrainStats()
    T = meta.steps
    seg_oks: List[Tuple[int, int, Any]] = []  # (t0, t1, device flags)

    t = 0
    while t < T:
        code = plan[t]
        if code == EXPLICIT or (code == APPROX and len(buffer) == 0):
            params, vel = _host_explicit_step(
                grad_fn, buffer, params, vel, t, W, G, cols, sd,
                float(sched.kept[t]), float(sched.dB[t]), Bf, mom, sign,
                momentum, stats)
            t += 1
        elif code == SKIP and len(buffer) == 0:
            t += 1
        else:
            t2 = t
            while t2 < T and plan[t2] != EXPLICIT:
                t2 += 1
            dWs, dGs = buffer.stacked()
            params, vel, oks = _replay_segment(
                params, vel, jnp.int32(t), W, G, cols, sd, dWs, dGs, Bf,
                clip, mom, grad_fn=grad_fn, sign=sign, momentum=momentum,
                guard=cfg.guard, fused=fused, span=t2 - t)
            seg_oks.append((t, t2, oks))
            t = t2

    # counters resolved once at the end — no per-step host syncs
    for t0_, t1_, oks in seg_oks:
        oks = np.asarray(oks)
        nonskip = plan[t0_:t1_] != SKIP
        kept_i = sched.kept[t0_:t1_].astype(np.int64)
        dB_i = sched.dB[t0_:t1_].astype(np.int64)
        if cfg.guard:
            fell = nonskip & ~oks
            stats.approx_steps += int((nonskip & oks).sum())
            stats.guard_fallbacks += int(fell.sum())
            # fallback steps applied the exact update — count them as
            # explicit, matching the python oracle's accounting
            stats.explicit_steps += int(fell.sum())
            stats.grad_examples += int(kept_i[fell].sum())
        else:
            stats.approx_steps += int(nonskip.sum())
        stats.grad_examples += int(dB_i[nonskip].sum())
    stats.skipped_steps = int((plan == SKIP).sum())
    base = sched.kept.astype(np.int64) if mode == "delete" \
        else sched.kept.astype(np.int64) + sched.dB.astype(np.int64)
    stats.grad_examples_baseline = int(base.sum())
    jax.block_until_ready(params)
    stats.wall_time_s = time.perf_counter() - t_start
    stats.extra["buffer_admitted"] = buffer.admitted
    stats.extra["buffer_rejected"] = buffer.rejected
    stats.extra["impl"] = "scan"
    stats.extra["fused"] = fused
    return params, stats


@partial(jax.jit, static_argnames=("grad_fn", "sign", "momentum"))
def _explicit_step(params, vel, t, W, G, cols, sd: DeviceSchedule, B, mom, *,
                   grad_fn, sign: int, momentum: bool):
    """The whole explicit step as ONE program: history slice, kept + changed
    gradients, pair construction (with the Algorithm-4 admission inner
    products), and the parameter update.  The host only syncs the two
    admission scalars — one round-trip per explicit step."""
    w_t = jax.tree.map(lambda x: x[t], W)
    g_t = jax.tree.map(lambda x: x[t], G)
    k, dB, lr = sd.kept[t], sd.dB[t], sd.lr[t]
    g_kept = grad_fn(params, _gather(cols, sd.idx[t]), sd.kept_w[t])
    has = (dB > 0).astype(jnp.float32)
    g_changed = jax.tree.map(
        lambda x: has * x,
        grad_fn(params, _gather(cols, sd.changed_idx[t]), sd.changed_w[t]))
    g_full, g_step = _combine_explicit(g_kept, g_changed, k, dB, B, sign)
    dw = tree_sub(params, w_t)
    dg = tree_sub(g_full, g_t)
    admit = jnp.stack([tree_vdot(dg, dw), tree_vdot(dw, dw)])
    if momentum:
        new_p, new_vel = _momentum_math(params, vel, g_step, lr, mom)
    else:
        new_p, new_vel = _sgd_math(params, g_step, lr), vel
    return new_p, new_vel, dw, dg, admit


def _host_explicit_step(grad_fn, buffer, params, vel, t, W, G, cols, sd,
                        k, dB, Bf, mom, sign, momentum, stats):
    """One explicit step (host-driven: it mutates the L-BFGS buffer)."""
    params, vel, dw, dg, admit = _explicit_step(
        params, vel, t, W, G, cols, sd, Bf, mom, grad_fn=grad_fn, sign=sign,
        momentum=momentum)
    curv, ss = np.asarray(admit)
    if not buffer.add_pair(dw, dg, float(curv), float(ss)):
        stats.pairs_rejected += 1
    stats.grad_examples += int(k + dB)
    stats.explicit_steps += 1
    return params, vel


def _run_replay_python(objective, history, ds, changed_idx, cfg, mode,
                       params0):
    """The pre-refactor per-step loop, verbatim — parity oracle + disk tier."""
    meta = history.meta
    changed_idx = np.asarray(changed_idx, dtype=np.int64)
    r = len(changed_idx)
    n, B = meta.n, min(meta.batch_size, meta.n)
    grad_fn = objective.make_grad_fn()
    buffer = LbfgsBuffer(cfg.history_size, curvature_eps=cfg.curvature_eps)

    r_pad = cfg.removal_pad or _next_pow2(max(1, min(r, B)))
    n_add = r if mode == "add" else 0
    clip = jnp.float32(cfg.guard_norm_clip)
    mom = jnp.float32(meta.momentum) if meta.momentum else None

    params = params0 if params0 is not None else history.params_at(0)
    vel = _tree_zeros(params) if meta.momentum else None
    stats = RetrainStats()
    t0 = time.perf_counter()

    for t in range(meta.steps):
        idx = batch_indices(meta.seed, t, n, meta.batch_size)
        if mode == "delete":
            kept_idx, changed_in = ds.split_batch(idx, removed_set=changed_idx)
        else:
            joins = addition_mask(meta.seed, t, n, meta.batch_size, n_add)
            kept_idx, changed_in = idx, changed_idx[joins]
        dB = len(changed_in)
        k = len(kept_idx)
        lr = jnp.float32(meta.lr_at(t))
        stats.grad_examples_baseline += (k if mode == "delete" else k + dB)

        if mode == "delete" and k == 0:
            stats.skipped_steps += 1  # paper §3: B - dB_t == 0 → no update
            continue

        explicit = cfg.is_explicit(t)
        w_t, g_t = history.entry(t)

        if not explicit and len(buffer) == 0:
            explicit = True  # nothing to approximate with yet

        if not explicit:
            # ---- approx step: gradients only on the changed samples --------
            if dB > 0:
                cb, cw = ds.padded_batch(changed_in, r_pad)
                g_changed = grad_fn(params, cb, cw)
                stats.grad_examples += dB
            else:
                g_changed = _tree_zeros(params)
            dWs, dGs = buffer.stacked()
            sign = 1 if mode == "delete" else -1
            if mom is not None:
                g_est, ok = _approx_gradient(
                    params, w_t, g_t, dWs, dGs, g_changed,
                    jnp.float32(B), jnp.float32(dB), clip, sign)
                if cfg.guard and not bool(ok):
                    stats.guard_fallbacks += 1
                    explicit = True
                else:
                    params, vel = _momentum_apply(params, vel, g_est, lr, mom)
                    stats.approx_steps += 1
            else:
                new_params, ok = _approx_update(
                    params, w_t, g_t, dWs, dGs, g_changed, lr,
                    jnp.float32(B), jnp.float32(dB), clip, sign
                )
                if cfg.guard and not bool(ok):
                    stats.guard_fallbacks += 1
                    explicit = True  # fall through to the explicit branch
                else:
                    params = new_params
                    stats.approx_steps += 1

        if explicit:
            # ---- explicit step: full-batch gradient at w^I_t ---------------
            kb, kw = ds.padded_batch(kept_idx,
                                     B if mode == "delete" else B + n_add)
            g_kept = grad_fn(params, kb, kw)
            if dB > 0:
                cb, cw = ds.padded_batch(changed_in, r_pad)
                g_changed = grad_fn(params, cb, cw)
            else:
                g_changed = _tree_zeros(params)
            stats.grad_examples += k + dB

            if mode == "delete":
                # mean over the ORIGINAL batch (pair definition, §A.1.2)
                g_full = jax.tree.map(
                    lambda a, b: (k * a + dB * b) / float(B), g_kept, g_changed
                )
                g_step = g_kept  # mean over kept == leave-r-out update
            else:
                g_full = g_kept  # original batch == kept in add mode
                g_step = jax.tree.map(
                    lambda a, b: (B * a + dB * b) / float(B + dB),
                    g_kept, g_changed
                )

            dw = tree_sub(params, w_t)
            dg = tree_sub(g_full, g_t)
            if not buffer.add(dw, dg):
                stats.pairs_rejected += 1
            if mom is not None:
                params, vel = _momentum_apply(params, vel, g_step, lr, mom)
            else:
                params = _sgd_apply(params, g_step, lr)
            stats.explicit_steps += 1

    stats.wall_time_s = time.perf_counter() - t0
    stats.extra["buffer_admitted"] = buffer.admitted
    stats.extra["buffer_rejected"] = buffer.rejected
    stats.extra["impl"] = "python"
    return params, stats


# --------------------------------------------------------------------------
# Phase 2': ONLINE — Algorithm 3 with history rewrite in the scan
# --------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("grad_fn", "guard", "span"))
def _online_segment(params, t0, W, G, cols, sd: DeviceSchedule, dWs, dGs,
                    clip, *, grad_fn, guard: bool, span: int):
    """Online-deletion approx segment: like `_replay_segment` but with the
    per-step effective batch size B_t(k) = kept + dB (paper's n-k
    bookkeeping) and emitting the rewrite pairs (w_t <- w^I_t, g_t <- g^a_t,
    eq. (S62)) as stacked scan outputs."""

    def body(params, t):
        w_t = jax.tree.map(lambda x: x[t], W)
        g_t = jax.tree.map(lambda x: x[t], G)
        lr, dB, kept = sd.lr[t], sd.dB[t], sd.kept[t]
        eff_prev = kept + dB
        has = (dB > 0).astype(jnp.float32)
        g_one = jax.tree.map(
            lambda x: has * x,
            grad_fn(params, _gather(cols, sd.changed_idx[t]),
                    sd.changed_w[t]))
        v = tree_sub(params, w_t)
        bv = lbfgs_hvp_stacked_pytree(dWs, dGs, v)
        g_new = _approx_math(g_t, bv, g_one, eff_prev, has, 1)
        new_p = _sgd_math(params, g_new, lr)
        ok = jnp.logical_and(tree_all_finite(new_p),
                             tree_norm(bv) <= clip * tree_norm(v))

        if guard:
            def fallback(_):
                g_cur = grad_fn(params, _gather(cols, sd.idx[t]),
                                sd.kept_w[t])
                return _sgd_math(params, g_cur, lr), g_cur

            new_p, g_new = jax.lax.cond(
                ok, lambda _: (new_p, g_new), fallback, None)

        skip = jnp.logical_and(kept <= 0, dB > 0)  # Algorithm 3's condition
        new_p = jax.tree.map(lambda n, o: jnp.where(skip, o, n), new_p, params)
        w_wr = jax.tree.map(lambda n, o: jnp.where(skip, o, n), params, w_t)
        g_wr = jax.tree.map(lambda n, o: jnp.where(skip, o, n), g_new, g_t)
        return new_p, (w_wr, g_wr, ok)

    params, (w_writes, g_writes, oks) = jax.lax.scan(
        body, params, t0 + jnp.arange(span))
    return params, w_writes, g_writes, oks


@jax.jit
def _write_segment(W, G, w_writes, g_writes, t0):
    upd = partial(jax.lax.dynamic_update_slice_in_dim, axis=0)
    return (jax.tree.map(lambda x, u: upd(x, u.astype(x.dtype), t0), W,
                         w_writes),
            jax.tree.map(lambda x, u: upd(x, u.astype(x.dtype), t0), G,
                         g_writes))


@jax.jit
def _write_entry(W, G, t, w, g):
    return (jax.tree.map(lambda x, v: x.at[t].set(v), W, w),
            jax.tree.map(lambda x, v: x.at[t].set(v), G, g))


@partial(jax.jit, static_argnames=("grad_fn",))
def _online_explicit_step(params, t, W, G, cols, sd: DeviceSchedule, *,
                          grad_fn):
    """Online explicit step fused into one program: post-request gradient,
    PRE-request pair gradient, cache rewrite at t, and the SGD step.  Only
    the two L-BFGS admission scalars return to the host."""
    w_t = jax.tree.map(lambda x: x[t], W)
    g_t = jax.tree.map(lambda x: x[t], G)
    kept, dB, lr = sd.kept[t], sd.dB[t], sd.lr[t]
    g_cur = grad_fn(params, _gather(cols, sd.idx[t]), sd.kept_w[t])
    has = (dB > 0).astype(jnp.float32)
    g_one = jax.tree.map(
        lambda x: has * x,
        grad_fn(params, _gather(cols, sd.changed_idx[t]), sd.changed_w[t]))
    # pair: gradient over the PRE-request batch at params (exact g_cur when
    # the request row is absent from batch t)
    g_prev = jax.tree.map(
        lambda a, b: jnp.where(has > 0, (kept * a + b) / (kept + dB), a),
        g_cur, g_one)
    dw = tree_sub(params, w_t)
    dg = tree_sub(g_prev, g_t)
    admit = jnp.stack([tree_vdot(dg, dw), tree_vdot(dw, dw)])
    W, G = _write_entry(W, G, t, params, g_cur)
    return _sgd_math(params, g_cur, lr), W, G, dw, dg, admit


def run_online_request(
    grad_fn,
    history: TrainingHistory,
    W, G,
    cols,
    req: int,
    cfg: DeltaGradConfig,
    live_mask: np.ndarray,
    idx_all: np.ndarray,
    static_dev: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> Tuple[Any, Any, Any, RetrainStats]:
    """One deletion request against the current (stacked) cached path.
    Returns (params, W', G', stats); the caller flushes W'/G' into history.
    `static_dev` is the request-invariant (idx, lr) pair already on device —
    pass it so a stream uploads the (T, B) schedule once, not per request."""
    meta = history.meta
    sched = build_schedule(meta.seed, meta.steps, meta.n, meta.batch_size,
                           np.asarray([req], np.int64), "delete", 1,
                           meta.lr_at, idx_all=idx_all, live_mask=live_mask)
    plan = build_plan(cfg, sched, online=True)
    sd = to_device(sched, *(static_dev or (None, None)))
    buffer = LbfgsBuffer(cfg.history_size, curvature_eps=cfg.curvature_eps)
    params = jax.tree.map(lambda x: x[0], W)  # w_0 is never rewritten
    clip = jnp.float32(cfg.guard_norm_clip)
    stats = RetrainStats()
    T = meta.steps
    seg_oks: List[Tuple[int, int, Any]] = []

    t = 0
    while t < T:
        code = plan[t]
        if code == EXPLICIT or (code == APPROX and len(buffer) == 0):
            params, W, G, dw, dg, admit = _online_explicit_step(
                params, t, W, G, cols, sd, grad_fn=grad_fn)
            curv, ss = np.asarray(admit)
            buffer.add_pair(dw, dg, float(curv), float(ss))
            stats.grad_examples += int(sched.kept[t])
            stats.explicit_steps += 1
            t += 1
        elif code == SKIP and len(buffer) == 0:
            t += 1
        else:
            t2 = t
            while t2 < T and plan[t2] != EXPLICIT:
                t2 += 1
            dWs, dGs = buffer.stacked()
            params, w_wr, g_wr, oks = _online_segment(
                params, jnp.int32(t), W, G, cols, sd, dWs, dGs, clip,
                grad_fn=grad_fn, guard=cfg.guard, span=t2 - t)
            W, G = _write_segment(W, G, w_wr, g_wr, jnp.int32(t))
            seg_oks.append((t, t2, oks))
            t = t2

    for t0_, t1_, oks in seg_oks:
        oks = np.asarray(oks)
        nonskip = plan[t0_:t1_] != SKIP
        if cfg.guard:
            fell = nonskip & ~oks
            stats.approx_steps += int((nonskip & oks).sum())
            stats.guard_fallbacks += int(fell.sum())
            stats.explicit_steps += int(fell.sum())  # exact update applied
            stats.grad_examples += int(
                sched.kept[t0_:t1_].astype(np.int64)[fell].sum())
        else:
            stats.approx_steps += int(nonskip.sum())
        stats.grad_examples += int(
            sched.dB[t0_:t1_].astype(np.int64)[nonskip].sum())
    stats.skipped_steps = int((plan == SKIP).sum())
    stats.grad_examples_baseline = int(sched.kept.astype(np.int64).sum())
    return params, W, G, stats
