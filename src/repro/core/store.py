"""HistoryStore — where history bytes live and how they reach the scan.

DeltaGrad's replay is bottlenecked by the cached optimization path, not the
model: the stacked tier burns ``O(T * |params|)`` HBM per host, and the
paper-faithful offload tiers (host/disk) used to abandon the compiled
``lax.scan`` engine for the per-step python loop.  This module owns the
placement/transport layer between `TrainingHistory` and the engines:

  * ``ResidentStore`` — stacked/device tiers.  The whole (T, ...) cache is
    one device pytree; with a `PlacementPolicy` each leaf is placed by
    `dist.sharding.stacked_spec_for_leaf` (time axis never sharded), so the
    cache shards across the mesh exactly like the live parameters and the
    per-host HBM share drops by the mesh factor.  The engines' segment
    scans then run under ``shard_map`` (built here by `ShardedReplay`):
    the minibatch schedule is batch-sharded over the mesh's data axis,
    per-example gradients are ``psum``-reduced with the global weight sum
    (`make_psum_grad_fn` — bit-compatible with the single-device weighted
    mean up to reduction order), sharded history leaves are all-gathered
    one step at a time inside the scan body, and the fused-update kernel
    is routed per shard over the flattened parameter vector.

  * ``SegmentStreamer`` — host/disk tiers.  History entries stay encoded on
    host (or spilled .npz); the replay scan is served device-resident
    WINDOWS of ``window`` steps, assembled + uploaded by a single worker
    thread with double buffering: while the scan for window *s* computes,
    the host stacks and ships window *s+1* (prefetch), so the compiled
    path never blocks on the offload tier and device high-water stays at
    ~2 windows instead of the whole path.  Online-request rewrites are
    committed back through the codec per window.

Both stores expose one engine-facing API: ``window(a, b) -> (W, G, off)``
(leaves indexed ``W[t - off]`` inside the scan), ``entry(t)`` for host-driven
explicit steps, and ``commit(...)`` for the online engine's end-of-request
rewrite flush.  `core.engine` and `core.online` consume it; `core.session`
chooses the policy.
"""

from __future__ import annotations

import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.history import TrainingHistory


def auto_window(steps: int, window: int = 0) -> int:
    """Steps per device-resident window on the offload tiers — ONE knob
    shared by the recorder (`core.engine.run_training`) and the read path
    (`SegmentStreamer`): large enough to amortize dispatch, small enough
    that two buffered windows stay far below the full path."""
    return int(window) if window else max(1, min(steps, 32))


def tree_nbytes(tree) -> int:
    """Logical bytes of a pytree, without forcing any device transfer."""
    return sum(int(np.prod(x.shape, dtype=np.int64))
               * np.dtype(x.dtype).itemsize
               for x in jax.tree.leaves(tree))


# --------------------------------------------------------------------------
# Placement policy (picklable mesh descriptor — session save/restore needs
# to round-trip it, and jax Mesh objects hold live Device handles)
# --------------------------------------------------------------------------


@dataclass
class PlacementPolicy:
    """Describes the replay mesh; builds the live `jax.sharding.Mesh` lazily.

    ``mesh_shape``/``axis_names`` feed `jax.make_mesh`; ``data_axis`` names
    the axis per-example gradients reduce over (batch sharding).  The
    descriptor is plain data so `UnlearnerSession.save()` can round-trip it
    through a checkpoint and rebuild the mesh on the restoring host."""

    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...] = ("data", "model")
    data_axis: str = "data"
    model_cfg: Any = None  # optional ModelConfig for the MoE spec rules

    def __post_init__(self):
        self.mesh_shape = tuple(int(s) for s in self.mesh_shape)
        self.axis_names = tuple(self.axis_names)
        self._mesh = None

    @classmethod
    def from_mesh(cls, mesh, data_axis: str = "data",
                  model_cfg=None) -> "PlacementPolicy":
        pol = cls(mesh_shape=tuple(mesh.devices.shape),
                  axis_names=tuple(mesh.axis_names), data_axis=data_axis,
                  model_cfg=model_cfg)
        pol._mesh = mesh
        return pol

    @classmethod
    def local(cls, data: Optional[int] = None) -> "PlacementPolicy":
        """1-D data mesh over the local devices (the CPU-mesh test shape)."""
        n = jax.local_device_count() if data is None else int(data)
        return cls(mesh_shape=(n,), axis_names=("data",))

    @property
    def mesh(self):
        if self._mesh is None:
            self._mesh = jax.make_mesh(self.mesh_shape, self.axis_names)
        return self._mesh

    @property
    def data_size(self) -> int:
        if self.data_axis not in self.axis_names:
            return 1
        return self.mesh_shape[self.axis_names.index(self.data_axis)]

    def plan(self):
        from repro.dist.sharding import ShardingPlan
        return ShardingPlan(mesh=self.mesh, cfg=self.model_cfg)

    # -- pickling (drop the live mesh; rebuilt lazily on the other side) ----

    def __getstate__(self):
        state = dict(self.__dict__)
        state["_mesh"] = None
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)

    def describe(self) -> Dict[str, Any]:
        return {"mesh_shape": list(self.mesh_shape),
                "axis_names": list(self.axis_names),
                "data_axis": self.data_axis}

    @classmethod
    def from_describe(cls, d: Optional[Dict[str, Any]]
                      ) -> Optional["PlacementPolicy"]:
        if d is None:
            return None
        return cls(mesh_shape=tuple(d["mesh_shape"]),
                   axis_names=tuple(d["axis_names"]),
                   data_axis=d["data_axis"])


# --------------------------------------------------------------------------
# Data-parallel gradients: the weighted mean as a psum (shard_map bodies)
# --------------------------------------------------------------------------


def make_psum_grad_fn(objective, axis: str):
    """`Objective.make_grad_fn` semantics under batch sharding.

    Each mesh member evaluates the weighted-SUM gradient over its rows; the
    sum and the weight total ``psum`` over `axis`, and the l2 term is added
    once after the reduction — algebraically identical to the single-device
    weighted mean ``(sum_i w_i grad_i) / max(sum_i w_i, 1) + l2*params``,
    differing only in float reduction order.  Cached per (objective, axis)
    so repeated segment calls reuse the traced closure."""
    cache = getattr(objective, "_psum_grad_fns", None)
    if cache is None:
        cache = objective._psum_grad_fns = {}
    if axis not in cache:
        gsum = jax.grad(
            lambda p, b, w: jnp.sum(objective.per_example_loss(p, b) * w))

        def grad_fn(params, batch, weights):
            g = gsum(params, batch, weights)
            den = jnp.maximum(jax.lax.psum(jnp.sum(weights), axis), 1.0)
            g = jax.tree.map(lambda x: jax.lax.psum(x, axis) / den, g)
            if objective.l2:
                g = jax.tree.map(lambda x, p: x + objective.l2 * p, g,
                                 params)
            return g

        cache[axis] = grad_fn
    return cache[axis]


# --------------------------------------------------------------------------
# HistoryStore
# --------------------------------------------------------------------------


class HistoryStore:
    """Engine-facing storage/placement layer over one `TrainingHistory`."""

    kind = "abstract"

    @staticmethod
    def create(history: TrainingHistory,
               placement: Optional[PlacementPolicy] = None,
               window: int = 0) -> "HistoryStore":
        """Pick the store for the history's tier: stacked/device →
        `ResidentStore` (optionally mesh-placed), host/disk →
        `SegmentStreamer` (``window`` steps per device-resident segment,
        0 → auto)."""
        if history.tier in ("host", "disk"):
            if placement is not None and placement.data_size > 1:
                raise NotImplementedError(
                    "sharded streaming (mesh placement over a host/disk-tier "
                    "history) is not implemented yet — shard a "
                    "stacked/device tier, or stream single-device "
                    "(ROADMAP follow-on)")
            return SegmentStreamer(history, window=window)
        return ResidentStore(history, placement=placement)

    # engine-facing API ------------------------------------------------------

    @property
    def meta(self):
        return self.history.meta

    @property
    def T(self) -> int:
        return self.history.meta.steps

    def span_end(self, t: int, t2: int) -> int:
        """Largest b <= t2 such that [t, b) fits one `window()` fetch."""
        raise NotImplementedError

    def window(self, a: int, b: int):
        """(W, G, off) device pytrees covering steps [a, b); scan bodies
        index ``W[t - off]``."""
        raise NotImplementedError

    def entry(self, t: int):
        raise NotImplementedError

    def params0(self):
        return self.entry(0)[0]

    def commit(self, regions, final_params) -> None:
        """Land an online request's deferred rewrites (see
        `core.engine.run_online_request` for the region format) and
        finalize `final_params` into the history."""
        raise NotImplementedError

    def sharded_replay(self) -> Optional["ShardedReplay"]:
        """The shard_map program builder when this store is mesh-placed."""
        return None

    def hbm_high_water(self) -> int:
        """Max device-resident history bytes this store ever held per
        device."""
        raise NotImplementedError


def _chunk_lift(p, kind):
    """Stack an explicit-step run into a (len, ...) chunk; scanned segments
    are already stacked."""
    if kind == "run":
        return jax.tree.map(lambda *xs: jnp.stack(xs), *p)
    return p


@jax.jit
def _scatter_chunk(W, G, t0, w_cat, g_cat):
    upd = partial(jax.lax.dynamic_update_slice_in_dim, axis=0)
    return (jax.tree.map(lambda x, u: upd(x, u.astype(x.dtype), t0), W, w_cat),
            jax.tree.map(lambda x, u: upd(x, u.astype(x.dtype), t0), G, g_cat))


@partial(jax.jit, static_argnames=("kinds",))
def _assemble_chunk(parts_w, parts_g, *, kinds):
    """One contiguous rewrite region as a single stacked (len, ...) pair."""
    ws = [_chunk_lift(p, k) for p, k in zip(parts_w, kinds)]
    gs = [_chunk_lift(p, k) for p, k in zip(parts_g, kinds)]
    return (jax.tree.map(lambda *xs: jnp.concatenate(xs), *ws),
            jax.tree.map(lambda *xs: jnp.concatenate(xs), *gs))


def _freeze_parts(parts):
    return tuple(tuple(p) if isinstance(p, list) else p for p in parts)


@jax.jit
def _entry_slices(W, G, t):
    """(w_t, g_t) as ONE jitted program — a host-driven explicit step costs
    one dispatch here, not 2 * n_leaves eager slice ops."""
    return (jax.tree.map(lambda x: x[t], W),
            jax.tree.map(lambda x: x[t], G))


class ResidentStore(HistoryStore):
    """Whole-path device residency (stacked/device tiers), optionally
    sharded across a mesh by `dist.sharding.stacked_spec_for_leaf`."""

    kind = "resident"

    def __init__(self, history: TrainingHistory,
                 placement: Optional[PlacementPolicy] = None):
        self.history = history
        self.placement = placement
        W, G = history.stacked_view()
        self._specs = None
        self._flat_specs_w: Optional[List[Any]] = None
        if placement is not None:
            from repro.dist.sharding import history_shardings
            plan = placement.plan()
            shard_w = history_shardings(plan, W)
            shard_g = history_shardings(plan, G)
            W = jax.tree.map(jax.device_put, W, shard_w)
            G = jax.tree.map(jax.device_put, G, shard_g)
            self._specs = (jax.tree.map(lambda s: s.spec, shard_w),
                           jax.tree.map(lambda s: s.spec, shard_g))
            self._flat_specs_w = [s.spec for s in jax.tree.leaves(shard_w)]
        self.W, self.G = W, G
        self._sharded: Optional["ShardedReplay"] = None
        self._hbm = self._per_device_bytes()

    def _per_device_bytes(self) -> int:
        """History bytes resident on ONE device — the number sharding is
        supposed to shrink (nbytes / mesh factor for sharded leaves)."""
        total = 0
        for leaf in jax.tree.leaves((self.W, self.G)):
            sh = getattr(leaf, "sharding", None)
            shape = sh.shard_shape(leaf.shape) if sh is not None \
                else leaf.shape
            total += (int(np.prod(shape, dtype=np.int64))
                      * np.dtype(leaf.dtype).itemsize)
        return total

    @property
    def specs(self):
        """Per-leaf (W, G) PartitionSpec trees when placed on a mesh."""
        return self._specs

    def span_end(self, t: int, t2: int) -> int:
        return t2  # the whole path is resident; never split a segment

    def window(self, a: int, b: int):
        return self.W, self.G, 0

    def entry(self, t: int):
        return _entry_slices(self.W, self.G, t)

    def commit(self, regions, final_params) -> None:
        for t0, kinds, pw, pg in regions:
            w_cat, g_cat = _assemble_chunk(_freeze_parts(pw),
                                           _freeze_parts(pg),
                                           kinds=tuple(kinds))
            self.W, self.G = _scatter_chunk(self.W, self.G, jnp.int32(t0),
                                            w_cat, g_cat)
        # O(1) pointer swap for stacked/device storage
        self.history.replace_from_stacked(self.W, self.G,
                                          final_params=final_params)

    def sharded_replay(self) -> Optional["ShardedReplay"]:
        if self.placement is None:
            return None
        if self._sharded is None:
            self._sharded = ShardedReplay(self)
        return self._sharded

    def hbm_high_water(self) -> int:
        return self._hbm


class SegmentStreamer(HistoryStore):
    """Serve a host/disk-tier history to the compiled scan in device-resident
    segment windows with double-buffered async host→device copies."""

    kind = "streamed"
    placement = None

    def __init__(self, history: TrainingHistory, window: int = 0,
                 prefetch: bool = True):
        assert history.tier in ("host", "disk"), history.tier
        self.history = history
        self.window_len = auto_window(history.meta.steps, window)
        self.prefetch = prefetch
        self._pool = ThreadPoolExecutor(max_workers=1) if prefetch else None
        self._buf: Dict[int, Tuple[Any, Any]] = {}
        self._inflight: Dict[int, Future] = {}
        self._hbm_now = 0
        self._hbm_high = 0
        self._enc_bytes = 0  # ENCODED bytes of the last staged window (the
        # in-flight prefetch copy is pre-decode, so lossy codecs stage at
        # 1/2 or 1/4 of the decoded f32 size)
        self.windows_fetched = 0
        self.prefetch_hits = 0
        self.host_wait_s = 0.0

    # -- window plumbing -----------------------------------------------------

    def _wid(self, t: int) -> int:
        return t // self.window_len

    def _bounds(self, wid: int) -> Tuple[int, int]:
        a = wid * self.window_len
        return a, min(self.T, a + self.window_len)

    def span_end(self, t: int, t2: int) -> int:
        return min(t2, self._bounds(self._wid(t))[1])

    def _stack_host(self, wid: int):
        """Host side of a fetch: stack the window's ENCODED entries per leaf
        and ship them with `jax.device_put` (async dispatch).  Runs on the
        worker thread for prefetches; no tracing happens here."""
        a, b = self._bounds(wid)
        enc_p, enc_g = [], []
        for t in range(a, b):
            p, g = self.history.encoded_entry(t)
            enc_p.append(p)
            enc_g.append(g)
        stack = lambda *xs: np.stack([np.asarray(x) for x in xs])
        Wh = jax.tree.map(stack, *enc_p) if len(enc_p) > 1 else \
            jax.tree.map(lambda x: np.asarray(x)[None], enc_p[0])
        Gh = jax.tree.map(stack, *enc_g) if len(enc_g) > 1 else \
            jax.tree.map(lambda x: np.asarray(x)[None], enc_g[0])
        return jax.device_put((Wh, Gh))

    def _decode(self, staged):
        Wh, Gh = staged
        codec = self.history.codec
        return codec.decode_stacked(Wh), codec.decode_stacked(Gh)

    def _fetch(self, wid: int):
        if wid in self._buf:
            return self._buf[wid]
        fut = self._inflight.pop(wid, None)
        if fut is not None:
            t0 = time.perf_counter()
            staged = fut.result()
            self.host_wait_s += time.perf_counter() - t0
            self.prefetch_hits += 1
        else:
            t0 = time.perf_counter()
            staged = self._stack_host(wid)
            self.host_wait_s += time.perf_counter() - t0
        self._enc_bytes = tree_nbytes(staged)
        W, G = self._decode(staged)
        self._buf[wid] = (W, G)
        self._hbm_now += tree_nbytes(W) + tree_nbytes(G)
        self._hbm_high = max(self._hbm_high, self._hbm_now)
        self.windows_fetched += 1
        return W, G

    def _evict_before(self, wid: int) -> None:
        for old in [w for w in self._buf if w < wid]:
            W, G = self._buf.pop(old)
            self._hbm_now -= tree_nbytes(W) + tree_nbytes(G)
        for old in [w for w in self._inflight if w < wid]:
            self._inflight.pop(old)

    def _prefetch(self, wid: int) -> None:
        if (self._pool is None or wid in self._buf or wid in self._inflight
                or wid * self.window_len >= self.T):
            return
        self._inflight[wid] = self._pool.submit(self._stack_host, wid)

    def window(self, a: int, b: int):
        wid = self._wid(a)
        assert b <= self._bounds(wid)[1], (a, b, self.window_len)
        self._evict_before(wid)
        W, G = self._fetch(wid)
        # double buffering: ship window s+1 while the scan for s computes
        self._prefetch(wid + 1)
        # the in-flight staged copy is device-resident too — that is the
        # double-buffer cost the high-water must report (at its ENCODED
        # size: decode happens on the consuming fetch)
        self._hbm_high = max(self._hbm_high,
                             self._hbm_now
                             + len(self._inflight) * self._enc_bytes)
        return W, G, wid * self.window_len

    def entry(self, t: int):
        wid = self._wid(t)
        if wid in self._buf:
            W, G = self._buf[wid]
            return _entry_slices(W, G, t - wid * self.window_len)
        return self.history.entry(t)

    # -- online rewrite commit ----------------------------------------------

    def commit(self, regions, final_params) -> None:
        # drain in-flight prefetches first: a worker mid-read of the same
        # entries we are about to overwrite is a read/write race on the
        # disk tier's .npz files
        for fut in self._inflight.values():
            try:
                fut.result()
            except Exception:
                pass  # a failed prefetch of soon-stale data is harmless
        for t0, kinds, pw, pg in regions:
            w_cat, g_cat = _assemble_chunk(_freeze_parts(pw),
                                           _freeze_parts(pg),
                                           kinds=tuple(kinds))
            w_host = jax.device_get(w_cat)
            g_host = jax.device_get(g_cat)
            span = jax.tree.leaves(w_host)[0].shape[0]
            for i in range(span):
                self.history.overwrite(
                    t0 + i, jax.tree.map(lambda x: x[i], w_host),
                    jax.tree.map(lambda x: x[i], g_host))
        self.history.finalize(final_params)
        # buffered windows hold pre-request values — drop them
        self._buf.clear()
        self._inflight.clear()
        self._hbm_now = 0

    def hbm_high_water(self) -> int:
        return self._hbm_high


# --------------------------------------------------------------------------
# Sharded replay: shard_map construction for the engines' segment scans
# --------------------------------------------------------------------------


class ShardedReplay:
    """Builds (and caches) the shard_map-wrapped segment programs for a
    `ResidentStore` placed on a mesh.

    The engines hand their segment *impl* functions (plain, un-jitted,
    with every static argument already bound) to `wrap`; the minibatch
    schedule arrives batch-sharded over the data axis, parameters and
    L-BFGS pairs replicate, and history leaves keep their storage
    placement — sharded leaves are all-gathered ONE STEP at a time inside
    the scan body (`gather_info`), so no device ever materializes the
    whole stacked path."""

    def __init__(self, store: ResidentStore):
        assert store.placement is not None
        self.store = store
        self._cache: Dict[Any, Any] = {}

    @property
    def placement(self) -> PlacementPolicy:
        return self.store.placement

    def gather_info(self) -> Tuple[Tuple[Tuple[int, str], ...], ...]:
        """Per-leaf ((dim, axis_name), ...) all-gather plan for one history
        ENTRY (the per-step leaf, after the time axis is sliced away),
        aligned with ``jax.tree.leaves(W)``; () means replicated."""
        out = []
        for spec in self.store._flat_specs_w:
            gathers = []
            for dim, ax in enumerate(tuple(spec)[1:]):  # drop time axis
                if ax is None:
                    continue
                for name in ((ax,) if isinstance(ax, str) else tuple(ax)):
                    gathers.append((dim, name))
            out.append(tuple(gathers))
        return tuple(out)

    def _schedule_specs(self):
        from jax.sharding import PartitionSpec as P

        from repro.core.engine import DeviceSchedule
        d = self.placement.data_axis
        return DeviceSchedule(idx=P(None, d), kept_w=P(None, d),
                              changed_idx=P(None, d), changed_w=P(None, d),
                              dB=P(), kept=P(), lr=P())

    def wrap(self, impl_fn, key, n_outputs: int):
        """shard_map + jit for ``impl_fn(params, vel, t0, off, W, G, cols,
        sd, *rest)`` with `n_outputs` replicated outputs; cached by `key`
        (span/sign/momentum/... — everything that changes the program)."""
        if key in self._cache:
            return self._cache[key]
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        specs_w, specs_g = self.store.specs
        rep = P()
        lead = (rep, rep, rep, rep, specs_w, specs_g, rep,
                self._schedule_specs())
        out_specs = (rep,) * n_outputs if n_outputs > 1 else rep
        mesh = self.placement.mesh

        def call(*args):
            in_specs = lead + (rep,) * (len(args) - len(lead))
            return shard_map(impl_fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False)(*args)

        jitted = jax.jit(call)
        self._cache[key] = jitted
        return jitted


def entry_at(W, t, off, gather=None):
    """Slice one step out of stacked history leaves, all-gathering sharded
    leaves per the ShardedReplay gather plan (no-op when gather is None)."""
    leaves, tdef = jax.tree.flatten(W)
    if gather is None:
        return jax.tree.unflatten(tdef, [x[t - off] for x in leaves])
    out = []
    for leaf, plan in zip(leaves, gather):
        x = leaf[t - off]
        for dim, ax in plan:
            x = jax.lax.all_gather(x, ax, axis=dim, tiled=True)
        out.append(x)
    return jax.tree.unflatten(tdef, out)


def pad_schedule_batch(sched_dev, multiple: int):
    """Pad the device schedule's batch-shaped dims (axis 1) to a multiple of
    the data-axis size with weight-0 rows, so batch sharding divides evenly.
    Zero-weight rows gather row 0 and contribute nothing to any gradient."""
    if multiple <= 1:
        return sched_dev

    def pad(x, fill=0):
        b = x.shape[1]
        want = -(-b // multiple) * multiple
        if want == b:
            return x
        return jnp.pad(x, ((0, 0), (0, want - b)), constant_values=fill)

    return sched_dev._replace(
        idx=pad(sched_dev.idx), kept_w=pad(sched_dev.kept_w),
        changed_idx=pad(sched_dev.changed_idx),
        changed_w=pad(sched_dev.changed_w))
