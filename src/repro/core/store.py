"""HistoryStore — where history bytes live and how they reach the scan.

DeltaGrad's replay is bottlenecked by the cached optimization path, not the
model: the stacked tier burns ``O(T * |params|)`` HBM per host, and the
paper-faithful offload tiers (host/disk) used to abandon the compiled
``lax.scan`` engine for the per-step python loop.  This module owns the
placement/transport layer between `TrainingHistory` and the engines:

  * ``ResidentStore`` — stacked/device tiers.  The whole (T, ...) cache is
    one device pytree; with a `PlacementPolicy` each leaf is placed by
    `dist.sharding.stacked_spec_for_leaf` (time axis never sharded), so the
    cache shards across the mesh exactly like the live parameters and the
    per-host HBM share drops by the mesh factor.  The engines' segment
    scans then run under ``shard_map`` (built here by `ShardedReplay`):
    the minibatch schedule is batch-sharded over the mesh's data axis,
    per-example gradients are ``psum``-reduced with the global weight sum
    (`make_psum_grad_fn` — bit-compatible with the single-device weighted
    mean up to reduction order), sharded history leaves are all-gathered
    one step at a time inside the scan body, and the fused-update kernel
    is routed per shard over the flattened parameter vector.

  * ``SegmentStreamer`` — host/disk tiers.  History entries stay encoded on
    host (or spilled .npz); the replay scan is served device-resident
    WINDOWS of ``window`` steps, assembled + uploaded by a single worker
    thread with double buffering: while the scan for window *s* computes,
    the host stacks and ships window *s+1* (prefetch), so the compiled
    path never blocks on the offload tier and device high-water stays at
    ~2 windows instead of the whole path.  When measured host stacking is
    SLOWER than the scan (small windows on the disk tier), the prefetch
    depth adapts: up to ``max_prefetch`` windows stage ahead so the scan
    never starves (`stats.extra["prefetch_depth"]` reports the depth
    used).  Online-request rewrites are committed back through the codec
    per window.

  * ``ShardedStreamer`` — host/disk tiers placed on a mesh: the
    composition of the two.  Each staged window's leaves are split into
    PER-SHARD encoded segments along the same `stacked_spec_for_leaf`
    axes as `ResidentStore` (time axis never sharded); the worker threads
    stack and upload ONLY each mesh shard's slice of each leaf
    (`jax.make_array_from_single_device_arrays` assembles the global
    window), the codec decodes shard-local on device, and the engines'
    ``shard_map`` scans all-gather the decoded window one step at a time
    exactly as the resident path does.  Device high-water is ~2 windows
    of the SHARD; per-host RAM holds the encoded path (/codec ratio) plus
    one window of staged slices.

Both streamers additionally support DECODE-IN-KERNEL reads
(``decode="kernel"``, the default for lossy codecs): windows stay ENCODED
on device as `EncodedLeaf` leaves (int8/bf16 payload + per-step scale +
delta keyframe bases) and the replay scan dequantizes one step at a time
in registers — `entry_at` slices then decodes (XLA fuses the elementwise
dequant; `kernels.dequant_update` fuses it with the approx update on
TPU), so device high-water drops by the codec ratio and no f32 copy of a
window is ever materialized.  ``decode="fetch"`` restores the
decode-on-arrival behaviour; both paths share one decode expression (and
both run it under jit, so XLA contracts the multiply-add identically),
which keeps delta-codec replays BITWISE identical across the two modes —
plain int8 may drift by 1 ulp where the lone decode multiply fuses into
a downstream subtract.

Every store exposes one engine-facing API: ``window(a, b) -> (W, G, off)``
(leaves indexed ``W[t - off]`` inside the scan), ``entry(t)`` for host-driven
explicit steps, and ``commit(...)`` for the online engine's end-of-request
rewrite flush.  `core.engine` and `core.online` consume it; `core.session`
chooses the policy.
"""

from __future__ import annotations

import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.history import Int8Codec, TrainingHistory
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


def auto_window(steps: int, window: int = 0) -> int:
    """Steps per device-resident window on the offload tiers — ONE knob
    shared by the recorder (`core.engine.run_training`) and the read path
    (`SegmentStreamer`): large enough to amortize dispatch, small enough
    that two buffered windows stay far below the full path."""
    return int(window) if window else max(1, min(steps, 32))


def tree_nbytes(tree) -> int:
    """Logical bytes of a pytree, without forcing any device transfer."""
    return sum(int(np.prod(x.shape, dtype=np.int64))
               * np.dtype(x.dtype).itemsize
               for x in jax.tree.leaves(tree))


def tree_device_nbytes(tree) -> int:
    """Bytes a pytree holds on ONE device: sharded leaves count a single
    shard, so a mesh-placed window reports the per-device cost the sharding
    is supposed to buy.  Equals `tree_nbytes` for unsharded arrays."""
    total = 0
    for x in jax.tree.leaves(tree):
        sh = getattr(x, "sharding", None)
        shape = sh.shard_shape(x.shape) if sh is not None else x.shape
        total += (int(np.prod(shape, dtype=np.int64))
                  * np.dtype(x.dtype).itemsize)
    return total


# --------------------------------------------------------------------------
# Encoded windows (decode-in-kernel streaming)
# --------------------------------------------------------------------------


class EncodedLeaf(NamedTuple):
    """One stacked history leaf kept ENCODED on device.

    ``q`` is the (L, ...) quantized payload (int8 residuals with a
    per-step ``scale`` (L,), or a bf16 residual with no scale); for delta
    codecs ``base`` stacks the window's f32 keyframes (n_kw, ...) and
    ``kidx`` (L,) maps each step to its keyframe row, so any
    stream-window/key-interval combination decodes without alignment
    constraints.  A NamedTuple is a pytree, so encoded windows flow
    through jit/scan/shard_map unchanged; every decode site uses the one
    expression ``q.astype(f32) * scale (+ base)`` — see
    `kernels.dequant_update.ref.dequant_ref` — which is what keeps
    kernel-mode and fetch-mode replays bitwise identical (slicing
    commutes with elementwise decode)."""

    q: Any
    scale: Optional[Any] = None
    base: Optional[Any] = None
    kidx: Optional[Any] = None


def _is_window_leaf(x) -> bool:
    return isinstance(x, EncodedLeaf)


def is_encoded_window(tree) -> bool:
    """True when a window() result carries EncodedLeaf leaves (the scan
    must decode per step; pytree structure is static under jit)."""
    found = [False]

    def probe(x):
        if isinstance(x, EncodedLeaf):
            found[0] = True
        return x

    jax.tree.map(probe, tree, is_leaf=_is_window_leaf)
    return found[0]


def _decode_leaf_slice(leaf, i):
    """Step ``i`` of one window leaf, decoded to f32 when encoded."""
    if isinstance(leaf, EncodedLeaf):
        x = leaf.q[i].astype(jnp.float32)
        if leaf.scale is not None:
            x = x * leaf.scale[i]
        if leaf.base is not None:
            x = x + leaf.base[leaf.kidx[i]]
        return x
    return leaf[i]


def decode_window_tree(tree):
    """Whole-window decode of EncodedLeaf leaves to stacked f32 — the
    fetch-mode read path.  Agrees bitwise, per step, with
    `_decode_leaf_slice` (elementwise decode commutes with slicing)."""

    def dec(x):
        if isinstance(x, EncodedLeaf):
            q = x.q.astype(jnp.float32)
            if x.scale is not None:
                q = q * x.scale.reshape((-1,) + (1,) * (q.ndim - 1))
            if x.base is not None:
                q = q + x.base[x.kidx]
            return q
        return x

    return jax.tree.map(dec, tree, is_leaf=_is_window_leaf)


@jax.jit
def _decode_window_pair(Wh, Gh):
    return decode_window_tree(Wh), decode_window_tree(Gh)


def decoded_window_nbytes(tree) -> int:
    """Logical f32 bytes the window WOULD occupy decoded (the numerator
    of the reported compression ratio)."""
    total = 0
    for leaf in jax.tree.leaves(tree, is_leaf=_is_window_leaf):
        shape = leaf.q.shape if isinstance(leaf, EncodedLeaf) else leaf.shape
        total += int(np.prod(shape, dtype=np.int64)) * 4
    return total


# --------------------------------------------------------------------------
# Placement policy (picklable mesh descriptor — session save/restore needs
# to round-trip it, and jax Mesh objects hold live Device handles)
# --------------------------------------------------------------------------


@dataclass
class PlacementPolicy:
    """Describes the replay mesh; builds the live `jax.sharding.Mesh` lazily.

    ``mesh_shape``/``axis_names`` feed `jax.make_mesh`; ``data_axis`` names
    the axis per-example gradients reduce over (batch sharding).  The
    descriptor is plain data so `UnlearnerSession.save()` can round-trip it
    through a checkpoint and rebuild the mesh on the restoring host."""

    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...] = ("data", "model")
    data_axis: str = "data"
    model_cfg: Any = None  # optional ModelConfig for the MoE spec rules

    def __post_init__(self):
        self.mesh_shape = tuple(int(s) for s in self.mesh_shape)
        self.axis_names = tuple(self.axis_names)
        self._mesh = None

    @classmethod
    def from_mesh(cls, mesh, data_axis: str = "data",
                  model_cfg=None) -> "PlacementPolicy":
        pol = cls(mesh_shape=tuple(mesh.devices.shape),
                  axis_names=tuple(mesh.axis_names), data_axis=data_axis,
                  model_cfg=model_cfg)
        pol._mesh = mesh
        return pol

    @classmethod
    def local(cls, data: Optional[int] = None) -> "PlacementPolicy":
        """1-D data mesh over the local devices (the CPU-mesh test shape)."""
        n = jax.local_device_count() if data is None else int(data)
        return cls(mesh_shape=(n,), axis_names=("data",))

    @property
    def mesh(self):
        if self._mesh is None:
            self._mesh = jax.make_mesh(self.mesh_shape, self.axis_names)
        return self._mesh

    @property
    def data_size(self) -> int:
        if self.data_axis not in self.axis_names:
            return 1
        return self.mesh_shape[self.axis_names.index(self.data_axis)]

    def plan(self):
        from repro.dist.sharding import ShardingPlan
        return ShardingPlan(mesh=self.mesh, cfg=self.model_cfg)

    # -- pickling (drop the live mesh; rebuilt lazily on the other side) ----

    def __getstate__(self):
        state = dict(self.__dict__)
        state["_mesh"] = None
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)

    def describe(self) -> Dict[str, Any]:
        """DISPLAY-only summary (stats.extra["mesh"]).  Not a round-trip:
        session save/restore pickles the policy object itself, which is
        what preserves ``model_cfg`` (the MoE spec rules)."""
        return {"mesh_shape": list(self.mesh_shape),
                "axis_names": list(self.axis_names),
                "data_axis": self.data_axis}


# --------------------------------------------------------------------------
# Data-parallel gradients: the weighted mean as a psum (shard_map bodies)
# --------------------------------------------------------------------------


def make_psum_grad_fn(objective, axis: str):
    """`Objective.make_grad_fn` semantics under batch sharding.

    Each mesh member evaluates the weighted-SUM gradient over its rows; the
    sum and the weight total ``psum`` over `axis`, and the l2 term is added
    once after the reduction — algebraically identical to the single-device
    weighted mean ``(sum_i w_i grad_i) / max(sum_i w_i, 1) + l2*params``,
    differing only in float reduction order.  Cached per (objective, axis)
    so repeated segment calls reuse the traced closure."""
    cache = getattr(objective, "_psum_grad_fns", None)
    if cache is None:
        cache = objective._psum_grad_fns = {}
    if axis not in cache:
        gsum = jax.grad(
            lambda p, b, w: jnp.sum(objective.per_example_loss(p, b) * w))

        def grad_fn(params, batch, weights):
            g = gsum(params, batch, weights)
            den = jnp.maximum(jax.lax.psum(jnp.sum(weights), axis), 1.0)
            g = jax.tree.map(lambda x: jax.lax.psum(x, axis) / den, g)
            if objective.l2:
                g = jax.tree.map(lambda x, p: x + objective.l2 * p, g,
                                 params)
            return g

        cache[axis] = grad_fn
    return cache[axis]


# --------------------------------------------------------------------------
# HistoryStore
# --------------------------------------------------------------------------


class HistoryStore:
    """Engine-facing storage/placement layer over one `TrainingHistory`."""

    kind = "abstract"

    @staticmethod
    def create(history: TrainingHistory,
               placement: Optional[PlacementPolicy] = None,
               window: int = 0, decode: str = "auto") -> "HistoryStore":
        """Pick the store for the history's tier: stacked/device →
        `ResidentStore` (optionally mesh-placed); host/disk →
        `SegmentStreamer` (``window`` steps per device-resident segment,
        0 → auto), or `ShardedStreamer` when a multi-device placement is
        given (each mesh shard streams only its slice of every window).

        ``decode`` picks the streamers' read path: "fetch" decodes every
        window to f32 on arrival (the pre-encoded-window behaviour);
        "kernel" keeps windows ENCODED on device and the scan dequantizes
        per step in registers (HBM high-water drops by the codec ratio);
        "auto" → "kernel" for every non-f32 codec."""
        if history.tier in ("host", "disk"):
            if placement is not None \
                    and int(np.prod(placement.mesh_shape)) > 1:
                return ShardedStreamer(history, placement, window=window,
                                       decode=decode)
            return SegmentStreamer(history, window=window, decode=decode)
        return ResidentStore(history, placement=placement)

    # engine-facing API ------------------------------------------------------

    @property
    def meta(self):
        return self.history.meta

    @property
    def T(self) -> int:
        return self.history.meta.steps

    def span_end(self, t: int, t2: int) -> int:
        """Largest b <= t2 such that [t, b) fits one `window()` fetch."""
        raise NotImplementedError

    def window(self, a: int, b: int):
        """(W, G, off) device pytrees covering steps [a, b); scan bodies
        index ``W[t - off]``."""
        raise NotImplementedError

    def entry(self, t: int):
        raise NotImplementedError

    def params0(self):
        return self.entry(0)[0]

    def commit(self, regions, final_params) -> None:
        """Land an online request's deferred rewrites (see
        `core.engine.run_online_request` for the region format) and
        finalize `final_params` into the history."""
        raise NotImplementedError

    def sharded_replay(self) -> Optional["ShardedReplay"]:
        """The shard_map program builder when this store is mesh-placed."""
        return None

    def hbm_high_water(self) -> int:
        """Max device-resident history bytes this store ever held per
        device."""
        raise NotImplementedError


def _chunk_lift(p, kind):
    """Stack an explicit-step run into a (len, ...) chunk; scanned segments
    are already stacked."""
    if kind == "run":
        return jax.tree.map(lambda *xs: jnp.stack(xs), *p)
    return p


@jax.jit
def _scatter_chunk(W, G, t0, w_cat, g_cat):
    upd = partial(jax.lax.dynamic_update_slice_in_dim, axis=0)
    return (jax.tree.map(lambda x, u: upd(x, u.astype(x.dtype), t0), W, w_cat),
            jax.tree.map(lambda x, u: upd(x, u.astype(x.dtype), t0), G, g_cat))


@partial(jax.jit, static_argnames=("kinds",))
def _assemble_chunk(parts_w, parts_g, *, kinds):
    """One contiguous rewrite region as a single stacked (len, ...) pair."""
    ws = [_chunk_lift(p, k) for p, k in zip(parts_w, kinds)]
    gs = [_chunk_lift(p, k) for p, k in zip(parts_g, kinds)]
    return (jax.tree.map(lambda *xs: jnp.concatenate(xs), *ws),
            jax.tree.map(lambda *xs: jnp.concatenate(xs), *gs))


def _freeze_parts(parts):
    return tuple(tuple(p) if isinstance(p, list) else p for p in parts)


@jax.jit
def _entry_slices(W, G, t):
    """(w_t, g_t) as ONE jitted program — a host-driven explicit step costs
    one dispatch here, not 2 * n_leaves eager slice ops.  Encoded windows
    (kernel decode mode) slice-then-dequant per leaf via `entry_at`."""
    return entry_at(W, t, 0), entry_at(G, t, 0)


class ResidentStore(HistoryStore):
    """Whole-path device residency (stacked/device tiers), optionally
    sharded across a mesh by `dist.sharding.stacked_spec_for_leaf`."""

    kind = "resident"

    def __init__(self, history: TrainingHistory,
                 placement: Optional[PlacementPolicy] = None):
        self.history = history
        self.placement = placement
        W, G = history.stacked_view()
        self._specs = None
        self._flat_specs_w: Optional[List[Any]] = None
        if placement is not None:
            from repro.dist.sharding import history_shardings
            plan = placement.plan()
            shard_w = history_shardings(plan, W)
            shard_g = history_shardings(plan, G)
            W = jax.tree.map(jax.device_put, W, shard_w)
            G = jax.tree.map(jax.device_put, G, shard_g)
            self._specs = (jax.tree.map(lambda s: s.spec, shard_w),
                           jax.tree.map(lambda s: s.spec, shard_g))
            self._flat_specs_w = [s.spec for s in jax.tree.leaves(shard_w)]
        self.W, self.G = W, G
        self._sharded: Optional["ShardedReplay"] = None
        self._hbm = self._per_device_bytes()

    def _per_device_bytes(self) -> int:
        """History bytes resident on ONE device — the number sharding is
        supposed to shrink (nbytes / mesh factor for sharded leaves)."""
        return tree_device_nbytes((self.W, self.G))

    @property
    def specs(self):
        """Per-leaf (W, G) PartitionSpec trees when placed on a mesh."""
        return self._specs

    @property
    def window_specs(self):
        return self._specs  # resident windows are always decoded leaves

    def span_end(self, t: int, t2: int) -> int:
        return t2  # the whole path is resident; never split a segment

    def window(self, a: int, b: int):
        return self.W, self.G, 0

    def entry(self, t: int):
        return _entry_slices(self.W, self.G, t)

    def commit(self, regions, final_params) -> None:
        for t0, kinds, pw, pg in regions:
            w_cat, g_cat = _assemble_chunk(_freeze_parts(pw),
                                           _freeze_parts(pg),
                                           kinds=tuple(kinds))
            self.W, self.G = _scatter_chunk(self.W, self.G, jnp.int32(t0),
                                            w_cat, g_cat)
        # O(1) pointer swap for stacked/device storage
        self.history.replace_from_stacked(self.W, self.G,
                                          final_params=final_params)

    def sharded_replay(self) -> Optional["ShardedReplay"]:
        if self.placement is None:
            return None
        if self._sharded is None:
            self._sharded = ShardedReplay(self)
        return self._sharded

    def hbm_high_water(self) -> int:
        return self._hbm


class SegmentStreamer(HistoryStore):
    """Serve a host/disk-tier history to the compiled scan in device-resident
    segment windows with double-buffered async host→device copies.

    Prefetch depth is ADAPTIVE: it starts at 1 (classic double buffering)
    and, when the measured host stacking time of a window exceeds the scan
    time the device spends consuming one, grows to
    ``ceil(stack / scan)`` windows (capped at ``max_prefetch``) so the
    compiled path never starves on the offload tier.  The depth actually
    used is reported via `stats.extra["prefetch_depth"]`; device
    high-water grows by one ENCODED window per extra depth step."""

    kind = "streamed"
    placement = None

    def __init__(self, history: TrainingHistory, window: int = 0,
                 prefetch: bool = True, max_prefetch: int = 4,
                 stage_threads: Optional[int] = None,
                 decode: str = "auto"):
        assert history.tier in ("host", "disk"), history.tier
        if decode not in ("auto", "kernel", "fetch"):
            raise ValueError(
                f"unknown decode mode {decode!r}; pick 'fetch' (decode "
                "windows to f32 on arrival), 'kernel' (keep windows "
                "encoded on device, dequantize per step in the scan), or "
                "'auto' (kernel for every non-f32 codec)")
        self.history = history
        # f32 windows have nothing to decode — kernel mode degenerates to
        # fetch (the staged window IS the decoded window)
        if history.codec.name == "f32":
            decode = "fetch"
        elif decode == "auto":
            decode = "kernel"
        self.decode_mode = decode
        self.window_len = auto_window(history.meta.steps, window)
        self.prefetch = prefetch
        # depth > 1 only pays when that many windows can STAGE concurrently
        # — a queued future behind one busy worker adds device bytes, not
        # throughput — so the depth cap IS the worker count (default: spare
        # cores; 1 on small hosts → classic double buffering, ~2-window
        # high-water)
        import os as _os
        workers = stage_threads if stage_threads is not None \
            else (_os.cpu_count() or 2) - 1
        self.max_prefetch = max(1, min(int(max_prefetch), int(workers)))
        self._pool = ThreadPoolExecutor(max_workers=self.max_prefetch) \
            if prefetch else None
        self._buf: Dict[int, Tuple[Any, Any]] = {}
        self._inflight: Dict[int, Future] = {}
        self._hbm_now = 0
        self._hbm_high = 0
        self._enc_bytes = 0  # ENCODED per-device bytes of the last staged
        # window (the in-flight prefetch copy is pre-decode, so lossy codecs
        # stage at 1/2 or 1/4 of the decoded f32 size)
        self.enc_bytes_high = 0  # high-water of encoded window bytes
        self.compression_ratio = 1.0  # decoded f32 bytes / encoded bytes
        self.windows_fetched = 0
        self.prefetch_hits = 0
        self.host_wait_s = 0.0
        # adaptive prefetch state: EMAs of host stacking time vs the scan
        # time between consecutive window() calls (both in seconds)
        self.prefetch_depth = 1  # depth chosen for the NEXT windows
        self.depth_used = 1  # high-water of chosen depths (stats.extra)
        # host RAM of staged windows: host_stage_high is the largest
        # SINGLE window's staged bytes (depth k stages up to k windows
        # concurrently); guarded by a lock because staging runs on pool
        # threads once the depth exceeds 1
        import threading
        self._meter_lock = threading.Lock()
        self.host_stage_bytes = 0
        self.host_stage_high = 0
        self._stack_ema = 0.0
        self._scan_ema = 0.0
        self._last_return_ts: Optional[float] = None

    # -- window plumbing -----------------------------------------------------

    def _wid(self, t: int) -> int:
        return t // self.window_len

    def _bounds(self, wid: int) -> Tuple[int, int]:
        a = wid * self.window_len
        return a, min(self.T, a + self.window_len)

    def span_end(self, t: int, t2: int) -> int:
        return min(t2, self._bounds(self._wid(t))[1])

    def _window_bases(self, a: int, b: int):
        """(kidx, base_w, base_g) for a delta-codec window [a, b): the
        stacked f32 keyframes of every key window the steps touch, plus
        the per-step row index into that stack — computed here so ANY
        stream window works with ANY key interval, aligned or not."""
        K = self.history.key_interval
        kw0 = a // K
        kwids = list(range(kw0, (b - 1) // K + 1))
        pairs = [self.history.base_entry(k) for k in kwids]
        stack = lambda *xs: np.stack([np.asarray(x) for x in xs])
        base_w = jax.tree.map(stack, *(p for p, _ in pairs))
        base_g = jax.tree.map(stack, *(g for _, g in pairs))
        kidx = np.asarray([t // K - kw0 for t in range(a, b)], np.int32)
        return kidx, base_w, base_g

    def _wrap_encoded(self, tree, base_tree, kidx):
        """Stacked encoded tree → EncodedLeaf leaves (device-ready form)."""

        def wrap(x, b):
            if _is_enc_leaf(x):  # int8 inner: {"q": (L, ...), "scale": (L,)}
                return EncodedLeaf(q=x["q"], scale=x["scale"], base=b,
                                   kidx=None if b is None else kidx)
            return EncodedLeaf(q=x, scale=None, base=b,
                               kidx=None if b is None else kidx)

        if base_tree is None:
            return jax.tree.map(lambda x: wrap(x, None), tree,
                                is_leaf=_is_enc_leaf)
        return jax.tree.map(wrap, tree, base_tree, is_leaf=_is_enc_leaf)

    def _stage_window(self, wid: int):
        """Host side of a fetch: stack the window's ENCODED entries per leaf
        and ship them with `jax.device_put` (async dispatch).  Runs on the
        worker thread for prefetches; no tracing happens here.  Non-f32
        codecs stage EncodedLeaf leaves (decoded on fetch or consumed
        encoded by the scan, per `decode_mode`); delta codecs ride their
        key windows' keyframe bases along."""
        a, b = self._bounds(wid)
        enc_p, enc_g = [], []
        for t in range(a, b):
            p, g = self.history.encoded_entry(t)
            enc_p.append(p)
            enc_g.append(g)
        stack = lambda *xs: np.stack([np.asarray(x) for x in xs])
        Wh = jax.tree.map(stack, *enc_p) if len(enc_p) > 1 else \
            jax.tree.map(lambda x: np.asarray(x)[None], enc_p[0])
        Gh = jax.tree.map(stack, *enc_g) if len(enc_g) > 1 else \
            jax.tree.map(lambda x: np.asarray(x)[None], enc_g[0])
        if self.history.codec.name != "f32":
            if self.history.is_delta:
                kidx, base_w, base_g = self._window_bases(a, b)
            else:
                kidx = base_w = base_g = None
            Wh = self._wrap_encoded(Wh, base_w, kidx)
            Gh = self._wrap_encoded(Gh, base_g, kidx)
        self._note_stage_bytes(tree_nbytes((Wh, Gh)))
        return jax.device_put((Wh, Gh))

    def _stack_host(self, wid: int):
        """`_stage_window` + the stacking-time EMA the adaptive prefetch
        depth feeds on (updated from whichever thread runs the stage).
        The ``store.window_stage`` span records on the staging-pool thread
        for prefetches — its own track in the exported trace."""
        t0 = time.perf_counter()
        with obs_trace.span("store.window_stage", wid=wid):
            staged = self._stage_window(wid)
        dt = time.perf_counter() - t0
        self._stack_ema = dt if self._stack_ema == 0.0 \
            else 0.5 * self._stack_ema + 0.5 * dt
        return staged

    def _note_stage_bytes(self, nbytes: int) -> None:
        with self._meter_lock:
            self.host_stage_bytes = int(nbytes)
            self.host_stage_high = max(self.host_stage_high, int(nbytes))

    def _decode(self, staged):
        """Read path: fetch mode decodes the whole window to f32 on
        arrival; kernel mode hands the ENCODED window straight to the
        scan (per-step dequant in `entry_at` / the Pallas kernels).
        Encoded windows decode under jit so XLA contracts the
        multiply-add exactly like the in-scan slice decode does — that
        (plus the shared decode expression) is what makes fetch-mode and
        kernel-mode replays bitwise identical."""
        if self.decode_mode == "kernel":
            return staged
        Wh, Gh = staged
        if is_encoded_window(Wh) or is_encoded_window(Gh):
            return _decode_window_pair(Wh, Gh)
        codec = self.history.codec
        return codec.decode_stacked(Wh), codec.decode_stacked(Gh)

    def _fetch(self, wid: int):
        if wid in self._buf:
            return self._buf[wid]
        reg = obs_metrics.get_registry()
        fut = self._inflight.pop(wid, None)
        if fut is not None:
            t0 = time.perf_counter()
            with obs_trace.span("store.prefetch_wait", wid=wid):
                staged = fut.result()
            wait = time.perf_counter() - t0
            self.host_wait_s += wait
            self.prefetch_hits += 1
            reg.counter("store.prefetch_hits", owner="core.store").inc()
        else:
            t0 = time.perf_counter()
            staged = self._stack_host(wid)
            wait = time.perf_counter() - t0
            self.host_wait_s += wait
        reg.counter("store.host_wait_s", unit="s",
                    owner="core.store").inc(wait)
        self._enc_bytes = tree_device_nbytes(staged)
        self.enc_bytes_high = max(self.enc_bytes_high, self._enc_bytes)
        if self._enc_bytes:
            self.compression_ratio = (decoded_window_nbytes(staged)
                                      / self._enc_bytes)
        W, G = self._decode(staged)
        self._buf[wid] = (W, G)
        self._hbm_now += tree_device_nbytes(W) + tree_device_nbytes(G)
        self._hbm_high = max(self._hbm_high, self._hbm_now)
        self.windows_fetched += 1
        reg.counter("store.windows_fetched", owner="core.store").inc()
        return W, G

    def _evict_before(self, wid: int) -> None:
        for old in [w for w in self._buf if w < wid]:
            W, G = self._buf.pop(old)
            self._hbm_now -= tree_device_nbytes(W) + tree_device_nbytes(G)
        for old in [w for w in self._inflight if w < wid]:
            self._inflight.pop(old)

    def _prefetch(self, wid: int) -> None:
        if (self._pool is None or wid in self._buf or wid in self._inflight
                or wid * self.window_len >= self.T):
            return
        self._inflight[wid] = self._pool.submit(self._stack_host, wid)

    def _choose_depth(self) -> int:
        """Prefetch depth for the next windows: 1 while the host keeps up,
        ceil(stack / scan) once stacking is MEASURABLY slower than the
        scan that consumes a window (ROADMAP adaptive-depth item).  The
        1 ms floor keeps microsecond-scale timing noise from buying extra
        device-resident windows that cannot possibly pay for themselves."""
        if (self._scan_ema <= 0.0 or self._stack_ema <= 1e-3
                or self._stack_ema <= self._scan_ema):
            return 1
        depth = min(self.max_prefetch,
                    int(np.ceil(self._stack_ema / self._scan_ema)))
        return max(1, depth)

    def window(self, a: int, b: int):
        now = time.perf_counter()
        if self._last_return_ts is not None:
            # time since the previous window was handed out ≈ the scan
            # time that consumed it (the denominator of the depth rule)
            dt = now - self._last_return_ts
            self._scan_ema = dt if self._scan_ema == 0.0 \
                else 0.5 * self._scan_ema + 0.5 * dt
        wid = self._wid(a)
        assert b <= self._bounds(wid)[1], (a, b, self.window_len)
        with obs_trace.span("store.window", wid=wid,
                            hit=wid in self._buf or wid in self._inflight):
            self._evict_before(wid)
            W, G = self._fetch(wid)
        # double buffering (depth 1), or deeper when the host is the
        # bottleneck: ship windows s+1..s+k while the scan for s computes
        depth = self._choose_depth()
        self.prefetch_depth = depth
        self.depth_used = max(self.depth_used, depth)
        for ahead in range(1, depth + 1):
            self._prefetch(wid + ahead)
        # in-flight staged copies are device-resident too — that is the
        # buffering cost the high-water must report (at ENCODED size:
        # decode happens on the consuming fetch)
        self._hbm_high = max(self._hbm_high,
                             self._hbm_now
                             + len(self._inflight) * self._enc_bytes)
        obs_metrics.get_registry().gauge(
            "store.hbm_high_water_bytes", unit="B",
            owner="core.store").set_max(self._hbm_high)
        self._last_return_ts = time.perf_counter()
        return W, G, wid * self.window_len

    def entry(self, t: int):
        wid = self._wid(t)
        if wid in self._buf:
            W, G = self._buf[wid]
            return _entry_slices(W, G, t - wid * self.window_len)
        return self.history.entry(t)

    # -- online rewrite commit ----------------------------------------------

    def commit(self, regions, final_params) -> None:
        # drain in-flight prefetches first: a worker mid-read of the same
        # entries we are about to overwrite is a read/write race on the
        # disk tier's .npz files
        for fut in self._inflight.values():
            try:
                fut.result()
            except Exception:
                pass  # a failed prefetch of soon-stale data is harmless
        for t0, kinds, pw, pg in regions:
            w_cat, g_cat = _assemble_chunk(_freeze_parts(pw),
                                           _freeze_parts(pg),
                                           kinds=tuple(kinds))
            w_host = jax.device_get(w_cat)
            g_host = jax.device_get(g_cat)
            span = jax.tree.leaves(w_host)[0].shape[0]
            for i in range(span):
                self.history.overwrite(
                    t0 + i, jax.tree.map(lambda x: x[i], w_host),
                    jax.tree.map(lambda x: x[i], g_host))
        self.history.finalize(final_params)
        # buffered windows hold pre-request values — drop them
        self._buf.clear()
        self._inflight.clear()
        self._hbm_now = 0

    def hbm_high_water(self) -> int:
        return self._hbm_high


def _is_enc_leaf(x) -> bool:
    """Codec-dict leaves (int8's {"q", "scale"}) in an ENCODED entry."""
    return isinstance(x, dict) and "q" in x


class ShardedStreamer(SegmentStreamer):
    """Host/disk-tier history sharded across a mesh AND streamed per window
    — the composition `HistoryStore.create` used to refuse.

    Placement: every staged window takes the same
    `dist.sharding.stacked_spec_for_leaf` placements a `ResidentStore`
    would give the full (T, ...) leaves (time axis never sharded —
    `stacked_entry_shardings`).  The staging path is PER-SHARD end to end:
    for each leaf, each mesh shard's worker thread stacks only its slice
    of the window's encoded entries (host RAM stages one window of
    slices, never a full stacked leaf) and uploads it to its own device;
    `jax.make_array_from_single_device_arrays` assembles the global
    window without any device ever holding a whole leaf.  The codec
    decodes shard-local on device (`out_shardings` pins the decoded
    window to the same placement), and `sharded_replay()` hands the
    engines the same `ShardedReplay` program builder the resident path
    uses — the shard_map scan body all-gathers the decoded window one
    step at a time, so `run_replay` / `run_online_request` run unchanged.

    Online rewrites commit exactly like `SegmentStreamer`: the request's
    (replicated) rewrite chunks land back in the owning history entries
    through the codec — the per-shard segments are staging artifacts,
    re-sliced from the rewritten entries on the next fetch.

    Per-device high-water: ~2 windows of the SHARD (decoded window +
    in-flight encoded window), i.e. ``2 * L * 2P / (mesh * ratio-ish)``
    instead of the full path — see the tier guide in `core.history`."""

    kind = "sharded_streamed"

    def __init__(self, history: TrainingHistory,
                 placement: PlacementPolicy, window: int = 0,
                 prefetch: bool = True, max_prefetch: int = 4,
                 stage_threads: Optional[int] = None,
                 stage_workers: int = 4, decode: str = "auto"):
        assert placement is not None
        need = int(np.prod(np.asarray(placement.mesh_shape, dtype=np.int64)))
        have = jax.device_count()
        if need > have:
            raise ValueError(
                f"sharded streaming asks for a {placement.mesh_shape} mesh "
                f"({need} shards) but only {have} device(s) are visible — "
                "the shard count must match the mesh the process can "
                "build (e.g. XLA_FLAGS=--xla_force_host_platform_device_"
                "count=N for CPU tests), or drop the placement to stream "
                "single-device")
        self.placement = placement
        super().__init__(history, window=window, prefetch=prefetch,
                         max_prefetch=max_prefetch,
                         stage_threads=stage_threads, decode=decode)
        from jax.sharding import NamedSharding, PartitionSpec

        plan = placement.plan()
        from repro.dist.sharding import stacked_entry_shardings
        w0, g0 = history.entry(0)  # per-step template (paths + shapes)
        self._shard_w = stacked_entry_shardings(plan, w0)
        self._shard_g = stacked_entry_shardings(plan, g0)
        self._specs = (jax.tree.map(lambda s: s.spec, self._shard_w),
                       jax.tree.map(lambda s: s.spec, self._shard_g))
        self._flat_specs_w = [s.spec
                              for s in jax.tree.leaves(self._shard_w)]
        self._rep_sharding = NamedSharding(placement.mesh, PartitionSpec())
        if self.decode_mode == "kernel":
            # the windows the engines see are ENCODED — build the matching
            # EncodedLeaf spec trees for shard_map (q/base shard like the
            # decoded leaf, time axis and keyframe axis never sharded;
            # scale/kidx replicate)
            codec = history.codec
            inner = codec.inner if history.is_delta else codec
            has_scale = isinstance(inner, Int8Codec)
            has_base = history.is_delta

            def espec(s):
                return EncodedLeaf(
                    q=s, scale=PartitionSpec() if has_scale else None,
                    base=s if has_base else None,
                    kidx=PartitionSpec() if has_base else None)

            self._window_specs = (jax.tree.map(espec, self._specs[0]),
                                  jax.tree.map(espec, self._specs[1]))
        else:
            self._window_specs = self._specs
        self._stage_pool = ThreadPoolExecutor(
            max_workers=max(1, min(int(stage_workers), need)))
        self._decode_fn = None
        self._sharded: Optional["ShardedReplay"] = None
        # staged keyframe bases per window: bases are IMMUTABLE (online
        # rewrites re-encode against the same keyframe), so repeated
        # replays off one store ship each window's base shards once
        self._base_cache: Dict[int, Tuple[Any, Any, Any, int]] = {}

    @property
    def specs(self):
        """Per-leaf (W, G) PartitionSpec trees (same contract as a
        mesh-placed `ResidentStore`)."""
        return self._specs

    @property
    def window_specs(self):
        """Spec trees matching what `window()` RETURNS — EncodedLeaf spec
        trees in kernel decode mode, the decoded-leaf specs otherwise."""
        return self._window_specs

    # -- per-shard staging ---------------------------------------------------

    def _stage_leaf(self, sharding, column, meter: List[int]):
        """One leaf of one window: stack PER-SHARD host slices of the
        ``len(column)`` encoded entries and upload each to its owning
        device — the per-shard encoded segment.  Fanned out over the
        stage pool so shards stack/ship concurrently; each shard appends
        its slice bytes to `meter` (list.append is atomic, and the meter
        is local to ONE window's stage, so concurrent windows under
        adaptive depth never clobber each other's sums)."""
        gshape = (len(column),) + tuple(np.shape(column[0]))
        idx_map = sharding.addressable_devices_indices_map(gshape)

        def one_shard(dev, index):
            per_entry = index[1:]  # the time axis is never sharded
            buf = np.stack([np.asarray(e)[per_entry] for e in column])
            meter.append(buf.nbytes)
            return jax.device_put(buf, dev)

        futs = [self._stage_pool.submit(one_shard, d, ix)
                for d, ix in idx_map.items()]
        return jax.make_array_from_single_device_arrays(
            gshape, sharding, [f.result() for f in futs])

    def _stage_tree(self, entries, shardings, meter: List[int],
                    base_flat=None, kidx_dev=None):
        """Stack one window of encoded per-step pytrees into globally
        sharded (L, ...) leaves.  Codec-dict leaves shard their payload
        ("q") like the decoded leaf; per-entry scales stack to a
        replicated (L,) vector shipped in ONE broadcast put.  Non-f32
        codecs come back as EncodedLeaf leaves; delta keyframe bases
        arrive pre-staged (immutable → cached, see `_staged_bases`)."""
        flat0, tdef = jax.tree.flatten(entries[0], is_leaf=_is_enc_leaf)
        cols = list(zip(*(jax.tree.leaves(e, is_leaf=_is_enc_leaf)
                          for e in entries)))
        if base_flat is None:
            base_flat = [None] * len(flat0)
        encoded = self.history.codec.name != "f32"
        out = []
        for proto, sh, col, bs in zip(flat0, jax.tree.leaves(shardings),
                                      cols, base_flat):
            if not encoded:
                out.append(self._stage_leaf(sh, col, meter))
                continue
            if _is_enc_leaf(proto):
                q = self._stage_leaf(sh, [c["q"] for c in col], meter)
                buf = np.stack([np.asarray(c["scale"]) for c in col])
                meter.append(buf.nbytes)
                scale = jax.device_put(buf, self._rep_sharding)
            else:  # bf16 residual — no per-step scale
                q = self._stage_leaf(sh, col, meter)
                scale = None
            out.append(EncodedLeaf(
                q=q, scale=scale, base=bs,
                kidx=None if bs is None else kidx_dev))
        return jax.tree.unflatten(tdef, out)

    def _staged_bases(self, wid: int, a: int, b: int):
        """(kidx_dev, flat base_w, flat base_g, new_bytes) for window
        `wid`, per-shard staged and cached: the keyframes are immutable,
        so every later fetch of the same window (other replays on this
        store, adaptive-prefetch restages) reuses the device shards.
        `new_bytes` is 0 on a hit so the window meter only counts the
        first staging."""
        hit = self._base_cache.get(wid)
        if hit is not None:
            return hit
        kidx, base_w, base_g = self._window_bases(a, b)
        meter: List[int] = []
        kidx_dev = jax.device_put(np.asarray(kidx, np.int32),
                                  self._rep_sharding)
        bw = [self._stage_leaf(sh, list(bs), meter)
              for bs, sh in zip(jax.tree.leaves(base_w),
                                jax.tree.leaves(self._shard_w))]
        bg = [self._stage_leaf(sh, list(bs), meter)
              for bs, sh in zip(jax.tree.leaves(base_g),
                                jax.tree.leaves(self._shard_g))]
        self._base_cache[wid] = (kidx_dev, bw, bg, 0)
        return kidx_dev, bw, bg, sum(meter)

    def _stage_window(self, wid: int):
        a, b = self._bounds(wid)
        enc_p, enc_g = [], []
        for t in range(a, b):
            p, g = self.history.encoded_entry(t)
            enc_p.append(p)
            enc_g.append(g)
        # per-shard staging: this window's host footprint is the SUM of
        # its staged slices (incl. replicated leaves once per device)
        if self.history.is_delta:
            kidx_dev, bw, bg, base_bytes = self._staged_bases(wid, a, b)
        else:
            kidx_dev = bw = bg = None
            base_bytes = 0
        meter: List[int] = [base_bytes]
        staged = (self._stage_tree(enc_p, self._shard_w, meter,
                                   bw, kidx_dev),
                  self._stage_tree(enc_g, self._shard_g, meter,
                                   bg, kidx_dev))
        self._note_stage_bytes(sum(meter))
        return staged

    def _decode(self, staged):
        """Decode the staged (encoded, sharded) window ON DEVICE, with
        `out_shardings` pinning every decoded leaf to its resident-path
        placement — shard-local work, no gather.  Kernel mode skips the
        decode entirely: the scan consumes the encoded window."""
        if self.decode_mode == "kernel":
            return staged
        if self._decode_fn is None:
            codec = self.history.codec
            if is_encoded_window(staged[0]) or is_encoded_window(staged[1]):
                fn = lambda Wh, Gh: (decode_window_tree(Wh),
                                     decode_window_tree(Gh))
            else:
                fn = lambda Wh, Gh: (codec.decode_stacked(Wh),
                                     codec.decode_stacked(Gh))
            self._decode_fn = jax.jit(
                fn, out_shardings=(self._shard_w, self._shard_g))
        return self._decode_fn(*staged)

    def entry(self, t: int):
        """Explicit steps read per-step slices of the OWNING window, kept
        sharded exactly like the resident path's entries — fetching the
        window on demand keeps the sharded-streamed and sharded-resident
        explicit-step programs (and so their float reduction orders)
        identical, which is what makes mesh streamed-vs-resident parity
        exact."""
        wid = self._wid(t)
        if wid not in self._buf:
            self._evict_before(wid)
            self._fetch(wid)
        W, G = self._buf[wid]
        return _entry_slices(W, G, t - wid * self.window_len)

    def sharded_replay(self) -> Optional["ShardedReplay"]:
        if self._sharded is None:
            self._sharded = ShardedReplay(self)
        return self._sharded


# --------------------------------------------------------------------------
# Sharded replay: shard_map construction for the engines' segment scans
# --------------------------------------------------------------------------


class ShardedReplay:
    """Builds (and caches) the shard_map-wrapped segment programs for a
    mesh-placed store (`ResidentStore` or `ShardedStreamer`).

    The engines hand their segment *impl* functions (plain, un-jitted,
    with every static argument already bound) to `wrap`; the minibatch
    schedule arrives batch-sharded over the data axis, parameters and
    L-BFGS pairs replicate, and history leaves keep their storage
    placement — sharded leaves are all-gathered ONE STEP at a time inside
    the scan body (`gather_info`), so no device ever materializes the
    whole stacked path (for a streamed store, not even a whole window).
    The same per-leaf gather plan serves full-path and windowed sources:
    a window is just a shorter, offset time axis, and the time axis is
    never sharded."""

    def __init__(self, store: HistoryStore):
        assert store.placement is not None and store.specs is not None
        self.store = store
        self._cache: Dict[Any, Any] = {}

    @property
    def placement(self) -> PlacementPolicy:
        return self.store.placement

    def gather_info(self) -> Tuple[Tuple[Tuple[int, str], ...], ...]:
        """Per-leaf ((dim, axis_name), ...) all-gather plan for one history
        ENTRY (the per-step leaf, after the time axis is sliced away),
        aligned with ``jax.tree.leaves(W)``; () means replicated."""
        out = []
        for spec in self.store._flat_specs_w:
            gathers = []
            for dim, ax in enumerate(tuple(spec)[1:]):  # drop time axis
                if ax is None:
                    continue
                for name in ((ax,) if isinstance(ax, str) else tuple(ax)):
                    gathers.append((dim, name))
            out.append(tuple(gathers))
        return tuple(out)

    def _schedule_specs(self):
        from jax.sharding import PartitionSpec as P

        from repro.core.engine import DeviceSchedule
        d = self.placement.data_axis
        return DeviceSchedule(idx=P(None, d), kept_w=P(None, d),
                              changed_idx=P(None, d), changed_w=P(None, d),
                              dB=P(), kept=P(), lr=P())

    def wrap(self, impl_fn, key, n_outputs: int):
        """shard_map + jit for ``impl_fn(params, vel, t0, off, W, G, cols,
        sd, *rest)`` with `n_outputs` replicated outputs; cached by `key`
        (span/sign/momentum/... — everything that changes the program)."""
        if key in self._cache:
            return self._cache[key]
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        specs_w, specs_g = self.store.window_specs
        rep = P()
        lead = (rep, rep, rep, rep, specs_w, specs_g, rep,
                self._schedule_specs())
        out_specs = (rep,) * n_outputs if n_outputs > 1 else rep
        mesh = self.placement.mesh

        def call(*args):
            in_specs = lead + (rep,) * (len(args) - len(lead))
            return shard_map(impl_fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False)(*args)

        jitted = jax.jit(call)
        self._cache[key] = jitted
        return jitted


def entry_at(W, t, off, gather=None):
    """Slice one step out of stacked history leaves, all-gathering sharded
    leaves per the ShardedReplay gather plan (no-op when gather is None).

    Encoded windows (`EncodedLeaf` leaves) dequantize the SLICE — shard-
    local, before the gather — so sharded kernel-mode replay ships the
    same f32 step the resident path would, while the window itself stays
    encoded in HBM.  One EncodedLeaf flattens to one decoded leaf, so the
    per-leaf gather plans line up unchanged."""
    leaves, tdef = jax.tree.flatten(W, is_leaf=_is_window_leaf)
    if gather is None:
        return jax.tree.unflatten(
            tdef, [_decode_leaf_slice(x, t - off) for x in leaves])
    out = []
    for leaf, plan in zip(leaves, gather):
        x = _decode_leaf_slice(leaf, t - off)
        for dim, ax in plan:
            x = jax.lax.all_gather(x, ax, axis=dim, tiled=True)
        out.append(x)
    return jax.tree.unflatten(tdef, out)


def pad_schedule_batch(sched_dev, multiple: int):
    """Pad the device schedule's batch-shaped dims (axis 1) to a multiple of
    the data-axis size with weight-0 rows, so batch sharding divides evenly.
    Zero-weight rows gather row 0 and contribute nothing to any gradient."""
    if multiple <= 1:
        return sched_dev

    def pad(x, fill=0):
        b = x.shape[1]
        want = -(-b // multiple) * multiple
        if want == b:
            return x
        return jnp.pad(x, ((0, 0), (0, want - b)), constant_values=fill)

    return sched_dev._replace(
        idx=pad(sched_dev.idx), kept_w=pad(sched_dev.kept_w),
        changed_idx=pad(sched_dev.changed_idx),
        changed_w=pad(sched_dev.changed_w))
