import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ before any other import — jax locks the device count on first init.

"""Dry-run of the PAPER'S OWN hot path at LM scale: one DeltaGrad approx
step (Algorithm 1, non-explicit branch) for an assigned architecture on the
production mesh.

The step = grad over the r removed sequences present in the batch
(+ L-BFGS B·v over the full parameter pytree + the leave-r-out update),
with the history pair buffers sharded exactly like the parameters.  This is
the cell the §Perf log hillclimbs as "most representative of the paper's
technique":

    python -m repro.launch.dryrun_deltagrad --arch internlm2-1.8b
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, get_shape
from repro.core.lbfgs import lbfgs_hvp_stacked_pytree
from repro.dist.sharding import inputs_shardings, make_plan, params_shardings
from repro.launch.mesh import make_production_mesh
from repro.models.registry import build, count_params
from repro.roofline.analysis import roofline_from_compiled
from repro.roofline.model import analytic_cost
from repro.utils.tree import tree_sub

M_HISTORY = 2  # paper default
# removed sequences present in this step's minibatch, padded UP to the
# data-parallel degree: a removal buffer smaller than the `data` axis is
# unshardable -> replicated -> every device redundantly recomputes the
# removed-gradient AND its TP all-reduces go 16x (§Perf deltagrad-step
# iteration 2). The engine's DeltaGradConfig.removal_pad does the same.
R_SEQS = 16


def lower_deltagrad_cell(arch: str, multi_pod: bool = False,
                         variant: str = "baseline"):
    cfg = get_config(arch)
    shape = get_shape("train_4k")
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    n_dev = int(np.prod(mesh.devices.shape))
    plan = make_plan(mesh, cfg)
    model = build(cfg)

    params_specs = jax.eval_shape(lambda: model.init(0))
    p_shard = params_shardings(plan, params_specs)
    # ZeRO compute constraint (same lesson as §Perf iteration 3): gradients
    # must see model-only-sharded weights, or GSPMD contraction-splits the
    # data-FSDP dim and replicates the batch.
    compute_shard = params_shardings(make_plan(mesh, cfg, fsdp=False),
                                     params_specs)
    stacked_specs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((M_HISTORY,) + s.shape, s.dtype),
        params_specs)
    # history pairs sharded like params (stack axis replicated)
    stk_shard = jax.tree.map(
        lambda ns: jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(None, *ns.spec)), p_shard)
    rem_specs = {"tokens": jax.ShapeDtypeStruct((R_SEQS, shape.seq_len),
                                                jnp.int32)}
    rem_shard = inputs_shardings(plan, rem_specs)
    scalars = jax.ShapeDtypeStruct((), jnp.float32)

    def approx_step(params, w_t, g_t, dWs, dGs, rem_batch, lr, n_total, r):
        """Paper eq. (2): w -= lr/(n-r) [ n (g_t + B v) - r g_removed ]."""
        v = tree_sub(params, w_t)
        bv = lbfgs_hvp_stacked_pytree(dWs, dGs, v)
        params_c = jax.lax.with_sharding_constraint(params, compute_shard)
        g_removed = jax.grad(lambda p: model.loss_fn(p, rem_batch))(params_c)
        denom = jnp.maximum(n_total - r, 1.0)

        def upd(p, gt, b, gr):
            return p - lr * (n_total * (gt + b) - r * gr) / denom

        return jax.tree.map(upd, params, g_t, bv, g_removed)

    with mesh:
        lowered = jax.jit(
            approx_step,
            in_shardings=(p_shard, p_shard, p_shard, stk_shard, stk_shard,
                          rem_shard, None, None, None),
            donate_argnums=(0,),
        ).lower(params_specs, params_specs, params_specs, stacked_specs,
                stacked_specs, rem_specs, scalars, scalars, scalars)
        compiled = lowered.compile()

    # analytic cost: removed-seq grad (train-like on R_SEQS sequences)
    # + (4m+3) parameter-sized streams for hvp/update + Gram psums.
    n_params = count_params(cfg)
    import dataclasses
    sub_shape = dataclasses.replace(shape, global_batch=R_SEQS)
    ac_grad = analytic_cost(cfg, sub_shape, n_params=n_params)
    hvp_flops = (4 * M_HISTORY + 3) * n_params * 2
    hvp_bytes = (4 * M_HISTORY + 6) * n_params * 4.0
    flops = ac_grad.flops_global + hvp_flops
    bytes_ = ac_grad.breakdown.get("bytes_acts", 0) + \
        3 * R_SEQS * shape.seq_len * cfg.vocab * 4.0 + hvp_bytes

    report = roofline_from_compiled(
        compiled, arch=f"deltagrad-step-{arch}", shape="train_4k",
        mesh_name=mesh_name, n_devices=n_dev,
        model_flops=6.0 * n_params * R_SEQS * shape.seq_len,
        analytic_flops=flops, analytic_bytes=bytes_, variant=variant,
        note=f"approx step, m={M_HISTORY}, r={R_SEQS} seqs in batch")
    return lowered, compiled, report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="benchmarks/artifacts")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()
    t0 = time.time()
    lowered, compiled, report = lower_deltagrad_cell(
        args.arch, args.multi_pod, args.variant)
    dt = time.time() - t0
    mem = str(compiled.memory_analysis())
    print(f"OK deltagrad-step {args.arch} compile={dt:.1f}s "
          f"dominant={report.dominant} t=({report.t_compute:.3e},"
          f"{report.t_memory:.3e},{report.t_collective:.3e})")
    print(f"   memory: {mem[:240]}")
    rec = json.loads(report.to_json())
    rec.update({"status": "ok", "compile_s": dt, "memory_analysis": mem})
    os.makedirs(args.out, exist_ok=True)
    tag = f"deltagrad-step-{args.arch}__train_4k__{report.mesh}__{args.variant}"
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
