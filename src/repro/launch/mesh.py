"""Production mesh builders.

Importing this module never touches jax device state — meshes are built
inside functions only (the dry-run sets XLA_FLAGS before any jax import).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (data, model) single pod; 2x16x16 (pod, data, model) multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small forced-host-device mesh for tests."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_replay_mesh(data: int = 0, model: int = 1):
    """Mesh for sharded DeltaGrad replay (core/store's mesh-parallel path):
    batch-sharded per-example gradients over ``data``, optional ``model``
    axis for the history-leaf placements.  ``data=0`` → all local devices.

    Most callers want `repro.core.store.PlacementPolicy` (a picklable
    descriptor that builds this mesh lazily); this helper is for code that
    already holds devices."""
    if not data:
        data = jax.local_device_count() // max(1, model)
    if model > 1:
        return jax.make_mesh((data, model), ("data", "model"))
    return jax.make_mesh((data,), ("data",))
