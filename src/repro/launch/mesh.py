"""Production mesh builders.

Importing this module never touches jax device state — meshes are built
inside functions only (the dry-run sets XLA_FLAGS before any jax import).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (data, model) single pod; 2x16x16 (pod, data, model) multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small forced-host-device mesh for tests."""
    return jax.make_mesh((data, model), ("data", "model"))
