"""Serving drivers.

Two entry points share this module:

  * ``unlearn`` — the DeltaGrad request server (ROADMAP serve-path item):
    trains a model with path caching, then answers a stream of online
    delete/add requests through ``core.engine.run_online_request`` (via
    `core.online.OnlineEngine`, stacked history resident on the device),
    reporting per-request latency with the compile cost separated out.

        PYTHONPATH=src python -m repro.launch.serve unlearn \
            --n 4000 --d 500 --steps 80 --requests 12 --add-frac 0.25

  * batched decode (default, backwards-compatible flags): prefill a prompt
    batch, then step the KV caches.

        PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
            --reduced --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models.registry import build


def unlearn_main(argv) -> None:
    """Stand up the online unlearning service and drive a request stream."""
    from repro.core.deltagrad import DeltaGradConfig, sgd_train_with_cache
    from repro.core.history import HistoryMeta
    from repro.core.online import OnlineEngine
    from repro.data.synthetic import binary_classification
    from repro.models.simple import (logreg_accuracy, logreg_init,
                                     logreg_objective)

    ap = argparse.ArgumentParser(prog="serve unlearn")
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--d", type=int, default=500)
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--batch", type=int, default=1024)
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--l2", type=float, default=5e-3)
    ap.add_argument("--momentum", type=float, default=0.0)
    ap.add_argument("--period", type=int, default=5)
    ap.add_argument("--burn-in", type=int, default=10)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--add-frac", type=float, default=0.25,
                    help="fraction of requests that are additions")
    ap.add_argument("--impl", default="scan", choices=("scan", "python"))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    ds = binary_classification(n=args.n, d=args.d, seed=args.seed)
    obj = logreg_objective(l2=args.l2)
    meta = HistoryMeta(n=ds.n, batch_size=min(args.batch, ds.n),
                       seed=args.seed, steps=args.steps,
                       lr_schedule=((0, args.lr),), momentum=args.momentum)
    t0 = time.perf_counter()
    params, hist = sgd_train_with_cache(obj, logreg_init(args.d, seed=1),
                                        ds, meta)
    jax.block_until_ready(params)
    print(f"trained {args.steps} steps (n={ds.n}, d={args.d}) with path "
          f"cache in {time.perf_counter() - t0:.2f}s; "
          f"accuracy {logreg_accuracy(params, ds):.4f}")

    # additions are served from a pre-appended row pool: appending
    # mid-stream would grow the device columns' leading dim and retrace
    # every compiled program per add request, so stage capacity up front
    rng = np.random.default_rng(args.seed + 1)
    pool_src = rng.integers(0, meta.n, size=args.requests)
    add_pool = list(ds.append({k: v[pool_src] for k, v in ds.columns.items()}))

    cfg = DeltaGradConfig(period=args.period, burn_in=args.burn_in,
                          impl=args.impl)
    warm = ("delete", "add") if args.add_frac > 0 else ("delete",)
    engine = OnlineEngine(obj, hist, ds, cfg,
                          warmup=warm if args.impl == "scan" else False,
                          add_capacity=args.requests)
    print(f"online engine up (impl={engine.impl}); first-request compile "
          f"{engine.compile_time_s * 1e3:.0f} ms")

    lat = []
    for i in range(args.requests):
        if add_pool and rng.random() < args.add_frac:
            op, row = "add", int(add_pool.pop(0))
        else:
            live = np.flatnonzero(engine.live[:meta.n])
            op, row = "delete", int(rng.choice(live))
        t0 = time.perf_counter()
        st = engine.request(op, row)
        jax.block_until_ready(engine.params)
        ms = (time.perf_counter() - t0) * 1e3
        lat.append(ms)
        print(f"  request {i:3d} {op:6s} row {row:5d}: {ms:7.1f} ms  "
              f"(approx {st.approx_steps}, explicit {st.explicit_steps}, "
              f"grad-eval speedup x{st.theoretical_speedup:.1f})")
    lat = np.asarray(lat)
    print(f"served {args.requests} requests: "
          f"p50 {np.percentile(lat, 50):.1f} ms, "
          f"p95 {np.percentile(lat, 95):.1f} ms; "
          f"accuracy {logreg_accuracy(engine.params, ds):.4f}")


def decode_main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    params = model.init(args.seed)
    max_len = args.prompt_len + args.gen
    if cfg.family == "audio":
        caches = model.cache_init(args.batch, max_len, enc_len=64)
    else:
        caches = model.cache_init(args.batch, max_len)

    decode = jax.jit(lambda p, b, c: model.decode_fn(p, b, c),
                     donate_argnums=(2,))

    rng = np.random.default_rng(args.seed)
    prompt = rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len),
                          dtype=np.int32)

    # prefill by stepping (simple driver; the prefill graph is exercised by
    # the dry-run / tests)
    t0 = time.perf_counter()
    logits = None
    for t in range(args.prompt_len):
        logits, caches = decode(params, {"tokens": jnp.asarray(prompt[:, t:t + 1])},
                                caches)
    t_prefill = time.perf_counter() - t0

    key = jax.random.PRNGKey(args.seed)
    out_tokens = []
    t0 = time.perf_counter()
    for t in range(args.gen):
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits / args.temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        nxt = nxt.astype(jnp.int32)[:, None]
        out_tokens.append(np.asarray(nxt))
        logits, caches = decode(params, {"tokens": nxt}, caches)
    t_gen = time.perf_counter() - t0

    gen = np.concatenate(out_tokens, axis=1)
    tok_s = args.batch * args.gen / max(t_gen, 1e-9)
    print(f"prefill {args.prompt_len} tok x {args.batch} in {t_prefill:.2f}s; "
          f"generated {args.gen} tok x {args.batch} in {t_gen:.2f}s "
          f"({tok_s:.1f} tok/s)")
    print("sample row 0:", gen[0].tolist())


def main() -> None:
    if len(sys.argv) > 1 and sys.argv[1] == "unlearn":
        unlearn_main(sys.argv[2:])
    else:
        decode_main()


if __name__ == "__main__":
    main()
