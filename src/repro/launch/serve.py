"""Batched decode driver: prefill a prompt batch, then step the KV caches.

    python -m repro.launch.serve --arch internlm2-1.8b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models.registry import build


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    params = model.init(args.seed)
    max_len = args.prompt_len + args.gen
    if cfg.family == "audio":
        caches = model.cache_init(args.batch, max_len, enc_len=64)
    else:
        caches = model.cache_init(args.batch, max_len)

    decode = jax.jit(lambda p, b, c: model.decode_fn(p, b, c),
                     donate_argnums=(2,))

    rng = np.random.default_rng(args.seed)
    prompt = rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len),
                          dtype=np.int32)

    # prefill by stepping (simple driver; the prefill graph is exercised by
    # the dry-run / tests)
    t0 = time.perf_counter()
    logits = None
    for t in range(args.prompt_len):
        logits, caches = decode(params, {"tokens": jnp.asarray(prompt[:, t:t + 1])},
                                caches)
    t_prefill = time.perf_counter() - t0

    key = jax.random.PRNGKey(args.seed)
    out_tokens = []
    t0 = time.perf_counter()
    for t in range(args.gen):
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits / args.temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        nxt = nxt.astype(jnp.int32)[:, None]
        out_tokens.append(np.asarray(nxt))
        logits, caches = decode(params, {"tokens": nxt}, caches)
    t_gen = time.perf_counter() - t0

    gen = np.concatenate(out_tokens, axis=1)
    tok_s = args.batch * args.gen / max(t_gen, 1e-9)
    print(f"prefill {args.prompt_len} tok x {args.batch} in {t_prefill:.2f}s; "
          f"generated {args.gen} tok x {args.batch} in {t_gen:.2f}s "
          f"({tok_s:.1f} tok/s)")
    print("sample row 0:", gen[0].tolist())


if __name__ == "__main__":
    main()
