"""Serving drivers.

Two entry points share this module:

  * ``unlearn`` — the DeltaGrad request server (ROADMAP serve-path item),
    built on ``core.session.UnlearnerSession``: trains with path caching,
    answers a stream of online delete/add requests (one lazy `submit()`
    per request — DISPATCH latency is what the server's queue sees, and is
    reported separately from BLOCKED latency, the device-drained time a
    per-request sync would pay), serves a burst of ``--burst`` deletes
    both serially and COALESCED into one group replay, then drives a
    seeded multi-tenant trace (``--trace poisson|diurnal|fixed``, mixed
    SLA classes) through `repro.serve.ServingScheduler` — admission,
    EDF flush, cross-tenant batching, and the lone-tail deadline tick.
    Summary percentiles include p99; a machine-readable
    ``BENCH_serve.json`` is written to ``--bench-out`` (the full
    continuous-batching load sweep lives in ``benchmarks/bench_serve.py``,
    which runs this driver in-process).

        PYTHONPATH=src python -m repro.launch.serve unlearn \
            --n 4000 --d 500 --steps 80 --requests 12 --add-frac 0.25 \
            --trace poisson --rate 200

    ``--model <name>`` swaps the default logreg problem for a reduced
    registry LM (`UnlearnerSession.from_config`): the dataset becomes a
    synthetic token stream (``--n`` docs of ``--seq-len`` tokens) and the
    reported score is an exp(-loss) proxy instead of accuracy — the rest
    of the surface (latency loop, coalesced burst, scheduler trace) is
    model-agnostic:

        PYTHONPATH=src python -m repro.launch.serve unlearn \
            --model internlm2-1.8b --n 256 --steps 40 --batch 64 \
            --lr 0.02 --requests 8 --rate 20

  * batched decode (default, backwards-compatible flags): prefill a prompt
    batch, then step the KV caches.

        PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
            --reduced --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models.registry import build
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


def unlearn_main(argv) -> None:
    """Stand up the online unlearning service and drive a request stream."""
    import json

    from repro.core.deltagrad import DeltaGradConfig
    from repro.core.privacy import PrivacyConfig
    from repro.core.session import UnlearnerConfig, UnlearnerSession
    from repro.data.synthetic import binary_classification
    from repro.models.simple import (logreg_accuracy, logreg_init,
                                     logreg_objective)
    from repro.utils.tree import tree_norm, tree_sub

    ap = argparse.ArgumentParser(prog="serve unlearn")
    ap.add_argument("--model", default="",
                    help="configs.registry name — serve a reduced LM "
                         "instead of the default logreg problem "
                         "(UnlearnerSession.from_config); --n becomes the "
                         "document count")
    ap.add_argument("--seq-len", type=int, default=32,
                    help="tokens per synthetic document (with --model)")
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--d", type=int, default=500)
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--batch", type=int, default=1024)
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--l2", type=float, default=5e-3)
    ap.add_argument("--momentum", type=float, default=0.0)
    ap.add_argument("--period", type=int, default=5)
    ap.add_argument("--burn-in", type=int, default=10)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--add-frac", type=float, default=0.25,
                    help="fraction of requests that are additions")
    ap.add_argument("--impl", default="scan", choices=("scan", "python"))
    ap.add_argument("--algorithm", default="deltagrad",
                    help="registered unlearning algorithm serving the "
                         "stream (core.algorithms registry)")
    ap.add_argument("--eps", type=float, default=1.0,
                    help="certified-deletion epsilon for the published "
                         "model / certificate report")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--burst", type=int, default=8,
                    help="K for the coalesced-vs-serial delete burst")
    ap.add_argument("--trace", default="poisson",
                    choices=("poisson", "diurnal", "fixed"),
                    help="arrival process for the continuous-serving "
                         "section (seeded; 'fixed' is the deterministic "
                         "equal-spacing mode driven by --arrival-ms)")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="offered load in requests/s for poisson/diurnal "
                         "traces (0 derives it from --arrival-ms)")
    ap.add_argument("--arrival-ms", type=float, default=2.0,
                    help="inter-arrival gap for --trace fixed (and the "
                         "rate fallback for the seeded traces)")
    ap.add_argument("--sla-class", default="mixed",
                    choices=("mixed", "interactive", "batch", "bulk_gdpr"),
                    help="SLA class for generated requests ('mixed' draws "
                         "from all three)")
    ap.add_argument("--bench-out", default="BENCH_serve.json",
                    help="machine-readable results path ('' disables)")
    ap.add_argument("--trace-out", default="",
                    help="enable the span tracer and write a Chrome/"
                         "Perfetto trace-event JSON here ('' disables); "
                         "the metrics registry lands beside it as "
                         "<path>.metrics.jsonl")
    ap.add_argument("--profile-dir", default="",
                    help="capture a jax.profiler device trace into this "
                         "directory ('' disables) — opt-in, for XLA-level "
                         "drill-down under the obs spans")
    args = ap.parse_args(argv)

    if args.trace_out:
        obs_trace.enable()
    if args.profile_dir:
        jax.profiler.start_trace(args.profile_dir)

    # the logreg-scale lr/batch defaults destroy a transformer (the
    # L-BFGS correction blows past the guard clip at lr=0.3): when
    # --model is set and the user left them at the logreg defaults,
    # swap in the LM recipe examples/unlearn_lm.py is calibrated at
    if args.model:
        if args.lr == ap.get_default("lr"):
            args.lr = 0.02
        if args.batch == ap.get_default("batch"):
            args.batch = 64

    cfg = UnlearnerConfig(
        steps=args.steps, batch_size=args.batch, lr=args.lr, seed=args.seed,
        momentum=args.momentum, algorithm=args.algorithm,
        privacy=PrivacyConfig(eps=args.eps, mu=0.5, L=1.0, c0=0.1, c2=0.1),
        # non-convex models need the Algorithm-4 curvature guard (the
        # paper's DNN recipe); the convex logreg path keeps it off
        deltagrad=DeltaGradConfig(period=args.period, burn_in=args.burn_in,
                                  impl=args.impl, guard=bool(args.model),
                                  curvature_eps=1e-8 if args.model else 0.0))

    # CI-sized LM reduction (matches examples/unlearn_lm.py); the serve
    # surface downstream is model-agnostic
    lm_reduced = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab=128, d_head=16)
    obj = None if args.model else logreg_objective(l2=args.l2)

    def build_session(config=cfg):
        if args.model:
            from repro.data.synthetic import token_stream
            ds = token_stream(n_docs=args.n, seq_len=args.seq_len,
                              vocab=lm_reduced["vocab"], seed=args.seed)
            sess = UnlearnerSession.from_config(
                args.model, ds, reduced=lm_reduced, config=config,
                loss_chunk=args.seq_len)
        else:
            ds = binary_classification(n=args.n, d=args.d, seed=args.seed)
            sess = UnlearnerSession(obj, logreg_init(args.d, seed=1), ds,
                                    config)
        sess.fit()
        return sess, ds

    def score(sess, params, ds) -> float:
        """Accuracy for logreg; an exp(-token-CE) proxy for an LM."""
        if not args.model:
            return float(logreg_accuracy(params, ds))
        toks = jnp.asarray(np.asarray(ds.columns["tokens"][:64]))
        loss = sess.model.loss_fn(params, {"tokens": toks}, remat=False,
                                  loss_chunk=args.seq_len)
        return float(jnp.exp(-loss))

    t0 = time.perf_counter()
    sess, ds = build_session()
    jax.block_until_ready(sess.params)
    print(f"trained {args.steps} steps "
          f"(n={ds.n}, {'model=' + args.model if args.model else 'd=%d' % args.d}) "
          f"with path cache in {time.perf_counter() - t0:.2f}s; "
          f"score {score(sess, sess.params, ds):.4f}")

    # additions are served from a pre-appended row pool; with the engine's
    # pow2-bucketed row capacity a stream MAY outgrow the pool at O(log)
    # retrace cost, but staging the expected count keeps steady-state
    # latency clean of re-uploads entirely
    rng = np.random.default_rng(args.seed + 1)
    pool_src = rng.integers(0, args.n, size=args.requests)
    add_pool = list(ds.append({k: v[pool_src] for k, v in ds.columns.items()}))
    algo = sess.algorithm
    algo.begin_plan(args.requests)

    warm = [("delete", 1)] + ([("add", 1)] if args.add_frac > 0 else [])
    compile_s = sess.warmup(warm)
    print(f"session up (algorithm={algo.name}); first-request compile "
          f"{compile_s * 1e3:.0f} ms")

    # -- latency loop: dispatch (what the request queue sees) vs blocked
    # (dispatch + device drain) measured separately — timing a forced
    # jax.block_until_ready inside the per-request loop conflates the two.
    # Percentiles come from the shared obs.metrics histogram (the same
    # implementation ServeMonitor quantiles use).
    reg = obs_metrics.get_registry()
    reg.gauge("online.compile_time_s", unit="s",
              owner="core.online").set(compile_s)
    h_disp = reg.histogram("launch.dispatch_ms", unit="ms",
                           owner="launch.serve")
    h_block = reg.histogram("launch.blocked_ms", unit="ms",
                            owner="launch.serve")
    for i in range(args.requests):
        if add_pool and rng.random() < args.add_frac:
            op, row = "add", int(add_pool.pop(0))
        else:
            live = np.flatnonzero(algo.live[:args.n])
            op, row = "delete", int(rng.choice(live))
        t0 = time.perf_counter()
        h = sess.submit(op=op, rows=[row], coalesce=False)
        sess.flush()
        t_disp = time.perf_counter() - t0
        jax.block_until_ready(algo.params)
        t_block = time.perf_counter() - t0
        h_disp.observe(t_disp * 1e3)
        h_block.observe(t_block * 1e3)
        st = h.stats[0]
        print(f"  request {i:3d} {op:6s} row {row:5d}: dispatch "
              f"{t_disp * 1e3:7.1f} ms, blocked {t_block * 1e3:7.1f} ms  "
              f"(approx {st.approx_steps}, explicit {st.explicit_steps}, "
              f"grad-eval speedup x{st.theoretical_speedup:.1f})")
    dp, bp = h_disp.summary(), h_block.summary()
    print(f"served {args.requests} requests: dispatch p50 {dp['p50']:.1f} / "
          f"p95 {dp['p95']:.1f} / p99 {dp['p99']:.1f} ms, blocked p50 "
          f"{bp['p50']:.1f} / p95 {bp['p95']:.1f} / p99 {bp['p99']:.1f} ms; "
          f"score {score(sess, sess.params, ds):.4f}")

    # -- certified release: the certificate the stream's cumulative
    # deletions buy at --eps (publishes through the session PRNG key)
    published, cert = sess.publish(eps=args.eps)
    print(f"certificate: algorithm={cert.algorithm} "
          f"mechanism={cert.mechanism} eps={cert.eps:g} "
          f"delta={cert.delta:g} bound={cert.bound:.3e} "
          f"noise_scale={cert.noise_scale:.3e} removals={cert.removals}")

    # -- coalesced burst: K deletes as ONE group replay vs the serial path
    K = args.burst
    results = {
        "config": {"n": args.n, "d": args.d, "steps": args.steps,
                   "batch": args.batch, "requests": args.requests,
                   "add_frac": args.add_frac, "impl": args.impl,
                   "momentum": args.momentum, "burst": K,
                   "algorithm": args.algorithm, "eps": args.eps,
                   "trace": args.trace, "sla_class": args.sla_class,
                   "arrival_ms": args.arrival_ms},
        "compile_s": compile_s,
        "latency_ms": {"dispatch": dp, "blocked": bp},
        "accuracy": score(sess, sess.params, ds),
        "certificate": cert.as_dict(),
        "published_accuracy": score(sess, published, ds),
    }
    if args.model:
        # only stamped for LM runs — the logreg config must keep matching
        # the committed serve baseline (check_bench compares config dicts)
        results["config"]["model"] = args.model
        results["config"]["seq_len"] = args.seq_len
    if K > 0 and args.algorithm == "deltagrad":
        burst_rows = np.random.default_rng(args.seed + 2).choice(
            args.n, size=K, replace=False).tolist()

        sess_a, _ = build_session()          # serial Algorithm-3 stream
        sess_a.warmup([("delete", 1)])
        t0 = time.perf_counter()
        sess_a.stream_delete(burst_rows)
        t_serial = time.perf_counter() - t0

        sess_b, ds_b = build_session()       # ONE coalesced group replay
        sess_b.warmup([("delete", K)])
        t0 = time.perf_counter()
        hb = sess_b.delete(burst_rows)
        jax.block_until_ready(hb.params)
        t_coal = time.perf_counter() - t0

        # parity of the coalesced replay vs the python oracle
        import dataclasses
        cfg_py = dataclasses.replace(
            cfg, deltagrad=dataclasses.replace(cfg.deltagrad, impl="python"))
        sess_c, _ = build_session(cfg_py)
        sess_c.delete(burst_rows).result()
        parity = float(tree_norm(tree_sub(sess_b.params, sess_c.params)))
        drift = float(tree_norm(tree_sub(sess_b.params, sess_a.params)))
        results["coalesce"] = {
            "k": K,
            "serial_ms_per_req": t_serial / K * 1e3,
            "coalesced_ms_per_req": t_coal / K * 1e3,
            "per_request_speedup": t_serial / max(t_coal, 1e-9),
            "parity_vs_python": parity,
            "serial_vs_coalesced_dist": drift,
        }
        print(f"burst K={K}: serial {t_serial / K * 1e3:.1f} ms/req, "
              f"coalesced {t_coal / K * 1e3:.1f} ms/req "
              f"(x{t_serial / max(t_coal, 1e-9):.1f}); parity vs python "
              f"{parity:.2e}; serial-vs-coalesced dist {drift:.2e}")

    # -- continuous serving: a seeded open-loop trace through the serving
    # tier (repro.serve) — admission control, SLA-class deadlines, EDF
    # flush, cross-tenant batching, one replay in flight.  This replaces
    # the old session-global auto-flush load loop (and its hand-rolled
    # drain logic); the session-level max_pending/max_delay_s policy still
    # exists for embedded use, but the serving CLI routes everything
    # through the scheduler.  The lone tail request at the end proves the
    # deadline holds with ZERO further arrivals — the executor's idle tick
    # serves it, no timer thread and no extra poll() calls.
    if args.requests > 0:
        from repro.serve import (LoadGenerator, ServeConfig,
                                 ServingScheduler, diurnal_trace,
                                 fixed_trace, materialize, poisson_trace)

        sess_f, ds_f = build_session()
        rate = args.rate or (1e3 / args.arrival_ms if args.arrival_ms
                             else 200.0)
        class_mix = ({"interactive": 0.5, "batch": 0.3, "bulk_gdpr": 0.2}
                     if args.sla_class == "mixed" else (args.sla_class,))
        tenants = {"tenant-a": 0.6, "tenant-b": 0.4}
        if args.trace == "poisson":
            events = poisson_trace(rate, args.requests, args.seed + 3,
                                   tenants=tenants, classes=class_mix,
                                   add_frac=args.add_frac)
        elif args.trace == "diurnal":
            events = diurnal_trace(
                max(rate / 2, 1e-3), rate * 2,
                period_s=max(0.25, args.requests / rate),
                n_events=args.requests, seed=args.seed + 3,
                tenants=tenants, classes=class_mix,
                add_frac=args.add_frac)
        else:
            events = fixed_trace((args.arrival_ms or 2.0) / 1e3,
                                 args.requests, args.seed + 3,
                                 tenants=tenants, classes=class_mix,
                                 add_frac=args.add_frac)
        materialize(events, ds_f, seed=args.seed + 4)
        n_add_rows = sum(ev.n_rows for ev in events if ev.op == "add")
        # one serving stack per CLI run — publish its monitor into the
        # process-wide registry so --trace-out exports queue + serve
        # metrics alongside the engine/store ones
        from repro.serve.monitor import ServeMonitor
        sched = ServingScheduler(
            sess_f, ServeConfig(add_capacity=max(1, n_add_rows)),
            monitor=ServeMonitor(registry=reg))
        warm = [("delete", k) for k in (1, 2, 4, 8)]
        if n_add_rows:
            warm += [("add", k) for k in (1, 2, 4)]
        sess_f.warmup(warm)
        sched.start()
        res = LoadGenerator(sched).open_loop(events)
        for tk in res.tickets:
            tk.wait(timeout=60.0)
        # lone tail, then silence: only the executor's deadline tick fires
        used = {r for ev in events if ev.rows for r in ev.rows}
        live = np.flatnonzero(sess_f.algorithm.live[:args.n])
        lone_row = next(int(r) for r in live if int(r) not in used)
        lone = sched.submit("delete", rows=[lone_row],
                            sla_class=("interactive"
                                       if args.sla_class == "mixed"
                                       else args.sla_class))
        lone_ok = lone.wait(timeout=10.0)
        sched.stop()
        st = sched.stats()
        results["serving"] = {
            "trace": args.trace,
            "rate_rps": rate,
            "arrival_ms": args.arrival_ms,
            "sla_class": args.sla_class,
            "rejected": res.rejected,
            "lone_request_served": bool(lone_ok),
            "lone_missed_deadline": bool(lone.missed_deadline),
            **st,
        }
        bt = st["batches"]
        miss = st["deadline_misses_total"]
        print(f"serving: {st['admission']['admitted']} admitted "
              f"({res.rejected} rejected), {bt['count']} batches "
              f"(mean {bt['size_mean']:.1f} rows, {bt['cross_tenant']} "
              f"cross-tenant), {miss} deadline misses, "
              f"{st['add_capacity_retraces']} capacity retraces; lone "
              f"tail served by deadline tick: {lone_ok}")

    if args.bench_out:
        with open(args.bench_out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.bench_out}")

    if args.profile_dir:
        jax.profiler.stop_trace()
        print(f"wrote jax profiler trace under {args.profile_dir}")
    if args.trace_out:
        tracer = obs_trace.disable()
        tracer.export_chrome(args.trace_out)
        reg.to_jsonl(args.trace_out + ".metrics.jsonl")
        n_scan = sum(1 for e in tracer.events()
                     if e["name"] == "replay.scan")
        print(f"wrote {args.trace_out} ({len(tracer.events())} spans, "
              f"{n_scan} replay.scan) + {args.trace_out}.metrics.jsonl")


def decode_main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    params = model.init(args.seed)
    max_len = args.prompt_len + args.gen
    if cfg.family == "audio":
        caches = model.cache_init(args.batch, max_len, enc_len=64)
    else:
        caches = model.cache_init(args.batch, max_len)

    decode = jax.jit(lambda p, b, c: model.decode_fn(p, b, c),
                     donate_argnums=(2,))

    rng = np.random.default_rng(args.seed)
    prompt = rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len),
                          dtype=np.int32)

    # prefill by stepping (simple driver; the prefill graph is exercised by
    # the dry-run / tests)
    t0 = time.perf_counter()
    logits = None
    for t in range(args.prompt_len):
        logits, caches = decode(params, {"tokens": jnp.asarray(prompt[:, t:t + 1])},
                                caches)
    t_prefill = time.perf_counter() - t0

    key = jax.random.PRNGKey(args.seed)
    out_tokens = []
    t0 = time.perf_counter()
    for t in range(args.gen):
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits / args.temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        nxt = nxt.astype(jnp.int32)[:, None]
        out_tokens.append(np.asarray(nxt))
        logits, caches = decode(params, {"tokens": nxt}, caches)
    t_gen = time.perf_counter() - t0

    gen = np.concatenate(out_tokens, axis=1)
    tok_s = args.batch * args.gen / max(t_gen, 1e-9)
    print(f"prefill {args.prompt_len} tok x {args.batch} in {t_prefill:.2f}s; "
          f"generated {args.gen} tok x {args.batch} in {t_gen:.2f}s "
          f"({tok_s:.1f} tok/s)")
    print("sample row 0:", gen[0].tolist())


def main() -> None:
    if len(sys.argv) > 1 and sys.argv[1] == "unlearn":
        unlearn_main(sys.argv[2:])
    else:
        decode_main()


if __name__ == "__main__":
    main()
