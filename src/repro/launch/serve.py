"""Serving drivers.

Two entry points share this module:

  * ``unlearn`` — the DeltaGrad request server (ROADMAP serve-path item),
    built on ``core.session.UnlearnerSession``: trains with path caching,
    answers a stream of online delete/add requests (one lazy `submit()`
    per request — DISPATCH latency is what the server's queue sees, and is
    reported separately from BLOCKED latency, the device-drained time a
    per-request sync would pay), then serves a burst of ``--burst``
    deletes both serially and COALESCED into one group replay.  Summary
    percentiles include p99; a machine-readable ``BENCH_serve.json`` is
    written to ``--bench-out``.

        PYTHONPATH=src python -m repro.launch.serve unlearn \
            --n 4000 --d 500 --steps 80 --requests 12 --add-frac 0.25

  * batched decode (default, backwards-compatible flags): prefill a prompt
    batch, then step the KV caches.

        PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
            --reduced --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models.registry import build


def _pcts(ms) -> dict:
    ms = np.asarray(ms, dtype=np.float64)
    return {"mean": float(ms.mean()),
            "p50": float(np.percentile(ms, 50)),
            "p95": float(np.percentile(ms, 95)),
            "p99": float(np.percentile(ms, 99))}


def unlearn_main(argv) -> None:
    """Stand up the online unlearning service and drive a request stream."""
    import json

    from repro.core.deltagrad import DeltaGradConfig
    from repro.core.privacy import PrivacyConfig
    from repro.core.session import UnlearnerConfig, UnlearnerSession
    from repro.data.synthetic import binary_classification
    from repro.models.simple import (logreg_accuracy, logreg_init,
                                     logreg_objective)
    from repro.utils.tree import tree_norm, tree_sub

    ap = argparse.ArgumentParser(prog="serve unlearn")
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--d", type=int, default=500)
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--batch", type=int, default=1024)
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--l2", type=float, default=5e-3)
    ap.add_argument("--momentum", type=float, default=0.0)
    ap.add_argument("--period", type=int, default=5)
    ap.add_argument("--burn-in", type=int, default=10)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--add-frac", type=float, default=0.25,
                    help="fraction of requests that are additions")
    ap.add_argument("--impl", default="scan", choices=("scan", "python"))
    ap.add_argument("--algorithm", default="deltagrad",
                    help="registered unlearning algorithm serving the "
                         "stream (core.algorithms registry)")
    ap.add_argument("--eps", type=float, default=1.0,
                    help="certified-deletion epsilon for the published "
                         "model / certificate report")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--burst", type=int, default=8,
                    help="K for the coalesced-vs-serial delete burst")
    ap.add_argument("--max-pending", type=int, default=4,
                    help="auto-flush: serve whenever this many requests are "
                         "queued (0 disables the auto-flush section)")
    ap.add_argument("--max-delay-ms", type=float, default=25.0,
                    help="auto-flush: serve when the oldest pending request "
                         "has waited this long (0 disables)")
    ap.add_argument("--arrival-ms", type=float, default=2.0,
                    help="inter-arrival gap for the auto-flush load loop")
    ap.add_argument("--bench-out", default="BENCH_serve.json",
                    help="machine-readable results path ('' disables)")
    args = ap.parse_args(argv)

    obj = logreg_objective(l2=args.l2)
    cfg = UnlearnerConfig(
        steps=args.steps, batch_size=args.batch, lr=args.lr, seed=args.seed,
        momentum=args.momentum, algorithm=args.algorithm,
        privacy=PrivacyConfig(eps=args.eps, mu=0.5, L=1.0, c0=0.1, c2=0.1),
        deltagrad=DeltaGradConfig(period=args.period, burn_in=args.burn_in,
                                  impl=args.impl))

    def build_session():
        ds = binary_classification(n=args.n, d=args.d, seed=args.seed)
        sess = UnlearnerSession(obj, logreg_init(args.d, seed=1), ds, cfg)
        sess.fit()
        return sess, ds

    t0 = time.perf_counter()
    sess, ds = build_session()
    jax.block_until_ready(sess.params)
    print(f"trained {args.steps} steps (n={ds.n}, d={args.d}) with path "
          f"cache in {time.perf_counter() - t0:.2f}s; "
          f"accuracy {logreg_accuracy(sess.params, ds):.4f}")

    # additions are served from a pre-appended row pool; with the engine's
    # pow2-bucketed row capacity a stream MAY outgrow the pool at O(log)
    # retrace cost, but staging the expected count keeps steady-state
    # latency clean of re-uploads entirely
    rng = np.random.default_rng(args.seed + 1)
    pool_src = rng.integers(0, args.n, size=args.requests)
    add_pool = list(ds.append({k: v[pool_src] for k, v in ds.columns.items()}))
    algo = sess.algorithm
    algo.begin_plan(args.requests)

    warm = [("delete", 1)] + ([("add", 1)] if args.add_frac > 0 else [])
    compile_s = sess.warmup(warm)
    print(f"session up (algorithm={algo.name}); first-request compile "
          f"{compile_s * 1e3:.0f} ms")

    # -- latency loop: dispatch (what the request queue sees) vs blocked
    # (dispatch + device drain) measured separately — timing a forced
    # jax.block_until_ready inside the per-request loop conflates the two
    dispatch_ms, blocked_ms = [], []
    for i in range(args.requests):
        if add_pool and rng.random() < args.add_frac:
            op, row = "add", int(add_pool.pop(0))
        else:
            live = np.flatnonzero(algo.live[:args.n])
            op, row = "delete", int(rng.choice(live))
        t0 = time.perf_counter()
        h = sess.submit(op=op, rows=[row], coalesce=False)
        sess.flush()
        t_disp = time.perf_counter() - t0
        jax.block_until_ready(algo.params)
        t_block = time.perf_counter() - t0
        dispatch_ms.append(t_disp * 1e3)
        blocked_ms.append(t_block * 1e3)
        st = h.stats[0]
        print(f"  request {i:3d} {op:6s} row {row:5d}: dispatch "
              f"{t_disp * 1e3:7.1f} ms, blocked {t_block * 1e3:7.1f} ms  "
              f"(approx {st.approx_steps}, explicit {st.explicit_steps}, "
              f"grad-eval speedup x{st.theoretical_speedup:.1f})")
    dp, bp = _pcts(dispatch_ms), _pcts(blocked_ms)
    print(f"served {args.requests} requests: dispatch p50 {dp['p50']:.1f} / "
          f"p95 {dp['p95']:.1f} / p99 {dp['p99']:.1f} ms, blocked p50 "
          f"{bp['p50']:.1f} / p95 {bp['p95']:.1f} / p99 {bp['p99']:.1f} ms; "
          f"accuracy {logreg_accuracy(sess.params, ds):.4f}")

    # -- certified release: the certificate the stream's cumulative
    # deletions buy at --eps (publishes through the session PRNG key)
    published, cert = sess.publish(eps=args.eps)
    print(f"certificate: algorithm={cert.algorithm} "
          f"mechanism={cert.mechanism} eps={cert.eps:g} "
          f"delta={cert.delta:g} bound={cert.bound:.3e} "
          f"noise_scale={cert.noise_scale:.3e} removals={cert.removals}")

    # -- coalesced burst: K deletes as ONE group replay vs the serial path
    K = args.burst
    results = {
        "config": {"n": args.n, "d": args.d, "steps": args.steps,
                   "batch": args.batch, "requests": args.requests,
                   "add_frac": args.add_frac, "impl": args.impl,
                   "momentum": args.momentum, "burst": K,
                   "algorithm": args.algorithm, "eps": args.eps},
        "compile_s": compile_s,
        "latency_ms": {"dispatch": dp, "blocked": bp},
        "accuracy": float(logreg_accuracy(sess.params, ds)),
        "certificate": cert.as_dict(),
        "published_accuracy": float(logreg_accuracy(published, ds)),
    }
    if K > 0 and args.algorithm == "deltagrad":
        burst_rows = np.random.default_rng(args.seed + 2).choice(
            args.n, size=K, replace=False).tolist()

        sess_a, _ = build_session()          # serial Algorithm-3 stream
        sess_a.warmup([("delete", 1)])
        t0 = time.perf_counter()
        sess_a.stream_delete(burst_rows)
        t_serial = time.perf_counter() - t0

        sess_b, ds_b = build_session()       # ONE coalesced group replay
        sess_b.warmup([("delete", K)])
        t0 = time.perf_counter()
        hb = sess_b.delete(burst_rows)
        jax.block_until_ready(hb.params)
        t_coal = time.perf_counter() - t0

        # parity of the coalesced replay vs the python oracle
        import dataclasses
        cfg_py = dataclasses.replace(
            cfg, deltagrad=dataclasses.replace(cfg.deltagrad, impl="python"))
        ds_c = binary_classification(n=args.n, d=args.d, seed=args.seed)
        sess_c = UnlearnerSession(obj, logreg_init(args.d, seed=1), ds_c,
                                  cfg_py)
        sess_c.fit()
        sess_c.delete(burst_rows).result()
        parity = float(tree_norm(tree_sub(sess_b.params, sess_c.params)))
        drift = float(tree_norm(tree_sub(sess_b.params, sess_a.params)))
        results["coalesce"] = {
            "k": K,
            "serial_ms_per_req": t_serial / K * 1e3,
            "coalesced_ms_per_req": t_coal / K * 1e3,
            "per_request_speedup": t_serial / max(t_coal, 1e-9),
            "parity_vs_python": parity,
            "serial_vs_coalesced_dist": drift,
        }
        print(f"burst K={K}: serial {t_serial / K * 1e3:.1f} ms/req, "
              f"coalesced {t_coal / K * 1e3:.1f} ms/req "
              f"(x{t_serial / max(t_coal, 1e-9):.1f}); parity vs python "
              f"{parity:.2e}; serial-vs-coalesced dist {drift:.2e}")

    # -- auto-flush under continuous load: submit WITHOUT forcing handles and
    # let the max_pending/max_delay_s policy decide when to serve — the
    # planner coalesces each flushed batch, and staleness (how long the
    # oldest submit waited) stays bounded by the policy.  The deadline is
    # driven by the session's daemon TIMER thread (`start_autoflush_timer`),
    # so max_delay_s holds even when the load loop stops arriving — the
    # final lone request below proves it with zero further submits/polls.
    if args.max_pending or args.max_delay_ms:
        sess_f, ds_f = build_session()
        sess_f.config.max_pending = args.max_pending or None
        sess_f.config.max_delay_s = (args.max_delay_ms / 1e3
                                     if args.max_delay_ms else None)
        warm_k = [("delete", 1)]
        if args.max_pending:
            warm_k.append(("delete", args.max_pending))
        sess_f.warmup(warm_k)
        algo_f = sess_f.algorithm
        timer = (sess_f.start_autoflush_timer()
                 if sess_f.config.max_delay_s else None)
        rng_f = np.random.default_rng(args.seed + 3)
        staleness_ms = []
        submitted: set = set()  # engine liveness lags until a flush lands
        t0 = time.perf_counter()
        for i in range(args.requests):
            live = np.flatnonzero(algo_f.live[:args.n])
            live = live[~np.isin(live, list(submitted))]
            staleness_ms.append(sess_f.pending_age_s * 1e3)
            row = int(rng_f.choice(live))
            submitted.add(row)
            sess_f.submit(op="delete", rows=[row])
            if args.arrival_ms:
                time.sleep(args.arrival_ms / 1e3)
            staleness_ms.append(sess_f.pending_age_s * 1e3)
        # LONE TAIL request, then silence: only the timer can flush it
        lone_deadline_ok = None
        if timer is not None:
            live = np.flatnonzero(algo_f.live[:args.n])
            live = live[~np.isin(live, list(submitted))]
            h_lone = sess_f.submit(op="delete", rows=[int(rng_f.choice(live))])
            t_lone = time.perf_counter()
            while not h_lone.done and \
                    time.perf_counter() - t_lone < 10.0:
                time.sleep(sess_f.config.max_delay_s / 10)
            lone_wait_ms = (time.perf_counter() - t_lone) * 1e3
            lone_deadline_ok = bool(h_lone.done)
            staleness_ms.append(lone_wait_ms)
        sess_f.flush()  # drain anything below the policy thresholds
        jax.block_until_ready(sess_f.params)
        t_total = time.perf_counter() - t0
        if timer is not None:
            timer.stop()
        group_rows = [len(e["rows"]) for e in sess_f.log]
        results["autoflush"] = {
            "max_pending": args.max_pending,
            "max_delay_ms": args.max_delay_ms,
            "arrival_ms": args.arrival_ms,
            "autoflushes": sess_f.autoflush_count,
            "reasons": dict(sess_f.autoflush_reasons),
            "max_staleness_ms": float(max(staleness_ms)),
            "mean_group_rows": float(np.mean(group_rows)),
            "wall_ms_per_req": t_total / max(1, args.requests) * 1e3,
            "timer_interval_ms": (timer.interval_s * 1e3
                                  if timer is not None else None),
            "lone_request_flushed_by_timer": lone_deadline_ok,
        }
        print(f"auto-flush: {sess_f.autoflush_count} policy flushes "
              f"({sess_f.autoflush_reasons}), max staleness "
              f"{max(staleness_ms):.1f} ms (bound "
              f"{args.max_delay_ms:.0f} ms), mean coalesced group "
              f"{np.mean(group_rows):.1f} rows, "
              f"{t_total / max(1, args.requests) * 1e3:.1f} ms/req"
              + (f"; lone tail request flushed by timer: "
                 f"{lone_deadline_ok}" if timer is not None else ""))

    if args.bench_out:
        with open(args.bench_out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.bench_out}")


def decode_main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    params = model.init(args.seed)
    max_len = args.prompt_len + args.gen
    if cfg.family == "audio":
        caches = model.cache_init(args.batch, max_len, enc_len=64)
    else:
        caches = model.cache_init(args.batch, max_len)

    decode = jax.jit(lambda p, b, c: model.decode_fn(p, b, c),
                     donate_argnums=(2,))

    rng = np.random.default_rng(args.seed)
    prompt = rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len),
                          dtype=np.int32)

    # prefill by stepping (simple driver; the prefill graph is exercised by
    # the dry-run / tests)
    t0 = time.perf_counter()
    logits = None
    for t in range(args.prompt_len):
        logits, caches = decode(params, {"tokens": jnp.asarray(prompt[:, t:t + 1])},
                                caches)
    t_prefill = time.perf_counter() - t0

    key = jax.random.PRNGKey(args.seed)
    out_tokens = []
    t0 = time.perf_counter()
    for t in range(args.gen):
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits / args.temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        nxt = nxt.astype(jnp.int32)[:, None]
        out_tokens.append(np.asarray(nxt))
        logits, caches = decode(params, {"tokens": nxt}, caches)
    t_gen = time.perf_counter() - t0

    gen = np.concatenate(out_tokens, axis=1)
    tok_s = args.batch * args.gen / max(t_gen, 1e-9)
    print(f"prefill {args.prompt_len} tok x {args.batch} in {t_prefill:.2f}s; "
          f"generated {args.gen} tok x {args.batch} in {t_gen:.2f}s "
          f"({tok_s:.1f} tok/s)")
    print("sample row 0:", gen[0].tolist())


def main() -> None:
    if len(sys.argv) > 1 and sys.argv[1] == "unlearn":
        unlearn_main(sys.argv[2:])
    else:
        decode_main()


if __name__ == "__main__":
    main()
