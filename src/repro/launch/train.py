"""End-to-end training driver.

Trains an LM-family arch (reduced or full config) with checkpoint/restart,
deterministic data order, and straggler instrumentation; or runs the paper's
own train -> delete -> DeltaGrad-retrain flow for the `simple` family.

Examples:
    python -m repro.launch.train --arch internlm2-1.8b --reduced \
        --steps 200 --batch 8 --seq 128 --ckpt /tmp/ckpt
    python -m repro.launch.train --arch paper-logreg --steps 150 \
        --delete-frac 0.01
Resume: re-run the same command; the driver picks up the last complete step.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.data.sampler import batch_indices
from repro.data.synthetic import binary_classification, token_stream
from repro.models.registry import build
from repro.optim.optimizers import adamw
from repro.optim.schedules import warmup_cosine
from repro.train import checkpoint as ckpt
from repro.train.loop import make_train_step
from repro.train.state import init_state
from repro.train.straggler import StepTimer


def train_lm(args) -> None:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    params = model.init(args.seed)
    opt = adamw(weight_decay=0.01)
    lr = warmup_cosine(args.lr, warmup=max(args.steps // 20, 1),
                       total_steps=args.steps)
    loss_fn = lambda p, b: model.loss_fn(  # noqa: E731
        p, b, remat=False, loss_chunk=min(128, args.seq))
    step_fn = jax.jit(make_train_step(loss_fn, opt, lr))
    state = init_state(params, opt)

    corpus = token_stream(n_docs=max(args.batch * 8, 64), seq_len=args.seq,
                          vocab=cfg.vocab, seed=args.seed)

    start = 0
    if args.ckpt:
        last = ckpt.latest_step(args.ckpt)
        if last is not None:
            state = ckpt.restore(args.ckpt, last, state)
            start = last
            print(f"resumed from step {last}")

    timer = StepTimer()
    for step in range(start, args.steps):
        idx = batch_indices(args.seed, step, corpus.n, args.batch)
        batch = {"tokens": jnp.asarray(corpus.take(idx)["tokens"])}
        if cfg.family == "audio":
            batch["frames"] = jax.random.normal(
                jax.random.PRNGKey(step), (args.batch, args.seq, cfg.d_model),
                jnp.bfloat16)
        timer.start()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = timer.stop()
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:7.1f} ms "
                  f"p50 {timer.percentile(0.5)*1e3:6.1f} ms")
        if args.ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt, step + 1, state)
    if args.ckpt:
        ckpt.save(args.ckpt, args.steps, state)
    print("done.")


def train_paper(args) -> None:
    from repro.core.api import Unlearner, UnlearnerConfig
    from repro.core.deltagrad import DeltaGradConfig
    from repro.models.simple import logreg_accuracy, logreg_init, logreg_objective
    from repro.utils.tree import tree_norm, tree_sub

    ds = binary_classification(n=args.n, d=args.dim, seed=args.seed)
    unl = Unlearner(
        logreg_objective(l2=5e-3),
        logreg_init(args.dim, seed=args.seed),
        ds,
        UnlearnerConfig(steps=args.steps, batch_size=args.batch, lr=args.lr,
                        seed=args.seed,
                        deltagrad=DeltaGradConfig(period=5, burn_in=10)),
    )
    t0 = time.perf_counter()
    unl.fit()
    print(f"trained {args.steps} steps in {time.perf_counter()-t0:.2f}s, "
          f"acc={logreg_accuracy(unl.params, ds):.4f}")
    r = max(1, int(args.delete_frac * ds.n))
    removed = np.random.default_rng(args.seed).choice(ds.n, r, replace=False)
    w_u, base_stats = unl.baseline(removed)
    stats = unl.delete(removed)
    dist = float(tree_norm(tree_sub(w_u, unl.params)))
    print(f"deleted {r} rows: DeltaGrad {stats.wall_time_s:.2f}s "
          f"(BaseL {base_stats.wall_time_s:.2f}s, "
          f"speedup x{base_stats.wall_time_s/max(stats.wall_time_s,1e-9):.2f}; "
          f"grad-eval speedup x{stats.theoretical_speedup:.2f}) "
          f"||w_U - w_I|| = {dist:.3e}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    # paper-model options
    ap.add_argument("--n", type=int, default=5000)
    ap.add_argument("--dim", type=int, default=50)
    ap.add_argument("--delete-frac", type=float, default=0.01)
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if cfg.family == "simple":
        if args.lr == 3e-4:
            args.lr = 0.1  # paper default
        train_paper(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
