import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct inputs and production NamedShardings, record
memory_analysis / cost_analysis / collective bytes for §Dry-run and
§Roofline of EXPERIMENTS.md.

Usage:
    python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
    python -m repro.launch.dryrun --all --out benchmarks/artifacts

Skips (documented in DESIGN.md §6): long_500k for pure full-attention archs.
"""

import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import all_archs, all_shapes, get_config, get_shape
from repro.dist.sharding import (
    caches_shardings,
    inputs_shardings,
    make_plan,
    params_shardings,
)
from repro.launch.mesh import make_production_mesh
from repro.models.registry import active_param_count, build, count_params
from repro.optim.optimizers import adamw
from repro.roofline.analysis import roofline_from_compiled
from repro.roofline.model import analytic_cost
from repro.train.loop import make_train_step
from repro.train.state import TrainState

# long_500k only runs for sub-quadratic (SSM/hybrid) families.
LONG_OK_FAMILIES = ("ssm", "hybrid")

# gradient-accumulation factor per train shape (activation-memory fit)
GRAD_ACCUM = {"train_4k": 8}


def cell_is_skipped(arch: str, shape: str) -> Optional[str]:
    cfg = get_config(arch)
    if cfg.family == "simple":
        return "paper model (exercised via repro.core, not the LM dry-run)"
    sh = get_shape(shape)
    if sh.kind == "long_decode" and cfg.family not in LONG_OK_FAMILIES:
        return "long_500k needs sub-quadratic attention (full-attention arch)"
    return None


def model_flops(cfg, shape) -> float:
    n = active_param_count(cfg) if cfg.moe else count_params(cfg)
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def lower_cell(arch: str, shape_name: str, multi_pod: bool, *,
               grad_accum: Optional[int] = None, variant: str = "baseline",
               plan_tweak=None):
    cfg = get_config(arch)
    if "moesort" in variant and cfg.moe is not None:
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch="sort"))
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    n_dev = int(np.prod(mesh.devices.shape))
    plan = make_plan(mesh, cfg)
    if "dpzero" in variant:
        plan.batch_over_model = True  # pure DP: model axis carries batch
    if plan_tweak is not None:
        plan = plan_tweak(plan)
    model = build(cfg)

    specs = model.input_specs(shape)
    in_batch_shardings = inputs_shardings(plan, specs)

    def _serve_params():
        """Serving stores weights compute-ready: bf16, model-only sharding.
        FSDP(data)-sharded fp32 weights would be re-gathered EVERY decoded
        token (measured: 2 weight all-gathers per layer per step on
        minicpm3 decode_32k — §Perf decode iteration 1); there is no
        optimizer state to justify it."""
        sp = jax.eval_shape(lambda: model.init(0))
        sp = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
            sp)
        serve_plan = make_plan(mesh, cfg, fsdp=False)
        return sp, params_shardings(serve_plan, sp)

    if shape.is_decode:
        if cfg.family == "audio":
            cache_specs = model.cache_specs(shape.global_batch, shape.seq_len,
                                            enc_len=1500)
        else:
            cache_specs = model.cache_specs(shape.global_batch, shape.seq_len)
        params_specs, p_shard = _serve_params()
        c_shard = caches_shardings(plan, cache_specs)

        def serve_step(params, batch, caches):
            return model.decode_fn(params, batch, caches)

        with mesh:
            lowered = jax.jit(
                serve_step,
                in_shardings=(p_shard, in_batch_shardings, c_shard),
                donate_argnums=(2,),
            ).lower(params_specs, specs, cache_specs)
            compiled = lowered.compile()
    elif shape.kind == "prefill":
        params_specs, p_shard = _serve_params()

        def prefill_step(params, batch):
            return model.prefill_fn(params, batch)

        with mesh:
            lowered = jax.jit(
                prefill_step,
                in_shardings=(p_shard, in_batch_shardings),
            ).lower(params_specs, specs)
            compiled = lowered.compile()
    else:
        accum = grad_accum if grad_accum is not None else GRAD_ACCUM.get(
            shape_name, 1)
        if "dpzero" in variant:
            accum = 1  # per-device batch is already global/256 sequences
        opt = adamw()
        loss_kwargs = {}
        if "seqpar" in variant:
            # sequence parallelism: residual stream sharded (dp, model, -)
            from jax.sharding import PartitionSpec as P
            sizes = plan.axis_sizes
            dp = tuple(a for a in ("pod", "data") if a in sizes)
            loss_kwargs["act_pspec"] = P(dp if len(dp) > 1 else dp[0],
                                         "model", None)
        loss = lambda p, b: model.loss_fn(p, b, **loss_kwargs)  # noqa: E731
        from repro.dist.sharding import batch_pspec

        def micro_shard(leaf):
            # microbatch leaves are (grad_accum, B/g, ...): batch is axis 1
            spec = batch_pspec(plan, leaf.shape, batch_axis=1)
            return plan.named(spec)

        if "dpzero" in variant:
            # pure DP: compute weights fully replicated (ZeRO gathers once)
            from repro.dist.sharding import replicated_shardings
            compute_shard = replicated_shardings(
                plan, jax.eval_shape(lambda: model.init(0)))
        else:
            compute_plan = make_plan(mesh, cfg, fsdp=False)
            if plan_tweak is not None:
                compute_plan = plan_tweak(compute_plan)
            compute_shard = params_shardings(
                compute_plan, jax.eval_shape(lambda: model.init(0)))
        compute_dtype = jnp.bfloat16 if "bf16zero" in variant else None
        params_specs = jax.eval_shape(lambda: model.init(0))
        step_fn = make_train_step(loss, opt, lambda s: jnp.float32(3e-4),
                                  grad_accum=accum,
                                  microbatch_sharding=micro_shard,
                                  compute_sharding=compute_shard,
                                  compute_dtype=compute_dtype,
                                  storage_sharding=params_shardings(
                                      plan, params_specs))
        opt_specs = jax.eval_shape(opt.init, params_specs)
        state_specs = TrainState(params_specs, opt_specs,
                                 jax.ShapeDtypeStruct((), jnp.int32))
        p_shard = params_shardings(plan, params_specs)
        o_shard = params_shardings(plan, opt_specs)
        s_shard = TrainState(p_shard, o_shard,
                             plan.named(jax.sharding.PartitionSpec()))
        with mesh:
            lowered = jax.jit(
                step_fn,
                in_shardings=(s_shard, in_batch_shardings),
                donate_argnums=(0,),
            ).lower(state_specs, specs)
            compiled = lowered.compile()

    ac = analytic_cost(cfg, shape,
                       grad_accum=(grad_accum or GRAD_ACCUM.get(shape_name, 1)),
                       n_params=count_params(cfg))
    report = roofline_from_compiled(
        compiled,
        arch=arch,
        shape=shape_name,
        mesh_name=mesh_name,
        n_devices=n_dev,
        model_flops=model_flops(cfg, shape),
        variant=variant,
        analytic_flops=ac.flops_global,
        analytic_bytes=ac.bytes_global,
    )
    return lowered, compiled, report


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Optional[str],
             verbose: bool = True, variant: str = "baseline"):
    skip = cell_is_skipped(arch, shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if skip:
        if verbose:
            print(f"SKIP  {arch} x {shape_name} x {mesh_name}: {skip}")
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": skip}
    t0 = time.time()
    try:
        lowered, compiled, report = lower_cell(arch, shape_name, multi_pod,
                                               variant=variant)
    except Exception as e:
        traceback.print_exc()
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "failed", "error": f"{type(e).__name__}: {e}"}
    dt = time.time() - t0
    try:
        mem = compiled.memory_analysis()
        mem_str = str(mem)
    except Exception:
        mem_str = "n/a"
    if verbose:
        print(f"OK    {arch} x {shape_name} x {mesh_name}  "
              f"compile={dt:.1f}s dominant={report.dominant} "
              f"t=({report.t_compute:.3e},{report.t_memory:.3e},"
              f"{report.t_collective:.3e})s useful={report.usefulness:.3f}")
        print(f"      memory_analysis: {mem_str[:300]}")
        print(f"      cost_analysis: flops/dev="
              f"{report.flops_global / report.n_devices:.3e} "
              f"bytes/dev={report.bytes_global / report.n_devices:.3e}")
    rec = json.loads(report.to_json())
    rec.update({"status": "ok", "compile_s": dt, "memory_analysis": mem_str})
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}__{shape_name}__{mesh_name}__{variant}".replace("/", "_")
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--out", default="benchmarks/artifacts")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()

    meshes = [False, True]
    if args.single_pod_only:
        meshes = [False]
    if args.multi_pod_only:
        meshes = [True]
    if not args.all and args.multi_pod:
        meshes = [True]
    elif not args.all and not args.multi_pod:
        meshes = [False]

    results = []
    if args.all:
        archs = [a for a, c in all_archs().items() if c.family != "simple"]
        shapes = list(all_shapes().keys())
        for mp in meshes:
            for arch in archs:
                for shape in shapes:
                    results.append(run_cell(arch, shape, mp, args.out,
                                            variant=args.variant))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for mp in meshes:
            results.append(run_cell(args.arch, args.shape, mp, args.out,
                                    variant=args.variant))

    ok = sum(1 for r in results if r["status"] == "ok")
    sk = sum(1 for r in results if r["status"] == "skipped")
    fail = [r for r in results if r["status"] == "failed"]
    print(f"\n=== dry-run summary: {ok} ok, {sk} skipped, {len(fail)} failed ===")
    for r in fail:
        print(f"FAILED {r['arch']} x {r['shape']} x {r['mesh']}: {r['error']}")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
