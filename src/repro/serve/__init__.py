"""Continuous-batching unlearning scheduler — the serving tier above
`core.session.UnlearnerSession`.

DeltaGrad answers a *single* deletion request far cheaper than retraining;
a production right-to-be-forgotten service answers an open-loop STREAM of
them — bursty, multi-tenant, with wildly different urgency (an
interactive "delete my account" click vs a bulk GDPR backfill).  The
session's own auto-flush policy (one global ``max_pending``/
``max_delay_s``) is a single-caller knob; this package is the multi-tenant
serving layer, shaped like an LLM-inference continuous-batching scheduler:

    queue.py      AdmissionQueue — per-tenant quotas, bounded depth,
                  backpressure (reject-with-retry-after or block, the
                  caller's choice), add-capacity accounting in pow2-bucket
                  units so a tenant burst cannot admit more additions than
                  the engine's staged device columns will hold.
    scheduler.py  SLA classes + earliest-deadline-first flush decisions,
                  cross-tenant batch formation (same-op requests from any
                  tenant coalesce into ONE group replay — the planner's
                  pow2-bucketed index-set groups mean cross-tenant batching
                  costs no new retraces), and the deadline clock that
                  replaces the deprecated `AutoFlushTimer`.
    executor.py   Drives the session's existing submit/coalesce/flush
                  path with AT MOST ONE replay in flight; the queue keeps
                  admitting while a replay runs, so the next batch forms
                  under the current one (continuous batching).
    monitor.py    Per-class dispatch/e2e percentiles, queue depth, batch
                  size histogram, deadline-miss and retrace counters —
                  the `continuous_batching` section of BENCH_serve.json.
    loadgen.py    Seeded open-loop arrivals (Poisson and diurnal traces,
                  multi-tenant delete/add mixes) plus the deterministic
                  fixed-interval and closed-loop modes parity tests use.

ARCHITECTURE — one request's life:

    caller ──▶ AdmissionQueue.admit()          (quota + depth + add-capacity
                   │                            checks; backpressure here)
                   ▼
    ServingScheduler.take_batch()              (EDF over the pending set:
                   │                            dispatch now / wait)
                   ▼
    Executor.serve_batch()                     (session.submit × batch,
                   │                            ONE flush, ONE device sync)
                   ▼
    ServeMonitor.observe_*()                   (e2e vs the class deadline)

SLA-CLASS SELECTION — pick the class whose deadline matches the caller's
contract; the scheduler holds a request only while its deadline affords
it, so looser classes batch harder and cost less per request:

    class        default deadline   typical caller             batching
    interactive  0.05 s             user-facing delete click   rarely waits
    batch        0.5  s             app-tier cleanup jobs      coalesces
    bulk_gdpr    5.0  s             compliance backfills       max batches

BACKPRESSURE SEMANTICS — admission fails BEFORE state changes, so a
rejected request has no trace.  ``on_full="reject"`` raises
`RetryAfter(retry_after_s)` with a hint derived from the current drain
rate; ``on_full="block"`` parks the submitting thread until the queue
drains (bounded by ``block_timeout_s``, then `RetryAfter`).  Per-tenant
quotas reject only the offending tenant; other tenants keep admitting.
Addition requests additionally charge the engine's pow2-bucketed add
capacity (padding columns included — see `queue.AddCapacityLedger`): adds
beyond the staged bucket are rejected with retry-after rather than forcing
a mid-flush retrace, and a retrace that still happens (capacity legally
re-bucketed between flushes) is surfaced as the monitor's
``add_capacity_retraces`` counter instead of silent recompile stalls.

The scheduler only decides WHEN to flush and WHAT to coalesce — never how
to replay: batches are served by the unchanged session/planner/engine
stack, so scan-vs-python replay parity (exactly 0.0 on the full-batch CI
config) is preserved by construction.  See `core/session.py` for the
algorithm-selection guide (deltagrad / descent_to_delete /
retrain_oracle); every registered algorithm serves through this tier
unchanged.

Quickstart:

    from repro.serve import ServeConfig, ServingScheduler
    sched = ServingScheduler(session, ServeConfig())
    sched.start()                                # executor thread
    t = sched.submit(op="delete", rows=[17], tenant="acme",
                     sla_class="interactive")
    t.wait()                                     # e2e includes queueing
    sched.drain(); sched.stop()                  # or sched.save(dir)
"""

from repro.serve.executor import Executor
from repro.serve.loadgen import (LoadGenerator, LoadResult, TraceEvent,
                                 diurnal_trace, fixed_trace, materialize,
                                 poisson_trace)
from repro.serve.monitor import ServeMonitor
from repro.serve.queue import (AddCapacityLedger, AdmissionQueue, QueuedRequest,
                               RetryAfter, TenantQuota)
from repro.serve.scheduler import (DEFAULT_CLASSES, ServeConfig,
                                   ServeTicket, ServingScheduler,
                                   SessionFlushClock, SLAClass)

__all__ = [
    "AddCapacityLedger", "AdmissionQueue", "QueuedRequest", "RetryAfter",
    "TenantQuota", "SLAClass", "DEFAULT_CLASSES", "ServeConfig",
    "ServeTicket", "ServingScheduler", "SessionFlushClock", "Executor",
    "ServeMonitor", "LoadGenerator", "LoadResult", "TraceEvent",
    "materialize", "poisson_trace", "diurnal_trace", "fixed_trace",
]
