"""SLA-aware continuous-batching scheduler over `UnlearnerSession`.

The session's auto-flush policy is one global ``max_pending``/
``max_delay_s`` pair — a single-caller knob.  `ServingScheduler` replaces
it with PER-REQUEST-CLASS deadlines: every admitted request carries an
absolute deadline (``arrival + SLAClass.deadline_s``) and the scheduler
chooses flush moments by earliest-deadline-first over the pending set:

  * a request becomes READY at ``min(arrival + hold_s,
    deadline − slack·service_est)`` — ``hold_s`` is the class's deliberate
    batching delay (0 for interactive: dispatch at once; larger for bulk
    classes: let cross-tenant batches form), and the deadline term
    guarantees the request still dispatches early enough to finish on
    time under the current service-time estimate;
  * when any pending request is ready (or the pending set fills
    ``max_batch``), the EDF-first request anchors the batch and every
    compatible pending request — same op, ``coalesce=True``, ANY tenant —
    joins it in EDF order.  The batch is served as ONE session flush, so
    the planner coalesces it into one group replay; because group widths
    bucket to pow2 (`build_online_schedule`), cross-tenant batching hits
    the same compiled programs single-tenant bursts do — no new retraces.

The scheduler decides WHEN to flush and WHAT to coalesce, never HOW to
replay: batches go through the unchanged session submit/coalesce/flush
path, preserving scan-vs-python parity by construction.

`SessionFlushClock` is the degenerate scheduler — one default SLA class
whose deadline is the session's own ``max_delay_s``, driven by a daemon
tick thread.  It replaces the deprecated `core.session.AutoFlushTimer`
(the old name remains as a shim that warns and delegates here).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.deltagrad import _next_pow2
from repro.obs import trace as obs_trace
from repro.serve.monitor import ServeMonitor
from repro.serve.queue import AdmissionQueue, QueuedRequest, TenantQuota

# --------------------------------------------------------------------------
# SLA classes
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class SLAClass:
    """One request class: a deadline the scheduler works back from, and a
    hold — the deliberate batching delay the class tolerates (always
    trimmed by the deadline term, so a hold never causes a miss that the
    service-time estimate could have predicted)."""

    name: str
    deadline_s: float
    hold_s: float = 0.0


DEFAULT_CLASSES: Tuple[SLAClass, ...] = (
    SLAClass("interactive", deadline_s=0.05, hold_s=0.0),
    SLAClass("batch", deadline_s=0.5, hold_s=0.05),
    SLAClass("bulk_gdpr", deadline_s=5.0, hold_s=0.5),
)


@dataclass
class ServeConfig:
    """Scheduler + admission knobs (see the package docstring's guide)."""

    classes: Tuple[SLAClass, ...] = DEFAULT_CLASSES
    max_batch: int = 64              # requests per dispatched batch
    max_depth: int = 1024            # bounded admission queue
    tenant_max_pending: Optional[int] = 64
    on_full: str = "reject"          # "reject" (RetryAfter) | "block"
    block_timeout_s: float = 30.0
    # addition rows to pre-stage (pow2-bucketed device columns); admission
    # charges adds against this bucket — padding included — and rejects
    # past it instead of forcing a mid-flush retrace
    add_capacity: int = 0
    enforce_add_capacity: bool = True
    slack_factor: float = 2.0        # deadline urgency margin on est
    service_est_init_s: float = 0.005
    idle_tick_s: float = 0.02        # executor wake interval when idle


class ServeTicket:
    """Caller-facing handle for one admitted request."""

    def __init__(self, scheduler: "ServingScheduler", req: QueuedRequest):
        self._scheduler = scheduler
        self.req = req

    @property
    def done(self) -> bool:
        return self.req.done.is_set()

    @property
    def error(self) -> Optional[Exception]:
        return self.req.error

    @property
    def e2e_s(self) -> Optional[float]:
        return self.req.e2e_s

    @property
    def missed_deadline(self) -> Optional[bool]:
        return self.req.missed_deadline

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until served (pumping inline when no executor thread is
        running); True when done.  Raises the request's error, if any."""
        if self._scheduler.running:
            ok = self.req.done.wait(timeout)
        else:
            deadline = (None if timeout is None
                        else time.monotonic() + timeout)
            while not self.req.done.is_set():
                self._scheduler.pump(force=True)
                if deadline is not None and time.monotonic() > deadline:
                    break
            ok = self.req.done.is_set()
        if ok and self.req.error is not None:
            raise RuntimeError(
                f"request {self.req.seq} failed: {self.req.error}"
            ) from self.req.error
        return ok


# --------------------------------------------------------------------------
# The scheduler
# --------------------------------------------------------------------------


class ServingScheduler:
    """Admission + EDF flush policy + cross-tenant batching over one
    `UnlearnerSession`.  Construction touches the session's algorithm (so
    capacity can be pre-staged); `start()` spins the executor thread, or
    call `pump()`/`drain()` inline for deterministic single-thread use
    (tests, virtual clocks)."""

    def __init__(self, session, config: Optional[ServeConfig] = None,
                 clock: Callable[[], float] = None,
                 monitor: Optional[ServeMonitor] = None):
        from repro.serve.executor import Executor  # avoid import cycle

        self.session = session
        self.config = config or ServeConfig()
        self.clock = clock if clock is not None else time.monotonic
        self.classes: Dict[str, SLAClass] = {c.name: c
                                             for c in self.config.classes}
        if not self.classes:
            raise ValueError("ServeConfig.classes must name at least one "
                             "SLA class")
        self.default_class = self.config.classes[0].name
        self.monitor = monitor or ServeMonitor()
        # the queue mirrors its admission counters into the monitor's
        # registry, so one surface carries the whole serving stack
        self.queue = AdmissionQueue(
            max_depth=self.config.max_depth,
            tenant_quota=TenantQuota(self.config.tenant_max_pending),
            on_full=self.config.on_full,
            block_timeout_s=self.config.block_timeout_s,
            clock=self.clock,
            registry=self.monitor.registry)
        self.service_est_s = float(self.config.service_est_init_s)
        self.wait_hint: Optional[float] = None
        self.batch_log: List[Dict[str, Any]] = []
        self._batch_ids = 0
        self.executor = Executor(self)
        # bind the algorithm now and pre-stage the add bucket so admission
        # accounting sees the real staged capacity from the first request
        if (cfg_mp := session.config.max_pending) or session.config.max_delay_s:
            raise ValueError(
                "the session's own auto-flush policy (max_pending="
                f"{cfg_mp}, max_delay_s={session.config.max_delay_s}) "
                "would race the scheduler's flush decisions — disable it; "
                "SLA-class deadlines replace it")
        session.algorithm.begin_plan(self.config.add_capacity)
        self._refresh_ledger()
        self._last_row_cap: Optional[int] = None

    # -- capacity accounting -------------------------------------------------

    def _capacity_view(self) -> Optional[Tuple[int, int]]:
        """(staged_rows, appended_rows) for the serving algorithm: the
        pow2 bucket its device columns stage (padding included) and the
        rows physically appended past the cached run."""
        algo = self.session._algorithm
        if algo is None:
            return None
        eng = getattr(algo, "_engine", None)
        if eng is not None:
            cap = max(len(eng.added), eng.add_capacity)
            staged = _next_pow2(cap) if cap else 0
            return staged, self.session.dataset.n - eng._base_n
        row_cap = getattr(algo, "_row_cap", None)
        base_n = getattr(algo, "_base_n", None)
        if row_cap is None or base_n is None:
            return None
        return row_cap - base_n, self.session.dataset.n - base_n

    def _refresh_ledger(self) -> None:
        view = self._capacity_view()
        if view is not None:
            self.queue.refresh_ledger(*view)

    def _note_batch_done(self, batch: List[QueuedRequest]) -> None:
        """Settle a completed (or abandoned) batch with the queue: absorb
        the appended rows into the ledger FIRST, then release the batch's
        in-flight charges — in that order there is no instant where
        in-flight add rows count as headroom."""
        self._refresh_ledger()
        self.queue.note_served(batch)

    def _row_cap_now(self) -> Optional[int]:
        algo = self.session._algorithm
        src = getattr(algo, "_engine", None) or algo
        return getattr(src, "_row_cap", None)

    # -- admission -----------------------------------------------------------

    def submit(self, op: str, rows: Optional[Sequence[int]] = None,
               data: Optional[Dict[str, np.ndarray]] = None,
               tenant: str = "default",
               sla_class: Optional[str] = None,
               coalesce: bool = True) -> ServeTicket:
        """Admit one request (or raise `RetryAfter`); returns a ticket.
        Nothing touches the session here — the executor submits admitted
        requests at dispatch time, so a rejected request has no trace."""
        cls_name = sla_class or self.default_class
        try:
            cls = self.classes[cls_name]
        except KeyError:
            raise ValueError(
                f"unknown SLA class {cls_name!r}; configured: "
                f"{', '.join(sorted(self.classes))}") from None
        if op not in ("delete", "add"):
            raise ValueError(f"op must be 'delete' or 'add', got {op!r}")
        if op == "add" and rows is None and data is None:
            raise ValueError("add requests need data (or rows)")
        now = self.clock()
        self._refresh_ledger()
        req = QueuedRequest(
            seq=-1, tenant=tenant, sla_class=cls_name, op=op,
            rows=list(rows) if rows is not None else None, data=data,
            coalesce=coalesce, t_enqueue=now,
            deadline=now + cls.deadline_s)
        with obs_trace.span("serve.admit", op=op, tenant=tenant,
                            cls=cls_name):
            self.queue.admit(
                req, enforce_add_capacity=self.config.enforce_add_capacity)
        self.monitor.observe_depth(self.queue.depth)
        return ServeTicket(self, req)

    # -- EDF flush decision --------------------------------------------------

    def _ready_t(self, q: QueuedRequest) -> float:
        cls = self.classes[q.sla_class]
        margin = self.config.slack_factor * self.service_est_s
        return min(q.t_enqueue + cls.hold_s, q.deadline - margin)

    def _choose(self, pending: List[QueuedRequest], now: float,
                force: bool) -> List[QueuedRequest]:
        """The flush decision, run atomically under the queue lock: [] to
        keep waiting (`wait_hint` says how long), else the batch — the
        EDF-first request plus every compatible pending request (same op,
        coalesce=True, any tenant) in EDF order, capped at max_batch."""
        self.wait_hint = None
        if not pending:
            return []
        if not force and len(pending) < self.config.max_batch:
            t_fire = min(self._ready_t(q) for q in pending)
            if now < t_fire:
                self.wait_hint = max(1e-4, t_fire - now)
                return []
        edf = sorted(pending, key=lambda q: (q.deadline, q.seq))
        head = edf[0]
        if not head.coalesce:
            return [head]
        return [q for q in edf
                if q.op == head.op and q.coalesce][:self.config.max_batch]

    def take_batch(self, now: Optional[float] = None,
                   force: bool = False) -> List[QueuedRequest]:
        now = self.clock() if now is None else now
        return self.queue.take(lambda p: self._choose(p, now, force))

    def note_service(self, service_s: float, batch: List[QueuedRequest],
                     retraced: bool) -> None:
        """Executor feedback after each batch — the FULL batch, including
        requests whose submit failed (the monitor routes those to the
        per-class failed counter): service-time EMA for the deadline
        margin, the batch record for the monitor + trace log."""
        self.service_est_s = 0.5 * self.service_est_s + 0.5 * float(service_s)
        self.monitor.observe_batch(batch, retraced=retraced)
        for q in batch:
            self.monitor.observe_request(q)
        self._batch_ids += 1
        self.batch_log.append({
            "batch": self._batch_ids,
            "op": batch[0].op,
            "rows": [r for q in batch for r in (q.rows or [])],
            "tenants": sorted({q.tenant for q in batch}),
            "classes": sorted({q.sla_class for q in batch}),
            "coalesce": batch[0].coalesce,
        })

    # -- execution modes -----------------------------------------------------

    @property
    def running(self) -> bool:
        return self.executor.running

    def start(self) -> "ServingScheduler":
        """Spin the executor thread: one replay in flight at most, the
        queue admitting (and the next batch forming) underneath it."""
        self.executor.start()
        return self

    def stop(self) -> None:
        """Stop the executor thread (waking any blocked admits).  The
        scheduler remains usable inline (`pump()`/`drain()`/`submit`)
        and `start()` brings the thread back."""
        self.executor.stop()
        self.queue.reopen()

    def pump(self, now: Optional[float] = None, force: bool = False) -> int:
        """Inline single-step (no thread): take one batch per the flush
        policy (`force=True` skips hold/deadline waiting — drain style)
        and serve it.  Returns requests served."""
        batch = self.take_batch(now=now, force=force)
        if not batch:
            return 0
        self.executor.serve_batch(batch)
        return len(batch)

    def drain(self) -> int:
        """Serve everything pending (queue AND session) to completion;
        returns requests served.  Safe next to a running executor thread —
        batches are taken atomically either way, and a batch the executor
        has already taken is waited out (`Executor.drain_wait`) before the
        final session flush, so a drain never lands mid-batch."""
        served = 0
        while True:
            n = self.pump(force=True) if not self.running else 0
            served += n
            if self.queue.depth == 0 and not n:
                # the queue is empty, but the executor may still be
                # serving a batch it took earlier — wait for it before
                # declaring the drain complete
                if not self.running or self.executor.drain_wait():
                    if self.queue.depth == 0 and self.queue.in_flight == 0:
                        break
            if self.running:
                time.sleep(0.002)
        self.session.flush()
        return served

    # -- snapshot consistency under load ------------------------------------

    def save(self, directory: str, step: Optional[int] = None,
             pending: str = "drain") -> str:
        """Snapshot the session UNDER LOAD, deterministically:

        ``pending="drain"`` serves every queued request first (the
        snapshot is a between-requests state — restoring and replaying
        the rest of a seeded trace is bitwise-identical to the
        uninterrupted run); ``pending="refuse"`` raises while anything is
        queued OR in flight, for callers that must not absorb latency
        here."""
        if pending not in ("drain", "refuse"):
            raise ValueError(f"pending must be 'drain' or 'refuse', got "
                             f"{pending!r}")
        if pending == "refuse":
            depth = self.queue.depth
            in_flight = self.queue.in_flight
            sess_pending = self.session.pending_count
            if depth or in_flight or sess_pending:
                raise RuntimeError(
                    f"save(pending='refuse') with {depth} queued + "
                    f"{in_flight} in-flight + {sess_pending} "
                    "session-pending request(s); drain first or "
                    "save(pending='drain')")
        else:
            self.drain()
        return self.session.save(directory, step)

    def stats(self) -> Dict[str, Any]:
        return self.monitor.snapshot(self.queue)


# --------------------------------------------------------------------------
# The degenerate scheduler: one default class over a bare session
# --------------------------------------------------------------------------


class SessionFlushClock:
    """Deadline clock for a session WITHOUT a full scheduler: one default
    SLA class whose deadline is the session's own ``max_delay_s``, driven
    by a daemon thread that ticks ``session.poll()`` so the deadline holds
    with ZERO further arrivals.  This is what the deprecated
    `core.session.AutoFlushTimer` now delegates to.

    A flush that raises (a failing request group) records the error on
    ``last_error`` and keeps ticking — the failing handles already resolve
    to the error through the session's usual path."""

    def __init__(self, session, interval_s: Optional[float] = None):
        deadline = session.config.max_delay_s
        if deadline is None:
            raise ValueError(
                "SessionFlushClock needs config.max_delay_s — there is no "
                "deadline to enforce (use ServingScheduler for SLA-class "
                "deadlines)")
        self.sla = SLAClass("default", deadline_s=float(deadline))
        # staleness is bounded by deadline + one tick interval, so default
        # to a small fraction of the deadline
        if interval_s is None:
            interval_s = self.sla.deadline_s / 8.0
        self.interval_s = max(1e-3, float(interval_s))
        self.ticks = 0
        self.last_error: Optional[Exception] = None
        self._session = session
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="unlearner-flush-clock")
        self._thread.start()

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            self.ticks += 1
            try:
                self._session.poll()
            except Exception as e:  # noqa: BLE001 — keep the clock alive
                self.last_error = e

    def stop(self) -> None:
        self._stop.set()
        self._thread.join(timeout=5.0)
