"""Serving-tier metrics — the numbers BENCH_serve.json's
``continuous_batching`` section reports and CI gates.

One `ServeMonitor` instance per scheduler.  Every latency/size quantile
is served from `repro.obs.metrics.Histogram` instances in the monitor's
registry — the same fixed-bucket implementation `launch/serve.py` uses
for its dispatch/blocked percentiles, so there is exactly ONE quantile
code path in the repo.  Recorded per request: dispatch latency (enqueue →
batch dispatch), e2e latency (enqueue → replay drained), and whether the
SLA-class deadline was met.  Recorded per batch: size, distinct tenants,
ops.  Counters: deadline misses per class, admission rejections (scraped
from the queue), add-capacity retraces (a flush that re-bucketed the
engine's staged device rows — each one recompiles every replay program,
which is exactly what admission-side accounting exists to prevent).

The monitor defaults to a PRIVATE `MetricsRegistry` (bench sweeps build
one monitor per point; snapshots must not accumulate across points) —
pass ``registry=obs.metrics.get_registry()`` to publish a single serving
stack into the process-wide surface, as the serve CLI does.
"""

from __future__ import annotations

from collections import Counter
from typing import Any, Dict, List, Optional

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.serve.queue import AdmissionQueue, QueuedRequest

_OWN = "serve.monitor"


class ServeMonitor:
    """Per-class latency, queue, and batching telemetry."""

    def __init__(self,
                 registry: Optional[obs_metrics.MetricsRegistry] = None):
        self.registry = registry if registry is not None \
            else obs_metrics.MetricsRegistry()
        self._classes: set = set()
        self.deadline_misses: Counter = Counter()
        self.served: Counter = Counter()
        self.failed: Counter = Counter()
        self.batch_sizes: List[int] = []
        self.batch_tenants: List[int] = []
        self.batch_ops: Counter = Counter()
        self.cross_tenant_batches = 0
        self.add_capacity_retraces = 0

    # -- registry accessors --------------------------------------------------

    def _hist(self, name: str, cls: Optional[str] = None,
              unit: str = "ms") -> obs_metrics.Histogram:
        labels = {"class": cls} if cls is not None else None
        return self.registry.histogram(name, unit=unit, owner=_OWN,
                                       labels=labels)

    def _counter(self, name: str,
                 cls: Optional[str] = None) -> obs_metrics.Counter:
        labels = {"class": cls} if cls is not None else None
        return self.registry.counter(name, owner=_OWN, labels=labels)

    # -- observations --------------------------------------------------------

    def observe_request(self, req: QueuedRequest) -> None:
        cls = req.sla_class
        self._classes.add(cls)
        if req.error is not None:
            self.failed[cls] += 1
            self._counter("serve.failed", cls).inc()
            return
        self.served[cls] += 1
        self._counter("serve.served", cls).inc()
        if req.t_dispatch is not None:
            self._hist("serve.dispatch_ms", cls).observe(
                (req.t_dispatch - req.t_enqueue) * 1e3)
        if req.t_done is not None:
            self._hist("serve.e2e_ms", cls).observe(
                (req.t_done - req.t_enqueue) * 1e3)
        if req.missed_deadline:
            self.deadline_misses[cls] += 1
            self._counter("serve.deadline_misses", cls).inc()

    def observe_batch(self, batch: List[QueuedRequest],
                      retraced: bool = False) -> None:
        self.batch_sizes.append(len(batch))
        self._hist("serve.batch_size", unit="1").observe(len(batch))
        tenants = len({q.tenant for q in batch})
        self.batch_tenants.append(tenants)
        if tenants > 1:
            self.cross_tenant_batches += 1
        for q in batch:
            self.batch_ops[q.op] += 1
        if retraced:
            self.add_capacity_retraces += 1
            self._counter("serve.add_capacity_retraces").inc()

    def observe_depth(self, depth: int) -> None:
        self._hist("serve.queue_depth", unit="1").observe(int(depth))

    # -- snapshot ------------------------------------------------------------

    def snapshot(self, queue: Optional[AdmissionQueue] = None
                 ) -> Dict[str, Any]:
        classes = sorted(self._classes | set(self.served)
                         | set(self.failed))
        out: Dict[str, Any] = {
            "per_class": {
                cls: {
                    "served": int(self.served[cls]),
                    "failed": int(self.failed[cls]),
                    "deadline_misses": int(self.deadline_misses[cls]),
                    "dispatch_ms":
                        self._hist("serve.dispatch_ms", cls).summary(),
                    "e2e_ms": self._hist("serve.e2e_ms", cls).summary(),
                } for cls in classes
            },
            "batches": {
                "count": len(self.batch_sizes),
                "size_mean": (float(np.mean(self.batch_sizes))
                              if self.batch_sizes else 0.0),
                "size_max": int(max(self.batch_sizes, default=0)),
                "size_hist": dict(Counter(self.batch_sizes)),
                "cross_tenant": int(self.cross_tenant_batches),
                "tenants_mean": (float(np.mean(self.batch_tenants))
                                 if self.batch_tenants else 0.0),
                "ops": dict(self.batch_ops),
            },
            "queue_depth": self._hist("serve.queue_depth",
                                      unit="1").summary(),
            "add_capacity_retraces": int(self.add_capacity_retraces),
            "deadline_misses_total": int(sum(self.deadline_misses.values())),
        }
        if queue is not None:
            out["admission"] = {
                "admitted": queue.admitted,
                "rejected_depth": queue.rejected_depth,
                "rejected_tenant": queue.rejected_tenant,
                "rejected_add_capacity": queue.rejected_add_capacity,
                "blocked_admissions": queue.blocked_admissions,
            }
        return out
