"""Serving-tier metrics — the numbers BENCH_serve.json's
``continuous_batching`` section reports and CI gates.

One `ServeMonitor` instance per scheduler.  Everything is recorded
in-memory (these are bench/CI runs, not a fleet), so `snapshot()` can
compute exact percentiles instead of streaming sketches.  Recorded per
request: dispatch latency (enqueue → batch dispatch), e2e latency
(enqueue → replay drained), and whether the SLA-class deadline was met.
Recorded per batch: size, distinct tenants, ops.  Counters: deadline
misses per class, admission rejections (scraped from the queue),
add-capacity retraces (a flush that re-bucketed the engine's staged
device rows — each one recompiles every replay program, which is exactly
what admission-side accounting exists to prevent).
"""

from __future__ import annotations

from collections import Counter, defaultdict
from typing import Any, Dict, List, Optional

import numpy as np

from repro.serve.queue import AdmissionQueue, QueuedRequest


def _pcts(xs: List[float]) -> Dict[str, float]:
    if not xs:
        return {"count": 0}
    a = np.asarray(xs, dtype=np.float64)
    return {"count": int(a.size), "mean": float(a.mean()),
            "p50": float(np.percentile(a, 50)),
            "p95": float(np.percentile(a, 95)),
            "p99": float(np.percentile(a, 99)),
            "max": float(a.max())}


class ServeMonitor:
    """Per-class latency, queue, and batching telemetry."""

    def __init__(self) -> None:
        self._dispatch_ms: Dict[str, List[float]] = defaultdict(list)
        self._e2e_ms: Dict[str, List[float]] = defaultdict(list)
        self.deadline_misses: Counter = Counter()
        self.served: Counter = Counter()
        self.failed: Counter = Counter()
        self.batch_sizes: List[int] = []
        self.batch_tenants: List[int] = []
        self.batch_ops: Counter = Counter()
        self.cross_tenant_batches = 0
        self.add_capacity_retraces = 0
        self.depth_samples: List[int] = []

    # -- observations --------------------------------------------------------

    def observe_request(self, req: QueuedRequest) -> None:
        cls = req.sla_class
        if req.error is not None:
            self.failed[cls] += 1
            return
        self.served[cls] += 1
        if req.t_dispatch is not None:
            self._dispatch_ms[cls].append(
                (req.t_dispatch - req.t_enqueue) * 1e3)
        if req.t_done is not None:
            self._e2e_ms[cls].append((req.t_done - req.t_enqueue) * 1e3)
        if req.missed_deadline:
            self.deadline_misses[cls] += 1

    def observe_batch(self, batch: List[QueuedRequest],
                      retraced: bool = False) -> None:
        self.batch_sizes.append(len(batch))
        tenants = len({q.tenant for q in batch})
        self.batch_tenants.append(tenants)
        if tenants > 1:
            self.cross_tenant_batches += 1
        for q in batch:
            self.batch_ops[q.op] += 1
        if retraced:
            self.add_capacity_retraces += 1

    def observe_depth(self, depth: int) -> None:
        self.depth_samples.append(int(depth))

    # -- snapshot ------------------------------------------------------------

    def snapshot(self, queue: Optional[AdmissionQueue] = None
                 ) -> Dict[str, Any]:
        classes = sorted(set(self._e2e_ms) | set(self._dispatch_ms)
                         | set(self.served) | set(self.failed))
        out: Dict[str, Any] = {
            "per_class": {
                cls: {
                    "served": int(self.served[cls]),
                    "failed": int(self.failed[cls]),
                    "deadline_misses": int(self.deadline_misses[cls]),
                    "dispatch_ms": _pcts(self._dispatch_ms[cls]),
                    "e2e_ms": _pcts(self._e2e_ms[cls]),
                } for cls in classes
            },
            "batches": {
                "count": len(self.batch_sizes),
                "size_mean": (float(np.mean(self.batch_sizes))
                              if self.batch_sizes else 0.0),
                "size_max": int(max(self.batch_sizes, default=0)),
                "size_hist": dict(Counter(self.batch_sizes)),
                "cross_tenant": int(self.cross_tenant_batches),
                "tenants_mean": (float(np.mean(self.batch_tenants))
                                 if self.batch_tenants else 0.0),
                "ops": dict(self.batch_ops),
            },
            "queue_depth": _pcts([float(d) for d in self.depth_samples]),
            "add_capacity_retraces": int(self.add_capacity_retraces),
            "deadline_misses_total": int(sum(self.deadline_misses.values())),
        }
        if queue is not None:
            out["admission"] = {
                "admitted": queue.admitted,
                "rejected_depth": queue.rejected_depth,
                "rejected_tenant": queue.rejected_tenant,
                "rejected_add_capacity": queue.rejected_add_capacity,
                "blocked_admissions": queue.blocked_admissions,
            }
        return out
