"""Admission control for the unlearning serving tier.

`AdmissionQueue` is the front door: every request is checked — and either
admitted, rejected with a retry-after hint, or blocked until space frees —
BEFORE any session state changes, so a rejected request leaves no trace.
Three independent limits gate admission:

  * bounded depth (``max_depth``) — the global pending set never grows
    past it, so a stalled executor surfaces as backpressure at the edge
    instead of unbounded memory growth;
  * per-tenant quotas (`TenantQuota`) — one tenant's burst cannot starve
    the others out of the queue (its own requests bounce, everyone else
    keeps admitting);
  * add-capacity accounting (`AddCapacityLedger`) — addition rows are
    charged against the engine's staged pow2-bucketed device-row capacity
    IN BUCKET UNITS (padding columns included), so a burst of adds that
    would outgrow `Dataset.device_columns(capacity=...)` — and force a
    mid-flush retrace of every compiled replay program — is refused with
    retry-after instead of admitted.

The queue is thread-safe with a single condition variable: producers
(callers, the load generator) admit concurrently with the one consumer
(the executor) taking batches via `take()`.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.deltagrad import _next_pow2
from repro.obs import metrics as obs_metrics


class RetryAfter(Exception):
    """Backpressure signal: the request was NOT admitted; try again in
    ``retry_after_s`` seconds (a hint from the queue's current drain
    rate, never a promise)."""

    def __init__(self, reason: str, retry_after_s: float):
        super().__init__(f"{reason} (retry after {retry_after_s:.3g}s)")
        self.reason = reason
        self.retry_after_s = float(retry_after_s)


@dataclass
class TenantQuota:
    """Per-tenant admission bounds (None disables a bound)."""

    max_pending: Optional[int] = 64


@dataclass
class QueuedRequest:
    """One admitted request, from admission to completion.

    The queue owns it while pending; the executor stamps the completion
    fields and sets ``done``.  ``deadline`` is absolute (clock units of
    the owning scheduler): ``t_enqueue + sla.deadline_s``."""

    seq: int
    tenant: str
    sla_class: str
    op: str
    rows: Optional[Sequence[int]]
    data: Optional[Dict[str, np.ndarray]]
    coalesce: bool
    t_enqueue: float
    deadline: float
    # completion bookkeeping (executor-stamped)
    t_dispatch: Optional[float] = None
    t_done: Optional[float] = None
    error: Optional[Exception] = None
    batch_id: Optional[int] = None
    done: threading.Event = field(default_factory=threading.Event,
                                  repr=False)

    @property
    def n_rows(self) -> int:
        if self.rows is not None:
            return len(self.rows)
        return len(next(iter(self.data.values())))

    @property
    def e2e_s(self) -> Optional[float]:
        return None if self.t_done is None else self.t_done - self.t_enqueue

    @property
    def missed_deadline(self) -> Optional[bool]:
        return None if self.t_done is None else self.t_done > self.deadline


class AddCapacityLedger:
    """Pow2-bucket accounting for addition rows.

    The engine stages device columns at ``base_n + next_pow2(adds)`` rows;
    everything inside the bucket — INCLUDING the padding columns between
    the appended rows and the pow2 boundary — is capacity that admits
    additions without a retrace, and the first row past the boundary
    re-traces every compiled replay program.  The ledger therefore counts
    headroom as

        staged_rows − appended_rows − pending_rows

    where ``staged_rows`` is the full bucket (padding included — the fix
    for the pre-scheduler accounting, which compared against the raw add
    count and let bursts slip past the boundary) and ``pending_rows`` are
    admitted-but-not-yet-appended adds: rows sitting in the queue AND
    rows in a batch the executor has taken but not finished serving.  A
    charge is released only once the batch completes and the scheduler
    has refreshed ``appended_rows`` (`AdmissionQueue.note_served`), so
    in-flight rows are never counted as headroom."""

    def __init__(self) -> None:
        self.staged_rows = 0
        self.appended_rows = 0
        self.pending_rows = 0

    def refresh(self, staged_rows: int, appended_rows: int) -> None:
        """Sync the engine-side facts (called by the scheduler with
        ``row_cap − base_n`` and ``ds.n − base_n``)."""
        self.staged_rows = int(staged_rows)
        self.appended_rows = int(appended_rows)

    @property
    def headroom(self) -> int:
        return self.staged_rows - self.appended_rows - self.pending_rows

    def try_charge(self, k: int) -> bool:
        """Reserve `k` add rows inside the staged bucket; False when the
        charge would cross the pow2 boundary (the caller backpressures)."""
        if k > self.headroom:
            return False
        self.pending_rows += k
        return True

    def force_charge(self, k: int) -> None:
        """Charge past the boundary (enforcement off): the eventual
        retrace is the monitor's ``add_capacity_retraces`` to count."""
        self.pending_rows += k

    def release(self, k: int) -> None:
        """A charged request finished serving (its rows are now visible
        in ``appended_rows``) or failed without appending."""
        self.pending_rows = max(0, self.pending_rows - k)

    @staticmethod
    def bucket(adds: int) -> int:
        """Rows the engine stages for `adds` additions (pow2 padding)."""
        return _next_pow2(adds) if adds else 0


class AdmissionQueue:
    """Bounded, tenant-aware FIFO between callers and the executor."""

    def __init__(self, max_depth: int = 1024,
                 tenant_quota: Optional[TenantQuota] = None,
                 on_full: str = "reject",
                 block_timeout_s: float = 30.0,
                 clock: Callable[[], float] = None,
                 registry: Optional[obs_metrics.MetricsRegistry] = None):
        if on_full not in ("reject", "block"):
            raise ValueError(f"on_full must be 'reject' or 'block', got "
                             f"{on_full!r}")
        import time as _time
        self.max_depth = int(max_depth)
        self.tenant_quota = tenant_quota or TenantQuota()
        self.on_full = on_full
        self.block_timeout_s = float(block_timeout_s)
        self.clock = clock if clock is not None else _time.monotonic
        self.ledger = AddCapacityLedger()
        self.cond = threading.Condition()
        self._pending: List[QueuedRequest] = []
        self._in_flight = 0
        self._seq = 0
        self._closed = False
        # admission outcome counters (monitor scrapes them); each is
        # mirrored into the registry as `queue.<name>` — the scheduler
        # passes its monitor's registry so the serving stack shares one
        # surface (see the contract table in `repro.obs`)
        self.registry = registry if registry is not None \
            else obs_metrics.get_registry()
        self.admitted = 0
        self.rejected_depth = 0
        self.rejected_tenant = 0
        self.rejected_add_capacity = 0
        self.blocked_admissions = 0
        # EMA of observed drain rate (requests/s) — the retry-after hint
        self._drain_rate = 0.0
        self._last_take_t: Optional[float] = None

    # -- introspection -------------------------------------------------------

    def __len__(self) -> int:
        with self.cond:
            return len(self._pending)

    @property
    def depth(self) -> int:
        return len(self)

    @property
    def in_flight(self) -> int:
        """Requests taken by the executor but not yet finished serving.
        A drain (or a snapshot) is only between-requests when BOTH the
        depth and this are zero."""
        with self.cond:
            return self._in_flight

    def tenant_depth(self, tenant: str) -> int:
        with self.cond:
            return sum(1 for q in self._pending if q.tenant == tenant)

    def snapshot(self) -> List[QueuedRequest]:
        with self.cond:
            return list(self._pending)

    def _retry_hint(self, backlog: int) -> float:
        """Seconds until `backlog` requests drain at the observed rate
        (floor 1 ms; 50 ms default before any batch has drained)."""
        if self._drain_rate <= 0:
            return 0.05
        return max(1e-3, backlog / self._drain_rate)

    def _count(self, name: str) -> None:
        self.registry.counter("queue." + name, owner="serve.queue").inc()

    # -- admission -----------------------------------------------------------

    def admit(self, req: QueuedRequest,
              enforce_add_capacity: bool = True) -> QueuedRequest:
        """Admit or backpressure (`RetryAfter`).  Depth and quota checks
        honor ``on_full`` ("block" parks the caller until space frees,
        bounded by ``block_timeout_s``); the add-capacity check always
        rejects — blocking cannot create device capacity."""
        with self.cond:
            if self.on_full == "block":
                def has_room():
                    return (self._closed
                            or (len(self._pending) < self.max_depth
                                and self._tenant_room(req.tenant)))
                if not has_room():
                    self.blocked_admissions += 1
                    self._count("blocked_admissions")
                    if not self.cond.wait_for(has_room,
                                              timeout=self.block_timeout_s):
                        self.rejected_depth += 1
                        self._count("rejected_depth")
                        raise RetryAfter(
                            "queue full past block_timeout_s",
                            self._retry_hint(len(self._pending)))
            if self._closed:
                raise RuntimeError("queue is closed (scheduler stopped)")
            if len(self._pending) >= self.max_depth:
                self.rejected_depth += 1
                self._count("rejected_depth")
                raise RetryAfter(
                    f"queue depth {len(self._pending)} at max_depth "
                    f"{self.max_depth}",
                    self._retry_hint(1 + len(self._pending)
                                     - self.max_depth))
            if not self._tenant_room(req.tenant):
                self.rejected_tenant += 1
                self._count("rejected_tenant")
                raise RetryAfter(
                    f"tenant {req.tenant!r} at quota "
                    f"{self.tenant_quota.max_pending}",
                    self._retry_hint(1))
            if req.op == "add":
                if not self.ledger.try_charge(req.n_rows):
                    if enforce_add_capacity:
                        self.rejected_add_capacity += 1
                        self._count("rejected_add_capacity")
                        raise RetryAfter(
                            f"add of {req.n_rows} rows exceeds staged "
                            f"device capacity (headroom "
                            f"{self.ledger.headroom} rows incl. pow2 "
                            "padding)",
                            self._retry_hint(len(self._pending) + 1))
                    self.ledger.force_charge(req.n_rows)
            req.seq = self._seq
            self._seq += 1
            self._pending.append(req)
            self.admitted += 1
            self._count("admitted")
            self.cond.notify_all()
            return req

    def _tenant_room(self, tenant: str) -> bool:
        mp = self.tenant_quota.max_pending
        if mp is None:
            return True
        return sum(1 for q in self._pending if q.tenant == tenant) < mp

    # -- the consumer side ---------------------------------------------------

    def wait_for_work(self, timeout: Optional[float] = None) -> bool:
        """Block the executor until something is pending (or timeout)."""
        with self.cond:
            return self.cond.wait_for(
                lambda: self._pending or self._closed, timeout=timeout)

    def take(self, chooser: Callable[[List[QueuedRequest]],
                                     List[QueuedRequest]]
             ) -> List[QueuedRequest]:
        """Atomically remove and return the batch `chooser` selects from
        the pending snapshot (the scheduler's EDF decision runs under the
        queue lock, so admissions cannot race the selection)."""
        with self.cond:
            batch = chooser(list(self._pending))
            if batch:
                picked = {q.seq for q in batch}
                self._pending = [q for q in self._pending
                                 if q.seq not in picked]
                # taken rows stay charged on the ledger until the batch
                # completes and `note_served` runs — releasing here would
                # overstate headroom while the rows are in flight
                self._in_flight += len(batch)
                now = self.clock()
                if self._last_take_t is not None:
                    dt = max(now - self._last_take_t, 1e-6)
                    inst = len(batch) / dt
                    self._drain_rate = (0.5 * self._drain_rate + 0.5 * inst
                                        if self._drain_rate else inst)
                self._last_take_t = now
                self.cond.notify_all()  # space freed: wake blocked admits
            return batch

    def note_served(self, batch: List[QueuedRequest]) -> None:
        """The executor finished (or abandoned) a taken batch: drop its
        in-flight count and release its add-row ledger charges.  Call
        AFTER `refresh_ledger` has absorbed the appended rows, so the
        charge hands off to ``appended_rows`` without a headroom gap."""
        with self.cond:
            self._in_flight = max(0, self._in_flight - len(batch))
            for q in batch:
                if q.op == "add":
                    self.ledger.release(q.n_rows)
            self.cond.notify_all()  # wake wait_idle / blocked admits

    def refresh_ledger(self, staged_rows: int, appended_rows: int) -> None:
        """Sync the ledger's engine-side facts under the queue lock (so
        a concurrent admit's `try_charge` never sees a half-updated
        view)."""
        with self.cond:
            self.ledger.refresh(staged_rows, appended_rows)

    def wait_idle(self, timeout: Optional[float] = None) -> bool:
        """Block until nothing is pending AND nothing is in flight (or
        timeout); True when idle.  This is the drain/snapshot barrier."""
        with self.cond:
            return self.cond.wait_for(
                lambda: not self._pending and not self._in_flight,
                timeout=timeout)

    def close(self) -> None:
        """Stop admitting (blocked admits wake and see the closed queue).
        Requests already pending stay takeable; `reopen()` reverses."""
        with self.cond:
            self._closed = True
            self.cond.notify_all()

    def reopen(self) -> None:
        with self.cond:
            self._closed = False
            self.cond.notify_all()
