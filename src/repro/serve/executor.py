"""Batch executor — drives the session's submit/coalesce/flush path.

One executor per scheduler, with AT MOST ONE replay in flight: a batch is
submitted to the session, flushed (one dispatch), and drained
(`jax.block_until_ready`) before the next batch is taken.  The admission
queue keeps admitting the whole time, so the next batch forms WHILE the
current replay runs — that overlap is the continuous-batching throughput
win: under load, every drain's worth of arrivals coalesces into the next
group replay instead of queueing serial replays.

The executor never interprets requests — validation errors surface from
`session.submit`, group failures from `session.flush`; either way the
failing request's ticket resolves to the error and the rest of the batch
is served (the session's flush already isolates failing groups)."""

from __future__ import annotations

import threading
from typing import List

from repro.obs import trace as obs_trace
from repro.serve.queue import QueuedRequest


class Executor:
    """Single-consumer serving loop (thread-run or pumped inline)."""

    def __init__(self, scheduler):
        self.scheduler = scheduler
        self._thread = None
        self._stop = threading.Event()
        self._serve_lock = threading.Lock()  # one replay in flight, ever
        self.batches_served = 0

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> None:
        if self.running:
            return
        self._stop.clear()
        self.scheduler.queue.reopen()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="unlearner-executor")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        self.scheduler.queue.close()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None

    def _loop(self) -> None:
        sched = self.scheduler
        tick = sched.config.idle_tick_s
        while not self._stop.is_set():
            if not sched.queue.wait_for_work(timeout=tick):
                continue
            batch = sched.take_batch()
            if not batch:
                # the flush policy says wait (hold / deadline slack) —
                # sleep exactly until the earliest ready time, but stay
                # interruptible so stop() never hangs on a held batch
                wait = sched.wait_hint if sched.wait_hint else tick
                self._stop.wait(min(wait, tick))
                continue
            self.serve_batch(batch)

    # -- one batch, one flush, one drain ------------------------------------

    def serve_batch(self, batch: List[QueuedRequest]) -> None:
        import jax

        sched = self.scheduler
        session = sched.session
        with self._serve_lock, obs_trace.span(
                "serve.batch", size=len(batch),
                op=batch[0].op if batch else ""):
            try:
                cap_before = sched._row_cap_now()
                t_disp = sched.clock()
                handles = []
                for q in batch:
                    try:
                        h = session.submit(op=q.op, rows=q.rows,
                                           data=q.data, coalesce=q.coalesce)
                        # adds resolve their appended row ids at submit
                        # time; reflect them so the trace log / parity
                        # replays see the served rows
                        q.rows = list(h.request.rows)
                        handles.append((q, h))
                    except Exception as e:  # noqa: BLE001 — per-req fault
                        q.error = e
                        q.t_dispatch = t_disp
                        q.t_done = sched.clock()
                        q.batch_id = sched._batch_ids + 1
                        q.done.set()
                # one flush per batch: the planner coalesces the run into
                # one group replay.  flush() isolates a failing group by
                # requeueing the groups behind it, so keep flushing until
                # the session's pending set is empty (bounded by the
                # batch size).
                for _ in range(max(1, len(handles))):
                    try:
                        session.flush()
                    except Exception:  # noqa: BLE001 — outcomes below
                        pass
                    if session.pending_count == 0:
                        break
                if handles:
                    try:
                        jax.block_until_ready(session._algorithm.params)
                    except Exception:  # noqa: BLE001 — per-handle below
                        pass
                t_done = sched.clock()
                for q, h in handles:
                    q.t_dispatch = t_disp
                    q.t_done = t_done
                    q.batch_id = sched._batch_ids + 1
                    try:
                        h.result(block=False)
                    except Exception as e:  # noqa: BLE001
                        q.error = e
                    q.done.set()
                cap_after = sched._row_cap_now()
                retraced = (cap_before is not None
                            and self.batches_served > 0
                            and cap_after != cap_before)
                self.batches_served += 1
                # the FULL batch, failed submits included: the monitor's
                # failed counter and the batch/trace log must record them
                sched.note_service(max(t_done - t_disp, 1e-9), batch,
                                   retraced)
            finally:
                # always settle the batch with the queue — refresh the
                # ledger's appended_rows, THEN release the in-flight rows
                # and count, so drain()/save() see a true between-requests
                # state and add headroom never double-counts
                sched._note_batch_done(batch)

    def drain_wait(self, timeout: float = 30.0) -> bool:
        """Wait (thread mode) until the queue is empty AND no taken batch
        is still being served; True on success.  `ServingScheduler.drain`
        uses this so a drain (and a ``pending="drain"`` snapshot) never
        lands mid-batch."""
        return self.scheduler.queue.wait_idle(timeout=timeout)
