"""Seeded load generation for the unlearning serving tier.

Production deletion traffic is OPEN-LOOP: requests arrive on their own
clock whether or not the service keeps up, which is what exposes queueing
behavior (throughput-vs-p99 curves, deadline misses past the knee) that a
closed loop — submit, wait, repeat — structurally cannot.  This module
generates both, deterministically from a seed:

  * `poisson_trace`   — memoryless arrivals at a fixed offered load, the
                        bench's default (`--trace poisson`);
  * `diurnal_trace`   — a Poisson process whose rate follows a sinusoidal
                        day curve (thinning construction), for burst
                        behavior across load swings;
  * `fixed_trace`     — deterministic equal spacing (the old serve.py
                        ``--arrival-ms`` behavior, kept as the
                        reproducible mode tests drive);
  * `materialize`     — binds rows/payloads to a trace deterministically:
                        deletes draw DISJOINT rows from a seeded
                        permutation of the live set, adds carry seeded
                        resampled payloads — so the same (trace_seed,
                        rows_seed) pair replays bitwise-identically no
                        matter how the scheduler batches it;
  * `LoadGenerator`   — drives a trace at a `ServingScheduler` open-loop
                        (wall-clock sleeps to each arrival) or
                        closed-loop (parity tests), counting backpressure
                        rejections instead of dying on them.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.queue import RetryAfter
from repro.serve.scheduler import ServeTicket, ServingScheduler


@dataclass
class TraceEvent:
    """One arrival: offset `t` seconds from trace start, fully typed; rows
    and add payloads are bound later by `materialize` so arrival shape and
    row identity replay independently."""

    t: float
    op: str
    tenant: str
    sla_class: str
    n_rows: int = 1
    rows: Optional[List[int]] = None
    data: Optional[Dict[str, np.ndarray]] = None


def _mix_names(mix) -> Tuple[List[str], np.ndarray]:
    """Normalize a mix ({name: weight} or [names]) to (names, probs)."""
    if isinstance(mix, dict):
        names = sorted(mix)
        w = np.asarray([float(mix[k]) for k in names], dtype=np.float64)
    else:
        names = list(mix)
        w = np.ones(len(names), dtype=np.float64)
    return names, w / w.sum()


def _assign(rng: np.random.Generator, times: np.ndarray, tenants,
            classes, add_frac: float) -> List[TraceEvent]:
    t_names, t_p = _mix_names(tenants)
    c_names, c_p = _mix_names(classes)
    events = []
    for t in times:
        op = "add" if rng.random() < add_frac else "delete"
        events.append(TraceEvent(
            t=float(t), op=op,
            tenant=t_names[int(rng.choice(len(t_names), p=t_p))],
            sla_class=c_names[int(rng.choice(len(c_names), p=c_p))]))
    return events


def poisson_trace(rate: float, n_events: int, seed: int,
                  tenants=("default",), classes=("interactive",),
                  add_frac: float = 0.0) -> List[TraceEvent]:
    """Open-loop Poisson arrivals at `rate` requests/s (exponential
    inter-arrival gaps), deterministic per seed."""
    assert rate > 0 and n_events > 0
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xA221]))
    gaps = rng.exponential(1.0 / rate, size=n_events)
    return _assign(rng, np.cumsum(gaps), tenants, classes, add_frac)


def diurnal_trace(base_rate: float, peak_rate: float, period_s: float,
                  n_events: int, seed: int,
                  tenants=("default",), classes=("interactive",),
                  add_frac: float = 0.0) -> List[TraceEvent]:
    """Non-homogeneous Poisson by thinning: the instantaneous rate swings
    sinusoidally between base and peak over `period_s` (a compressed
    day), so the scheduler sees both idle valleys and overload crests."""
    assert peak_rate >= base_rate > 0
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xD10]))
    times, t = [], 0.0
    while len(times) < n_events:
        t += rng.exponential(1.0 / peak_rate)
        rate_t = base_rate + (peak_rate - base_rate) * 0.5 * (
            1.0 + np.sin(2.0 * np.pi * t / period_s))
        if rng.random() < rate_t / peak_rate:
            times.append(t)
    return _assign(rng, np.asarray(times), tenants, classes, add_frac)


def fixed_trace(interval_s: float, n_events: int, seed: int = 0,
                tenants=("default",), classes=("interactive",),
                add_frac: float = 0.0) -> List[TraceEvent]:
    """Deterministic fixed-interval arrivals (the legacy ``--arrival-ms``
    load shape).  Ops/tenants/classes still draw from the seeded rng so
    mixes work, but arrival TIMES carry no randomness."""
    assert interval_s > 0 and n_events > 0
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xF18ED]))
    times = interval_s * np.arange(1, n_events + 1)
    return _assign(rng, times, tenants, classes, add_frac)


def materialize(events: Sequence[TraceEvent], dataset, seed: int,
                base_n: Optional[int] = None) -> List[TraceEvent]:
    """Bind rows/payloads deterministically: delete events consume
    DISJOINT rows from a seeded permutation of the currently-live original
    rows (so no batching order can conflict), add events get payloads
    resampled (seeded) from the original rows.  Returns the same event
    objects, filled in."""
    base_n = int(base_n if base_n is not None else dataset.n)
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0x805]))
    live = np.flatnonzero(~np.asarray(dataset.removed[:base_n], dtype=bool))
    perm = rng.permutation(live)
    cursor = 0
    for ev in events:
        if ev.rows is not None or ev.data is not None:
            continue
        if ev.op == "delete":
            if cursor + ev.n_rows > perm.size:
                raise ValueError(
                    f"trace deletes {cursor + ev.n_rows} rows but only "
                    f"{perm.size} live rows exist")
            ev.rows = [int(r) for r in perm[cursor:cursor + ev.n_rows]]
            cursor += ev.n_rows
        else:
            src = rng.integers(0, base_n, size=ev.n_rows)
            ev.data = {k: np.asarray(v)[src]
                       for k, v in dataset.columns.items()}
    return events


@dataclass
class LoadResult:
    """What a generator run produced: tickets in submission order plus
    backpressure accounting (a rejected arrival is dropped and counted —
    open-loop clients retry on their own clock, not ours)."""

    tickets: List[ServeTicket] = field(default_factory=list)
    events: List[TraceEvent] = field(default_factory=list)
    rejected: int = 0
    retry_after_s: List[float] = field(default_factory=list)
    wall_s: float = 0.0

    @property
    def served(self) -> int:
        return sum(1 for t in self.tickets if t.done and t.error is None)


class LoadGenerator:
    """Drives a materialized trace at a scheduler."""

    def __init__(self, scheduler: ServingScheduler):
        self.scheduler = scheduler

    def _submit(self, ev: TraceEvent, out: LoadResult) -> None:
        try:
            t = self.scheduler.submit(op=ev.op, rows=ev.rows, data=ev.data,
                                      tenant=ev.tenant,
                                      sla_class=ev.sla_class)
            out.tickets.append(t)
            out.events.append(ev)
        except RetryAfter as e:
            out.rejected += 1
            out.retry_after_s.append(e.retry_after_s)

    def open_loop(self, events: Sequence[TraceEvent],
                  time_scale: float = 1.0) -> LoadResult:
        """Submit each event at its arrival time (wall-clock), regardless
        of service progress — the queue, not the caller, absorbs overload.
        `time_scale` stretches the trace (2.0 = half the offered load)."""
        out = LoadResult()
        t0 = time.perf_counter()
        for ev in events:
            delay = ev.t * time_scale - (time.perf_counter() - t0)
            if delay > 0:
                time.sleep(delay)
            self._submit(ev, out)
        out.wall_s = time.perf_counter() - t0
        return out

    def closed_loop(self, events: Sequence[TraceEvent],
                    timeout_s: float = 60.0) -> LoadResult:
        """Submit-wait-repeat (arrival times ignored): the deterministic
        mode parity and snapshot tests replay, since batches degenerate
        to submission order."""
        out = LoadResult()
        t0 = time.perf_counter()
        for ev in events:
            self._submit(ev, out)
            if out.tickets:
                out.tickets[-1].wait(timeout=timeout_s)
        out.wall_s = time.perf_counter() - t0
        return out
