"""Shared neural building blocks (pure functions over param dicts).

Conventions:
  * params are nested dicts of jnp arrays; `init_*` builds them, `*_apply`
    consumes them;
  * activations default to bf16 on accelerators (caller passes dtype);
    reductions (softmax, norms, losses) always accumulate in fp32;
  * attention uses a blockwise online-softmax formulation (scan over KV
    blocks) so peak memory is O(S * block) rather than O(S^2) — the XLA
    analogue of the Pallas flash kernel in `repro.kernels.flash_attention`.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32):
    scale = 1.0 / np.sqrt(d_in)
    return (scale * jax.random.normal(key, (d_in, d_out))).astype(dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------


def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


def layernorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mean) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------


def rope_freqs(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, D) with D even; positions: (..., S) int32."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)  # (d/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, d/2)
    cos = jnp.cos(ang)[..., :, None, :]  # (..., S, 1, d/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Blockwise (flash-style) attention — the XLA fallback path; the Pallas
# kernel in repro.kernels.flash_attention implements the same contraction.
# --------------------------------------------------------------------------


def _pad_to(x, size, axis):
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def blockwise_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_k: int = 512,
    q_offset: int = 0,
) -> jax.Array:
    """Online-softmax attention, scanning over KV blocks.

    q: (B, Sq, H, D); k, v: (B, Sk, Hkv, D); H = Hkv * G.
    `window > 0` restricts attention to the last `window` positions
    (sliding-window / hybrid long-context mode).  `q_offset` is the absolute
    position of q[0] (prefill continuation / decode).
    """
    B, Sq, H, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = H // Hkv
    scale = 1.0 / np.sqrt(D)

    blk = min(block_k, Sk)
    Skp = ((Sk + blk - 1) // blk) * blk
    n_blocks = Skp // blk
    k = _pad_to(k, Skp, 1)
    v = _pad_to(v, Skp, 1)

    qg = q.reshape(B, Sq, Hkv, G, D)
    q_pos = q_offset + jnp.arange(Sq)

    # scan carry: running max m, normalizer l, accumulator acc (fp32)
    m0 = jnp.full((B, Sq, Hkv, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    acc0 = jnp.zeros((B, Sq, Hkv, G, D), jnp.float32)

    kb = k.reshape(B, n_blocks, blk, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n_blocks, blk, Hkv, D).transpose(1, 0, 2, 3, 4)

    def body(carry, inp):
        m, l, acc, blk_idx = carry
        kblk, vblk = inp  # (B, blk, Hkv, D)
        # inputs stay in their storage dtype (bf16 on TPU); accumulation is
        # fp32 via preferred_element_type — MXU-native, and it keeps the
        # f32 upcasts (2x HBM + 2x collective bytes) out of the graph.
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, kblk,
                       preferred_element_type=jnp.float32) * scale
        k_pos = blk_idx * blk + jnp.arange(blk)
        valid = (k_pos < Sk)[None, None, :]
        if causal:
            valid = jnp.logical_and(valid, k_pos[None, None, :] <= q_pos[None, :, None])
        if window > 0:
            valid = jnp.logical_and(
                valid, k_pos[None, None, :] > q_pos[None, :, None] - window
            )
        s = jnp.where(valid[:, :, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new, blk_idx + 1), None

    from repro.models.scan_config import scan_unroll
    (m, l, acc, _), _ = jax.lax.scan(
        body, (m0, l0, acc0, jnp.int32(0)), (kb, vb), unroll=scan_unroll()
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, H, D).astype(q.dtype)


# Cache of custom_vjp-wrapped flash entry points, keyed by the static
# (causal, interpret) pair so each traces once per configuration.
_FLASH_VJP_CACHE: Dict[Tuple[bool, bool], object] = {}


def _flash_attention_ref_grad(q, k, v, *, causal: bool, interpret: bool):
    """Pallas flash forward with the blockwise reference as its backward.

    The flash kernel is forward-only, but replay differentiates every
    attention call, so the kernel is wrapped in a ``jax.custom_vjp`` whose
    backward is the VJP of `blockwise_attention` — the XLA oracle the
    kernel is tested against.  Forward activations come off the kernel
    (fused, O(S) memory); gradients come off the reference program, which
    keeps the pair consistent to the kernel-vs-ref tolerance.
    """
    key = (causal, interpret)
    fn = _FLASH_VJP_CACHE.get(key)
    if fn is None:
        from repro.kernels.flash_attention.ops import attention as _flash

        @jax.custom_vjp
        def fn(q, k, v):
            return _flash(q, k, v, causal=causal, interpret=interpret)

        def fwd(q, k, v):
            return fn(q, k, v), (q, k, v)

        def bwd(res, g):
            q, k, v = res
            _, vjp = jax.vjp(
                lambda a, b, c: blockwise_attention(a, b, c, causal=causal),
                q, k, v)
            return vjp(g)

        fn.defvjp(fwd, bwd)
        _FLASH_VJP_CACHE[key] = fn
    return fn(q, k, v)


def full_attention(q, k, v, *, causal: bool = True, window: int = 0):
    """Route the full-sequence attention contraction.

    Honours `models.attention_config`: flash handles the causal,
    non-windowed case (what LM training/replay forwards use); anything
    else falls back to the blockwise reference.  Flash lowers natively on
    TPU and runs the same kernel under the Pallas interpreter elsewhere,
    so CPU CI exercises the kernel program itself.
    """
    from repro.models.attention_config import attention_impl
    impl = attention_impl()
    if impl != "blockwise" and causal and window == 0:
        interpret = (impl == "flash_interpret"
                     or jax.default_backend() != "tpu")
        return _flash_attention_ref_grad(q, k, v, causal=causal,
                                         interpret=interpret)
    return blockwise_attention(q, k, v, causal=causal, window=window)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len,
    *,
    window: int = 0,
) -> jax.Array:
    """Single-token attention against a fixed-size cache.

    q: (B, H, D); caches: (B, S, Hkv, D); cache_len: () int32 — number of
    valid positions (the new token's k/v must already be written).
    """
    B, H, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = H // Hkv
    scale = 1.0 / np.sqrt(D)
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    pos = jnp.arange(S)
    valid = pos < cache_len
    if window > 0:
        valid = jnp.logical_and(valid, pos > cache_len - 1 - window)
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)


# --------------------------------------------------------------------------
# GQA attention block
# --------------------------------------------------------------------------


def gqa_init(key, d_model: int, n_heads: int, n_kv: int, d_head: int,
             qk_norm: bool = False):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * d_head),
        "wk": dense_init(ks[1], d_model, n_kv * d_head),
        "wv": dense_init(ks[2], d_model, n_kv * d_head),
        "wo": dense_init(ks[3], n_heads * d_head, d_model),
    }
    if qk_norm:
        p["q_norm"] = rmsnorm_init(d_head)
        p["k_norm"] = rmsnorm_init(d_head)
    return p


def gqa_apply(
    params,
    x,
    *,
    n_heads: int,
    n_kv: int,
    d_head: int,
    rope_theta: float,
    causal: bool = True,
    window: int = 0,
    qk_norm: bool = False,
    positions: Optional[jax.Array] = None,
):
    B, S, _ = x.shape
    q = (x @ params["wq"]).reshape(B, S, n_heads, d_head)
    k = (x @ params["wk"]).reshape(B, S, n_kv, d_head)
    v = (x @ params["wv"]).reshape(B, S, n_kv, d_head)
    if qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    if positions is None:
        positions = jnp.arange(S)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    o = full_attention(q, k, v, causal=causal, window=window)
    return o.reshape(B, S, n_heads * d_head) @ params["wo"]


def gqa_decode(
    params,
    x,  # (B, 1, d_model)
    cache: Dict[str, jax.Array],
    *,
    n_heads: int,
    n_kv: int,
    d_head: int,
    rope_theta: float,
    window: int = 0,
    qk_norm: bool = False,
):
    """One-token decode; cache = {k: (B,S,Hkv,D), v: ..., len: ()}.

    When `window > 0` and the cache was allocated at `window` slots, the
    cache is a ring buffer: writes go to ``len % window`` and validity is
    "all slots written so far" — attention over a sliding window does not
    need positional order of the slots (RoPE is already baked into k).
    """
    B = x.shape[0]
    pos = cache["len"]
    cache_size = cache["k"].shape[1]
    ring = window > 0 and cache_size <= window
    q = (x[:, 0] @ params["wq"]).reshape(B, n_heads, d_head)
    k = (x[:, 0] @ params["wk"]).reshape(B, n_kv, d_head)
    v = (x[:, 0] @ params["wv"]).reshape(B, n_kv, d_head)
    if qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    posv = jnp.full((B, 1), pos, dtype=jnp.int32)
    q = apply_rope(q[:, None], posv, rope_theta)[:, 0]
    k = apply_rope(k[:, None], posv, rope_theta)[:, 0]
    slot = (pos % cache_size) if ring else pos
    k_cache = jax.lax.dynamic_update_index_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, 1)
    v_cache = jax.lax.dynamic_update_index_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, 1)
    o = decode_attention(q, k_cache, v_cache, pos + 1, window=0 if ring else window)
    out = o.reshape(B, 1, n_heads * d_head) @ params["wo"]
    new_cache = {"k": k_cache, "v": v_cache, "len": pos + 1}
    return out, new_cache


def gqa_cache_spec(batch: int, seq: int, n_kv: int, d_head: int, dtype=jnp.bfloat16):
    return {
        "k": jax.ShapeDtypeStruct((batch, seq, n_kv, d_head), dtype),
        "v": jax.ShapeDtypeStruct((batch, seq, n_kv, d_head), dtype),
        "len": jax.ShapeDtypeStruct((), jnp.int32),
    }


def gqa_cache_init(batch: int, seq: int, n_kv: int, d_head: int, dtype=jnp.bfloat16):
    return {
        "k": jnp.zeros((batch, seq, n_kv, d_head), dtype),
        "v": jnp.zeros((batch, seq, n_kv, d_head), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------


def mlp_init(key, d_model: int, d_ff: int, kind: str):
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "w_gate": dense_init(ks[0], d_model, d_ff),
            "w_up": dense_init(ks[1], d_model, d_ff),
            "w_down": dense_init(ks[2], d_ff, d_model),
        }
    if kind in ("relu_sq", "gelu"):
        return {
            "w_up": dense_init(ks[0], d_model, d_ff),
            "w_down": dense_init(ks[1], d_ff, d_model),
        }
    raise ValueError(kind)


def mlp_apply(params, x, kind: str):
    if kind == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    elif kind == "relu_sq":
        h = jnp.square(jax.nn.relu(x @ params["w_up"]))
    elif kind == "gelu":
        h = jax.nn.gelu(x @ params["w_up"], approximate=True)
    else:
        raise ValueError(kind)
    return h @ params["w_down"]
