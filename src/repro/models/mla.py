"""Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3 style).

Train/prefill uses the expanded form; decode uses the *absorbed* form — the
per-head up-projections W_uk / W_uv are folded into the query / output so the
KV cache stores only the latent ``c_kv`` (kv_lora_rank) plus the shared
RoPE key (qk_rope_head_dim) per position.  That cache is 1-2 orders of
magnitude smaller than a GQA cache and is the reason MLA exists.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MLAConfig
from repro.models.layers import (
    apply_rope,
    blockwise_attention,
    dense_init,
    rmsnorm,
    rmsnorm_init,
)


def mla_init(key, d_model: int, n_heads: int, cfg: MLAConfig):
    ks = jax.random.split(key, 8)
    qk_head = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    return {
        "w_dq": dense_init(ks[0], d_model, cfg.q_lora_rank),
        "q_norm": rmsnorm_init(cfg.q_lora_rank),
        "w_uq": dense_init(ks[1], cfg.q_lora_rank, n_heads * qk_head),
        "w_dkv": dense_init(ks[2], d_model, cfg.kv_lora_rank + cfg.qk_rope_head_dim),
        "kv_norm": rmsnorm_init(cfg.kv_lora_rank),
        "w_uk": dense_init(ks[3], cfg.kv_lora_rank, n_heads * cfg.qk_nope_head_dim),
        "w_uv": dense_init(ks[4], cfg.kv_lora_rank, n_heads * cfg.v_head_dim),
        "wo": dense_init(ks[5], n_heads * cfg.v_head_dim, d_model),
    }


def _project_q(params, x, n_heads: int, cfg: MLAConfig, positions, rope_theta):
    B, S, _ = x.shape
    qk_head = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    cq = rmsnorm(params["q_norm"], x @ params["w_dq"])
    q = (cq @ params["w_uq"]).reshape(B, S, n_heads, qk_head)
    q_nope = q[..., : cfg.qk_nope_head_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_head_dim:], positions, rope_theta)
    return q_nope, q_rope


def _project_kv_latent(params, x, cfg: MLAConfig, positions, rope_theta):
    ckv_full = x @ params["w_dkv"]
    c_kv = rmsnorm(params["kv_norm"], ckv_full[..., : cfg.kv_lora_rank])
    k_rope = ckv_full[..., cfg.kv_lora_rank:]  # (B, S, rope_dim), shared head
    k_rope = apply_rope(k_rope[:, :, None, :], positions, rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_apply(params, x, *, n_heads: int, cfg: MLAConfig, rope_theta: float,
              causal: bool = True, window: int = 0):
    """Expanded-form MLA for train/prefill."""
    B, S, _ = x.shape
    positions = jnp.arange(S)
    q_nope, q_rope = _project_q(params, x, n_heads, cfg, positions, rope_theta)
    c_kv, k_rope = _project_kv_latent(params, x, cfg, positions, rope_theta)
    k_nope = (c_kv @ params["w_uk"]).reshape(B, S, n_heads, cfg.qk_nope_head_dim)
    v = (c_kv @ params["w_uv"]).reshape(B, S, n_heads, cfg.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, n_heads, cfg.qk_rope_head_dim))],
        axis=-1,
    )
    # pad v up to qk head dim so we can reuse the shared attention primitive
    qk_head = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_head - cfg.v_head_dim)))
    o = blockwise_attention(q, k, v_pad, causal=causal, window=window)
    o = o[..., : cfg.v_head_dim].reshape(B, S, n_heads * cfg.v_head_dim)
    return o @ params["wo"]


# -- decode (absorbed form, latent KV cache) --------------------------------


def mla_cache_init(batch: int, seq: int, cfg: MLAConfig, dtype=jnp.bfloat16):
    return {
        "c_kv": jnp.zeros((batch, seq, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, seq, cfg.qk_rope_head_dim), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def mla_cache_spec(batch: int, seq: int, cfg: MLAConfig, dtype=jnp.bfloat16):
    return {
        "c_kv": jax.ShapeDtypeStruct((batch, seq, cfg.kv_lora_rank), dtype),
        "k_rope": jax.ShapeDtypeStruct((batch, seq, cfg.qk_rope_head_dim), dtype),
        "len": jax.ShapeDtypeStruct((), jnp.int32),
    }


def mla_decode(params, x, cache: Dict[str, jax.Array], *, n_heads: int,
               cfg: MLAConfig, rope_theta: float):
    """Absorbed-form single-token decode.

    score_h(t) = q_nope_h^T W_uk_h c_t + q_rope_h^T k_rope_t
               = (W_uk_h^T q_nope_h)^T c_t + ...   (absorb W_uk into q)
    out_h      = W_uv_h (sum_t p_t c_t)            (absorb W_uv into output)
    """
    B = x.shape[0]
    pos = cache["len"]
    posv = jnp.full((B, 1), pos, dtype=jnp.int32)
    q_nope, q_rope = _project_q(params, x, n_heads, cfg, posv, rope_theta)
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]  # (B, H, dims)
    c_new, kr_new = _project_kv_latent(params, x, cfg, posv, rope_theta)
    c_cache = jax.lax.dynamic_update_index_in_dim(
        cache["c_kv"], c_new[:, 0].astype(cache["c_kv"].dtype), pos, 1)
    kr_cache = jax.lax.dynamic_update_index_in_dim(
        cache["k_rope"], kr_new[:, 0].astype(cache["k_rope"].dtype), pos, 1)

    w_uk = params["w_uk"].reshape(cfg.kv_lora_rank, n_heads, cfg.qk_nope_head_dim)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))  # absorbed query
    scale = 1.0 / np.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    s = (
        jnp.einsum("bhr,bsr->bhs", q_lat, c_cache.astype(jnp.float32))
        + jnp.einsum("bhd,bsd->bhs", q_rope.astype(jnp.float32),
                     kr_cache.astype(jnp.float32))
    ) * scale
    valid = jnp.arange(c_cache.shape[1]) <= pos
    s = jnp.where(valid[None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", p, c_cache.astype(jnp.float32))
    w_uv = params["w_uv"].reshape(cfg.kv_lora_rank, n_heads, cfg.v_head_dim)
    o = jnp.einsum("bhr,rhd->bhd", o_lat, w_uv.astype(jnp.float32))
    out = o.reshape(B, 1 * n_heads * cfg.v_head_dim)[:, None, :] @ params["wo"]
    new_cache = {"c_kv": c_cache, "k_rope": kr_cache, "len": pos + 1}
    return out.astype(x.dtype), new_cache
