"""Mamba2 / SSD block (Dao & Gu 2024, arXiv:2405.21060) — TPU-adapted.

Training/prefill uses the chunked SSD algorithm: within each chunk of Q
positions the recurrence is evaluated as a masked attention-like contraction
(dense MXU work), and chunk boundary states are combined with a short
`lax.scan` over L/Q chunks.  This keeps peak memory at O(L*Q + (L/Q)*N*P)
instead of the O(L*N*P) of a naive associative scan, and maps the inner
contractions onto 128-aligned matmuls.

Decode carries (conv_state, ssm_state) — O(1) in sequence length, which is
why the `long_500k` cell runs for SSM/hybrid archs only.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SSMConfig
from repro.models.layers import dense_init, rmsnorm, rmsnorm_init


def _dims(d_model: int, cfg: SSMConfig):
    d_inner = cfg.expand * d_model
    n_heads = d_inner // cfg.head_dim
    conv_dim = d_inner + 2 * cfg.n_groups * cfg.d_state
    return d_inner, n_heads, conv_dim


def mamba2_init(key, d_model: int, cfg: SSMConfig):
    d_inner, n_heads, conv_dim = _dims(d_model, cfg)
    ks = jax.random.split(key, 6)
    return {
        # order: [z (gate), x, B, C, dt]
        "w_in": dense_init(ks[0], d_model, 2 * d_inner + 2 * cfg.n_groups * cfg.d_state
                           + n_heads),
        "conv_w": 0.1 * jax.random.normal(ks[1], (cfg.d_conv, conv_dim), jnp.float32),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)),  # A = -exp(a_log)
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "out_norm": rmsnorm_init(d_inner),
        "w_out": dense_init(ks[2], d_inner, d_model),
    }


def _split_in(params, x, d_model: int, cfg: SSMConfig):
    d_inner, n_heads, _ = _dims(d_model, cfg)
    gn = cfg.n_groups * cfg.d_state
    zxbcdt = x @ params["w_in"]
    z = zxbcdt[..., :d_inner]
    xin = zxbcdt[..., d_inner:2 * d_inner]
    b_in = zxbcdt[..., 2 * d_inner:2 * d_inner + gn]
    c_in = zxbcdt[..., 2 * d_inner + gn:2 * d_inner + 2 * gn]
    dt = zxbcdt[..., 2 * d_inner + 2 * gn:]
    return z, xin, b_in, c_in, dt


def _causal_conv(conv_w, conv_b, u):
    """Depthwise causal conv over (B, L, C) with kernel (K, C)."""
    K = conv_w.shape[0]
    u_pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(u_pad[:, i:i + u.shape[1], :] * conv_w[i] for i in range(K))
    return jax.nn.silu(out + conv_b)


def ssd_chunked(xh, dt, a_log, b_in, c_in, cfg: SSMConfig,
                init_state=None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    xh: (B, L, H, P); dt: (B, L, H) (post-softplus); b_in/c_in: (B, L, G, N).
    Returns (y: (B, L, H, P), final_state: (B, H, P, N)).
    """
    Bsz, L, H, P = xh.shape
    G, N = b_in.shape[-2], b_in.shape[-1]
    Q = min(cfg.chunk, L)
    assert L % Q == 0, f"seq len {L} must divide by chunk {Q}"
    nc = L // Q
    hg = H // G  # heads per group

    a = (-jnp.exp(a_log))[None, None, :] * dt  # (B, L, H) log-decay, <= 0
    xbar = xh * dt[..., None]  # dt-scaled input

    # reshape into chunks
    ac = a.reshape(Bsz, nc, Q, H)
    xc = xbar.reshape(Bsz, nc, Q, H, P)
    bc = b_in.reshape(Bsz, nc, Q, G, N)
    cc = c_in.reshape(Bsz, nc, Q, G, N)

    cum = jnp.cumsum(ac, axis=2)  # (B, nc, Q, H) within-chunk cumulative decay
    total = cum[:, :, -1]  # (B, nc, H)

    # ---- intra-chunk (dense, attention-like) -------------------------------
    # decay matrix Lmask[i, j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    ii = jnp.arange(Q)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    # mask BEFORE exp: exp of masked (positive) entries overflows and the
    # inf * 0 in the backward pass would poison gradients with NaNs.
    lmask = jnp.exp(jnp.where(causal, diff, -jnp.inf))
    # scores over groups: (B,nc,Q,Q,G) = C_i . B_j
    scores = jnp.einsum("bnqgs,bnkgs->bnqkg", cc.astype(jnp.float32),
                        bc.astype(jnp.float32))
    # expand to heads: head h belongs to group h // hg
    scores = jnp.repeat(scores, hg, axis=-1)  # (B,nc,Q,Q,H)
    att = scores * lmask
    y_diag = jnp.einsum("bnqkh,bnkhp->bnqhp", att, xc.astype(jnp.float32))

    # ---- chunk states -------------------------------------------------------
    # S_n = sum_j exp(total - cum_j) * B_j (outer) xbar_j  -> (B,nc,H,N,P)
    wts = jnp.exp(total[:, :, None, :] - cum)  # (B,nc,Q,H)
    bh = jnp.repeat(bc, hg, axis=-2) if G > 1 else jnp.broadcast_to(
        bc, (Bsz, nc, Q, G, N))
    if G == 1:
        b_heads = jnp.broadcast_to(bc, (Bsz, nc, Q, 1, N))
        b_heads = jnp.repeat(b_heads, H, axis=-2)
    else:
        b_heads = jnp.repeat(bc, hg, axis=-2)
    states = jnp.einsum("bcqh,bcqhs,bcqhp->bchsp",
                        wts, b_heads.astype(jnp.float32), xc.astype(jnp.float32))
    del bh

    # ---- inter-chunk recurrence over nc chunks ------------------------------
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, N, P), jnp.float32)

    def body(s_prev, inp):
        s_chunk, tot = inp  # (B,H,N,P), (B,H)
        s_new = s_prev * jnp.exp(tot)[:, :, None, None] + s_chunk
        return s_new, s_prev

    from repro.models.scan_config import scan_unroll
    (final_state, prev_states) = jax.lax.scan(
        body,
        init_state,
        (states.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
        unroll=scan_unroll(),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,nc,H,N,P)

    # ---- off-diagonal contribution ------------------------------------------
    c_heads = (jnp.broadcast_to(cc, (Bsz, nc, Q, 1, N)).repeat(H, axis=-2)
               if G == 1 else jnp.repeat(cc, hg, axis=-2))
    y_off = jnp.einsum("bcqhs,bchsp->bcqhp", c_heads.astype(jnp.float32),
                       prev_states) * jnp.exp(cum)[..., None]

    y = (y_diag + y_off).reshape(Bsz, L, H, P)
    # transpose state to (B,H,P,N) for the decode convention
    return y.astype(xh.dtype), final_state.transpose(0, 1, 3, 2)


def mamba2_apply(params, x, d_model: int, cfg: SSMConfig):
    """Full-sequence forward. x: (B, L, d_model)."""
    d_inner, n_heads, conv_dim = _dims(d_model, cfg)
    Bsz, L, _ = x.shape
    z, xin, b_in, c_in, dt = _split_in(params, x, d_model, cfg)
    u = jnp.concatenate([xin, b_in, c_in], axis=-1)
    u = _causal_conv(params["conv_w"], params["conv_b"], u)
    xin = u[..., :d_inner]
    b_in = u[..., d_inner:d_inner + cfg.n_groups * cfg.d_state]
    c_in = u[..., d_inner + cfg.n_groups * cfg.d_state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,L,H)
    xh = xin.reshape(Bsz, L, n_heads, cfg.head_dim)
    bg = b_in.reshape(Bsz, L, cfg.n_groups, cfg.d_state)
    cg = c_in.reshape(Bsz, L, cfg.n_groups, cfg.d_state)
    y, _ = ssd_chunked(xh, dt, params["a_log"], bg, cg, cfg)
    y = y + params["d_skip"][None, None, :, None] * xh
    y = y.reshape(Bsz, L, d_inner)
    y = rmsnorm(params["out_norm"], y * jax.nn.silu(z))
    return y @ params["w_out"]


# -- decode ------------------------------------------------------------------


def mamba2_cache_init(batch: int, d_model: int, cfg: SSMConfig, dtype=jnp.float32):
    d_inner, n_heads, conv_dim = _dims(d_model, cfg)
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, n_heads, cfg.head_dim, cfg.d_state), jnp.float32),
    }


def mamba2_cache_spec(batch: int, d_model: int, cfg: SSMConfig, dtype=jnp.float32):
    d_inner, n_heads, conv_dim = _dims(d_model, cfg)
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.d_conv - 1, conv_dim), dtype),
        "ssm": jax.ShapeDtypeStruct((batch, n_heads, cfg.head_dim, cfg.d_state),
                                    jnp.float32),
    }


def mamba2_decode(params, x, cache: Dict[str, jax.Array], d_model: int,
                  cfg: SSMConfig):
    """Single-token step. x: (B, 1, d_model)."""
    d_inner, n_heads, conv_dim = _dims(d_model, cfg)
    Bsz = x.shape[0]
    z, xin, b_in, c_in, dt = _split_in(params, x[:, 0:1], d_model, cfg)
    u_new = jnp.concatenate([xin, b_in, c_in], axis=-1)[:, 0]  # (B, conv_dim)
    window = jnp.concatenate([cache["conv"], u_new[:, None, :]], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          params["conv_w"]) + params["conv_b"]
    u = jax.nn.silu(conv_out)
    new_conv = window[:, 1:, :].astype(cache["conv"].dtype)

    xin = u[..., :d_inner]
    gn = cfg.n_groups * cfg.d_state
    b_t = u[..., d_inner:d_inner + gn].reshape(Bsz, cfg.n_groups, cfg.d_state)
    c_t = u[..., d_inner + gn:].reshape(Bsz, cfg.n_groups, cfg.d_state)
    dt_t = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    xh = xin.reshape(Bsz, n_heads, cfg.head_dim)

    hg = n_heads // cfg.n_groups
    b_heads = jnp.repeat(b_t, hg, axis=1)  # (B, H, N)
    c_heads = jnp.repeat(c_t, hg, axis=1)
    decay = jnp.exp(-jnp.exp(params["a_log"])[None, :] * dt_t)  # (B, H)
    # state update: s = s * decay + dt * x (outer) B
    upd = (dt_t[..., None] * xh.astype(jnp.float32))[..., None] * \
        b_heads[:, :, None, :].astype(jnp.float32)  # (B,H,P,N)
    new_ssm = cache["ssm"] * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_ssm, c_heads.astype(jnp.float32))
    y = y + params["d_skip"][None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(Bsz, d_inner).astype(x.dtype)
    y = rmsnorm(params["out_norm"], y * jax.nn.silu(z[:, 0]))
    out = (y @ params["w_out"])[:, None, :]
    return out, {"conv": new_conv, "ssm": new_ssm}
