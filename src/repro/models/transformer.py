"""Decoder-only LM assembly: heterogeneous block stacks, scan-over-layers.

Layer parameters are stacked along a leading `n_units` axis and the stack is
driven by `jax.lax.scan`, so HLO size (and compile time on the 512-device
dry-run) is independent of depth — the MaxText approach.  A "unit" is the
repeating block pattern: homogeneous models have a 1-block unit; zamba2 has
(5 x mamba2 + shared-attention); xLSTM has (mLSTM, sLSTM).  Shared blocks
(`attn_shared`) keep ONE parameter set (closure) but per-occurrence KV
caches (stacked, scanned).

Forward flavors:
  * `lm_loss`        — train: full sequence, chunked cross-entropy
  * `prefill`        — full sequence, returns (logits_last, caches)
  * `decode_step`    — one token against caches
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import mamba2 as m2
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import xlstm as xl
from repro.models.layers import (
    dense_init,
    gqa_apply,
    gqa_cache_init,
    gqa_cache_spec,
    gqa_decode,
    gqa_init,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
)


def layout_of(cfg: ModelConfig) -> Tuple[Tuple[str, ...], int]:
    """(unit, n_units)."""
    if cfg.layout_unit:
        unit = tuple(cfg.layout_unit)
        assert cfg.n_layers % len(unit) == 0, (cfg.n_layers, unit)
        return unit, cfg.n_layers // len(unit)
    return ("attn",), cfg.n_layers


# --------------------------------------------------------------------------
# Per-block init / apply / decode
# --------------------------------------------------------------------------


def _block_init(key, kind: str, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": rmsnorm_init(cfg.d_model)}
    if kind in ("attn", "attn_shared"):
        if cfg.attention == "mla":
            p["mixer"] = mla_mod.mla_init(ks[0], cfg.d_model, cfg.n_heads, cfg.mla)
        else:
            p["mixer"] = gqa_init(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                  cfg.head_dim, cfg.qk_norm)
        p["ln2"] = rmsnorm_init(cfg.d_model)
        if cfg.mlp == "moe":
            p["mlp"] = moe_mod.moe_init(ks[1], cfg.d_model, cfg.moe)
        elif cfg.mlp != "none":
            p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp)
    elif kind == "mamba2":
        p["mixer"] = m2.mamba2_init(ks[0], cfg.d_model, cfg.ssm)
    elif kind == "mlstm":
        p["mixer"] = xl.mlstm_init(ks[0], cfg.d_model, cfg.n_heads, cfg.xlstm)
    elif kind == "slstm":
        p["mixer"] = xl.slstm_init(ks[0], cfg.d_model, cfg.n_heads, cfg.xlstm)
    else:
        raise ValueError(kind)
    return p


def _block_apply(kind: str, p, x, cfg: ModelConfig, *, causal=True):
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind in ("attn", "attn_shared"):
        if cfg.attention == "mla":
            h = mla_mod.mla_apply(p["mixer"], h, n_heads=cfg.n_heads, cfg=cfg.mla,
                                  rope_theta=cfg.rope_theta, causal=causal,
                                  window=cfg.attn_window)
        else:
            h = gqa_apply(p["mixer"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                          d_head=cfg.head_dim, rope_theta=cfg.rope_theta,
                          causal=causal, window=cfg.attn_window,
                          qk_norm=cfg.qk_norm)
        x = x + h
        if cfg.mlp != "none":
            h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
            if cfg.mlp == "moe":
                out, aux = moe_mod.moe_apply(p["mlp"], h2, cfg.moe)
            else:
                out = mlp_apply(p["mlp"], h2, cfg.mlp)
            x = x + out
        return x, aux
    if kind == "mamba2":
        return x + m2.mamba2_apply(p["mixer"], h, cfg.d_model, cfg.ssm), aux
    if kind == "mlstm":
        return x + xl.mlstm_chunked(p["mixer"], h, cfg.n_heads), aux
    if kind == "slstm":
        return x + xl.slstm_apply(p["mixer"], h, cfg.n_heads), aux
    raise ValueError(kind)


def _block_cache_init(kind: str, cfg: ModelConfig, batch: int, seq: int, spec: bool):
    gq = gqa_cache_spec if spec else gqa_cache_init
    if kind in ("attn", "attn_shared"):
        if cfg.attention == "mla":
            f = mla_mod.mla_cache_spec if spec else mla_mod.mla_cache_init
            return f(batch, seq, cfg.mla)
        win = cfg.attn_window
        s = min(seq, win) if win else seq
        return gq(batch, s, cfg.n_kv_heads, cfg.head_dim)
    if kind == "mamba2":
        f = m2.mamba2_cache_spec if spec else m2.mamba2_cache_init
        return f(batch, cfg.d_model, cfg.ssm)
    if kind == "mlstm":
        f = xl.mlstm_cache_spec if spec else xl.mlstm_cache_init
        return f(batch, cfg.d_model, cfg.n_heads, cfg.xlstm)
    if kind == "slstm":
        f = xl.slstm_cache_spec if spec else xl.slstm_cache_init
        return f(batch, cfg.d_model, cfg.n_heads)
    raise ValueError(kind)


def _block_decode(kind: str, p, x, cache, cfg: ModelConfig):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind in ("attn", "attn_shared"):
        if cfg.attention == "mla":
            h, cache = mla_mod.mla_decode(p["mixer"], h, cache, n_heads=cfg.n_heads,
                                          cfg=cfg.mla, rope_theta=cfg.rope_theta)
        else:
            h, cache = gqa_decode(p["mixer"], h, cache, n_heads=cfg.n_heads,
                                  n_kv=cfg.n_kv_heads, d_head=cfg.head_dim,
                                  rope_theta=cfg.rope_theta, window=cfg.attn_window,
                                  qk_norm=cfg.qk_norm)
        x = x + h
        if cfg.mlp != "none":
            h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
            if cfg.mlp == "moe":
                out, _ = moe_mod.moe_apply(p["mlp"], h2, cfg.moe)
            else:
                out = mlp_apply(p["mlp"], h2, cfg.mlp)
            x = x + out
        return x, cache
    if kind == "mamba2":
        out, cache = m2.mamba2_decode(p["mixer"], h, cache, cfg.d_model, cfg.ssm)
        return x + out, cache
    if kind == "mlstm":
        out, cache = xl.mlstm_step(p["mixer"], h, cache, cfg.n_heads)
        return x + out, cache
    if kind == "slstm":
        out, cache = xl.slstm_step(p["mixer"], h, cache, cfg.n_heads)
        return x + out, cache
    raise ValueError(kind)


# --------------------------------------------------------------------------
# Model
# --------------------------------------------------------------------------


def init_params(cfg: ModelConfig, seed: int = 0):
    unit, n_units = layout_of(cfg)
    key = jax.random.PRNGKey(seed)
    k_embed, k_head, k_shared, *k_layers = jax.random.split(key, 3 + len(unit))
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab, cfg.d_model)) * 0.02
                  ).astype(jnp.float32),
        "final_norm": rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab)
    for pos, kind in enumerate(unit):
        if kind == "attn_shared":
            continue
        keys = jax.random.split(k_layers[pos], n_units)
        stacked = [
            _block_init(keys[u], kind, cfg) for u in range(n_units)
        ]
        params[f"u{pos}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *stacked)
    if "attn_shared" in unit:
        params["shared"] = _block_init(k_shared, "attn_shared", cfg)
    return params


def cast_params(params, dtype):
    """Cast float params to the compute dtype (fp32 master copies live in the
    optimizer state; norms/softmax/loss still accumulate in fp32 internally)."""
    return jax.tree.map(
        lambda x: x.astype(dtype) if x.dtype == jnp.float32 else x, params)


def _embed(params, batch, cfg: ModelConfig, dtype):
    if cfg.frontend == "frames":
        return batch["frames"].astype(dtype)  # precomputed stub embeddings
    return params["embed"][batch["tokens"]].astype(dtype)


def _lm_head(params, h, cfg: ModelConfig):
    # bf16 matmul with fp32 accumulation: casting w to f32 would make the
    # embedding/lm_head GRADIENT fp32 too — a 2x tax on the DP all-reduce of
    # the largest single tensor in the model (§Perf iteration 7).
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return jnp.matmul(h, w, preferred_element_type=jnp.float32)


def forward_hidden(params, x, cfg: ModelConfig, *, remat: bool = False,
                   act_pspec=None):
    """Run the block stack. x: (B, S, d) embedded input. Returns (h, aux).

    `act_pspec` (a PartitionSpec) pins the residual stream between blocks —
    sequence parallelism when set to P(dp, 'model', None): norms/elementwise
    run on sequence shards and the TP all-reduces become half-volume
    reduce-scatter / all-gather pairs (Korthikanti et al. 2022).
    """
    unit, n_units = layout_of(cfg)

    def unit_body(carry, unit_params):
        h, aux = carry
        if act_pspec is not None:
            h = jax.lax.with_sharding_constraint(h, act_pspec)
        for pos, kind in enumerate(unit):
            p = params["shared"] if kind == "attn_shared" else unit_params[f"u{pos}"]
            h, a = _block_apply(kind, p, h, cfg)
            aux = aux + a
        if act_pspec is not None:
            h = jax.lax.with_sharding_constraint(h, act_pspec)
        return (h, aux), None

    body = jax.checkpoint(unit_body) if remat else unit_body
    stacked = {f"u{pos}": params[f"u{pos}"]
               for pos, kind in enumerate(unit) if kind != "attn_shared"}
    from repro.models.scan_config import scan_unroll
    (h, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), stacked,
                               unroll=scan_unroll())
    return rmsnorm(params["final_norm"], h, cfg.norm_eps), aux


def lm_loss(params, batch, cfg: ModelConfig, *, dtype=jnp.bfloat16,
            remat: bool = True, loss_chunk: int = 512, act_pspec=None):
    """Next-token cross-entropy, chunked over the sequence so the (S, vocab)
    logits tensor never fully materializes."""
    params = cast_params(params, dtype)
    x = _embed(params, batch, cfg, dtype)
    h, aux = forward_hidden(params, x, cfg, remat=remat, act_pspec=act_pspec)
    if cfg.frontend == "frames":
        targets = batch["targets"]
    else:
        targets = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)))
    B, S, _ = h.shape
    C = min(loss_chunk, S)
    n_chunks = S // C if S % C == 0 else -(-S // C)
    Sp = n_chunks * C
    h = jnp.pad(h, ((0, 0), (0, Sp - S), (0, 0)))
    targets = jnp.pad(targets, ((0, 0), (0, Sp - S)))
    mask = jnp.pad(jnp.ones((B, S - 1), jnp.float32), ((0, 0), (0, Sp - S + 1)))
    hc = h.reshape(B, n_chunks, C, -1).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, n_chunks, C).transpose(1, 0, 2)
    mc = mask.reshape(B, n_chunks, C).transpose(1, 0, 2)

    def chunk_loss(carry, inp):
        hx, tx, mx = inp
        logits = _lm_head(params, hx, cfg)
        logz = jax.nn.logsumexp(logits, axis=-1)
        true = jnp.take_along_axis(logits, tx[..., None].astype(jnp.int32),
                                   axis=-1)[..., 0]
        return carry + jnp.sum((logz - true) * mx), None

    from repro.models.scan_config import scan_unroll
    total, _ = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32), (hc, tc, mc),
                            unroll=scan_unroll())
    loss = total / jnp.maximum(jnp.sum(mask), 1.0)
    if cfg.moe is not None:
        _, n_units = layout_of(cfg)
        loss = loss + cfg.moe.router_aux_weight * aux / n_units
    return loss


# -- serving -----------------------------------------------------------------


def init_caches(cfg: ModelConfig, batch: int, seq: int, spec: bool = False):
    """Stacked (n_units-leading) caches for every block in the unit."""
    unit, n_units = layout_of(cfg)
    caches = {}
    for pos, kind in enumerate(unit):
        one = _block_cache_init(kind, cfg, batch, seq, spec)
        if spec:
            caches[f"u{pos}"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n_units,) + s.shape, s.dtype), one)
        else:
            caches[f"u{pos}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_units,) + a.shape).copy(), one)
    return caches


def decode_step(params, batch, caches, cfg: ModelConfig, *, dtype=jnp.bfloat16):
    """One-token decode. batch: {"tokens": (B, 1)} (or {"frames"}). Returns
    (logits (B, vocab), new_caches)."""
    unit, n_units = layout_of(cfg)
    params = cast_params(params, dtype)
    x = _embed(params, batch, cfg, dtype)

    def unit_body(h, scanned):
        unit_params, unit_caches = scanned
        new_caches = {}
        for pos, kind in enumerate(unit):
            p = params["shared"] if kind == "attn_shared" else unit_params[f"u{pos}"]
            h, new_caches[f"u{pos}"] = _block_decode(kind, p, h, unit_caches[f"u{pos}"], cfg)
        return h, new_caches

    stacked = {f"u{pos}": params[f"u{pos}"]
               for pos, kind in enumerate(unit) if kind != "attn_shared"}
    h, new_caches = jax.lax.scan(unit_body, x, (stacked, caches))
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = _lm_head(params, h[:, 0], cfg)
    return logits, new_caches


def prefill(params, batch, cfg: ModelConfig, *, dtype=jnp.bfloat16):
    """Inference prefill: full-sequence forward, last-position logits.

    Forward-only (no backward residuals), so peak memory is one layer's
    activations + the scan carry — the roofline for `prefill_32k` measures
    exactly this pass."""
    params = cast_params(params, dtype)
    x = _embed(params, batch, cfg, dtype)
    h, _ = forward_hidden(params, x, cfg, remat=False)
    logits = _lm_head(params, h[:, -1], cfg)
    return logits
