"""Unified Model facade over the zoo: init / loss / decode / input specs.

Everything the launcher, dry-run, tests and benchmarks need, keyed by
`--arch <id>`.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, transformer


@dataclass
class Model:
    cfg: ModelConfig
    init: Callable[[int], Any]
    loss_fn: Callable[..., jax.Array]  # (params, batch) -> scalar
    decode_fn: Optional[Callable] = None  # (params, batch, caches) -> (logits, caches)
    prefill_fn: Optional[Callable] = None  # (params, batch) -> logits
    cache_specs: Optional[Callable] = None  # (batch, seq) -> pytree of SDS
    cache_init: Optional[Callable] = None

    def input_specs(self, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input of a cell."""
        cfg = self.cfg
        B = shape.global_batch
        if shape.is_decode:
            if cfg.family == "audio":
                return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
            return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        S = shape.seq_len
        if cfg.family == "audio":
            return {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            }
        if cfg.frontend == "frames":
            return {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16),
                "targets": jax.ShapeDtypeStruct((B, S), jnp.int32),
            }
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}

    def objective(self, *, remat: bool = False, loss_chunk: Optional[int] = None,
                  l2: float = 0.0, attn_impl: Optional[str] = None):
        """An engine `core.deltagrad.Objective` over this model's loss.

        Delegates to `Objective.from_model` (lazy import — models stay
        importable without the engine).  This is the model→engine bridge:
        ``build(cfg).objective()`` is everything unlearning needs.
        """
        from repro.core.deltagrad import Objective
        return Objective.from_model(self, remat=remat, loss_chunk=loss_chunk,
                                    l2=l2, attn_impl=attn_impl)

    def sample_batch(self, shape: ShapeConfig, seed: int = 0):
        """Concrete random inputs matching input_specs (smoke tests)."""
        rng = np.random.default_rng(seed)
        out = {}
        for k, s in self.input_specs(shape).items():
            if s.dtype == jnp.int32:
                out[k] = jnp.asarray(
                    rng.integers(0, max(self.cfg.vocab, 2), size=s.shape,
                                 dtype=np.int32))
            else:
                out[k] = jnp.asarray(rng.normal(size=s.shape), dtype=s.dtype)
        return out


def build(cfg: ModelConfig) -> Model:
    if cfg.family == "audio":
        return Model(
            cfg=cfg,
            init=lambda seed=0: encdec.init_params(cfg, seed),
            loss_fn=lambda p, b, **kw: encdec.lm_loss(p, b, cfg, **kw),
            decode_fn=lambda p, b, c, **kw: encdec.decode_step(p, b, c, cfg, **kw),
            prefill_fn=lambda p, b, **kw: encdec.prefill(p, b, cfg, **kw),
            cache_specs=lambda batch, seq, enc_len=1500: encdec.init_caches(
                cfg, batch, seq, enc_len, spec=True),
            cache_init=lambda batch, seq, enc_len=1500: encdec.init_caches(
                cfg, batch, seq, enc_len, spec=False),
        )
    return Model(
        cfg=cfg,
        init=lambda seed=0: transformer.init_params(cfg, seed),
        loss_fn=lambda p, b, **kw: transformer.lm_loss(p, b, cfg, **kw),
        decode_fn=lambda p, b, c, **kw: transformer.decode_step(p, b, c, cfg, **kw),
        prefill_fn=lambda p, b, **kw: transformer.prefill(p, b, cfg, **kw),
        cache_specs=lambda batch, seq: transformer.init_caches(cfg, batch, seq,
                                                               spec=True),
        cache_init=lambda batch, seq: transformer.init_caches(cfg, batch, seq,
                                                              spec=False),
    )


def count_params(cfg: ModelConfig) -> int:
    """Analytic parameter count (no allocation)."""
    model = build(cfg)
    shapes = jax.eval_shape(lambda: model.init(0))
    return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))


def active_param_count(cfg: ModelConfig) -> int:
    """MoE: parameters touched per token (routed top-k of E + shared + dense)."""
    total = count_params(cfg)
    if cfg.moe is None:
        return total
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    expert_p = 3 * cfg.d_model * cfg.moe.d_expert  # gate/up/down per expert
    unit, n_units = transformer.layout_of(cfg)
    n_moe_layers = sum(1 for kind in unit if kind in ("attn", "attn_shared"))
    n_moe_layers *= n_units
    inactive = n_moe_layers * (e - k) * expert_p
    return total - inactive
