"""Trace-time switch: fully unroll model scans.

Used ONLY by roofline validation (tests/test_roofline.py) — XLA's
cost_analysis counts while-loop bodies once, so the analytic FLOP model is
cross-checked against an unrolled lowering of reduced configs.  Production
lowering always keeps scans (compile time and HLO size are depth-independent).

The sLSTM time scan is exempt (trip count == sequence length).
"""

from contextlib import contextmanager

_UNROLL = False


def scan_unroll():
    """Value to pass as lax.scan(..., unroll=...)."""
    return True if _UNROLL else 1


@contextmanager
def unrolled_scans():
    global _UNROLL
    prev = _UNROLL
    _UNROLL = True
    try:
        yield
    finally:
        _UNROLL = prev
