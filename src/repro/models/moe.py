"""Shared + routed top-k Mixture-of-Experts FFN (Qwen-MoE / Moonlight family).

Dispatch is capacity-based scatter/gather (Switch/GShard style, but without
the O(T*E*C) dispatch tensor): tokens are placed into a fixed (E, C, d)
expert-input buffer with `scatter`, processed with one batched GEMM, and
gathered back with their router weights.  Overflowed tokens fall through the
residual (dropless-up-to-capacity).  Expert weights are stacked along a
leading E axis so they can be expert-parallel (sharded on `model`) when E is
divisible by the mesh axis, or tensor-parallel on d_expert otherwise.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.models.layers import dense_init, mlp_apply, mlp_init


def moe_init(key, d_model: int, cfg: MoEConfig):
    ks = jax.random.split(key, 5)
    E, dff = cfg.num_experts, cfg.d_expert
    s_in = 1.0 / np.sqrt(d_model)
    s_out = 1.0 / np.sqrt(dff)
    p = {
        "router": dense_init(ks[0], d_model, E),
        "w_gate": s_in * jax.random.normal(ks[1], (E, d_model, dff), jnp.float32),
        "w_up": s_in * jax.random.normal(ks[2], (E, d_model, dff), jnp.float32),
        "w_down": s_out * jax.random.normal(ks[3], (E, dff, d_model), jnp.float32),
    }
    if cfg.num_shared > 0:
        p["shared"] = mlp_init(ks[4], d_model, cfg.d_shared, "swiglu")
        p["shared_gate"] = dense_init(ks[4], d_model, 1)
    return p


def moe_apply(params, x, cfg: MoEConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d). Returns (out, router_aux_loss)."""
    B, S, d = x.shape
    T = B * S
    E, k = cfg.num_experts, cfg.top_k
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((E,), jnp.float32)
    for j in range(k):
        ce = ce + jnp.mean(jax.nn.one_hot(gate_idx[:, j], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce / k)

    capacity = int(np.ceil(cfg.capacity_factor * k * T / E))
    capacity = max(capacity, 1)

    # joint dispatch across all k choices: ONE (E, C+1, d) buffer and ONE
    # batched GEMM (naive per-choice dispatch costs k x the expert FLOPs).
    e_flat = gate_idx.reshape(-1)  # (T*k,) expert of (token t, choice j)
    if cfg.dispatch == "sort":
        # argsort-based rank-within-expert: O(T*k) memory.  The one-hot
        # variant materializes a (T*k, E) cumsum which GSPMD cannot shard
        # (measured 119 GB/device temp on the MoE prefill cells).
        order = jnp.argsort(e_flat)
        e_sorted = e_flat[order]
        starts = jnp.searchsorted(e_sorted, jnp.arange(E))
        pos_sorted = jnp.arange(T * k) - starts[e_sorted]
        pos = jnp.zeros((T * k,), jnp.int32).at[order].set(
            pos_sorted.astype(jnp.int32))
    else:
        onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)  # (T*k, E)
        pos = jnp.sum((jnp.cumsum(onehot, axis=0) - 1) * onehot, axis=-1)
    keep = pos < capacity
    slot = jnp.where(keep, pos, capacity)  # overflow -> scratch slot
    tok = jnp.repeat(jnp.arange(T), k)
    buf = jnp.zeros((E, capacity + 1, d), xt.dtype)
    buf = buf.at[e_flat, slot].set(xt[tok])
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    y = jnp.einsum("ecf,efd->ecd", h, params["w_down"])  # (E, C+1, d)
    tok_y = y[e_flat, slot]  # (T*k, d)
    contrib = jnp.where(keep[:, None],
                        gate_vals.reshape(-1)[:, None] * tok_y, 0.0)
    out = jnp.sum(contrib.reshape(T, k, d), axis=1).astype(jnp.float32)

    if cfg.num_shared > 0:
        shared = mlp_apply(params["shared"], xt, "swiglu")
        sg = jax.nn.sigmoid(xt @ params["shared_gate"])
        out = out + (sg * shared).astype(jnp.float32)

    return out.reshape(B, S, d).astype(x.dtype), aux


def moe_ref(params, x, cfg: MoEConfig):
    """Dense oracle: every token through its top-k experts, no capacity.

    O(T * E) compute — tests only.
    """
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, cfg.top_k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, params["w_gate"])) * jnp.einsum(
        "td,edf->tef", xt, params["w_up"]
    )
    y_all = jnp.einsum("tef,efd->ted", h, params["w_down"])  # (T, E, d)
    out = jnp.zeros_like(xt, dtype=jnp.float32)
    for j in range(cfg.top_k):
        yj = jnp.take_along_axis(y_all, gate_idx[:, j][:, None, None], axis=1)[:, 0]
        out = out + gate_vals[:, j:j + 1] * yj
    if cfg.num_shared > 0:
        shared = mlp_apply(params["shared"], xt, "swiglu")
        sg = jax.nn.sigmoid(xt @ params["shared_gate"])
        out = out + (sg * shared).astype(jnp.float32)
    return out.reshape(B, S, d).astype(x.dtype)
