"""xLSTM blocks (Beck et al. 2024, arXiv:2405.04517): mLSTM + sLSTM.

mLSTM — matrix-memory cell, trained with the stabilized *parallel* form
(attention-like L x L contraction with a cumulative-forget-gate decay mask);
decoded with the O(1)-state recurrent form.  The two are algebraically
identical (running max m_t == row max of the decay matrix), which
`tests/test_models_smoke.py::test_xlstm_parallel_vs_recurrent` asserts.

sLSTM — scalar-memory cell with block-diagonal recurrent weights; inherently
sequential, trained with `lax.scan` (the paper makes the same point).

Block layout follows the paper's residual pre-LN structure with a
post-up-projection (mLSTM, pf=2) and post-cell gated MLP (sLSTM, pf=4/3).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import XLSTMConfig
from repro.models.layers import dense_init, rmsnorm, rmsnorm_init


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------


def mlstm_init(key, d_model: int, n_heads: int, cfg: XLSTMConfig):
    d_inner = int(cfg.proj_factor_mlstm * d_model)
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], d_model, d_inner),
        "w_z": dense_init(ks[1], d_model, d_inner),
        "w_q": dense_init(ks[2], d_inner, d_inner),
        "w_k": dense_init(ks[3], d_inner, d_inner),
        "w_v": dense_init(ks[4], d_inner, d_inner),
        "w_gates": dense_init(ks[5], d_inner, 2 * n_heads),  # (i, f) per head
        "gate_bias": jnp.concatenate(
            [jnp.zeros((n_heads,)), 3.0 * jnp.ones((n_heads,))]  # forget bias
        ),
        "cell_norm": rmsnorm_init(d_inner),
        "w_down": dense_init(ks[6], d_inner, d_model),
    }


def _mlstm_qkv_gates(params, x, n_heads: int):
    B, L, _ = x.shape
    up = x @ params["w_up"]
    d_inner = up.shape[-1]
    dh = d_inner // n_heads
    q = (up @ params["w_q"]).reshape(B, L, n_heads, dh) / np.sqrt(dh)
    k = (up @ params["w_k"]).reshape(B, L, n_heads, dh)
    v = (up @ params["w_v"]).reshape(B, L, n_heads, dh)
    gates = (up @ params["w_gates"] + params["gate_bias"]).astype(jnp.float32)
    i_tilde = gates[..., :n_heads]  # (B, L, H)
    f_tilde = gates[..., n_heads:]
    z = x @ params["w_z"]
    return q, k, v, i_tilde, f_tilde, z, d_inner, dh


def mlstm_parallel(params, x, n_heads: int):
    """Training/prefill forward; x: (B, L, d_model)."""
    B, L, _ = x.shape
    q, k, v, i_tilde, f_tilde, z, d_inner, dh = _mlstm_qkv_gates(params, x, n_heads)
    logf = jax.nn.log_sigmoid(f_tilde)  # (B, L, H)
    F = jnp.cumsum(logf, axis=1)
    # D[b, h, i, j] = F_i - F_j + itilde_j   (j <= i)
    D = (F.transpose(0, 2, 1)[:, :, :, None]
         - F.transpose(0, 2, 1)[:, :, None, :]
         + i_tilde.transpose(0, 2, 1)[:, :, None, :])
    ii = jnp.arange(L)
    causal = ii[:, None] >= ii[None, :]
    D = jnp.where(causal[None, None], D, -jnp.inf)
    m = jnp.max(D, axis=-1)  # (B, H, L)
    S = jnp.einsum("blhd,bmhd->bhlm", q.astype(jnp.float32), k.astype(jnp.float32))
    W = S * jnp.exp(D - m[..., None])
    b = jnp.sum(W, axis=-1)  # (B, H, L)
    denom = jnp.maximum(jnp.abs(b), jnp.exp(-m))
    h = jnp.einsum("bhlm,bmhd->blhd", W, v.astype(jnp.float32))
    h = h / denom.transpose(0, 2, 1)[..., None]
    h = h.reshape(B, L, d_inner).astype(x.dtype)
    h = rmsnorm(params["cell_norm"], h)
    out = (h * jax.nn.silu(z)) @ params["w_down"]
    return out


def mlstm_chunked(params, x, n_heads: int, chunk: int = 256):
    """Chunkwise-parallel mLSTM: O(L*Q) memory instead of O(L^2).

    Same algebra as `mlstm_parallel`; chunk-boundary state (C, n, m) is
    carried by a lax.scan, with the stabilizer folded into the state exactly
    as in the recurrent form.  This is the TPU-memory-feasible path used for
    train_4k / prefill_32k / long_500k.
    """
    B, L, _ = x.shape
    q, k, v, i_tilde, f_tilde, z, d_inner, dh = _mlstm_qkv_gates(params, x, n_heads)
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q
    logf = jax.nn.log_sigmoid(f_tilde)  # (B, L, H)

    qc = q.reshape(B, nc, Q, n_heads, dh).transpose(1, 0, 3, 2, 4)  # (nc,B,H,Q,dh)
    kc = k.reshape(B, nc, Q, n_heads, dh).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(B, nc, Q, n_heads, dh).transpose(1, 0, 3, 2, 4)
    ic = i_tilde.reshape(B, nc, Q, n_heads).transpose(1, 0, 3, 2)  # (nc,B,H,Q)
    fc = logf.reshape(B, nc, Q, n_heads).transpose(1, 0, 3, 2)

    ii = jnp.arange(Q)
    causal = ii[:, None] >= ii[None, :]

    C0 = jnp.zeros((B, n_heads, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, n_heads, dh), jnp.float32)
    m0 = jnp.full((B, n_heads), -jnp.inf, jnp.float32)

    def body(carry, inp):
        C, n, m = carry
        qb, kb, vb, ib, fb = inp  # (B,H,Q,*)
        F = jnp.cumsum(fb, axis=-1)  # (B,H,Q) local cumulative forget
        # intra-chunk decay D_ij = F_i - F_j + i_j
        D = F[..., :, None] - F[..., None, :] + ib[..., None, :]
        D = jnp.where(causal[None, None], D, -jnp.inf)
        m_intra = jnp.max(D, axis=-1)  # (B,H,Q)
        m_inter = F + m[..., None]  # decayed carry stabilizer
        m_i = jnp.maximum(m_intra, m_inter)
        S = jnp.einsum("bhqd,bhkd->bhqk", qb.astype(jnp.float32),
                       kb.astype(jnp.float32))
        W = S * jnp.exp(D - m_i[..., None])
        num = jnp.einsum("bhqk,bhkd->bhqd", W, vb.astype(jnp.float32))
        den = jnp.sum(W, axis=-1)
        carry_scale = jnp.where(jnp.isfinite(m[..., None]),
                                jnp.exp(m_inter - m_i), 0.0)  # (B,H,Q)
        num = num + carry_scale[..., None] * jnp.einsum(
            "bhde,bhqe->bhqd", C, qb.astype(jnp.float32))
        den = den + carry_scale * jnp.einsum("bhe,bhqe->bhq", n,
                                             qb.astype(jnp.float32))
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
        # ---- chunk-boundary state update --------------------------------
        Ftot = F[..., -1]  # (B,H)
        g = Ftot[..., None] - F + ib  # decay from j to chunk end (B,H,Q)
        m_next = jnp.maximum(Ftot + m, jnp.max(g, axis=-1))
        c_old = jnp.where(jnp.isfinite(m), jnp.exp(Ftot + m - m_next), 0.0)
        wj = jnp.exp(g - m_next[..., None])  # (B,H,Q)
        C_new = c_old[..., None, None] * C + jnp.einsum(
            "bhq,bhqd,bhqe->bhde", wj, vb.astype(jnp.float32),
            kb.astype(jnp.float32))
        n_new = c_old[..., None] * n + jnp.einsum(
            "bhq,bhqe->bhe", wj, kb.astype(jnp.float32))
        return (C_new, n_new, m_next), h

    from repro.models.scan_config import scan_unroll
    (_, _, _), hs = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, ic, fc),
                                 unroll=scan_unroll())
    # hs: (nc, B, H, Q, dh) -> (B, L, d_inner)
    h = hs.transpose(1, 0, 3, 2, 4).reshape(B, L, d_inner).astype(x.dtype)
    h = rmsnorm(params["cell_norm"], h)
    return (h * jax.nn.silu(z)) @ params["w_down"]


def mlstm_cache_init(batch: int, d_model: int, n_heads: int, cfg: XLSTMConfig):
    d_inner = int(cfg.proj_factor_mlstm * d_model)
    dh = d_inner // n_heads
    return {
        "C": jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, n_heads, dh), jnp.float32),
        "m": jnp.full((batch, n_heads), -jnp.inf, jnp.float32),
    }


def mlstm_cache_spec(batch: int, d_model: int, n_heads: int, cfg: XLSTMConfig):
    d_inner = int(cfg.proj_factor_mlstm * d_model)
    dh = d_inner // n_heads
    return {
        "C": jax.ShapeDtypeStruct((batch, n_heads, dh, dh), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, n_heads, dh), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, n_heads), jnp.float32),
    }


def mlstm_step(params, x, cache, n_heads: int):
    """Single-token recurrent step; x: (B, 1, d_model)."""
    B = x.shape[0]
    q, k, v, i_tilde, f_tilde, z, d_inner, dh = _mlstm_qkv_gates(params, x, n_heads)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]  # (B, H, dh)
    i_t, logf = i_tilde[:, 0], jax.nn.log_sigmoid(f_tilde[:, 0])  # (B, H)
    m_prev, C_prev, n_prev = cache["m"], cache["C"], cache["n"]
    m_new = jnp.maximum(logf + m_prev, i_t)
    i_sc = jnp.exp(i_t - m_new)
    f_sc = jnp.where(jnp.isfinite(m_prev), jnp.exp(logf + m_prev - m_new), 0.0)
    C = f_sc[..., None, None] * C_prev + i_sc[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", v.astype(jnp.float32), k.astype(jnp.float32))
    n = f_sc[..., None] * n_prev + i_sc[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhde,bhe->bhd", C, q.astype(jnp.float32))
    den = jnp.maximum(jnp.abs(jnp.einsum("bhe,bhe->bh", n, q.astype(jnp.float32))),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, 1, d_inner).astype(x.dtype)
    h = rmsnorm(params["cell_norm"], h)
    out = (h * jax.nn.silu(z)) @ params["w_down"]
    return out, {"C": C, "n": n, "m": m_new}


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------


def slstm_init(key, d_model: int, n_heads: int, cfg: XLSTMConfig):
    dh = d_model // n_heads
    ks = jax.random.split(key, 4)
    d_up = int(cfg.proj_factor_slstm * d_model)
    return {
        "w_in": dense_init(ks[0], d_model, 4 * d_model),  # z, i, f, o
        "r": 0.1 * jax.random.normal(ks[1], (n_heads, dh, 4 * dh), jnp.float32),
        "bias": jnp.concatenate(
            [jnp.zeros((2 * d_model,)), 3.0 * jnp.ones((d_model,)),
             jnp.zeros((d_model,))]
        ),
        "cell_norm": rmsnorm_init(d_model),
        "mlp_up": dense_init(ks[2], d_model, 2 * d_up),  # GeGLU
        "mlp_down": dense_init(ks[3], d_up, d_model),
    }


def slstm_cell_step(params, wx_t, state, n_heads: int):
    """wx_t: (B, 4*d) precomputed input contribution at time t."""
    c, n, h, m = state  # each (B, H, dh) except m: (B, H, dh)
    B = wx_t.shape[0]
    d = wx_t.shape[-1] // 4
    dh = d // n_heads
    rh = jnp.einsum("bhd,hde->bhe", h, params["r"])  # (B, H, 4*dh)
    gates = wx_t.reshape(B, n_heads, 4 * dh) + rh + \
        params["bias"].reshape(4, n_heads, dh).transpose(1, 0, 2).reshape(
            n_heads, 4 * dh)
    zt = jnp.tanh(gates[..., :dh])
    it = gates[..., dh:2 * dh]
    ft = gates[..., 2 * dh:3 * dh]
    ot = jax.nn.sigmoid(gates[..., 3 * dh:])
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + m, it)
    i_sc = jnp.exp(it - m_new)
    f_sc = jnp.where(jnp.isfinite(m), jnp.exp(logf + m - m_new), 0.0)
    c_new = f_sc * c + i_sc * zt
    n_new = f_sc * n + i_sc
    h_new = ot * c_new / jnp.maximum(n_new, jnp.exp(-m_new))
    return (c_new, n_new, h_new, m_new)


def slstm_apply(params, x, n_heads: int):
    """Sequential forward over L (lax.scan); x: (B, L, d_model)."""
    B, L, d = x.shape
    dh = d // n_heads
    wx = (x @ params["w_in"]).astype(jnp.float32)  # (B, L, 4d) (z|i|f|o blocks)
    # reorder to per-head contiguous [z,i,f,o]
    wx = wx.reshape(B, L, 4, n_heads, dh).transpose(0, 1, 3, 2, 4).reshape(
        B, L, n_heads, 4 * dh).reshape(B, L, 4 * d)
    zeros = jnp.zeros((B, n_heads, dh), jnp.float32)
    state0 = (zeros, zeros, zeros, jnp.full((B, n_heads, dh), -jnp.inf))

    def body(state, wx_t):
        new = slstm_cell_step(params, wx_t, state, n_heads)
        return new, new[2]

    _, hs = jax.lax.scan(body, state0, wx.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2, 3).reshape(B, L, d).astype(x.dtype)
    h = rmsnorm(params["cell_norm"], h)
    up = h @ params["mlp_up"]
    u, g = jnp.split(up, 2, axis=-1)
    return (u * jax.nn.gelu(g, approximate=True)) @ params["mlp_down"]


def slstm_cache_init(batch: int, d_model: int, n_heads: int):
    dh = d_model // n_heads
    z = jnp.zeros((batch, n_heads, dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, n_heads, dh), -jnp.inf)}


def slstm_cache_spec(batch: int, d_model: int, n_heads: int):
    dh = d_model // n_heads
    s = jax.ShapeDtypeStruct((batch, n_heads, dh), jnp.float32)
    return {"c": s, "n": s, "h": s, "m": s}


def slstm_step(params, x, cache, n_heads: int):
    B, _, d = x.shape
    dh = d // n_heads
    wx = (x[:, 0] @ params["w_in"]).astype(jnp.float32)
    wx = wx.reshape(B, 4, n_heads, dh).transpose(0, 2, 1, 3).reshape(B, 4 * d)
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    c, n, h, m = slstm_cell_step(params, wx, state, n_heads)
    hv = h.reshape(B, 1, d).astype(x.dtype)
    hv = rmsnorm(params["cell_norm"], hv)
    up = hv @ params["mlp_up"]
    u, g = jnp.split(up, 2, axis=-1)
    out = (u * jax.nn.gelu(g, approximate=True)) @ params["mlp_down"]
    return out, {"c": c, "n": n, "h": h, "m": m}
