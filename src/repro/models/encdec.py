"""Whisper-style encoder-decoder backbone (conv audio frontend is a stub:
the batch carries precomputed frame embeddings (B, S_enc, d_model)).

Encoder: scan of (bidirectional attention + MLP) blocks over frames.
Decoder: scan of (causal self-attention + cross-attention + MLP) blocks.
Decode caches: per-layer self KV ring + precomputed cross K/V from the
encoder memory (computed once at prefill, reused every step).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import (
    blockwise_attention,
    decode_attention,
    dense_init,
    gqa_apply,
    gqa_cache_init,
    gqa_cache_spec,
    gqa_decode,
    gqa_init,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
)


def _cross_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    d, H, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        "wq": dense_init(ks[0], d, H * dh),
        "wk": dense_init(ks[1], d, H * dh),
        "wv": dense_init(ks[2], d, H * dh),
        "wo": dense_init(ks[3], H * dh, d),
    }


def _cross_apply(p, x, memory, cfg: ModelConfig):
    B, S, _ = x.shape
    Sm = memory.shape[1]
    H, dh = cfg.n_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, dh)
    k = (memory @ p["wk"]).reshape(B, Sm, H, dh)
    v = (memory @ p["wv"]).reshape(B, Sm, H, dh)
    o = blockwise_attention(q, k, v, causal=False)
    return o.reshape(B, S, H * dh) @ p["wo"]


def init_params(cfg: ModelConfig, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    ke, kd, kemb, khead = jax.random.split(key, 4)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": rmsnorm_init(cfg.d_model),
            "attn": gqa_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                             cfg.head_dim),
            "ln2": rmsnorm_init(cfg.d_model),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln1": rmsnorm_init(cfg.d_model),
            "self": gqa_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                             cfg.head_dim),
            "ln_x": rmsnorm_init(cfg.d_model),
            "cross": _cross_init(k2, cfg),
            "ln2": rmsnorm_init(cfg.d_model),
            "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.mlp),
        }

    enc_keys = jax.random.split(ke, cfg.n_encoder_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "embed": (0.02 * jax.random.normal(kemb, (cfg.vocab, cfg.d_model))
                  ).astype(jnp.float32),
        "enc": jax.tree.map(lambda *xs: jnp.stack(xs),
                            *[enc_layer(k) for k in enc_keys]),
        "dec": jax.tree.map(lambda *xs: jnp.stack(xs),
                            *[dec_layer(k) for k in dec_keys]),
        "enc_norm": rmsnorm_init(cfg.d_model),
        "final_norm": rmsnorm_init(cfg.d_model),
        "lm_head": dense_init(khead, cfg.d_model, cfg.vocab),
    }


def encode(params, frames, cfg: ModelConfig, *, remat: bool = False):
    def body(h, p):
        a = gqa_apply(p["attn"], rmsnorm(p["ln1"], h), n_heads=cfg.n_heads,
                      n_kv=cfg.n_kv_heads, d_head=cfg.head_dim,
                      rope_theta=cfg.rope_theta, causal=False)
        h = h + a
        h = h + mlp_apply(p["mlp"], rmsnorm(p["ln2"], h), cfg.mlp)
        return h, None

    from repro.models.scan_config import scan_unroll
    body = jax.checkpoint(body) if remat else body
    h, _ = jax.lax.scan(body, frames, params["enc"], unroll=scan_unroll())
    return rmsnorm(params["enc_norm"], h)


def decode_train(params, tokens_embedded, memory, cfg: ModelConfig,
                 *, remat: bool = False):
    def body(h, p):
        a = gqa_apply(p["self"], rmsnorm(p["ln1"], h), n_heads=cfg.n_heads,
                      n_kv=cfg.n_kv_heads, d_head=cfg.head_dim,
                      rope_theta=cfg.rope_theta, causal=True)
        h = h + a
        h = h + _cross_apply(p["cross"], rmsnorm(p["ln_x"], h), memory, cfg)
        h = h + mlp_apply(p["mlp"], rmsnorm(p["ln2"], h), cfg.mlp)
        return h, None

    from repro.models.scan_config import scan_unroll
    body = jax.checkpoint(body) if remat else body
    h, _ = jax.lax.scan(body, tokens_embedded, params["dec"], unroll=scan_unroll())
    return rmsnorm(params["final_norm"], h)


def lm_loss(params, batch, cfg: ModelConfig, *, dtype=jnp.bfloat16,
            remat: bool = True, loss_chunk: int = 512):
    from repro.models.transformer import cast_params
    params = cast_params(params, dtype)
    frames = batch["frames"].astype(dtype)
    memory = encode(params, frames, cfg, remat=remat)
    x = params["embed"][batch["tokens"]].astype(dtype)
    h = decode_train(params, x, memory, cfg, remat=remat)
    targets = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)))
    B, S, _ = h.shape
    C = min(loss_chunk, S)
    n_chunks = -(-S // C)
    Sp = n_chunks * C
    h = jnp.pad(h, ((0, 0), (0, Sp - S), (0, 0)))
    targets = jnp.pad(targets, ((0, 0), (0, Sp - S)))
    mask = jnp.pad(jnp.ones((B, S - 1), jnp.float32), ((0, 0), (0, Sp - S + 1)))

    def chunk_loss(carry, inp):
        hx, tx, mx = inp
        logits = jnp.matmul(hx, params["lm_head"],
                            preferred_element_type=jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        true = jnp.take_along_axis(logits, tx[..., None].astype(jnp.int32),
                                   axis=-1)[..., 0]
        return carry + jnp.sum((logz - true) * mx), None

    hc = h.reshape(B, n_chunks, C, -1).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, n_chunks, C).transpose(1, 0, 2)
    mc = mask.reshape(B, n_chunks, C).transpose(1, 0, 2)
    from repro.models.scan_config import scan_unroll
    total, _ = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32), (hc, tc, mc),
                            unroll=scan_unroll())
    return total / jnp.maximum(jnp.sum(mask), 1.0)


def prefill(params, batch, cfg: ModelConfig, *, dtype=jnp.bfloat16):
    """Inference prefill: encode frames + run the decoder over the prompt,
    returning last-position logits (forward-only)."""
    from repro.models.transformer import cast_params
    params = cast_params(params, dtype)
    memory = encode(params, batch["frames"].astype(dtype), cfg, remat=False)
    x = params["embed"][batch["tokens"]].astype(dtype)
    h = decode_train(params, x, memory, cfg, remat=False)
    return jnp.matmul(h[:, -1], params["lm_head"],
                      preferred_element_type=jnp.float32)


# -- serving -----------------------------------------------------------------


def init_caches(cfg: ModelConfig, batch: int, seq: int, enc_len: int,
                spec: bool = False):
    """Self-attn ring caches + cross K/V memory slots, stacked over layers."""
    n = cfg.n_layers
    H, dh = cfg.n_heads, cfg.head_dim
    if spec:
        self_c = gqa_cache_spec(batch, seq, cfg.n_kv_heads, cfg.head_dim)
        self_c = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), self_c)
        cross = jax.ShapeDtypeStruct((n, batch, enc_len, H, dh), jnp.bfloat16)
        return {"self": self_c, "cross_k": cross, "cross_v": cross}
    self_c = gqa_cache_init(batch, seq, cfg.n_kv_heads, cfg.head_dim)
    self_c = jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(),
                          self_c)
    z = jnp.zeros((n, batch, enc_len, H, dh), jnp.bfloat16)
    return {"self": self_c, "cross_k": z, "cross_v": z}


def fill_cross_caches(params, memory, cfg: ModelConfig):
    """Precompute per-layer cross K/V from the encoder memory."""
    B, Sm, _ = memory.shape
    H, dh = cfg.n_heads, cfg.head_dim

    def body(_, p):
        k = (memory @ p["cross"]["wk"]).reshape(B, Sm, H, dh)
        v = (memory @ p["cross"]["wv"]).reshape(B, Sm, H, dh)
        return None, (k.astype(jnp.bfloat16), v.astype(jnp.bfloat16))

    _, (ks, vs) = jax.lax.scan(body, None, params["dec"])
    return ks, vs


def decode_step(params, batch, caches, cfg: ModelConfig, *, dtype=jnp.bfloat16):
    """One decoder token; cross K/V already in `caches`."""
    from repro.models.transformer import cast_params
    params = cast_params(params, dtype)
    x = params["embed"][batch["tokens"]].astype(dtype)
    H, dh = cfg.n_heads, cfg.head_dim

    def body(h, scanned):
        p, self_cache, ck, cv = scanned
        a, new_self = gqa_decode(p["self"], rmsnorm(p["ln1"], h), self_cache,
                                 n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                                 d_head=cfg.head_dim, rope_theta=cfg.rope_theta)
        h = h + a
        B = h.shape[0]
        q = (rmsnorm(p["ln_x"], h)[:, 0] @ p["cross"]["wq"]).reshape(B, H, dh)
        o = decode_attention(q, ck, cv, jnp.int32(ck.shape[1]))
        h = h + (o.reshape(B, 1, H * dh) @ p["cross"]["wo"])
        h = h + mlp_apply(p["mlp"], rmsnorm(p["ln2"], h), cfg.mlp)
        return h, new_self

    h, new_self = jax.lax.scan(
        body, x, (params["dec"], caches["self"], caches["cross_k"],
                  caches["cross_v"]))
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = jnp.matmul(h[:, 0], params["lm_head"],
                        preferred_element_type=jnp.float32)
    return logits, {"self": new_self, "cross_k": caches["cross_k"],
                    "cross_v": caches["cross_v"]}
