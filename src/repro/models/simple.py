"""The paper's own model family: L2-regularized (multinomial) logistic
regression and a 2-layer ReLU network — plus their DeltaGrad Objectives."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deltagrad import Objective


# --------------------------------------------------------------------------
# Binary logistic regression (RCV1 / HIGGS experiments)
# --------------------------------------------------------------------------


def logreg_init(d: int, seed: int = 0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": 0.01 * jax.random.normal(k, (d,), dtype=jnp.float32),
        "b": jnp.zeros((), dtype=jnp.float32),
    }


def logreg_per_example_loss(params, batch: Dict[str, jax.Array]) -> jax.Array:
    logits = batch["x"] @ params["w"] + params["b"]
    y = batch["y"].astype(jnp.float32)
    # numerically stable BCE-with-logits
    return jnp.maximum(logits, 0.0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))


def logreg_objective(l2: float = 5e-3) -> Objective:
    return Objective(per_example_loss=logreg_per_example_loss, l2=l2)


def logreg_predict(params, x: np.ndarray) -> np.ndarray:
    return (np.asarray(x @ np.asarray(params["w"]) + float(params["b"])) > 0).astype(
        np.int32
    )


def logreg_accuracy(params, ds) -> float:
    pred = logreg_predict(params, ds.columns["x"])
    return float((pred == ds.columns["y"]).mean())


# --------------------------------------------------------------------------
# Multinomial logistic regression (MNIST / covtype experiments)
# --------------------------------------------------------------------------


def multiclass_init(d: int, num_classes: int, seed: int = 0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": 0.01 * jax.random.normal(k, (d, num_classes), dtype=jnp.float32),
        "b": jnp.zeros((num_classes,), dtype=jnp.float32),
    }


def multiclass_per_example_loss(params, batch):
    logits = batch["x"] @ params["w"] + params["b"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    true = jnp.take_along_axis(logits, batch["y"][:, None].astype(jnp.int32), axis=-1)[
        :, 0
    ]
    return logz - true


def multiclass_objective(l2: float = 5e-3) -> Objective:
    return Objective(per_example_loss=multiclass_per_example_loss, l2=l2)


def multiclass_accuracy(params, ds) -> float:
    logits = ds.columns["x"] @ np.asarray(params["w"]) + np.asarray(params["b"])
    return float((logits.argmax(-1) == ds.columns["y"]).mean())


# --------------------------------------------------------------------------
# 2-layer ReLU network (the paper's MNIST^n experiment; non-convex →
# run DeltaGrad with cfg.guard=True, curvature_eps>0: Algorithm 4)
# --------------------------------------------------------------------------


def mlp_init(d: int, hidden: int, num_classes: int, seed: int = 0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    s1 = 1.0 / np.sqrt(d)
    s2 = 1.0 / np.sqrt(hidden)
    return {
        "w1": s1 * jax.random.normal(k1, (d, hidden), dtype=jnp.float32),
        "b1": jnp.zeros((hidden,), dtype=jnp.float32),
        "w2": s2 * jax.random.normal(k2, (hidden, num_classes), dtype=jnp.float32),
        "b2": jnp.zeros((num_classes,), dtype=jnp.float32),
    }


def mlp_per_example_loss(params, batch):
    h = jax.nn.relu(batch["x"] @ params["w1"] + params["b1"])
    logits = h @ params["w2"] + params["b2"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    true = jnp.take_along_axis(logits, batch["y"][:, None].astype(jnp.int32), axis=-1)[
        :, 0
    ]
    return logz - true


def mlp_objective(l2: float = 1e-3) -> Objective:
    return Objective(per_example_loss=mlp_per_example_loss, l2=l2)


def mlp_accuracy(params, ds) -> float:
    h = np.maximum(ds.columns["x"] @ np.asarray(params["w1"]) + np.asarray(params["b1"]), 0)
    logits = h @ np.asarray(params["w2"]) + np.asarray(params["b2"])
    return float((logits.argmax(-1) == ds.columns["y"]).mean())
