"""Process-wide switch for the full-sequence attention implementation.

`models.layers.gqa_apply` consults this at TRACE time to pick the
attention contraction for training/replay forwards:

  * ``"blockwise"``       — the XLA online-softmax scan over KV blocks
    (`layers.blockwise_attention`); the default everywhere, and the
    reference the kernel path is checked against;
  * ``"flash"``           — the Pallas flash kernel
    (`kernels.flash_attention`) where shapes allow (causal, no sliding
    window); lowers natively on TPU and falls back to INTERPRET mode on
    other backends, so CPU CI runs the same kernel program as the
    ref/interpret oracle;
  * ``"flash_interpret"`` — force interpret mode on every backend (kernel
    debugging / oracle runs on TPU).

The switch is read when a function is traced, so a jitted objective built
under `use_attention_impl("flash")` keeps the flash path for its whole
cached life — `core.deltagrad.Objective.from_model(..., attn_impl=...)`
pins it per objective, which is how the replay engine routes the kernel
onto the LM replay forward without any global state at serve time.
"""

from __future__ import annotations

from contextlib import contextmanager

_IMPLS = ("blockwise", "flash", "flash_interpret")
_IMPL = "blockwise"


def attention_impl() -> str:
    """The currently selected implementation name."""
    return _IMPL


def set_attention_impl(name: str) -> str:
    """Set the implementation; returns the previous one."""
    global _IMPL
    if name not in _IMPLS:
        raise ValueError(f"attention impl must be one of {_IMPLS}, "
                         f"got {name!r}")
    prev, _IMPL = _IMPL, name
    return prev


@contextmanager
def use_attention_impl(name):
    """Scoped override; ``None`` is a no-op (keep whatever is active)."""
    if name is None:
        yield
        return
    prev = set_attention_impl(name)
    try:
        yield
    finally:
        set_attention_impl(prev)
