"""Unified observability layer: span tracing + the shared metrics registry.

This package is the ONE instrumentation contract for the repo — it
replaces the three ad-hoc stats paths that used to coexist
(`ServeMonitor`'s private percentile helper, `launch/serve.py`'s private
percentile helper, and untyped `RetrainStats.extra` dicts as the only
window into engine/store behavior):

  * `obs.trace`   — thread-safe monotonic span tracer with Chrome/Perfetto
                    trace-event export; near-zero cost while disabled.
  * `obs.metrics` — counters / gauges / fixed-bucket histograms in one
                    registry, with JSONL and Prometheus-text exporters.

Enable tracing with ``repro.obs.trace.enable()`` (the serve CLI's
``--trace-out`` flag and ``benchmarks/bench_serve.py --trace-out`` do this
and export the trace); metrics publish unconditionally — read them with
``repro.obs.metrics.get_registry().snapshot()`` or either exporter.

SPAN CONTRACT — every span name, where it is emitted, and its args:

    span                    owner module        args
    ----------------------- ------------------- ---------------------------
    replay.schedule_build   core.engine         steps, r
    replay.scan             core.engine         t0, t1, pred_s, measured_s,
                                                roofline_ratio
    replay.explicit         core.engine         t0, steps
    replay.guard_retry      core.engine         t, prefix
    replay.commit           core.engine         regions
    online.warmup           core.online         ops
    online.request          core.online         op, k, pred_s, measured_s,
                                                roofline_ratio
    store.window_stage      core.store          wid  (staging-pool thread)
    store.prefetch_wait     core.store          wid
    store.window            core.store          wid, hit
    serve.admit             serve.scheduler     op, tenant, cls
    serve.batch             serve.executor      size, op

    ``pred_s`` is the roofline-predicted span cost attached by
    `repro.roofline.replay`; the tracer stamps ``measured_s`` and
    ``roofline_ratio`` (measured / predicted) on span exit, so every
    replay span in a trace carries predicted-vs-measured cost.

METRIC CONTRACT — every metric name, its type/unit, and the owner that
publishes it:

    metric                       type       unit  owner
    ---------------------------- ---------- ----- ---------------------
    engine.replays               counter    1     core.engine
    engine.explicit_steps        counter    1     core.engine
    engine.approx_steps          counter    1     core.engine
    engine.guard_fallbacks       counter    1     core.engine
    engine.grad_examples         counter    1     core.engine
    online.compile_time_s        gauge      s     core.online
    store.hbm_high_water_bytes   gauge      B     core.store
    store.windows_fetched        counter    1     core.store
    store.prefetch_hits          counter    1     core.store
    store.host_wait_s            counter    s     core.store
    queue.admitted               counter    1     serve.queue
    queue.rejected_depth         counter    1     serve.queue
    queue.rejected_tenant        counter    1     serve.queue
    queue.rejected_add_capacity  counter    1     serve.queue
    queue.blocked_admissions     counter    1     serve.queue
    serve.dispatch_ms{class}     histogram  ms    serve.monitor
    serve.e2e_ms{class}          histogram  ms    serve.monitor
    serve.queue_depth            histogram  1     serve.monitor
    serve.batch_size             histogram  1     serve.monitor
    serve.served{class}          counter    1     serve.monitor
    serve.failed{class}          counter    1     serve.monitor
    serve.deadline_misses{class} counter    1     serve.monitor
    serve.add_capacity_retraces  counter    1     serve.monitor
    launch.dispatch_ms           histogram  ms    launch.serve
    launch.blocked_ms            histogram  ms    launch.serve
    bench.warmup_compile_s       histogram  s     benchmarks

    `ServeMonitor` keeps one PRIVATE registry per instance by default
    (bench sweeps build a monitor per point; snapshots must not
    accumulate across points) — pass ``registry=get_registry()`` to
    publish a single serving stack into the process-wide surface, as the
    serve CLI does.  Structured per-replay facts remain available on
    `RetrainStats.extra` for backward compatibility, but new consumers
    should read this registry (see the migration note in
    `core/session.py`).
"""

from repro.obs import metrics, trace
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               get_registry, read_jsonl, set_registry)
from repro.obs.trace import (Span, Tracer, disable, enable, enabled,
                             get_tracer, span)

__all__ = [
    "metrics", "trace",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "get_registry",
    "set_registry", "read_jsonl",
    "Span", "Tracer", "span", "enable", "disable", "enabled", "get_tracer",
]
