"""Shared metrics registry: counters, gauges, fixed-bucket histograms.

One `MetricsRegistry` is the contract every layer publishes into —
`ServeMonitor` (per-class latency quantiles), `OnlineEngine` (compile
time), `SegmentStreamer`/`ShardedStreamer` (prefetch hits, HBM high
water), the `AdmissionQueue` (admission outcomes), and the replay engine
(step counters) — replacing the private percentile helpers that used to
live in `serve/monitor.py` and `launch/serve.py`.

`Histogram` quantiles come from a FIXED log-spaced bucket grid (no sorted
sample lists): `observe` is O(log #buckets) and memory is constant, while
``count``/``mean``/``min``/``max`` stay exact.  Quantiles interpolate
linearly inside the landing bucket and clamp to the exact observed
min/max, so worst-case quantile error is one bucket width (~4% at the
default growth of 1.04) — well inside every CI gate's cross-runner slack.

Exporters: `to_jsonl` writes one JSON object per metric (re-read with
`read_jsonl` for round-trips and CI artifacts); `to_prometheus` renders
the Prometheus text exposition format (histograms as summaries with
``quantile`` labels plus ``_count``/``_sum``).

A process-wide default registry is reachable via `get_registry()`;
components that must not accumulate across runs (one `ServeMonitor` per
bench sweep point) construct their own instance instead.
"""

from __future__ import annotations

import json
import math
import re
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "get_registry", "set_registry", "read_jsonl"]


class _Metric:
    """Shared identity fields; see the `repro.obs` contract table."""

    kind = "metric"

    def __init__(self, name: str, unit: str = "", owner: str = "",
                 labels: Optional[Dict[str, str]] = None):
        self.name = name
        self.unit = unit
        self.owner = owner
        self.labels = dict(labels or {})
        self._lock = threading.Lock()

    def _ident(self) -> Dict[str, Any]:
        return {"type": self.kind, "name": self.name, "unit": self.unit,
                "owner": self.owner, "labels": dict(self.labels)}


class Counter(_Metric):
    """Monotonically increasing count (int or float increments)."""

    kind = "counter"

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> Dict[str, Any]:
        return {**self._ident(), "value": float(self._value)}


class Gauge(_Metric):
    """Last-set value plus its high-water mark."""

    kind = "gauge"

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self._value = 0.0
        self._high = -math.inf

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)
            self._high = max(self._high, self._value)

    def set_max(self, v: float) -> None:
        """Raise-only update (high-water gauges: HBM bytes, ring depth)."""
        with self._lock:
            v = float(v)
            if v > self._value:
                self._value = v
            self._high = max(self._high, v)

    @property
    def value(self) -> float:
        return self._value

    @property
    def high(self) -> float:
        return self._high if self._high != -math.inf else 0.0

    def snapshot(self) -> Dict[str, Any]:
        return {**self._ident(), "value": float(self._value),
                "high": float(self.high)}


class Histogram(_Metric):
    """Fixed log-bucket latency/size histogram with exact count/mean/max.

    ``summary()`` returns the exact dict shape `ServeMonitor` has always
    reported (``{"count", "mean", "p50", "p95", "p99", "max"}``; just
    ``{"count": 0}`` when empty) so migrated call sites are drop-in.
    """

    kind = "histogram"

    #: default grid: 1e-6 .. 1e9 at 4% geometric steps (covers ns-scale
    #: span costs through multi-hour walls in any one unit)
    LO, HI, GROWTH = 1e-6, 1e9, 1.04

    def __init__(self, name: str, unit: str = "", owner: str = "",
                 labels: Optional[Dict[str, str]] = None,
                 lo: float = LO, hi: float = HI, growth: float = GROWTH):
        super().__init__(name, unit=unit, owner=owner, labels=labels)
        n = int(math.ceil(math.log(hi / lo) / math.log(growth)))
        # bucket i covers [edges[i], edges[i+1]); one underflow bucket
        # below lo and one overflow bucket above hi bound the grid
        self._edges = lo * np.power(growth, np.arange(n + 1))
        self._counts = np.zeros(n + 2, dtype=np.int64)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.sum += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)
            # searchsorted over the fixed edges: 0 is the underflow bucket
            self._counts[int(np.searchsorted(self._edges, v,
                                             side="right"))] += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def _bucket_bounds(self, i: int) -> Tuple[float, float]:
        if i == 0:  # underflow: everything below the grid
            return min(self.min, self._edges[0]), self._edges[0]
        if i == len(self._counts) - 1:  # overflow
            return self._edges[-1], max(self.max, self._edges[-1])
        return self._edges[i - 1], self._edges[i]

    def quantile(self, q: float) -> float:
        with self._lock:
            if self.count == 0:
                return 0.0
            target = q * self.count
            cum = np.cumsum(self._counts)
            i = int(np.searchsorted(cum, target, side="left"))
            i = min(i, len(self._counts) - 1)
            lo_e, hi_e = self._bucket_bounds(i)
            prev = float(cum[i - 1]) if i > 0 else 0.0
            in_bucket = float(self._counts[i])
            frac = (target - prev) / in_bucket if in_bucket else 0.0
            est = lo_e + frac * (hi_e - lo_e)
            return float(min(max(est, self.min), self.max))

    def summary(self) -> Dict[str, float]:
        if self.count == 0:
            return {"count": 0}
        return {"count": int(self.count), "mean": float(self.mean),
                "p50": self.quantile(0.50), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99), "max": float(self.max)}

    def snapshot(self) -> Dict[str, Any]:
        out = self._ident()
        s = self.summary()
        out.update({"count": int(self.count), "sum": float(self.sum),
                    "min": float(self.min if self.count else 0.0),
                    "max": float(self.max if self.count else 0.0),
                    "p50": float(s.get("p50", 0.0)),
                    "p95": float(s.get("p95", 0.0)),
                    "p99": float(s.get("p99", 0.0))})
        return out


_PROM_NAME = re.compile(r"[^a-zA-Z0-9_:]")


def _prom(name: str) -> str:
    return _PROM_NAME.sub("_", name)


def _prom_labels(labels: Dict[str, str], extra: str = "") -> str:
    parts = [f'{_prom(k)}="{v}"' for k, v in sorted(labels.items())]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


class MetricsRegistry:
    """Get-or-create metric store keyed by (name, labels)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, Tuple[Tuple[str, str], ...]],
                            _Metric] = {}

    def _get(self, cls, name: str, unit: str, owner: str,
             labels: Optional[Dict[str, str]], **kw):
        key = (name, tuple(sorted((labels or {}).items())))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = cls(name, unit=unit, owner=owner,
                                             labels=labels, **kw)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested {cls.kind}")
            return m

    def counter(self, name: str, unit: str = "1", owner: str = "",
                labels: Optional[Dict[str, str]] = None) -> Counter:
        return self._get(Counter, name, unit, owner, labels)

    def gauge(self, name: str, unit: str = "1", owner: str = "",
              labels: Optional[Dict[str, str]] = None) -> Gauge:
        return self._get(Gauge, name, unit, owner, labels)

    def histogram(self, name: str, unit: str = "1", owner: str = "",
                  labels: Optional[Dict[str, str]] = None,
                  **kw) -> Histogram:
        return self._get(Histogram, name, unit, owner, labels, **kw)

    # -- export --------------------------------------------------------------

    def metrics(self) -> List[_Metric]:
        with self._lock:
            return [self._metrics[k] for k in sorted(self._metrics)]

    def snapshot(self) -> List[Dict[str, Any]]:
        return [m.snapshot() for m in self.metrics()]

    def to_jsonl(self, path: str, mode: str = "w") -> str:
        """One JSON object per line per metric (the CI artifact format;
        `read_jsonl` parses it back)."""
        with open(path, mode) as f:
            for snap in self.snapshot():
                f.write(json.dumps(snap, sort_keys=True) + "\n")
        return path

    def to_prometheus(self) -> str:
        """Prometheus text exposition format."""
        lines: List[str] = []
        seen_meta = set()
        for m in self.metrics():
            pname = _prom(m.name)
            if pname not in seen_meta:
                seen_meta.add(pname)
                help_bits = [b for b in (m.unit and f"unit={m.unit}",
                                         m.owner and f"owner={m.owner}")
                             if b]
                lines.append(f"# HELP {pname} "
                             + (", ".join(help_bits) or pname))
                ptype = {"counter": "counter", "gauge": "gauge",
                         "histogram": "summary"}[m.kind]
                lines.append(f"# TYPE {pname} {ptype}")
            if m.kind == "counter":
                lines.append(f"{pname}{_prom_labels(m.labels)} "
                             f"{m.value:.10g}")
            elif m.kind == "gauge":
                lines.append(f"{pname}{_prom_labels(m.labels)} "
                             f"{m.value:.10g}")
            else:
                for q in (0.5, 0.95, 0.99):
                    qlabel = 'quantile="%g"' % q
                    lines.append(
                        f"{pname}{_prom_labels(m.labels, qlabel)}"
                        f" {m.quantile(q):.10g}")
                lines.append(f"{pname}_count{_prom_labels(m.labels)} "
                             f"{m.count}")
                lines.append(f"{pname}_sum{_prom_labels(m.labels)} "
                             f"{m.sum:.10g}")
        return "\n".join(lines) + ("\n" if lines else "")

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    """Parse a `to_jsonl` artifact back into metric snapshots."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


_default = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide default registry (engine/store/queue publish
    here; per-run components construct their own)."""
    return _default


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    global _default
    _default = registry
    return _default
