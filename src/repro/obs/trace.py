"""Thread-safe span tracer with Chrome/Perfetto trace-event export.

One module-level tracer serves the whole process: instrumentation sites
call ``span("replay.scan", rows=K)`` unconditionally, and the call is a
near-zero-cost no-op until someone calls `enable()` (a module attribute
load, a None check, and one small dict — no locks, no clock reads).  When
enabled, every span records wall time from a MONOTONIC clock
(`time.perf_counter` by default; inject a virtual clock for deterministic
tests), the recording thread (executor worker, streamer staging pool,
main), and its same-thread parent span, then lands in one shared event
buffer under a lock.

Export is the Chrome trace-event JSON format (``"X"`` complete events +
thread-name metadata), so a serve run's trace opens directly in
``ui.perfetto.dev`` or ``chrome://tracing`` — spans nest per thread by
timestamp containment, and cross-thread work (a scan on the executor
thread overlapping a window stage on the prefetch pool) shows as parallel
tracks.

Roofline hook: a span opened with a ``pred_s=<seconds>`` attribute (see
`repro.roofline.replay`) closes with ``measured_s`` and
``roofline_ratio`` (measured / predicted) computed into its args, so
every replay span in the exported trace carries predicted-vs-measured
cost.

See `repro.obs` for the span/metric naming contract.
"""

from __future__ import annotations

import json
import threading
import time
from typing import Any, Callable, Dict, List, Optional

__all__ = ["Tracer", "Span", "NOOP_SPAN", "span", "enable", "disable",
           "enabled", "get_tracer"]

_active: Optional["Tracer"] = None


def enabled() -> bool:
    """True when a tracer is installed (use to gate attr computation that
    would otherwise run on the disabled hot path)."""
    return _active is not None


def get_tracer() -> Optional["Tracer"]:
    return _active


def enable(tracer: Optional["Tracer"] = None) -> "Tracer":
    """Install (and return) the process tracer.  ``enable()`` with no
    argument reuses the current tracer or creates a fresh one."""
    global _active
    _active = tracer if tracer is not None else (_active or Tracer())
    return _active


def disable() -> Optional["Tracer"]:
    """Uninstall the tracer (spans become no-ops again); returns it so the
    caller can still export what was recorded."""
    global _active
    t, _active = _active, None
    return t


class _NoopSpan:
    """Shared do-nothing span handed out while tracing is disabled."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NoopSpan":
        return self


NOOP_SPAN = _NoopSpan()


def span(name: str, **attrs):
    """``with span("replay.scan", rows=K): ...`` — the one instrumentation
    entry point.  Disabled: returns the shared no-op span immediately."""
    t = _active
    if t is None:
        return NOOP_SPAN
    return Span(t, name, attrs)


class Span:
    """One live span (context manager).  `set(**attrs)` adds args mid-span
    (e.g. a result size known only after the work ran)."""

    __slots__ = ("tracer", "name", "args", "t0")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, Any]):
        self.tracer = tracer
        self.name = name
        self.args = args
        self.t0 = 0.0

    def set(self, **attrs) -> "Span":
        self.args.update(attrs)
        return self

    def __enter__(self) -> "Span":
        self.tracer._stack().append(self.name)
        self.t0 = self.tracer.clock()
        return self

    def __exit__(self, *exc) -> bool:
        tr = self.tracer
        t1 = tr.clock()
        stack = tr._stack()
        if stack and stack[-1] == self.name:
            stack.pop()
        args = self.args
        pred = args.get("pred_s")
        if pred:
            dur = max(t1 - self.t0, 0.0)
            args["measured_s"] = dur
            args["roofline_ratio"] = dur / float(pred)
        if stack:
            args.setdefault("parent", stack[-1])
        tr._record(self.name, self.t0, t1, args)
        return False


def _jsonable(v):
    """Chrome-export fallback for non-JSON arg values (numpy scalars,
    dtypes, exceptions, ...)."""
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)


class Tracer:
    """Event buffer + clock.  Thread-safe: spans may open and close on any
    thread; each thread keeps its own nesting stack (`threading.local`)
    and all completed spans serialize into one buffer under a lock."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 max_events: int = 1_000_000):
        self.clock = clock
        self.max_events = int(max_events)
        self.dropped = 0
        self._lock = threading.Lock()
        self._events: List[Dict[str, Any]] = []
        self._tls = threading.local()
        self._tids: Dict[int, int] = {}
        self._tid_names: Dict[int, str] = {}
        self._t0 = clock()  # trace epoch: ts are relative microseconds

    # -- per-thread nesting ------------------------------------------------

    def _stack(self) -> List[str]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    # -- recording ---------------------------------------------------------

    def _record(self, name: str, t0: float, t1: float,
                args: Dict[str, Any]) -> None:
        ident = threading.get_ident()
        thread_name = threading.current_thread().name
        with self._lock:
            if len(self._events) >= self.max_events:
                self.dropped += 1
                return
            tid = self._tids.get(ident)
            if tid is None:
                tid = self._tids[ident] = len(self._tids)
                self._tid_names[tid] = thread_name
            self._events.append({
                "name": name, "ph": "X", "pid": 0, "tid": tid,
                "ts": (t0 - self._t0) * 1e6,
                "dur": max(t1 - t0, 0.0) * 1e6,
                "args": args,
            })

    # -- introspection / export --------------------------------------------

    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [dict(e) for e in self._events]

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0

    def to_chrome(self) -> Dict[str, Any]:
        """The trace as a Chrome trace-event JSON document (a dict ready
        for `json.dump`): thread-name metadata first, then every completed
        span as a ``"X"`` complete event in completion order."""
        with self._lock:
            meta = [{"name": "thread_name", "ph": "M", "pid": 0,
                     "tid": tid, "args": {"name": nm}}
                    for tid, nm in sorted(self._tid_names.items())]
            return {"traceEvents": meta + [dict(e) for e in self._events],
                    "displayTimeUnit": "ms"}

    def export_chrome(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, default=_jsonable)
        return path
