"""Architecture / shape registry — populated by the per-arch config modules."""

from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig

ARCHS: Dict[str, ModelConfig] = {}
SHAPES: Dict[str, ShapeConfig] = {}

_ARCH_MODULES = [
    "minicpm3_4b",
    "nemotron_4_15b",
    "internlm2_1_8b",
    "qwen3_32b",
    "zamba2_7b",
    "xlstm_350m",
    "qwen2_moe_a2_7b",
    "moonshot_v1_16b_a3b",
    "whisper_large_v3",
    "chameleon_34b",
    "paper_logreg",
    "paper_mlp",
]


def register(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


def register_shape(cfg: ShapeConfig) -> ShapeConfig:
    SHAPES[cfg.name] = cfg
    return cfg


def _load_all() -> None:
    from repro.configs import shapes  # noqa: F401

    for mod in _ARCH_MODULES:
        try:
            importlib.import_module(f"repro.configs.{mod}")
        except ModuleNotFoundError:
            pass


def get_config(name: str) -> ModelConfig:
    if not ARCHS:
        _load_all()
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if not SHAPES:
        _load_all()
    return SHAPES[name]


def all_archs() -> Dict[str, ModelConfig]:
    if not ARCHS:
        _load_all()
    return dict(ARCHS)


def all_shapes() -> Dict[str, ShapeConfig]:
    if not SHAPES:
        _load_all()
    return dict(SHAPES)
