"""Nemotron-4 15B [arXiv:2402.16819; unverified-tier].

32L, d_model 6144, 48 heads / 8 KV (GQA), d_ff 24576, vocab 256000,
squared-ReLU MLP, RoPE.
"""

from repro.configs.base import ModelConfig
from repro.configs.registry import register

CONFIG = register(
    ModelConfig(
        name="nemotron-4-15b",
        family="dense",
        n_layers=32,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=24576,
        vocab=256_000,
        mlp="relu_sq",
        rope_theta=10000.0,
        source="arXiv:2402.16819",
        notes="squared-ReLU FFN; long_500k skipped (full attention).",
    )
)
