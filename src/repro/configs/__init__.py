from repro.configs.base import (  # noqa: F401
    AttentionKind,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    SSMConfig,
    ShapeConfig,
    XLSTMConfig,
)
from repro.configs.registry import ARCHS, SHAPES, get_config, get_shape  # noqa: F401
