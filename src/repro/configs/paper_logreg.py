"""The paper's own primary benchmark model: L2-regularized logistic
regression (RCV1 / HIGGS / MNIST / covtype experiments, §4.1).

Not an LM — exercised through repro.core + repro.models.simple; registered
here so benchmarks and examples can look it up by name.  Hyper-parameters
follow §4.1: L2 5e-3, lr 0.1 (RCV1 defaults T0=10, j0=10, m=2).
"""

from repro.configs.base import ModelConfig
from repro.configs.registry import register

CONFIG = register(
    ModelConfig(
        name="paper-logreg",
        family="simple",
        n_layers=0,
        d_model=0,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab=2,
        mlp="none",
        source="DeltaGrad ICML 2020 §4.1",
        notes="hyperparams: l2=5e-3, lr=0.1, T0=10, j0=10, m=2 (RCV1)",
    )
)
