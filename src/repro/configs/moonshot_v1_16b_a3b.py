"""Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B; hf-tier].

ASSIGNMENT dims: 48L, d_model 2048, 16 heads (kv=16), vocab 163840, MoE FFN
64 routed experts (top-6, d_expert 1408) + shared experts (2 x 1408).
NOTE: these dims total 28.9B params (4.8B active) — the HF 16B checkpoint
uses 27 layers; we follow the assignment's 48L verbatim and record the
tension here.  64 experts ARE divisible by the 16-way model axis ->
expert-parallel.
"""

from repro.configs.base import ModelConfig, MoEConfig
from repro.configs.registry import register

CONFIG = register(
    ModelConfig(
        name="moonshot-v1-16b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab=163_840,
        mlp="moe",
        moe=MoEConfig(
            num_experts=64,
            top_k=6,
            d_expert=1408,
            num_shared=2,
            d_shared=2816,
            capacity_factor=1.25,
        ),
        rope_theta=50_000.0,
        source="hf:moonshotai/Moonlight-16B-A3B",
        notes="64e divisible by 16 -> expert-parallel; "
              "long_500k skipped (full attention).",
    )
)
