"""Whisper-large-v3 backbone [arXiv:2212.04356; unverified-tier].

Encoder-decoder, d_model 1280, 20 heads (MHA), d_ff 5120, vocab 51866, GELU.
The assignment specifies "32L": realized as 32 encoder + 32 decoder layers
(whisper-large's published layout).  The conv audio frontend is a STUB —
`input_specs()` supplies precomputed frame embeddings (B, S, d_model); shape
cells interpret seq_len as the post-conv frame count and decoder length.

Backbone simplifications (documented): RMSNorm+RoPE in place of
LayerNorm+learned positions, to share the framework's fused block machinery.
long_500k skipped (full attention).  Decode runs the decoder with self- +
cross-attention caches against a fixed encoder memory.
"""

from repro.configs.base import ModelConfig
from repro.configs.registry import register

CONFIG = register(
    ModelConfig(
        name="whisper-large-v3",
        family="audio",
        n_layers=32,  # decoder layers; + n_encoder_layers below
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        d_ff=5120,
        vocab=51866,
        mlp="gelu",
        n_encoder_layers=32,
        frontend="frames",
        rope_theta=10000.0,
        source="arXiv:2212.04356",
        notes="enc-dec; conv frontend stubbed to precomputed frames; "
              "long_500k skipped (full attention).",
    )
)
