"""InternLM2-1.8B [arXiv:2403.17297; hf-tier].

24L, d_model 2048, 16 heads / 8 KV (GQA), d_ff 8192, vocab 92544, SwiGLU.
"""

from repro.configs.base import ModelConfig
from repro.configs.registry import register

CONFIG = register(
    ModelConfig(
        name="internlm2-1.8b",
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=8192,
        vocab=92544,
        mlp="swiglu",
        rope_theta=1_000_000.0,
        source="arXiv:2403.17297 / hf:internlm/internlm2-1_8b",
        notes="long_500k skipped (full attention).",
    )
)
