"""Config dataclasses for the model zoo and the input-shape cells."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


class AttentionKind:
    GQA = "gqa"  # grouped-query (MHA when kv == heads)
    MLA = "mla"  # multi-head latent attention


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 60
    top_k: int = 4
    d_expert: int = 1408  # per-expert FFN hidden
    num_shared: int = 4  # shared experts (always-on)
    d_shared: int = 5632  # shared-expert FFN hidden (total)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    # "onehot": cumsum-of-one-hot position ranking (simple, but the
    # (T*k, E) tensor is unshardable at scale); "sort": argsort-based
    # ranking, O(T*k) memory (see models/moe.py + EXPERIMENTS §Perf MoE)
    dispatch: str = "onehot"


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD block."""

    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128


@dataclass(frozen=True)
class XLSTMConfig:
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 1.3333
    conv_kernel: int = 4


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | hybrid | ssm | moe | audio | vlm | simple
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 -> d_model // n_heads
    attention: str = AttentionKind.GQA
    mlp: str = "swiglu"  # swiglu | relu_sq | gelu | moe | none
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # layer layout: for homogeneous stacks leave None (n_layers x block).
    # hybrid stacks give a repeating unit, e.g. ("mamba2",)*5 + ("attn_shared",)
    layout_unit: Optional[Tuple[str, ...]] = None
    # enc-dec (whisper): encoder layers use bidirectional attention
    n_encoder_layers: int = 0
    # sliding-window size used by attention layers at long context (hybrids)
    attn_window: int = 0  # 0 = full causal
    # frontend stub kind for [audio]/[vlm]: "frames" | "tokens"
    frontend: str = "tokens"
    notes: str = ""
    source: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128,
            vocab=256,
            d_head=16,
            n_encoder_layers=2 if self.n_encoder_layers else 0,
        )
        if self.mla:
            small["mla"] = MLAConfig(
                q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=8,
                qk_rope_head_dim=8, v_head_dim=8,
            )
        if self.moe:
            small["moe"] = dataclasses.replace(
                self.moe, num_experts=8, top_k=2, d_expert=32,
                num_shared=min(self.moe.num_shared, 2), d_shared=64,
            )
        if self.ssm:
            small["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk=16
            )
        if self.layout_unit:
            unit = tuple(self.layout_unit)
            small["n_layers"] = len(unit)  # one repeating unit
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | long_decode

    @property
    def is_decode(self) -> bool:
        return self.kind in ("decode", "long_decode")
