"""Chameleon-34B backbone [arXiv:2405.09818; unverified-tier].

Early-fusion multimodal decoder: 48L, d_model 8192, 64 heads / 8 KV (GQA),
d_ff 22016, vocab 65536 (text + VQ image codes in ONE token space).  The VQ
image tokenizer is a STUB — `input_specs()` supplies fused token ids, which
is exactly what early fusion means for the backbone.  Chameleon's published
training fix (QK-norm) is enabled.  long_500k skipped (full attention).
"""

from repro.configs.base import ModelConfig
from repro.configs.registry import register

CONFIG = register(
    ModelConfig(
        name="chameleon-34b",
        family="vlm",
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab=65536,
        mlp="swiglu",
        qk_norm=True,
        rope_theta=10000.0,
        source="arXiv:2405.09818",
        notes="early fusion = plain decoder over fused token space; "
              "VQ frontend stubbed.",
    )
)
