"""Qwen3-32B [hf:Qwen/Qwen3-8B family scaling; hf-tier].

64L, d_model 5120, 64 heads / 8 KV (GQA), head_dim 128, d_ff 25600,
vocab 151936, QK-RMSNorm.
"""

from repro.configs.base import ModelConfig
from repro.configs.registry import register

CONFIG = register(
    ModelConfig(
        name="qwen3-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=64,
        n_kv_heads=8,
        d_head=128,
        d_ff=25600,
        vocab=151_936,
        mlp="swiglu",
        qk_norm=True,
        rope_theta=1_000_000.0,
        source="hf:Qwen/Qwen3-32B",
        notes="qk_norm per-head RMSNorm; long_500k skipped (full attention).",
    )
)
