"""xLSTM-350M [arXiv:2405.04517; unverified-tier].

24 blocks alternating mLSTM / sLSTM (1:1), d_model 1024, 4 heads,
vocab 50304.  d_ff=0 in the assignment: xLSTM blocks carry their own
up-projections (mLSTM pf=2, sLSTM gated-MLP pf=4/3).

SSM family => long_500k RUNS (recurrent state is O(1) in sequence length).
"""

from repro.configs.base import ModelConfig, XLSTMConfig
from repro.configs.registry import register

CONFIG = register(
    ModelConfig(
        name="xlstm-350m",
        family="ssm",
        n_layers=24,
        d_model=1024,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab=50304,
        mlp="none",
        xlstm=XLSTMConfig(proj_factor_mlstm=2.0, proj_factor_slstm=4.0 / 3.0),
        layout_unit=("mlstm", "slstm"),
        source="arXiv:2405.04517",
        notes="mLSTM trained with the chunkwise-parallel form; sLSTM via scan; "
              "long_500k runs (recurrent).",
    )
)
