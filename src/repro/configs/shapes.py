"""Assigned input-shape cells (LM family: seq_len x global_batch)."""

from repro.configs.base import ShapeConfig
from repro.configs.registry import register_shape

TRAIN_4K = register_shape(
    ShapeConfig(name="train_4k", seq_len=4_096, global_batch=256, kind="train")
)
PREFILL_32K = register_shape(
    ShapeConfig(name="prefill_32k", seq_len=32_768, global_batch=32, kind="prefill")
)
DECODE_32K = register_shape(
    ShapeConfig(name="decode_32k", seq_len=32_768, global_batch=128, kind="decode")
)
LONG_500K = register_shape(
    ShapeConfig(name="long_500k", seq_len=524_288, global_batch=1, kind="long_decode")
)
