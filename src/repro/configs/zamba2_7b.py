"""Zamba2-7B [arXiv:2411.15242; unverified-tier] — Mamba2 + shared-attention hybrid.

d_model 3584, 32 heads (shared attention block), d_ff 14336, vocab 32000,
ssm_state 64.  Public description: a stack of Mamba2 blocks with a SHARED
full transformer block applied periodically.  We realize this as 13 units of
(5 x mamba2 + 1 shared-attn) = 78 mixer blocks (the published "81 layers"
counts sub-blocks differently; source is unverified-tier, deviation noted).

Hybrid family => long_500k RUNS for this arch; the shared attention blocks
use a 4096-token sliding-window ring cache at long context so decode state
stays O(window) while the Mamba2 state is O(1).

Realized parameter count: 5.5B (the published 7.4B includes per-invocation
LoRA adapters on the shared blocks and a second alternating shared block,
which this realization folds into one shared block; unverified-tier source).
"""

from repro.configs.base import ModelConfig, SSMConfig
from repro.configs.registry import register

CONFIG = register(
    ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        n_layers=78,  # 13 x (5 mamba2 + 1 shared attn)
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        d_ff=14336,
        vocab=32000,
        mlp="swiglu",
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1,
                      chunk=128),
        layout_unit=("mamba2", "mamba2", "mamba2", "mamba2", "mamba2",
                     "attn_shared"),
        attn_window=4096,
        rope_theta=10000.0,
        source="arXiv:2411.15242",
        notes="shared attention params, per-occurrence KV caches; "
              "long_500k runs (hybrid).",
    )
)
