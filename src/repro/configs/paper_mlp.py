"""The paper's 2-layer ReLU network (MNIST^n experiment, §4.1).

300 hidden units, L2 1e-3, lr 0.2 -> 0.1 after 10 iterations, deterministic
GD, DeltaGrad run with the Algorithm-4 non-convex guard (T0=2, first quarter
of iterations as burn-in).
"""

from repro.configs.base import ModelConfig
from repro.configs.registry import register

CONFIG = register(
    ModelConfig(
        name="paper-mlp",
        family="simple",
        n_layers=2,
        d_model=300,
        n_heads=0,
        n_kv_heads=0,
        d_ff=300,
        vocab=10,
        mlp="none",
        source="DeltaGrad ICML 2020 §4.1 (MNIST^n)",
        notes="hyperparams: l2=1e-3, lr=(0:0.2, 10:0.1), T0=2, j0=T/4, guard on",
    )
)
