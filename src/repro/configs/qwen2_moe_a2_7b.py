"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B; hf-tier].

24L, d_model 2048, 16 heads (MHA: kv=16), vocab 151936.  MoE FFN: 60 routed
experts (top-4, d_expert 1408) + 4 shared experts (shared intermediate 5632).
60 experts are NOT divisible by the 16-way model axis, so expert weights are
tensor-parallel on d_expert instead of expert-parallel (see dist/sharding).
"""

from repro.configs.base import ModelConfig, MoEConfig
from repro.configs.registry import register

CONFIG = register(
    ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab=151_936,
        mlp="moe",
        moe=MoEConfig(
            num_experts=60,
            top_k=4,
            d_expert=1408,
            num_shared=4,
            d_shared=5632,
            capacity_factor=1.25,
        ),
        rope_theta=1_000_000.0,
        source="hf:Qwen/Qwen1.5-MoE-A2.7B",
        notes="60e not divisible by model axis -> TP on d_expert; "
              "long_500k skipped (full attention).",
    )
)
