"""Scan-engine parity vs the legacy per-step loop (core/engine.py).

The compiled replay engine must be a pure performance refactor: for every
mode x optimizer combination, final parameters from the `lax.scan` path must
match the pre-refactor python loop (kept as `impl="python"`) to <= 1e-5, and
the RetrainStats counters must agree exactly.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.deltagrad import (
    DeltaGradConfig,
    baseline_retrain,
    deltagrad_retrain,
    sgd_train_with_cache,
)
from repro.core.history import HistoryMeta, TrainingHistory
from repro.core.online import online_deltagrad
from repro.data.synthetic import binary_classification
from repro.models.simple import logreg_init, logreg_objective
from repro.utils.tree import tree_norm, tree_sub

TOL = 1e-5


def _problem(n=1200, d=12, steps=60, batch=256, momentum=0.0, seed=0):
    ds = binary_classification(n=n, d=d, seed=seed)
    obj = logreg_objective(l2=5e-3)
    meta = HistoryMeta(n=ds.n, batch_size=batch, seed=7, steps=steps,
                       lr_schedule=((0, 0.3),), momentum=momentum)
    p0 = logreg_init(d, seed=seed + 1)
    return ds, obj, meta, p0


def _dist(a, b):
    return float(tree_norm(tree_sub(a, b)))


CFG = DeltaGradConfig(period=5, burn_in=8, history_size=2)
CFG_PY = dataclasses.replace(CFG, impl="python")


class TestTrainingParity:
    @pytest.mark.parametrize("momentum", [0.0, 0.9])
    def test_record_scan_matches_loop(self, momentum):
        ds, obj, meta, p0 = _problem(momentum=momentum)
        w_s, h_s = sgd_train_with_cache(obj, p0, ds, meta, impl="scan")
        w_p, h_p = sgd_train_with_cache(obj, p0, ds, meta, impl="python")
        assert _dist(w_s, w_p) < TOL
        for t in (0, meta.steps // 2, meta.steps - 1):
            es, ep = h_s.entry(t), h_p.entry(t)
            assert _dist(es[0], ep[0]) < TOL
            assert _dist(es[1], ep[1]) < TOL


class TestBaselineParity:
    @pytest.mark.parametrize("mode", ["delete", "add"])
    @pytest.mark.parametrize("batch", [256, 1 << 30])
    def test_baseline_scan_matches_loop(self, mode, batch):
        ds, obj, meta, p0 = _problem(batch=batch)
        changed = np.random.default_rng(3).choice(meta.n, 12, replace=False)
        if mode == "add":
            changed = ds.append({k: v[changed] for k, v in ds.columns.items()})
        w_s, _ = baseline_retrain(obj, ds, meta, p0, changed, mode, impl="scan")
        w_p, _ = baseline_retrain(obj, ds, meta, p0, changed, mode,
                                  impl="python")
        assert _dist(w_s, w_p) < TOL


class TestReplayParity:
    @pytest.mark.parametrize("mode", ["delete", "add"])
    @pytest.mark.parametrize("batch", [256, 1 << 30])  # SGD and GD
    @pytest.mark.parametrize("momentum", [0.0, 0.9])
    def test_replay_scan_matches_loop(self, mode, batch, momentum):
        ds, obj, meta, p0 = _problem(batch=batch, momentum=momentum)
        w_star, hist = sgd_train_with_cache(obj, p0, ds, meta)
        changed = np.random.default_rng(4).choice(meta.n, 10, replace=False)
        if mode == "add":
            changed = ds.append({k: v[changed] for k, v in ds.columns.items()})
        w_s, st_s = deltagrad_retrain(obj, hist, ds, changed, CFG, mode=mode)
        w_p, st_p = deltagrad_retrain(obj, hist, ds, changed, CFG_PY,
                                      mode=mode)
        assert _dist(w_s, w_p) < TOL, (mode, batch, momentum)
        assert st_s.extra["impl"] == "scan" and st_p.extra["impl"] == "python"
        for f in ("explicit_steps", "approx_steps", "guard_fallbacks",
                  "skipped_steps", "grad_examples", "grad_examples_baseline"):
            assert getattr(st_s, f) == getattr(st_p, f), f

    def test_skip_steps_counted_identically(self):
        ds, obj, meta, p0 = _problem(n=40, d=5, steps=10, batch=8)
        _, hist = sgd_train_with_cache(obj, p0, ds, meta)
        from repro.data.sampler import batch_indices
        batch0 = batch_indices(meta.seed, 0, 40, 8)
        cfg = dataclasses.replace(CFG, period=3, burn_in=2)
        w_s, st_s = deltagrad_retrain(obj, hist, ds, batch0, cfg)
        w_p, st_p = deltagrad_retrain(
            obj, hist, ds, batch0, dataclasses.replace(cfg, impl="python"))
        assert st_s.skipped_steps == st_p.skipped_steps >= 1
        assert _dist(w_s, w_p) < TOL

    def test_guard_fallback_counters_on_device(self):
        """guard_norm_clip=0 trips the guard on every approx step; the
        segment-splitting retry must turn each into an explicit step (one
        host sync per scanned segment, never per step)."""
        ds, obj, meta, p0 = _problem()
        _, hist = sgd_train_with_cache(obj, p0, ds, meta)
        changed = np.arange(10)
        cfg = dataclasses.replace(CFG, guard=True, guard_norm_clip=0.0)
        w, st = deltagrad_retrain(obj, hist, ds, changed, cfg)
        assert st.approx_steps == 0
        assert st.guard_fallbacks > 0
        assert st.explicit_steps == meta.steps - st.skipped_steps
        assert np.isfinite(_dist(w, p0))

    @pytest.mark.parametrize("clip", [0.2, 0.0])
    def test_guard_retry_full_stats_parity(self, clip):
        """The two documented scan/python divergences are gone: fallback
        steps admit their L-BFGS pair mid-segment (segment-splitting retry)
        and both backends charge the true grad_examples cost kept + dB, so
        with the guard ON the scan path matches the oracle exactly —
        parameters AND every counter."""
        ds, obj, meta, p0 = _problem()
        _, hist = sgd_train_with_cache(obj, p0, ds, meta)
        changed = np.random.default_rng(4).choice(meta.n, 10, replace=False)
        cfg = dataclasses.replace(CFG, guard=True, guard_norm_clip=clip)
        w_s, st_s = deltagrad_retrain(obj, hist, ds, changed, cfg)
        w_p, st_p = deltagrad_retrain(obj, hist, ds, changed,
                                      dataclasses.replace(cfg, impl="python"))
        assert st_p.guard_fallbacks > 0  # the regime under test
        assert _dist(w_s, w_p) < TOL
        for f in ("explicit_steps", "approx_steps", "guard_fallbacks",
                  "skipped_steps", "grad_examples", "grad_examples_baseline",
                  "pairs_rejected"):
            assert getattr(st_s, f) == getattr(st_p, f), f


ONLINE_TOL = 1.5e-7  # both backends share the per-step math verbatim


def _assert_request_stats_equal(st_s, st_p):
    assert len(st_s.per_request) == len(st_p.per_request)
    for a, b in zip(st_s.per_request, st_p.per_request):
        for f in ("explicit_steps", "approx_steps", "guard_fallbacks",
                  "skipped_steps", "grad_examples",
                  "grad_examples_baseline"):
            assert getattr(a, f) == getattr(b, f), f


class TestOnlineParity:
    def test_online_delete_scan_matches_loop(self):
        reqs = [3, 17, 101]
        ds1, obj, meta, p0 = _problem()
        _, h1 = sgd_train_with_cache(obj, p0, ds1, meta)
        w_s, st_s = online_deltagrad(obj, h1, ds1, reqs, CFG, mode="delete")
        ds2, _, _, _ = _problem()
        _, h2 = sgd_train_with_cache(obj, p0, ds2, meta)
        w_p, st_p = online_deltagrad(obj, h2, ds2, reqs, CFG_PY,
                                     mode="delete")
        assert _dist(w_s, w_p) < ONLINE_TOL
        assert len(st_s.per_request) == len(reqs)
        _assert_request_stats_equal(st_s, st_p)
        # the rewritten caches must agree too (they seed the NEXT request)
        for t in (0, meta.steps - 1):
            assert _dist(h1.entry(t)[0], h2.entry(t)[0]) < TOL
            assert _dist(h1.entry(t)[1], h2.entry(t)[1]) < TOL

    @pytest.mark.parametrize("momentum", [0.0, 0.9])
    def test_online_add_scan_matches_loop(self, momentum):
        """Addition streams must run the scanned path (no python fallback)
        and agree with the per-step oracle in params, rewritten cache, and
        every counter."""

        def run(cfg):
            ds, obj, meta, p0 = _problem(momentum=momentum)
            _, h = sgd_train_with_cache(obj, p0, ds, meta)
            src = np.arange(4)
            new = ds.append({k: v[src] for k, v in ds.columns.items()})
            w, st = online_deltagrad(obj, h, ds, new.tolist(), cfg,
                                     mode="add")
            return w, st, h, meta

        w_s, st_s, h1, meta = run(CFG)
        w_p, st_p, h2, _ = run(CFG_PY)
        assert _dist(w_s, w_p) < ONLINE_TOL, momentum
        _assert_request_stats_equal(st_s, st_p)
        for t in (0, meta.steps // 2, meta.steps - 1):
            assert _dist(h1.entry(t)[0], h2.entry(t)[0]) < TOL
            assert _dist(h1.entry(t)[1], h2.entry(t)[1]) < TOL

    def test_online_momentum_delete_scan_matches_loop(self):
        """Heavy-ball histories are no longer rejected: the velocity is
        reconstructed per request inside the scan carry."""
        reqs = [3, 17, 101, 640]

        def run(cfg):
            ds, obj, meta, p0 = _problem(momentum=0.9)
            _, h = sgd_train_with_cache(obj, p0, ds, meta)
            return online_deltagrad(obj, h, ds, reqs, cfg, mode="delete")

        w_s, st_s = run(CFG)
        w_p, st_p = run(CFG_PY)
        assert _dist(w_s, w_p) < ONLINE_TOL
        _assert_request_stats_equal(st_s, st_p)

    def test_online_mixed_stream_scan_matches_loop(self):
        """Interleaved delete/add requests — including deletion of a row
        added earlier in the same stream."""

        def run(cfg):
            ds, obj, meta, p0 = _problem()
            _, h = sgd_train_with_cache(obj, p0, ds, meta)
            new = ds.append({k: v[10:13] for k, v in ds.columns.items()})
            reqs = [("delete", 3), ("add", int(new[0])), ("delete", 17),
                    ("add", int(new[1])), ("delete", int(new[0])),
                    ("add", int(new[2])), ("delete", 101)]
            return online_deltagrad(obj, h, ds, reqs, cfg)

        w_s, st_s = run(CFG)
        w_p, st_p = run(CFG_PY)
        assert _dist(w_s, w_p) < ONLINE_TOL
        _assert_request_stats_equal(st_s, st_p)

    def test_online_guard_retry_matches_loop(self):
        """Online guard fallbacks admit their L-BFGS pair via the
        segment-splitting retry, so the scan path tracks the oracle even
        when the Algorithm-4 guard trips repeatedly."""
        cfg = dataclasses.replace(CFG, guard=True, guard_norm_clip=0.1)

        def run(c):
            ds, obj, meta, p0 = _problem()
            _, h = sgd_train_with_cache(obj, p0, ds, meta)
            return online_deltagrad(obj, h, ds, [3, 17, 101], c)

        w_s, st_s = run(cfg)
        w_p, st_p = run(dataclasses.replace(cfg, impl="python"))
        assert sum(s.guard_fallbacks for s in st_p.per_request) > 0
        assert _dist(w_s, w_p) < ONLINE_TOL
        _assert_request_stats_equal(st_s, st_p)

    def test_online_fully_deleted_batch_matches_loop(self):
        """Degenerate Algorithm-3 case: earlier requests empty a whole batch,
        then a later request replays it with kept == 0 and the request row
        absent — the scan path must execute (not skip) those steps exactly
        like the python oracle."""
        from repro.data.sampler import batch_indices

        def make():
            ds = binary_classification(n=40, d=5, seed=9)
            obj = logreg_objective(l2=5e-3)
            meta = HistoryMeta(n=40, batch_size=4, seed=1, steps=12,
                               lr_schedule=((0, 0.1),))
            p0 = logreg_init(5, seed=2)
            _, h = sgd_train_with_cache(obj, p0, ds, meta)
            return ds, obj, meta, h

        ds1, obj, meta, h1 = make()
        batch3 = batch_indices(meta.seed, 3, meta.n, meta.batch_size)
        outside = next(i for i in range(meta.n) if i not in set(batch3))
        reqs = [int(i) for i in batch3] + [outside]
        cfg = dataclasses.replace(CFG, burn_in=2, period=4)
        w_s, st_s = online_deltagrad(obj, h1, ds1, reqs, cfg, mode="delete")
        ds2, _, _, h2 = make()
        w_p, st_p = online_deltagrad(
            obj, h2, ds2, reqs, dataclasses.replace(cfg, impl="python"),
            mode="delete")
        assert _dist(w_s, w_p) < TOL
        for a, b in zip(st_s.per_request, st_p.per_request):
            assert a.skipped_steps == b.skipped_steps
            assert a.approx_steps == b.approx_steps
        for t in (3, meta.steps - 1):
            assert _dist(h1.entry(t)[1], h2.entry(t)[1]) < TOL


class TestStackedTier:
    def test_stacked_history_roundtrip_and_overwrite(self):
        ds, obj, meta, p0 = _problem(steps=20)
        _, h = sgd_train_with_cache(obj, p0, ds, meta, tier="stacked")
        _, h2 = sgd_train_with_cache(obj, p0, ds, meta, tier="device")
        assert len(h) == meta.steps
        for t in (0, 7, 19):
            assert _dist(h.entry(t)[0], h2.entry(t)[0]) < 1e-7
        w5, g5 = h.entry(5)
        marked = {k: v + 1.0 for k, v in w5.items()}
        h.overwrite(5, marked, g5)
        assert _dist(h.entry(5)[0], marked) < 1e-7
        assert _dist(h.entry(4)[0], h2.entry(4)[0]) < 1e-7
        state = h.state_dict()
        h3 = TrainingHistory.from_state_dict(state)
        assert _dist(h3.entry(5)[0], marked) < 1e-7

    def test_replay_works_from_every_memory_tier(self):
        changed = np.arange(8)
        ds, obj, meta, p0 = _problem(steps=30)
        ref_w = None
        for tier, want_store in (("stacked", "resident"),
                                 ("device", "resident"),
                                 ("host", "streamed")):
            _, h = sgd_train_with_cache(obj, p0, ds, meta, tier=tier)
            w, st = deltagrad_retrain(obj, h, ds, changed, CFG)
            # every tier runs the compiled scan; offload tiers are not
            # stacked onto the device — they stream segment windows
            # (core.store.SegmentStreamer), never the whole path
            assert st.extra["impl"] == "scan", tier
            assert st.extra["store"] == want_store, tier
            ref_w = w if ref_w is None else ref_w
            assert _dist(w, ref_w) < TOL, tier

    def test_device_tier_records_without_duplicating(self):
        """set_stacked must not keep per-entry slice copies next to the
        stacked arrays (2x HBM)."""
        ds, obj, meta, p0 = _problem(steps=10)
        _, h = sgd_train_with_cache(obj, p0, ds, meta, tier="device")
        leaves = sum(x.nbytes for x in
                     __import__("jax").tree.leaves(h.stacked_view()))
        assert h.nbytes() <= leaves * 1.01


class TestFusedKernelRouting:
    def test_interpret_mode_matches_ref(self):
        """The Pallas fused_update wiring, exercised end-to-end through the
        engine in interpret mode (CPU stand-in for the TPU kernel path)."""
        ds, obj, meta, p0 = _problem(steps=30)
        _, hist = sgd_train_with_cache(obj, p0, ds, meta)
        changed = np.arange(6)
        w_ref, st_ref = deltagrad_retrain(
            obj, hist, ds, changed,
            dataclasses.replace(CFG, fused="ref"))
        w_int, st_int = deltagrad_retrain(
            obj, hist, ds, changed,
            dataclasses.replace(CFG, fused="interpret"))
        assert st_ref.extra["fused"] == "ref"
        assert st_int.extra["fused"] == "interpret"
        assert _dist(w_ref, w_int) < TOL
