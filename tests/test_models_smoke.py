"""Per-arch reduced-config smoke tests (assignment requirement): one forward
+ one train step + one decode step on CPU; output shapes + finiteness.
Plus cross-form equivalence tests for the recurrent blocks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, SSMConfig, XLSTMConfig
from repro.configs.registry import all_archs, get_config
from repro.models.registry import build, count_params

TINY = ShapeConfig(name="tiny", seq_len=32, global_batch=2, kind="train")

LM_ARCHS = [name for name, cfg in all_archs().items() if cfg.family != "simple"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_arch_smoke(arch):
    cfg = get_config(arch).reduced()
    model = build(cfg)
    params = model.init(0)
    batch = model.sample_batch(TINY)

    loss = model.loss_fn(params, batch, remat=False, loss_chunk=16)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch

    grads = jax.grad(
        lambda p: model.loss_fn(p, batch, remat=False, loss_chunk=16))(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch

    if cfg.family == "audio":
        caches = model.cache_init(2, 32, enc_len=16)
    else:
        caches = model.cache_init(2, 32)
    logits, caches2 = model.decode_fn(
        params, {"tokens": jnp.zeros((2, 1), jnp.int32)}, caches)
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    # cache structure preserved
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_arch_remat_matches_noremat(arch):
    cfg = get_config(arch).reduced()
    model = build(cfg)
    params = model.init(1)
    batch = model.sample_batch(TINY, seed=1)
    l1 = model.loss_fn(params, batch, remat=False, loss_chunk=16)
    l2 = model.loss_fn(params, batch, remat=True, loss_chunk=16)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_full_config_param_counts_in_expected_range():
    """Analytic (eval_shape) parameter counts vs published sizes."""
    expect = {
        "minicpm3-4b": (3e9, 6e9),
        "nemotron-4-15b": (13e9, 18e9),
        "internlm2-1.8b": (1.5e9, 2.3e9),
        "qwen3-32b": (28e9, 36e9),
        # zamba2: our 13x(5 mamba + shared attn) realization of the
        # unverified-tier config counts 5.5B (see configs/zamba2_7b.py)
        "zamba2-7b": (5e9, 9e9),
        "xlstm-350m": (0.25e9, 0.5e9),
        "qwen2-moe-a2.7b": (12e9, 16e9),
        # moonshot: the ASSIGNMENT dims (48L x 64e x 1408) give 28.9B total
        # (4.8B active); the HF 16B model uses 27 layers — we follow the
        # assignment (see configs/moonshot_v1_16b_a3b.py)
        "moonshot-v1-16b-a3b": (25e9, 32e9),
        "whisper-large-v3": (1.2e9, 2.2e9),
        "chameleon-34b": (30e9, 38e9),
    }
    for arch, (lo, hi) in expect.items():
        n = count_params(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


# -- recurrent-form equivalences --------------------------------------------


def test_mamba2_decode_matches_full():
    from repro.models.mamba2 import (mamba2_apply, mamba2_cache_init,
                                     mamba2_decode, mamba2_init)
    cfg = SSMConfig(d_state=8, head_dim=8, chunk=4, n_groups=1, expand=2)
    d = 16
    p = mamba2_init(jax.random.PRNGKey(2), d, cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(3), (2, 8, d))
    full = mamba2_apply(p, x, d, cfg)
    cache = mamba2_cache_init(2, d, cfg)
    outs = []
    for t in range(8):
        o, cache = mamba2_decode(p, x[:, t:t + 1], cache, d, cfg)
        outs.append(o[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), atol=2e-5)


def test_xlstm_parallel_vs_recurrent_vs_chunked():
    from repro.models.xlstm import (mlstm_cache_init, mlstm_chunked,
                                    mlstm_init, mlstm_parallel, mlstm_step)
    cfg = XLSTMConfig()
    d, H = 32, 4
    p = mlstm_init(jax.random.PRNGKey(0), d, H, cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, d))
    full = mlstm_parallel(p, x, H)
    chk = mlstm_chunked(p, x, H, chunk=4)
    np.testing.assert_allclose(np.asarray(chk), np.asarray(full), atol=2e-5)
    cache = mlstm_cache_init(2, d, H, cfg)
    outs = []
    for t in range(16):
        o, cache = mlstm_step(p, x[:, t:t + 1], cache, H)
        outs.append(o[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), atol=2e-5)


def test_moe_capacity_dispatch_matches_dense_oracle():
    from repro.configs.base import MoEConfig
    from repro.models.moe import moe_apply, moe_init, moe_ref
    cfg = MoEConfig(num_experts=8, top_k=2, d_expert=16, num_shared=2,
                    d_shared=32, capacity_factor=2.0)
    p = moe_init(jax.random.PRNGKey(0), 32, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 32))
    out, aux = moe_apply(p, x, cfg)
    ref = moe_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
    assert float(aux) >= 1.0  # Switch aux loss is >= 1 at balance


def test_gqa_ring_cache_matches_full_window():
    """Sliding-window ring cache == full cache + window mask."""
    from repro.models.layers import gqa_cache_init, gqa_decode, gqa_init
    d, H, Hkv, dh, win = 32, 4, 2, 8, 4
    p = gqa_init(jax.random.PRNGKey(0), d, H, Hkv, dh)
    xs = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (1, 12, d))
    ring = gqa_cache_init(1, win, Hkv, dh)  # ring buffer (size == window)
    full = gqa_cache_init(1, 12, Hkv, dh)
    for t in range(12):
        o_ring, ring = gqa_decode(p, xs[:, t:t + 1], ring, n_heads=H,
                                  n_kv=Hkv, d_head=dh, rope_theta=1e4,
                                  window=win)
        o_full, full = gqa_decode(p, xs[:, t:t + 1], full, n_heads=H,
                                  n_kv=Hkv, d_head=dh, rope_theta=1e4,
                                  window=win)
        np.testing.assert_allclose(np.asarray(o_ring), np.asarray(o_full),
                                   atol=3e-5, err_msg=f"t={t}")
