"""Per-kernel shape/dtype sweeps vs pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lbfgs import lbfgs_hvp_stacked
from repro.kernels.dequant_update.ops import dequant_sub, dequant_update
from repro.kernels.dequant_update.ref import (dequant_ref, dequant_sub_ref,
                                              dequant_update_ref)
from repro.kernels.flash_attention.ops import attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.fused_update.ops import update
from repro.kernels.fused_update.ref import deltagrad_update_ref
from repro.kernels.lbfgs.ops import lbfgs_hvp_fused, multidot
from repro.kernels.lbfgs.ref import multidot_ref


# -- lbfgs multidot / rank update ---------------------------------------------


@pytest.mark.parametrize("m,p", [(1, 512), (2, 1000), (3, 4096), (8, 777)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_multidot_sweep(m, p, dtype):
    rng = np.random.default_rng(m * 1000 + p)
    dW = jnp.asarray(rng.normal(size=(m, p)), dtype)
    dG = jnp.asarray(rng.normal(size=(m, p)), dtype)
    v = jnp.asarray(rng.normal(size=(p,)), dtype)
    sw, sy, wv, gv = multidot(dW, dG, v, interpret=True)
    rsw, rsy, rwv, rgv = multidot_ref(dW, dG, v)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    for got, ref in [(sw, rsw), (sy, rsy), (wv, rwv), (gv, rgv)]:
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=tol, atol=tol * p)


@pytest.mark.parametrize("m,p", [(2, 1024), (5, 2222)])
def test_hvp_fused_matches_core(m, p):
    rng = np.random.default_rng(7)
    A = rng.normal(size=(p, p)).astype(np.float32) / p
    H = A @ A.T + np.eye(p, dtype=np.float32)
    dW = jnp.asarray(rng.normal(size=(m, p)).astype(np.float32))
    dG = jnp.asarray(np.asarray(dW) @ H)
    v = jnp.asarray(rng.normal(size=(p,)).astype(np.float32))
    out = lbfgs_hvp_fused(dW, dG, v, interpret=True)
    ref = lbfgs_hvp_stacked(dW, dG, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4,
                               atol=1e-4)


# -- flash attention ------------------------------------------------------------


@pytest.mark.parametrize(
    "B,S,H,Hkv,D,bq,bk,causal",
    [
        (2, 128, 4, 2, 64, 64, 64, True),
        (1, 256, 8, 8, 32, 128, 128, True),
        (2, 100, 4, 1, 64, 32, 32, True),  # unaligned seq (padding path)
        (1, 128, 2, 2, 128, 128, 128, False),
        (1, 64, 4, 4, 16, 16, 32, True),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, Hkv, D, bq, bk, causal, dtype):
    key = jax.random.PRNGKey(B * 100 + S)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    out = attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                    interpret=True)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3),
                        causal=causal).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol)


def test_flash_attention_matches_model_blockwise_path():
    """Kernel == the XLA blockwise path used inside the models."""
    from repro.models.layers import blockwise_attention
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    B, S, H, Hkv, D = 2, 128, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    out_kernel = attention(q, k, v, causal=True, block_q=64, block_k=64,
                           interpret=True)
    out_xla = blockwise_attention(q, k, v, causal=True, block_k=64)
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_xla),
                               rtol=2e-5, atol=2e-5)


# -- fused DeltaGrad update -------------------------------------------------------


@pytest.mark.parametrize("p", [512, 1000, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sign", [1.0, -1.0])
def test_fused_update_sweep(p, dtype, sign):
    rng = np.random.default_rng(p)
    w, g, bv, gc = [jnp.asarray(rng.normal(size=(p,)), dtype)
                    for _ in range(4)]
    out = update(w, g, bv, gc, 0.1, 512.0, 3.0, sign, interpret=True)
    ref = deltagrad_update_ref(w, g, bv, gc, jnp.float32(0.1),
                               jnp.float32(512.0), jnp.float32(3.0),
                               jnp.float32(sign))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=2e-2,
                               atol=2e-2)


# -- fused dequant + update (encoded streamed history) ------------------------


def _encoded_operand(rng, p, qdtype, delta):
    """(q, scale, base) mimicking an EncodedLeaf slice: int8 carries a
    per-entry scale, bf16 is a plain cast, `delta` adds an f32 keyframe."""
    x = rng.normal(size=(p,)).astype(np.float32) * 0.05
    if qdtype == jnp.int8:
        scale = np.float32(np.max(np.abs(x)) / 127.0)
        q = jnp.asarray(np.clip(np.round(x / scale), -127, 127), jnp.int8)
    else:
        scale = np.float32(1.0)
        q = jnp.asarray(x, jnp.bfloat16)
    base = jnp.asarray(rng.normal(size=(p,)).astype(np.float32)) \
        if delta else None
    return q, scale, base


@pytest.mark.parametrize("p", [512, 1000, 4096])
@pytest.mark.parametrize("qdtype", [jnp.int8, jnp.bfloat16])
@pytest.mark.parametrize("delta", [False, True])
def test_dequant_update_sweep(p, qdtype, delta):
    rng = np.random.default_rng(p + int(delta))
    q, scale, base = _encoded_operand(rng, p, qdtype, delta)
    w, bv, gc = [jnp.asarray(rng.normal(size=(p,)).astype(np.float32))
                 for _ in range(3)]
    out = dequant_update(w, q, bv, gc, 0.1, 512.0, 3.0, 1, scale, base,
                         interpret=True)
    f32 = jnp.float32
    ref = dequant_update_ref(w, q, bv, gc, f32(0.1), f32(512.0), f32(3.0),
                             f32(1.0), f32(scale), base)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-2,
                               atol=2e-2)


@pytest.mark.parametrize("p", [512, 1000])
@pytest.mark.parametrize("qdtype", [jnp.int8, jnp.bfloat16])
@pytest.mark.parametrize("delta", [False, True])
def test_dequant_sub_sweep(p, qdtype, delta):
    rng = np.random.default_rng(p + 2 * int(delta))
    q, scale, base = _encoded_operand(rng, p, qdtype, delta)
    w = jnp.asarray(rng.normal(size=(p,)).astype(np.float32))
    out = dequant_sub(w, q, scale, base, interpret=True)
    ref = dequant_sub_ref(w, q, jnp.float32(scale), base)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-2,
                               atol=2e-2)


def test_dequant_absmax_zero_scale_one():
    """An all-zero residual leaf stores scale 1.0 and q zeros — the kernel
    must return the base exactly (keyframe entries decode bitwise)."""
    p = 512
    base = jnp.asarray(np.random.default_rng(0)
                       .normal(size=(p,)).astype(np.float32))
    q = jnp.zeros((p,), jnp.int8)
    w = base * 2.0
    out = dequant_sub(w, q, np.float32(1.0), base, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(w - base))


def test_dequant_ref_is_the_decode_expression():
    """The ref oracle and the store's slice decode share one expression."""
    from repro.core.store import EncodedLeaf, _decode_leaf_slice
    rng = np.random.default_rng(3)
    p = 64
    q = jnp.asarray(rng.integers(-127, 127, size=(2, p)), jnp.int8)
    scale = jnp.asarray(rng.random(2).astype(np.float32))
    base = jnp.asarray(rng.normal(size=(1, p)).astype(np.float32))
    leaf = EncodedLeaf(q=q, scale=scale, base=base,
                       kidx=jnp.zeros((2,), jnp.int32))
    got = jax.jit(lambda lf: _decode_leaf_slice(lf, 1))(leaf)
    ref = jax.jit(dequant_ref)(q[1], scale[1], base[0])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
