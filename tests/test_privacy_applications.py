"""§5 applications: privacy (Laplace deletion), jackknife, conformal,
data valuation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.applications import (
    cross_conformal,
    data_values,
    jackknife_bias_correct,
)
from repro.core.deltagrad import DeltaGradConfig, sgd_train_with_cache
from repro.core.history import HistoryMeta
from repro.core.privacy import (
    DeletionBoundConstants,
    empirical_epsilon,
    laplace_publish,
    num_params,
)
from repro.data.synthetic import binary_classification
from repro.models.simple import logreg_init, logreg_objective


@pytest.fixture(scope="module")
def fitted():
    ds = binary_classification(n=400, d=8, seed=0)
    obj = logreg_objective(l2=5e-3)
    meta = HistoryMeta(n=400, batch_size=128, seed=3, steps=30,
                      lr_schedule=((0, 0.3),))
    p0 = logreg_init(8, seed=1)
    w, h = sgd_train_with_cache(obj, p0, ds, meta)
    return ds, obj, w, h


def test_laplace_publish_shapes_and_scale(fitted):
    _, _, w, _ = fitted
    noised = laplace_publish(jax.random.PRNGKey(0), w, eps=1.0, delta0=1e-3)
    assert jax.tree.structure(noised) == jax.tree.structure(w)
    p = num_params(w)
    diff = np.concatenate([np.asarray(a - b).ravel()
                           for a, b in zip(jax.tree.leaves(noised),
                                           jax.tree.leaves(w))])
    # Laplace(scale) has std sqrt(2)*scale; sanity-band the empirical std
    scale = np.sqrt(p) * 1e-3 / 1.0
    assert 0.2 * scale < diff.std() < 5 * scale


def test_deletion_bound_constants():
    # the guarantee needs mu/2 > c0*M1*r/(2n) (+ r/(n-r) mu), M1 = 2 c2/mu
    c = DeletionBoundConstants(mu=0.5, L=1.0, c0=0.1, c2=0.1, lr=0.1,
                               n=1_000_000, r=10)
    d0 = c.delta0()
    assert d0 > 0
    # weak convexity + large r -> denominator <= 0 -> must refuse
    bad = DeletionBoundConstants(mu=5e-3, L=1.0, c0=1.0, c2=1.0, lr=0.1,
                                 n=10000, r=10)
    with pytest.raises(ValueError):
        bad.delta0()


def test_empirical_epsilon_monotone(fitted):
    _, _, w, _ = fitted
    w2 = jax.tree.map(lambda x: x + 1e-4, w)
    p = num_params(w)
    e1 = empirical_epsilon(w, w2, eps=1.0, delta0=1e-2, p=p)
    e2 = empirical_epsilon(w, w2, eps=1.0, delta0=1e-3, p=p)
    assert e2 > e1  # tighter claimed bound -> larger achieved ratio


def test_data_values_flag_no_influence(fitted):
    ds, obj, _, hist = fitted
    cfg = DeltaGradConfig(period=10, burn_in=5)
    vals = data_values(obj, hist, ds, indices=[0, 1, 2], cfg=cfg)
    assert vals.shape == (3,)
    assert (vals >= 0).all() and np.isfinite(vals).all()


def test_jackknife_bias_correct(fitted):
    ds, obj, _, hist = fitted
    cfg = DeltaGradConfig(period=10, burn_in=5)
    est = lambda params: np.asarray(params["w"])[:2]  # noqa: E731
    out = jackknife_bias_correct(est, obj, hist, ds, cfg, indices=range(5))
    assert out["corrected"].shape == (2,)
    np.testing.assert_allclose(out["corrected"],
                               out["estimate"] - out["bias"])


def test_cross_conformal_intervals(fitted):
    ds, obj, _, hist = fitted
    cfg = DeltaGradConfig(period=10, burn_in=5)

    def predict(params, x):
        return np.asarray(jax.nn.sigmoid(x @ np.asarray(params["w"])
                                         + float(params["b"])))

    x_test = ds.columns["x"][:10]
    cs = cross_conformal(obj, hist, ds, predict, x_test, K=4, alpha=0.1,
                         cfg=cfg)
    assert (cs.upper >= cs.lower).all()
    y = ds.columns["y"][:10].astype(np.float64)
    coverage = ((y >= cs.lower) & (y <= cs.upper)).mean()
    assert coverage >= 0.5  # loose sanity (alpha=0.1 target is ~0.8)


# -- satellite: calibrated, jit-compatible publication mechanisms ----------


def test_noise_scale_monotone_decreasing_in_eps():
    """Looser budget -> less noise, for BOTH mechanisms.  Same key means
    identical unit samples, so the Laplace perturbations must scale
    EXACTLY as 1/eps."""
    from repro.core.privacy import gaussian_sigma

    sigmas = [gaussian_sigma(0.5, eps, 1e-5) for eps in (0.1, 1.0, 10.0)]
    assert sigmas[0] > sigmas[1] > sigmas[2] > 0.0

    w = {"w": jnp.linspace(-1, 1, 32, dtype=jnp.float32)}
    key = jax.random.PRNGKey(3)
    d1 = laplace_publish(key, w, eps=0.1, delta0=1e-3)["w"] - w["w"]
    d2 = laplace_publish(key, w, eps=10.0, delta0=1e-3)["w"] - w["w"]
    # recovering the perturbation by subtraction rounds at f32, so the
    # exact 1/eps proportionality of the samples shows up at ~1e-3
    np.testing.assert_allclose(np.asarray(d1), 100.0 * np.asarray(d2),
                               rtol=5e-3)


def test_publish_preserves_structure_and_dtypes():
    """Published pytrees must match the input EXACTLY in structure, leaf
    shapes, and leaf dtypes (mixed-precision models included)."""
    from repro.core.privacy import gaussian_publish

    w = {"w": jnp.ones((4, 3), dtype=jnp.float32),
         "b": jnp.zeros((), dtype=jnp.float32),
         "h": jnp.full((5,), 0.5, dtype=jnp.float16)}
    for noised in (laplace_publish(jax.random.PRNGKey(0), w, eps=1.0,
                                   delta0=1e-3),
                   gaussian_publish(jax.random.PRNGKey(0), w, sigma=1e-3)):
        assert jax.tree.structure(noised) == jax.tree.structure(w)
        for a, b in zip(jax.tree.leaves(noised), jax.tree.leaves(w)):
            assert a.dtype == b.dtype and a.shape == b.shape
            assert not np.array_equal(np.asarray(a), np.asarray(b))


def test_publish_is_deterministic_under_key_and_split_per_leaf():
    """Same key -> bitwise-identical publication (the session snapshot
    guarantee); different leaves must not share a noise stream."""
    w = {"a": jnp.zeros((8,), dtype=jnp.float32),
         "b": jnp.zeros((8,), dtype=jnp.float32)}
    key = jax.random.PRNGKey(7)
    n1 = laplace_publish(key, w, eps=1.0, delta0=1e-3)
    n2 = laplace_publish(key, w, eps=1.0, delta0=1e-3)
    for x, y in zip(jax.tree.leaves(n1), jax.tree.leaves(n2)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert not np.array_equal(np.asarray(n1["a"]), np.asarray(n1["b"]))


def test_gaussian_sigma_validates_delta():
    from repro.core.privacy import gaussian_sigma

    with pytest.raises(ValueError):
        gaussian_sigma(0.5, 1.0, 0.0)
    with pytest.raises(ValueError):
        gaussian_sigma(0.5, 1.0, 1.0)
