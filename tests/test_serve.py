"""Continuous-batching serving tier: admission, EDF flush, snapshots.

The contract under test: the serving subsystem decides WHEN to flush and
WHAT to coalesce but never HOW to replay — every batch goes through the
unchanged session submit/coalesce/flush path, so admission control, SLA
deadlines, and cross-tenant batching compose with the engine's numerics
(scan-vs-python parity, pow2 bucketing, snapshot determinism) untouched.
"""

import copy

import numpy as np
import pytest

from repro.core.deltagrad import DeltaGradConfig, _next_pow2
from repro.core.session import (AutoFlushTimer, UnlearnerConfig,
                                UnlearnerSession)
from repro.data.synthetic import binary_classification
from repro.models.simple import logreg_init, logreg_objective
from repro.serve import (AddCapacityLedger, AdmissionQueue, LoadGenerator,
                         QueuedRequest, RetryAfter, ServeConfig,
                         ServingScheduler, SessionFlushClock, SLAClass,
                         TenantQuota, fixed_trace, materialize,
                         poisson_trace)
from repro.utils.tree import tree_norm, tree_sub

CFG = DeltaGradConfig(period=5, burn_in=10, history_size=2)
META = dict(n=200, batch_size=64, seed=0, steps=30, l2=1e-3)


def _session(**kw):
    ds = binary_classification(n=META["n"], d=16, seed=0)
    obj = logreg_objective(l2=META["l2"])
    cfg = UnlearnerConfig(steps=META["steps"],
                          batch_size=META["batch_size"], lr=0.2,
                          seed=0, deltagrad=CFG, **kw)
    sess = UnlearnerSession(obj, logreg_init(16, seed=1), ds, cfg)
    sess.fit()
    return sess


def _dist(a, b):
    return float(tree_norm(tree_sub(a, b)))


def _req(seq=0, tenant="t", op="delete", rows=(1,), sla="interactive",
         t=0.0, deadline=1.0, coalesce=True, data=None):
    return QueuedRequest(seq=seq, tenant=tenant, sla_class=sla, op=op,
                        rows=list(rows) if rows is not None else None,
                        data=data, coalesce=coalesce, t_enqueue=t,
                        deadline=deadline)


class VirtualClock:
    """Deterministic monotonic clock: a fixed tick per call."""

    def __init__(self, tick_s=1e-3):
        self.t = 0.0
        self.tick_s = tick_s

    def __call__(self):
        self.t += self.tick_s
        return self.t


# --------------------------------------------------------------------------
# Admission queue: bounds, quotas, backpressure
# --------------------------------------------------------------------------


class TestAdmissionQueue:
    def test_depth_bound_rejects_with_retry_after(self):
        q = AdmissionQueue(max_depth=2)
        q.admit(_req())
        q.admit(_req())
        with pytest.raises(RetryAfter, match="max_depth"):
            q.admit(_req())
        assert q.rejected_depth == 1 and q.admitted == 2
        # the hint is a positive drain-rate estimate, not a promise
        try:
            q.admit(_req())
        except RetryAfter as e:
            assert e.retry_after_s > 0

    def test_tenant_quota_isolates_tenants(self):
        q = AdmissionQueue(max_depth=100,
                           tenant_quota=TenantQuota(max_pending=2))
        q.admit(_req(tenant="a"))
        q.admit(_req(tenant="a"))
        with pytest.raises(RetryAfter, match="tenant 'a'"):
            q.admit(_req(tenant="a"))
        # tenant a at quota does NOT starve tenant b
        q.admit(_req(tenant="b"))
        assert q.rejected_tenant == 1
        assert q.tenant_depth("a") == 2 and q.tenant_depth("b") == 1

    def test_take_frees_quota(self):
        q = AdmissionQueue(max_depth=100,
                           tenant_quota=TenantQuota(max_pending=1))
        q.admit(_req(tenant="a"))
        q.take(lambda p: list(p))
        q.admit(_req(tenant="a"))  # quota freed by the take

    def test_block_mode_times_out_to_retry_after(self):
        q = AdmissionQueue(max_depth=1, on_full="block",
                           block_timeout_s=0.05)
        q.admit(_req())
        with pytest.raises(RetryAfter, match="block_timeout_s"):
            q.admit(_req())
        assert q.blocked_admissions == 1

    def test_block_mode_wakes_when_space_frees(self):
        import threading
        q = AdmissionQueue(max_depth=1, on_full="block", block_timeout_s=5.0)
        q.admit(_req())
        admitted = threading.Event()

        def blocked_producer():
            q.admit(_req(seq=1))
            admitted.set()

        t = threading.Thread(target=blocked_producer, daemon=True)
        t.start()
        assert not admitted.wait(0.05)  # parked: the queue is full
        q.take(lambda p: p[:1])         # space frees -> producer wakes
        assert admitted.wait(2.0)
        t.join(timeout=2.0)

    def test_closed_queue_raises_runtime_error_and_reopens(self):
        q = AdmissionQueue(max_depth=4)
        q.close()
        with pytest.raises(RuntimeError, match="closed"):
            q.admit(_req())
        q.reopen()
        q.admit(_req())

    def test_take_is_atomic_choice(self):
        q = AdmissionQueue(max_depth=10)
        for i in range(4):
            q.admit(_req(rows=[i]))
        batch = q.take(lambda p: [x for x in p if x.seq % 2 == 0])
        assert [b.seq for b in batch] == [0, 2]
        assert [b.seq for b in q.snapshot()] == [1, 3]

    def test_taken_batch_is_in_flight_until_noted_served(self):
        """A drain/snapshot barrier must see a taken-but-unserved batch:
        wait_idle only passes once `note_served` settles it."""
        q = AdmissionQueue(max_depth=10)
        q.admit(_req())
        batch = q.take(lambda p: list(p))
        assert q.depth == 0 and q.in_flight == 1
        assert not q.wait_idle(timeout=0.01)
        q.note_served(batch)
        assert q.in_flight == 0
        assert q.wait_idle(timeout=0.01)


class TestAddCapacityLedger:
    def test_padding_counts_as_capacity(self):
        """The pre-scheduler accounting compared against the raw add count;
        the fix charges the FULL pow2 bucket, padding included."""
        led = AddCapacityLedger()
        led.refresh(staged_rows=_next_pow2(5), appended_rows=5)
        # bucket(5) == 8: three padding rows admit without a retrace
        assert led.headroom == 3
        assert led.try_charge(3)
        assert not led.try_charge(1)   # the 4th row crosses the boundary
        led.release(3)
        assert led.headroom == 3

    def test_bucket_is_next_pow2(self):
        assert AddCapacityLedger.bucket(0) == 0
        assert AddCapacityLedger.bucket(1) == 1
        assert AddCapacityLedger.bucket(5) == 8

    def test_queue_rejects_add_past_headroom(self):
        q = AdmissionQueue(max_depth=10)
        q.ledger.refresh(staged_rows=2, appended_rows=0)
        data = {"x": np.zeros((4, 16)), "y": np.zeros(4)}
        with pytest.raises(RetryAfter, match="staged"):
            q.admit(_req(op="add", rows=None, data=data))
        assert q.rejected_add_capacity == 1
        # blocking cannot create device capacity: adds reject even in
        # block mode
        qb = AdmissionQueue(max_depth=10, on_full="block")
        qb.ledger.refresh(staged_rows=2, appended_rows=0)
        with pytest.raises(RetryAfter, match="staged"):
            qb.admit(_req(op="add", rows=None, data=data))

    def test_take_keeps_add_charge_until_served(self):
        """In-flight add rows are NOT headroom: the charge survives the
        take and hands off to appended_rows only at note_served, so a
        concurrent admit can never overstate the staged bucket."""
        q = AdmissionQueue(max_depth=10)
        q.ledger.refresh(staged_rows=4, appended_rows=0)
        data = {"x": np.zeros((4, 16)), "y": np.zeros(4)}
        q.admit(_req(op="add", rows=None, data=data))
        batch = q.take(lambda p: list(p))
        # the rows are in flight, not yet appended — still charged
        assert q.ledger.pending_rows == 4 and q.ledger.headroom == 0
        with pytest.raises(RetryAfter, match="staged"):
            q.admit(_req(op="add", rows=None,
                         data={k: v[:1] for k, v in data.items()}))
        # executor appended the rows, then settles the batch
        q.refresh_ledger(staged_rows=4, appended_rows=4)
        q.note_served(batch)
        assert q.ledger.pending_rows == 0 and q.ledger.headroom == 0

    def test_enforcement_off_force_charges(self):
        q = AdmissionQueue(max_depth=10)
        q.ledger.refresh(staged_rows=1, appended_rows=0)
        data = {"x": np.zeros((4, 16)), "y": np.zeros(4)}
        q.admit(_req(op="add", rows=None, data=data),
                enforce_add_capacity=False)
        assert q.ledger.pending_rows == 4


# --------------------------------------------------------------------------
# Scheduler: EDF flush policy, cross-tenant batching, SLA accounting
# --------------------------------------------------------------------------


class TestServingScheduler:
    def _sched(self, sess=None, **cfg_kw):
        sess = sess or _session()
        clock = VirtualClock()
        cfg = ServeConfig(**cfg_kw)
        return ServingScheduler(sess, cfg, clock=clock), clock

    def test_rejects_session_with_own_autoflush_policy(self):
        sess = _session(max_pending=3)
        with pytest.raises(ValueError, match="max_pending"):
            ServingScheduler(sess, ServeConfig())

    def test_unknown_sla_class_rejected(self):
        sched, _ = self._sched()
        with pytest.raises(ValueError, match="unknown SLA class"):
            sched.submit("delete", rows=[1], sla_class="platinum")

    def test_edf_head_anchors_cross_tenant_batch(self):
        """Requests from DIFFERENT tenants with the same op coalesce into
        one batch, ordered earliest-deadline-first, served as ONE flush."""
        sched, _ = self._sched()
        sched.submit("delete", rows=[1], tenant="a", sla_class="bulk_gdpr")
        sched.submit("delete", rows=[2], tenant="b", sla_class="interactive")
        sched.submit("delete", rows=[3], tenant="c", sla_class="batch")
        served = sched.pump(force=True)
        assert served == 3
        (rec,) = sched.batch_log
        assert rec["rows"] == [2, 3, 1]      # EDF order, not arrival order
        assert rec["tenants"] == ["a", "b", "c"]
        stats = sched.stats()
        assert stats["batches"]["cross_tenant"] == 1
        assert stats["batches"]["count"] == 1

    def test_mixed_ops_do_not_coalesce(self):
        sess = _session()
        sched, _ = self._sched(sess=sess, add_capacity=4)
        sched.submit("delete", rows=[1], sla_class="interactive")
        data = {k: np.asarray(v)[:1] for k, v in sess.dataset.columns.items()}
        sched.submit("add", data=data, sla_class="interactive")
        assert sched.pump(force=True) == 1   # the EDF head's op only
        assert sched.pump(force=True) == 1
        ops = [rec["op"] for rec in sched.batch_log]
        assert sorted(ops) == ["add", "delete"]

    def test_no_coalesce_request_served_alone(self):
        sched, _ = self._sched()
        sched.submit("delete", rows=[1], sla_class="bulk_gdpr",
                     coalesce=True)
        sched.submit("delete", rows=[2], sla_class="interactive",
                     coalesce=False)
        assert sched.pump(force=True) == 1
        assert sched.batch_log[0]["rows"] == [2]

    def test_hold_delays_dispatch_until_ready(self):
        """A batch-class request is NOT ready before its hold expires (the
        deliberate batching delay); force=False honors it, and wait_hint
        tells the executor exactly how long to sleep."""
        classes = (SLAClass("batch", deadline_s=10.0, hold_s=1.0),)
        sched, clock = self._sched(classes=classes, service_est_init_s=0.01)
        sched.submit("delete", rows=[1], sla_class="batch")
        t0 = sched.queue.snapshot()[0].t_enqueue
        assert sched.take_batch(now=t0 + 0.1) == []
        assert sched.wait_hint == pytest.approx(0.9)
        batch = sched.take_batch(now=t0 + 1.1)
        assert len(batch) == 1

    def test_deadline_trims_hold(self):
        """ready_t = min(enqueue + hold, deadline - slack*est): a hold can
        never park a request past the point where the service estimate
        says it would miss."""
        classes = (SLAClass("batch", deadline_s=0.5, hold_s=10.0),)
        sched, _ = self._sched(classes=classes, slack_factor=2.0,
                               service_est_init_s=0.1)
        sched.submit("delete", rows=[1], sla_class="batch")
        q = sched.queue.snapshot()[0]
        assert sched._ready_t(q) == pytest.approx(q.deadline - 0.2)

    def test_full_pending_set_dispatches_without_waiting(self):
        classes = (SLAClass("batch", deadline_s=10.0, hold_s=5.0),)
        sched, _ = self._sched(classes=classes, max_batch=2)
        sched.submit("delete", rows=[1], sla_class="batch")
        sched.submit("delete", rows=[2], sla_class="batch")
        # pending hit max_batch: holds are moot, dispatch now
        assert len(sched.take_batch()) == 2

    def test_deadline_miss_detected_and_counted(self):
        classes = (SLAClass("rush", deadline_s=1e-6, hold_s=0.0),)
        sched, _ = self._sched(classes=classes)
        t = sched.submit("delete", rows=[1], sla_class="rush")
        t.wait(timeout=30.0)
        assert t.missed_deadline is True
        stats = sched.stats()
        assert stats["deadline_misses_total"] == 1
        assert stats["per_class"]["rush"]["deadline_misses"] == 1

    def test_service_estimate_ema_updates(self):
        sched, _ = self._sched()
        est0 = sched.service_est_s
        sched.submit("delete", rows=[1], sla_class="interactive")
        sched.pump(force=True)
        assert sched.service_est_s != est0

    def test_ticket_error_surfaces(self):
        sched, _ = self._sched()
        t = sched.submit("delete", rows=[10 ** 9], sla_class="interactive")
        with pytest.raises(RuntimeError, match="failed"):
            t.wait(timeout=30.0)
        assert sched.stats()["per_class"]["interactive"]["failed"] == 1

    def test_partial_batch_failure_counts_failed_request(self):
        """A request whose session.submit raises inside an otherwise
        healthy batch still reaches the monitor: failed counts it, served
        counts only the rest."""
        sched, _ = self._sched()
        ok = sched.submit("delete", rows=[1], sla_class="interactive")
        bad = sched.submit("delete", rows=[10 ** 9],
                           sla_class="interactive")
        assert sched.pump(force=True) == 2   # one coalesced batch
        assert ok.done and bad.done
        assert bad.error is not None and ok.error is None
        cls = sched.stats()["per_class"]["interactive"]
        assert cls["served"] == 1 and cls["failed"] == 1

    def test_add_over_capacity_rejected_at_admission(self):
        sess = _session()
        sched, _ = self._sched(sess=sess, add_capacity=2)
        cols = sess.dataset.columns
        data = {k: np.asarray(v)[:4] for k, v in cols.items()}
        with pytest.raises(RetryAfter, match="staged"):
            sched.submit("add", data=data)
        assert sched.stats()["admission"]["rejected_add_capacity"] == 1
        # within the staged bucket (padding included) adds admit and serve
        ok = sched.submit("add",
                          data={k: np.asarray(v)[:2] for k, v in cols.items()})
        ok.wait(timeout=30.0)
        assert sched.stats()["add_capacity_retraces"] == 0

    def test_unenforced_add_burst_counts_retrace(self):
        """enforce_add_capacity=False admits past the pow2 boundary; the
        resulting mid-serve retrace is surfaced as a monitor counter
        instead of silently eating a recompile."""
        sess = _session()
        sched, _ = self._sched(sess=sess, add_capacity=1,
                               enforce_add_capacity=False)
        sched.submit("delete", rows=[0])
        sched.pump(force=True)           # batches_served > 0, cap staged
        cols = sess.dataset.columns
        data = {k: np.asarray(v)[:3] for k, v in cols.items()}
        sched.submit("add", data=data)   # 3 rows into a 1-row bucket
        sched.pump(force=True)
        assert sched.stats()["add_capacity_retraces"] == 1


# --------------------------------------------------------------------------
# Load generation: determinism, parity of loop modes
# --------------------------------------------------------------------------


class TestLoadGen:
    def test_trace_deterministic_per_seed(self):
        a = poisson_trace(100.0, 50, seed=7, tenants={"a": 0.5, "b": 0.5},
                          classes=("interactive", "batch"), add_frac=0.3)
        b = poisson_trace(100.0, 50, seed=7, tenants={"a": 0.5, "b": 0.5},
                          classes=("interactive", "batch"), add_frac=0.3)
        c = poisson_trace(100.0, 50, seed=8, tenants={"a": 0.5, "b": 0.5},
                          classes=("interactive", "batch"), add_frac=0.3)
        assert [(e.t, e.op, e.tenant, e.sla_class) for e in a] \
            == [(e.t, e.op, e.tenant, e.sla_class) for e in b]
        assert [e.t for e in a] != [e.t for e in c]

    def test_fixed_trace_times_carry_no_randomness(self):
        ev = fixed_trace(0.01, 5, seed=3)
        assert [e.t for e in ev] == pytest.approx(
            [0.01, 0.02, 0.03, 0.04, 0.05])

    def test_materialize_deletes_disjoint_and_deterministic(self):
        ds = binary_classification(n=50, d=4, seed=0)
        ev1 = materialize(fixed_trace(0.01, 10, seed=1), ds, seed=5)
        ev2 = materialize(fixed_trace(0.01, 10, seed=1), ds, seed=5)
        rows1 = [r for e in ev1 if e.op == "delete" for r in e.rows]
        rows2 = [r for e in ev2 if e.op == "delete" for r in e.rows]
        assert rows1 == rows2
        assert len(set(rows1)) == len(rows1)  # no batching order conflicts

    def test_materialize_exhausting_live_rows_raises(self):
        ds = binary_classification(n=5, d=4, seed=0)
        with pytest.raises(ValueError, match="live rows"):
            materialize(fixed_trace(0.01, 6, seed=1), ds, seed=5)

    def test_closed_loop_serves_every_event_inline(self):
        sess = _session()
        sched = ServingScheduler(sess, ServeConfig(add_capacity=4))
        ev = materialize(fixed_trace(0.001, 6, seed=2,
                                     tenants=("a", "b"), add_frac=0.25),
                         sess.dataset, seed=9)
        res = LoadGenerator(sched).closed_loop(ev, timeout_s=60.0)
        assert res.rejected == 0 and res.served == 6


# --------------------------------------------------------------------------
# Snapshot consistency under load (ISSUE satellite c)
# --------------------------------------------------------------------------


class TestSnapshotUnderLoad:
    def test_save_refuse_raises_with_queued_work(self, tmp_path):
        sched, _ = TestServingScheduler()._sched()
        sched.submit("delete", rows=[1], sla_class="bulk_gdpr")
        with pytest.raises(RuntimeError, match="refuse"):
            sched.save(str(tmp_path), pending="refuse")
        # the queued request is untouched by the refused save
        assert sched.queue.depth == 1
        sched.drain()
        sched.save(str(tmp_path), pending="refuse")  # now clean: fine

    def test_save_refuse_counts_in_flight_batch(self, tmp_path):
        """A batch the executor has taken but not finished serving blocks
        ``pending="refuse"`` just like queued work — the snapshot must
        never land mid-batch."""
        sched, _ = TestServingScheduler()._sched()
        sched.submit("delete", rows=[1], sla_class="bulk_gdpr")
        batch = sched.take_batch(force=True)   # taken, not yet served
        assert sched.queue.in_flight == 1
        with pytest.raises(RuntimeError, match="in-flight"):
            sched.save(str(tmp_path), pending="refuse")
        sched.executor.serve_batch(batch)
        assert sched.queue.in_flight == 0
        sched.save(str(tmp_path), pending="refuse")  # settled: fine

    def test_save_drain_serves_queue_first(self, tmp_path):
        sched, _ = TestServingScheduler()._sched()
        t = sched.submit("delete", rows=[3], sla_class="bulk_gdpr")
        sched.save(str(tmp_path), pending="drain")
        assert t.done and sched.queue.depth == 0

    def test_restore_and_replay_is_bitwise_identical(self, tmp_path):
        """Drain-save mid-trace, restore, replay the remainder: params are
        bitwise-identical to the uninterrupted run of the same seeded
        trace (same per-event batching on both sides)."""
        obj = logreg_objective(l2=META["l2"])
        ev = fixed_trace(0.001, 8, seed=4, tenants=("a", "b"), add_frac=0.25)
        sess_ref = _session()
        ev = materialize(ev, sess_ref.dataset, seed=11)
        ev_mid = copy.deepcopy(ev)

        def replay(sched, events):
            for e in events:
                sched.submit(op=e.op, rows=e.rows, data=e.data,
                             tenant=e.tenant, sla_class=e.sla_class)
                while sched.pump(force=True):
                    pass

        # uninterrupted run
        sched_ref = ServingScheduler(sess_ref, ServeConfig(add_capacity=4))
        replay(sched_ref, ev)

        # interrupted run: first half, drain-save, restore, second half
        sess_a = _session()
        sched_a = ServingScheduler(sess_a, ServeConfig(add_capacity=4))
        replay(sched_a, ev_mid[:4])
        sched_a.save(str(tmp_path), pending="drain")
        sess_b = UnlearnerSession.restore(str(tmp_path), obj)
        sched_b = ServingScheduler(sess_b, ServeConfig(add_capacity=4))
        replay(sched_b, ev_mid[4:])

        assert _dist(sched_ref.session.params, sched_b.session.params) == 0.0
        plans = lambda s: [(r["op"], tuple(r["rows"])) for r in s.batch_log]  # noqa: E731
        assert plans(sched_a) + plans(sched_b) == plans(sched_ref)


# --------------------------------------------------------------------------
# Deprecation shims (ISSUE satellite a)
# --------------------------------------------------------------------------


class TestDeprecationShims:
    def test_start_autoflush_timer_warns_and_delegates(self):
        sess = _session(max_delay_s=0.05)
        with pytest.warns(DeprecationWarning, match="SessionFlushClock"):
            clock = sess.start_autoflush_timer()
        try:
            assert isinstance(clock, SessionFlushClock)
            assert clock.sla.deadline_s == pytest.approx(0.05)
        finally:
            clock.stop()

    def test_autoflush_timer_class_warns_and_delegates(self):
        sess = _session(max_delay_s=0.05)
        with pytest.warns(DeprecationWarning, match="SessionFlushClock"):
            timer = AutoFlushTimer(sess)
        try:
            assert isinstance(timer, SessionFlushClock)
        finally:
            timer.stop()

    def test_clock_without_deadline_rejected(self):
        sess = _session()
        with pytest.raises(ValueError, match="max_delay_s"):
            SessionFlushClock(sess)

    def test_flush_clock_holds_deadline_with_zero_arrivals(self):
        import time
        sess = _session(max_delay_s=0.05)
        clock = SessionFlushClock(sess)
        try:
            h = sess.submit(op="delete", rows=[1])
            deadline = time.monotonic() + 10.0
            while not h.done and time.monotonic() < deadline:
                time.sleep(0.005)
            assert h.done and clock.ticks >= 1
        finally:
            clock.stop()


# --------------------------------------------------------------------------
# Threaded executor: continuous batching end to end
# --------------------------------------------------------------------------


class TestThreadedExecutor:
    def test_open_loop_burst_coalesces_under_thread(self):
        sess = _session()
        sched = ServingScheduler(sess, ServeConfig(add_capacity=4))
        # warm the compiled programs so the burst measures steady state
        sess.delete([190], coalesce=True)
        ev = materialize(
            poisson_trace(400.0, 12, seed=6, tenants=("a", "b"),
                          classes=("batch",)),
            sess.dataset, seed=13)
        sched.start()
        try:
            res = LoadGenerator(sched).open_loop(ev)
            for t in res.tickets:
                t.wait(timeout=30.0)
        finally:
            sched.stop()
        assert res.served == 12
        stats = sched.stats()
        assert stats["batches"]["count"] < 12       # batching happened
        assert stats["batches"]["cross_tenant"] >= 1
        assert sched.queue.depth == 0

    def test_drain_waits_for_in_flight_batch(self, monkeypatch):
        """drain() (and so save(pending='drain')) must wait out a batch
        the executor already took: the session flush that ends the drain
        may not interleave with the executor's in-flight serve."""
        import threading
        import time as _time
        sess = _session()
        sched = ServingScheduler(sess, ServeConfig())
        entered = threading.Event()
        real_flush = sess.flush

        def slow_flush():
            entered.set()
            out = real_flush()
            _time.sleep(0.2)       # batch still in flight after the flush
            return out

        monkeypatch.setattr(sess, "flush", slow_flush)
        sched.start()
        try:
            t = sched.submit("delete", rows=[2], sla_class="interactive")
            assert entered.wait(30.0)   # the executor took the batch
            sched.drain()
            assert t.done               # ...so drain waited it out
            assert sched.queue.in_flight == 0
        finally:
            sched.stop()

    def test_stop_then_inline_use_still_works(self):
        sched, _ = TestServingScheduler()._sched()
        sched.start()
        sched.stop()
        t = sched.submit("delete", rows=[5], sla_class="interactive")
        assert t.wait(timeout=30.0)
