"""Transformer-LM unlearning: the model→Objective API end-to-end.

Tier-1 coverage for the LM integration path: `Objective.from_model` /
`UnlearnerSession.from_config` on a reduced-config transformer,
guard-ON deltagrad vs exact retrain, snapshot/restore bitwise parity,
the streamed + delta_int8 history path on a per-layer pytree, and the
flash-attention routing (interpret-mode kernel on CPU) against the
blockwise reference.  Shapes are toy; the architecture (GQA + RoPE +
SwiGLU, stacked per-layer leaves) is the real one.
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.core.deltagrad import (DeltaGradConfig, Objective,
                                  deltagrad_retrain, sgd_train_with_cache)
from repro.core.history import HistoryMeta
from repro.core.session import UnlearnerConfig, UnlearnerSession
from repro.core.store import HistoryStore
from repro.data.synthetic import token_stream
from repro.models.registry import build
from repro.utils.tree import tree_norm, tree_sub

REDUCED = dict(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
               vocab=64, d_head=8)
N_DOCS, SEQ, STEPS, BATCH = 48, 16, 18, 16
REMOVED = [3, 11, 25, 40]

# the paper's DNN recipe (§4.1): small T0, long burn-in, guard on
DG = DeltaGradConfig(period=2, burn_in=10, history_size=2, guard=True,
                     curvature_eps=1e-8)


def leaves_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


@pytest.fixture(scope="module")
def docs():
    return token_stream(n_docs=N_DOCS, seq_len=SEQ, vocab=REDUCED["vocab"],
                        seed=0)


# the end-to-end distance assertion needs a deletion small relative to the
# corpus (4/256 docs) and enough SGD path for the correction to pay off —
# the tiny parity shapes above are too noisy for the quality claim
E2E = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
           vocab=128, d_head=16)
E2E_DOCS, E2E_SEQ, E2E_STEPS = 256, 32, 40


def make_lm_session(docs):
    return UnlearnerSession.from_config(
        "internlm2-1.8b", docs, reduced=E2E,
        config=UnlearnerConfig(steps=E2E_STEPS, batch_size=64, lr=0.02,
                               seed=5, deltagrad=DG),
        loss_chunk=E2E_SEQ)


# -- Objective.from_model ---------------------------------------------------


def test_from_model_matches_handrolled_vmap_bitwise(docs):
    """`Objective.from_model`'s per-example loss must equal the inline
    vmap every LM caller used to hand-roll — bitwise, not approximately:
    both trace the identical per-row program."""
    cfg = get_config("internlm2-1.8b").reduced(**REDUCED)
    model = build(cfg)
    params = model.init(1)
    batch = {"tokens": jnp.asarray(np.asarray(docs.columns["tokens"]))}

    obj = Objective.from_model(model, loss_chunk=SEQ)

    def handrolled(params, batch):
        def one(row):
            return model.loss_fn(params, {"tokens": row[None]},
                                 remat=False, loss_chunk=SEQ)
        return jax.vmap(one)(batch["tokens"])

    a = np.asarray(obj.per_example_loss(params, batch))
    b = np.asarray(handrolled(params, batch))
    assert a.shape == (docs.n,)
    assert (a == b).all()


def test_model_objective_convenience(docs):
    """`build(cfg).objective()` is the same bridge as Objective.from_model."""
    cfg = get_config("internlm2-1.8b").reduced(**REDUCED)
    model = build(cfg)
    obj = model.objective(loss_chunk=SEQ)
    assert isinstance(obj, Objective)
    batch = {"tokens": jnp.asarray(np.asarray(docs.columns["tokens"][:4]))}
    losses = np.asarray(obj.per_example_loss(model.init(1), batch))
    assert losses.shape == (4,) and np.isfinite(losses).all()


# -- end-to-end session surface on the LM -----------------------------------


def test_lm_session_end_to_end(tmp_path):
    """train-with-cache → snapshot → guard-ON delete vs exact retrain →
    restore → identical delete is bitwise → add resolves.

    One fit, the whole request surface: this is the ISSUE's acceptance
    path on a reduced transformer."""
    docs = token_stream(n_docs=E2E_DOCS, seq_len=E2E_SEQ,
                        vocab=E2E["vocab"], seed=0)
    sess = make_lm_session(docs)
    w_star = sess.fit()
    assert len(sess.history) == E2E_STEPS

    sess.save(str(tmp_path))

    w_u, _ = sess.baseline(REMOVED)              # exact retrain reference
    resp = sess.delete(REMOVED).result()
    w_i, stats = resp.params, resp.stats[0]

    d_ui = float(tree_norm(tree_sub(w_u, w_i)))
    d_us = float(tree_norm(tree_sub(w_u, w_star)))
    # DeltaGrad must land closer to the exact leave-K-out model than the
    # original params (the paper's Fig-style distance claim, non-convex)
    assert d_ui < d_us, (d_ui, d_us)
    assert stats.guard_fallbacks >= 0           # guard path exercised

    # restore serves the SAME plan bitwise-identically
    restored = UnlearnerSession.restore(str(tmp_path), sess.objective)
    w_r = restored.delete(REMOVED).result().params
    assert leaves_equal(w_i, w_r)

    # add: append two new documents, engine must serve them on the LM
    rng = np.random.default_rng(9)
    new_docs = {"tokens": rng.integers(
        0, E2E["vocab"], size=(2, E2E_SEQ), dtype=np.int32)}
    w_a = restored.add(data=new_docs).result().params
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(w_a))


# -- streamed + delta_int8 history on the LM pytree -------------------------


def test_lm_streamed_history_replay_parity(docs):
    """The tentpole storage claim at LM shape: (a) host-streamed f32
    replay is EXACTLY the resident replay (bit-identical recorders), and
    (b) the delta_int8 encoded path stays within the quantization
    envelope of the per-step python oracle on the same encoded history."""
    cfg_m = get_config("internlm2-1.8b").reduced(**REDUCED)
    model = build(cfg_m)
    obj = Objective.from_model(model, loss_chunk=SEQ)
    p0 = model.init(1)
    meta = HistoryMeta(n=docs.n, batch_size=BATCH, seed=5, steps=STEPS,
                       lr_schedule=((0, 0.05),))
    changed = np.asarray(REMOVED, dtype=np.int64)
    window = 4
    cfg = dataclasses.replace(DG, stream_window=window)

    # resident reference
    _, hist_res = sgd_train_with_cache(obj, p0, docs, meta, tier="stacked")
    w_res, _ = deltagrad_retrain(obj, hist_res, docs, changed, cfg)

    # (a) streamed f32: exact
    _, hist_f32 = sgd_train_with_cache(obj, p0, docs, meta, tier="host")
    store = HistoryStore.create(hist_f32, window=window)
    w_st, st = deltagrad_retrain(obj, hist_f32, docs, changed, cfg,
                                 store=store)
    assert st.extra["store"] == "streamed"
    assert float(tree_norm(tree_sub(w_st, w_res))) == 0.0

    # (b) delta_int8: within quantization envelope of the python oracle
    _, hist_d = sgd_train_with_cache(obj, p0, docs, meta, tier="host",
                                     codec="delta_int8")
    store_d = HistoryStore.create(hist_d, window=window)
    w_d, st_d = deltagrad_retrain(obj, hist_d, docs, changed, cfg,
                                  store=store_d)
    assert st_d.extra["store"] == "streamed"
    w_py, _ = deltagrad_retrain(obj, hist_d, docs, changed,
                                dataclasses.replace(cfg, impl="python"))
    rel = float(tree_norm(tree_sub(w_d, w_py))) \
        / max(1e-12, float(tree_norm(w_py)))
    assert rel < 5e-2, rel
    # the encoded path must actually compress the f32 rows (the margin is
    # modest here: at 18 steps the f32 keyframes dominate the encoded
    # bytes — bench_lm gates the amortized ratio on longer histories)
    assert store_d.compression_ratio > 1.2


# -- flash-attention routing on the replay forward --------------------------


def test_flash_routing_parity(docs):
    """An objective pinned to the flash kernel (interpret-mode on CPU)
    must match the blockwise reference to kernel tolerance — loss and
    gradient — through jit + vmap + grad, i.e. exactly how the replay
    engine drives it."""
    cfg = get_config("internlm2-1.8b").reduced(**REDUCED)
    model = build(cfg)
    p = model.init(1)
    batch = {"tokens": jnp.asarray(np.asarray(docs.columns["tokens"][:8]))}
    w = jnp.ones((8,))

    obj_ref = Objective.from_model(model, loss_chunk=SEQ)
    obj_fl = Objective.from_model(model, loss_chunk=SEQ, attn_impl="flash")

    l_ref, g_ref = obj_ref.make_value_grad_fn()(p, batch, w)
    l_fl, g_fl = obj_fl.make_value_grad_fn()(p, batch, w)

    # bf16 model dtype: kernel-vs-ref tolerance, not exactness
    assert abs(float(l_ref) - float(l_fl)) < 5e-3
    rel = float(tree_norm(tree_sub(g_fl, g_ref))) \
        / max(1e-12, float(tree_norm(g_ref)))
    assert rel < 5e-2, rel


def test_attention_impl_switch_validates():
    from repro.models.attention_config import (attention_impl,
                                               set_attention_impl,
                                               use_attention_impl)
    assert attention_impl() == "blockwise"
    with pytest.raises(ValueError):
        set_attention_impl("nope")
    with use_attention_impl("flash_interpret"):
        assert attention_impl() == "flash_interpret"
    assert attention_impl() == "blockwise"
    with use_attention_impl(None):
        assert attention_impl() == "blockwise"
