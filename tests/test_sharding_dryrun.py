"""Sharding resolver rules + a small-scale multi-device dry-run.

The multi-device part runs in a SUBPROCESS so the forced host device count
never pollutes the main test process (smoke tests must see 1 device).
"""

import os
import subprocess
import sys
import textwrap

import pytest
from jax.sharding import PartitionSpec as P


class FakeMesh:
    axis_names = ("data", "model")
    class devices:  # noqa: D401
        shape = (16, 16)


def plan():
    from repro.dist.sharding import ShardingPlan
    return ShardingPlan(mesh=FakeMesh())


def spec(path, shape):
    from repro.dist.sharding import spec_for_leaf
    return spec_for_leaf(plan(), path, shape)


class TestResolverRules:
    def test_column_parallel(self):
        # stacked (n_units, d, H*dh): model on OUTPUT dim, data-FSDP on input
        assert spec("u0/mixer/wq", (24, 2048, 2048)) == P(None, "data", "model")
        assert spec("u0/mlp/w_up", (24, 2048, 8192)) == P(None, "data", "model")
        # non-stacked (shared/hybrid closure block)
        assert spec("shared/mixer/wq", (2048, 2048)) == P("data", "model")

    def test_row_parallel(self):
        assert spec("u0/mixer/wo", (24, 2048, 2048)) == P(None, "model", "data")
        assert spec("u0/mlp/w_down", (24, 8192, 2048)) == P(None, "model", "data")

    def test_stacked_layer_axis_never_sharded(self):
        s = spec("u0/mlp/w_up", (32, 2048, 8192))  # 32 divisible by 16!
        assert s == P(None, "data", "model")

    def test_non_divisible_replicates(self):
        # an output dim of 20 heads * 7 = 140 is not divisible by 16
        s = spec("u0/mixer/wq", (24, 2048, 140))
        assert s == P(None, "data", None)

    def test_embed_replicated_on_model(self):
        s = spec("embed", (92544, 2048))
        assert s == P("data", None)

    def test_norms_replicated(self):
        assert spec("u0/ln1/scale", (24, 2048)) == P(None, "data")
        assert spec("final_norm/scale", (2048,)) == P("data")

    def test_batch_pspec_fallbacks(self):
        from repro.dist.sharding import batch_pspec
        p = plan()
        assert batch_pspec(p, (256, 4096)) == P("data", None)
        assert batch_pspec(p, (1, 1)) == P(None, None)  # long_500k batch 1


class TestMoERules:
    def test_expert_parallel_when_divisible(self):
        from repro.dist.sharding import make_plan, spec_for_leaf
        from repro.configs.registry import get_config
        pl = make_plan(FakeMesh(), get_config("moonshot-v1-16b-a3b"))
        s = spec_for_leaf(pl, "u0/mlp/w_gate", (48, 64, 2048, 1408))
        assert s == P(None, "model", None, "data")

    def test_tp_fallback_when_not_divisible(self):
        from repro.dist.sharding import make_plan, spec_for_leaf
        from repro.configs.registry import get_config
        pl = make_plan(FakeMesh(), get_config("qwen2-moe-a2.7b"))
        s = spec_for_leaf(pl, "u0/mlp/w_gate", (24, 60, 2048, 1408))
        assert s == P(None, None, "data", "model")


SUBPROCESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.configs.registry import get_config
    from repro.configs.base import ShapeConfig
    from repro.dist.sharding import make_plan, params_shardings, inputs_shardings
    from repro.models.registry import build

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_config("internlm2-1.8b").reduced(d_model=64, n_heads=4,
                                               n_kv_heads=2, d_ff=128,
                                               vocab=256, d_head=16)
    model = build(cfg)
    plan = make_plan(mesh, cfg)
    shape = ShapeConfig(name="t", seq_len=16, global_batch=8, kind="train")
    specs = model.input_specs(shape)
    params_specs = jax.eval_shape(lambda: model.init(0))
    p_shard = params_shardings(plan, params_specs)
    in_shard = inputs_shardings(plan, specs)

    def loss(p, b):
        return model.loss_fn(p, b, remat=False, loss_chunk=8)

    with mesh:
        lowered = jax.jit(jax.grad(loss),
                          in_shardings=(p_shard, in_shard)).lower(
            params_specs, specs)
        compiled = lowered.compile()
    from repro.roofline.analysis import cost_analysis_dict
    cost = cost_analysis_dict(compiled)
    assert float(cost.get("flops", 0)) > 0
    # actually execute on the 8 fake devices — numerics + shardings together
    params = jax.device_put(model.init(0), p_shard)
    batch = jax.device_put(model.sample_batch(shape), in_shard)
    g = jax.jit(jax.grad(loss), in_shardings=(p_shard, in_shard))(params, batch)
    total = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(total) and total > 0
    # compare against single-device execution
    g1 = jax.grad(loss)(model.init(0), model.sample_batch(shape))
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-2, atol=2e-2)
    print("MULTIDEVICE_OK")
""")


def test_multidevice_lower_compile_and_execute():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", SUBPROCESS_PROG],
                         capture_output=True, text=True, env=env, timeout=500)
    assert out.returncode == 0, (out.stdout[-1000:], out.stderr[-3000:])
    assert "MULTIDEVICE_OK" in out.stdout
