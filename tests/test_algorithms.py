"""The pluggable unlearning-algorithm registry (core.algorithms): every
registered algorithm behind the one session surface, the retrain-oracle
anchor, certificates, and snapshot round-trips of the descriptor + PRNG."""

import dataclasses
import os
import pickle

import numpy as np
import pytest

import jax

from repro.core.algorithms import (available_algorithms, get_algorithm,
                                   DescentToDeleteConfig)
from repro.core.deltagrad import DeltaGradConfig
from repro.core.privacy import PrivacyConfig
from repro.core.session import UnlearnerConfig, UnlearnerSession
from repro.data.synthetic import binary_classification
from repro.models.simple import logreg_init, logreg_objective
from repro.utils.tree import tree_norm, tree_sub

# the objective's own l2 (5e-3) is too weak for delta0 at these removal
# counts (the designed ValueError) — state strong constants instead
PRIVACY = PrivacyConfig(eps=1.0, delta=1e-5, mu=0.5, L=1.0, c0=0.1, c2=0.1)


def make_session(algorithm="deltagrad", n=600, d=8, steps=30, batch=200,
                 seed=0):
    ds = binary_classification(n=n, d=d, seed=seed)
    obj = logreg_objective(l2=5e-3)
    cfg = UnlearnerConfig(
        steps=steps, batch_size=batch, lr=0.4, seed=seed,
        deltagrad=DeltaGradConfig(period=5, burn_in=8, history_size=2),
        algorithm=algorithm, privacy=PRIVACY,
        descent=DescentToDeleteConfig(finetune_steps=4))
    sess = UnlearnerSession(obj, logreg_init(d, seed=seed + 1), ds, cfg)
    sess.fit()
    return sess, ds


def leaves_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


# -- registry --------------------------------------------------------------


def test_registry_lists_builtins():
    names = available_algorithms()
    assert {"deltagrad", "descent_to_delete", "retrain_oracle"} <= set(names)
    for name in names:
        assert get_algorithm(name).name == name


def test_registry_unknown_name_raises_with_choices():
    with pytest.raises(ValueError, match="deltagrad"):
        get_algorithm("no_such_algorithm")


def test_session_rejects_unknown_algorithm_lazily():
    sess, _ = make_session()
    sess.config = dataclasses.replace(sess.config, algorithm="bogus")
    sess._algorithm = None
    with pytest.raises(ValueError, match="bogus"):
        sess.delete([3]).result()


# -- one serving surface for every algorithm -------------------------------


@pytest.mark.parametrize("algorithm", sorted(available_algorithms()))
def test_every_algorithm_serves_delete_and_add(algorithm):
    """The tentpole contract: submit()/delete()/add() are algorithm-blind —
    the same mixed stream resolves through each registered algorithm."""
    sess, ds = make_session(algorithm)
    h1 = sess.delete([3, 5, 7])
    h2 = sess.add(data={k: np.asarray(v[:2]) for k, v in ds.columns.items()})
    h3 = sess.delete([11])
    w = h3.params  # forcing one handle flushes the whole plan
    assert h1.done and h2.done and h3.done
    assert all(np.all(np.isfinite(np.asarray(x)))
               for x in jax.tree.leaves(w))
    algo = sess.algorithm
    assert algo.name == algorithm
    assert algo._removals == 4
    assert set(algo.added) == {600, 601}
    live = np.asarray(algo.live[:600])
    assert not live[[3, 5, 7, 11]].any() and live.sum() == 596


def test_retrain_oracle_is_bitwise_baseline_retrain():
    """`retrain_oracle` = the engine under an all-explicit plan — it must
    reproduce `baseline_retrain` (BaseL eq. (1)) EXACTLY, not approximately."""
    rows = [4, 17, 256, 511]
    sess, _ = make_session("retrain_oracle")
    w_oracle = sess.delete(rows).params
    w_base, _ = sess.baseline(rows)
    assert leaves_equal(w_oracle, w_base)


def test_descent_to_delete_contracts_toward_retrained_optimum():
    """Finetuning from the cached optimum must move TOWARD the retrained
    model (the contraction the certificate is built on).  The reference
    must actually BE near the optimum, so train long full-batch GD; the
    schedule-replay distance is NOT contracted (d2d certifies distance to
    the post-deletion minimizer, not to an unconverged replay)."""
    rows = list(range(0, 120))  # big enough group to move the optimum
    ds = binary_classification(n=600, d=8, seed=0)
    obj = logreg_objective(l2=5e-3)
    cfg = UnlearnerConfig(
        steps=400, batch_size=600, lr=0.4, seed=0,
        algorithm="descent_to_delete", privacy=PRIVACY,
        descent=DescentToDeleteConfig(finetune_steps=25, lr=0.4))
    sess = UnlearnerSession(obj, logreg_init(8, seed=1), ds, cfg)
    sess.fit()
    w_star = sess.params
    w_base, _ = sess.baseline(rows)
    w_d2d = sess.delete(rows).params
    d_before = float(tree_norm(tree_sub(w_star, w_base)))
    d_after = float(tree_norm(tree_sub(w_d2d, w_base)))
    assert d_after < d_before, (d_after, d_before)


def test_descent_to_delete_bound_grows_with_requests():
    sess, _ = make_session("descent_to_delete")
    sess.delete([1]).result()
    b1 = sess.certificate(eps=1.0).bound
    sess.delete([2]).result()
    b2 = sess.certificate(eps=1.0).bound
    assert 0.0 < b1 < b2


# -- certificates ----------------------------------------------------------


def test_certificates_per_algorithm_mechanisms():
    for algorithm, mechanism in (("deltagrad", "laplace"),
                                 ("descent_to_delete", "gaussian"),
                                 ("retrain_oracle", "exact")):
        sess, _ = make_session(algorithm)
        sess.delete([2, 9]).result()
        cert = sess.certificate(eps=1.0)
        assert cert.mechanism == mechanism
        assert cert.algorithm == algorithm
        assert cert.removals == 2
        if mechanism == "exact":
            assert cert.noise_scale == 0.0 and cert.bound == 0.0
        else:
            assert cert.noise_scale > 0.0 and cert.bound > 0.0
        d = cert.as_dict()
        assert d["mechanism"] == mechanism and d["eps"] == cert.eps


def test_publish_adds_calibrated_noise_and_advances_key():
    sess, _ = make_session("deltagrad")
    sess.delete([2, 9]).result()
    w = sess.params
    p1, c1 = sess.publish(eps=1.0)
    p2, c2 = sess.publish(eps=1.0)
    assert c1.noise_scale == c2.noise_scale > 0.0
    assert not leaves_equal(p1, w)  # noise was added
    assert not leaves_equal(p1, p2)  # key advanced between publishes
    assert jax.tree.structure(p1) == jax.tree.structure(w)


# -- snapshot round-trip ---------------------------------------------------


@pytest.mark.parametrize("algorithm", ["deltagrad", "descent_to_delete"])
def test_save_restore_roundtrips_descriptor_and_prng(tmp_path, algorithm):
    """restore() must resume the SAME algorithm mid-stream: next request
    and next publish both bitwise-identical to the uninterrupted session."""
    sess, _ = make_session(algorithm)
    sess.delete([3, 5]).result()
    sess.publish(eps=1.0)  # advance the PRNG key before the snapshot
    path = str(tmp_path / "snap")
    sess.save(path)

    restored = UnlearnerSession.restore(path, logreg_objective(l2=5e-3))
    assert restored.config.algorithm == algorithm
    assert leaves_equal(restored.params, sess.params)

    wa = sess.delete([9]).params
    wb = restored.delete([9]).params
    assert leaves_equal(wa, wb)

    pa, ca = sess.publish(eps=1.0)
    pb, cb = restored.publish(eps=1.0)
    assert leaves_equal(pa, pb)
    assert ca.as_dict() == cb.as_dict()


def test_restore_rejects_algorithm_mismatch(tmp_path):
    sess, _ = make_session("deltagrad")
    sess.delete([3]).result()
    path = str(tmp_path / "snap")
    step_dir = sess.save(path)
    extra_path = os.path.join(step_dir, "extra.pkl")
    with open(extra_path, "rb") as f:
        extra = pickle.load(f)
    extra["config"] = dataclasses.replace(extra["config"],
                                          algorithm="descent_to_delete")
    with open(extra_path, "wb") as f:
        pickle.dump(extra, f)
    with pytest.raises(ValueError, match="deltagrad"):
        UnlearnerSession.restore(path, logreg_objective(l2=5e-3))
