"""End-to-end behaviour: Unlearner API, checkpoint/restart, elastic plans,
straggler policy, train driver smoke."""

import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import Unlearner, UnlearnerConfig
from repro.core.deltagrad import DeltaGradConfig
from repro.data.synthetic import binary_classification
from repro.models.simple import logreg_accuracy, logreg_init, logreg_objective
from repro.utils.tree import tree_norm, tree_sub


def make_unlearner(n=600, d=8, steps=40):
    ds = binary_classification(n=n, d=d, seed=0)
    return Unlearner(
        logreg_objective(l2=5e-3), logreg_init(d, seed=1), ds,
        UnlearnerConfig(steps=steps, batch_size=128, lr=0.3, seed=2,
                        deltagrad=DeltaGradConfig(period=5, burn_in=8)),
    ), ds


class TestUnlearnerAPI:
    def test_fit_delete_add_stream(self):
        unl, ds = make_unlearner()
        unl.fit()
        acc0 = logreg_accuracy(unl.params, ds)
        assert acc0 > 0.7

        stats = unl.delete([1, 2, 3])
        assert stats.theoretical_speedup > 1.5
        assert ds.removed[[1, 2, 3]].all()

        stats2 = unl.add({"x": ds.columns["x"][:2] + 0.1,
                          "y": ds.columns["y"][:2]})
        assert stats2.approx_steps > 0

        ostats = unl.stream_delete([10, 11])
        assert len(ostats.per_request) == 2
        assert logreg_accuracy(unl.params, ds) > 0.6

    def test_delete_matches_baseline_closely(self):
        unl, ds = make_unlearner()
        unl.fit()
        w_u, _ = unl.baseline([5, 6, 7, 8])
        unl.delete([5, 6, 7, 8])
        d = float(tree_norm(tree_sub(w_u, unl.params)))
        assert d < 5e-3, d

    def test_requires_fit(self):
        unl, _ = make_unlearner()
        with pytest.raises(RuntimeError):
            unl.delete([0])


class TestCheckpoint:
    def test_save_restore_resume(self, tmp_path):
        from repro.train import checkpoint as ckpt
        from repro.optim.optimizers import adamw
        from repro.train.state import init_state

        params = {"w": jnp.arange(12.0).reshape(3, 4)}
        opt = adamw()
        state = init_state(params, opt)
        ckpt.save(str(tmp_path), 10, state)
        ckpt.save(str(tmp_path), 20, state._replace(step=jnp.int32(20)))
        assert ckpt.latest_step(str(tmp_path)) == 20
        restored = ckpt.restore(str(tmp_path), 20, state)
        assert int(restored.step) == 20
        np.testing.assert_array_equal(np.asarray(restored.params["w"]),
                                      np.asarray(params["w"]))

    def test_incomplete_checkpoint_ignored(self, tmp_path):
        from repro.train import checkpoint as ckpt
        os.makedirs(tmp_path / "step_00000099")  # no MANIFEST
        assert ckpt.latest_step(str(tmp_path)) is None

    def test_prune_keeps_last(self, tmp_path):
        from repro.train import checkpoint as ckpt
        state = {"w": jnp.ones(3)}
        for s in range(6):
            ckpt.save(str(tmp_path), s, state, keep_last=3)
        assert ckpt.complete_steps(str(tmp_path)) == [3, 4, 5]

    def test_history_rides_in_extra(self, tmp_path):
        from repro.train import checkpoint as ckpt
        from repro.core.history import HistoryMeta, TrainingHistory
        meta = HistoryMeta(n=10, batch_size=5, seed=0, steps=2,
                           lr_schedule=((0, 0.1),))
        h = TrainingHistory(meta, tier="host")
        h.append({"w": jnp.ones(3)}, {"w": jnp.zeros(3)})
        h.finalize({"w": jnp.ones(3)})
        ckpt.save(str(tmp_path), 1, {"w": jnp.ones(2)},
                  extra={"history": h.state_dict()})
        extra = ckpt.restore_extra(str(tmp_path), 1)
        h2 = TrainingHistory.from_state_dict(extra["history"])
        assert len(h2) == 1


class TestElasticStraggler:
    def test_plan_remesh(self):
        from repro.train.elastic import plan_remesh
        d = plan_remesh(n_devices=128, model_parallel=16, global_batch=256)
        assert d.ok and d.mesh_shape == (8, 16) and d.dropped_batch == 0
        bad = plan_remesh(n_devices=100, model_parallel=16, global_batch=256)
        assert not bad.ok

    def test_plan_remesh_multipod(self):
        from repro.train.elastic import plan_remesh
        d = plan_remesh(n_devices=512, model_parallel=16, global_batch=256,
                        multi_pod=True, pod_size=256)
        assert d.ok and d.mesh_shape == (2, 16, 16)

    def test_straggler_policy(self):
        from repro.train.straggler import StragglerPolicy
        pol = StragglerPolicy(tolerance=1.5, patience=2)
        times = {0: 1.0, 1: 1.0, 2: 1.0, 3: 2.5}
        assert pol.observe(times) == []  # first strike
        assert pol.observe(times) == [3]  # second strike -> flagged
        assert pol.reweight(3, 4) == pytest.approx(4 / 3)


def test_train_driver_paper_model_runs():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "paper-logreg",
         "--steps", "30", "--n", "400", "--dim", "8", "--batch", "128"],
        capture_output=True, text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "speedup" in out.stdout
