"""Time-axis delta codecs + decode-in-kernel streamed replay.

Contract under test (see core/history.py `DeltaCodec` and
core/store.py `EncodedLeaf`):

  * entry t is stored as ``inner(x_t - base)`` against the immutable f32
    keyframe of key window ``t // key_interval`` — keyframe entries decode
    EXACTLY (residual 0 -> int8 absmax 0 -> scale 1.0, q zeros);
  * overwrites re-encode against the SAME base, so online rewrites never
    ripple into neighbouring entries;
  * the streamed scan path can keep windows ENCODED on device
    (``stream_decode="kernel"``) and dequantize inside the update — the
    endpoint must be bitwise identical to decode-on-fetch, and within the
    repo parity envelope of the per-step python oracle;
  * the disk tier batches one ``win_*.npz`` per stream window, stays
    readable next to the legacy per-step layout, and survives a
    state_dict round-trip mid-stream.
"""

import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.core.deltagrad import (DeltaGradConfig, deltagrad_retrain,
                                  sgd_train_with_cache)
from repro.core.history import (CODECS, DeltaInt8Codec, HistoryMeta,
                                TrainingHistory)
from repro.core.online import online_deltagrad
from repro.core.store import (SegmentStreamer, entry_at, is_encoded_window,
                              tree_nbytes)
from repro.data.synthetic import binary_classification
from repro.models.simple import logreg_init, logreg_objective
from repro.utils.tree import tree_norm, tree_sub

TOL = 1.5e-7
CFG = DeltaGradConfig(period=5, burn_in=10, history_size=2)
META = dict(n=200, batch_size=64, seed=0, steps=30,
            lr_schedule=((0, 0.2),), l2=1e-3)


def _problem():
    ds = binary_classification(n=META["n"], d=16, seed=0)
    obj = logreg_objective(l2=META["l2"])
    return ds, obj, HistoryMeta(**META), logreg_init(16, seed=1)


def _dist(a, b):
    return float(tree_norm(tree_sub(a, b)))


def _tree(seed, scale=1.0):
    rng = np.random.RandomState(seed)
    return {"w": rng.randn(6, 4).astype(np.float32) * scale,
            "b": rng.randn(4).astype(np.float32) * scale}


# --------------------------------------------------------------------------
# Codec-level contracts
# --------------------------------------------------------------------------


class TestDeltaCodec:
    def test_roundtrip_within_residual_quant_error(self):
        codec = DeltaInt8Codec()
        base = codec.make_base(_tree(0))
        x = jax.tree.map(lambda b: b + np.float32(0.01) *
                         np.random.RandomState(1).randn(*b.shape)
                         .astype(np.float32), base)
        out = codec.decode_delta(codec.encode_delta(x, base), base)
        # int8 residual error <= absmax/127 per leaf; residual absmax~0.03
        for k in x:
            err = np.max(np.abs(np.asarray(out[k]) - x[k]))
            bound = np.max(np.abs(x[k] - base[k])) / 127.0
            assert err <= bound + 1e-7

    def test_keyframe_entry_decodes_exactly(self):
        """Residual 0 -> int8 absmax 0 -> scale fallback 1.0, q all-zero:
        the keyframe itself round-trips bitwise."""
        codec = DeltaInt8Codec()
        base = codec.make_base(_tree(2))
        stored = codec.encode_delta(_tree(2), base)
        for k in ("w", "b"):
            assert stored[k]["q"].dtype == np.int8
            assert not stored[k]["q"].any()
            assert float(stored[k]["scale"]) == 1.0
        out = codec.decode_delta(stored, base)
        assert _dist(out, jax.tree.map(np.asarray, base)) == 0.0

    def test_absmax_zero_leaf_no_nan(self):
        codec = CODECS["int8"]()
        z = {"w": np.zeros((3, 3), np.float32)}
        dec = codec.decode(codec.encode(z))
        assert np.all(np.asarray(dec["w"]) == 0.0)

    def test_codec_without_base_raises_actionably(self):
        codec = DeltaInt8Codec()
        with pytest.raises(ValueError, match="encode_delta"):
            codec.encode(_tree(0))
        with pytest.raises(ValueError, match="TrainingHistory"):
            codec.decode({"q": None})

    @pytest.mark.parametrize("codec", ["delta_int8", "delta_bf16"])
    def test_history_entries_within_quant_envelope(self, codec):
        ds, obj, meta, p0 = _problem()
        _, h32 = sgd_train_with_cache(obj, p0, ds, meta, tier="host")
        _, h = sgd_train_with_cache(obj, p0, ds, meta, tier="host",
                                    codec=codec)
        K = h.codec.key_interval
        for t in (0, K - 1, K, K + 1, meta.steps - 1):
            w32, g32 = h32.entry(t)
            w, g = h.entry(t)
            ref = float(tree_norm(w32))
            assert _dist(w, w32) <= 0.05 * max(ref, 1.0)
            assert _dist(g, g32) <= 0.05 * max(float(tree_norm(g32)), 1.0)
        # keyframe entries are exact: residual quantizes to all-zero
        w0, g0 = h.entry(K)
        w0_32, _ = h32.entry(K)
        assert _dist(w0, w0_32) == 0.0

    def test_overwrite_does_not_ripple(self):
        """Rewriting entry t re-encodes against the SAME keyframe: every
        other entry's decoded value is untouched, as is the base."""
        ds, obj, meta, p0 = _problem()
        _, h = sgd_train_with_cache(obj, p0, ds, meta, tier="host",
                                    codec="delta_int8")
        before = [h.entry(t) for t in range(meta.steps)]
        base_before = jax.tree.map(np.copy, h.base_entry(0)[0])
        new_w = jax.tree.map(lambda x: x * 1.5, before[5][0])
        h.overwrite(5, new_w, before[5][1])
        assert _dist(h.base_entry(0)[0], base_before) == 0.0
        for t in range(meta.steps):
            if t == 5:
                continue
            assert _dist(h.entry(t)[0], before[t][0]) == 0.0
            assert _dist(h.entry(t)[1], before[t][1]) == 0.0

    def test_delta_bytes_beat_f32(self):
        ds, obj, meta, p0 = _problem()
        _, h32 = sgd_train_with_cache(obj, p0, ds, meta, tier="host")
        _, h = sgd_train_with_cache(obj, p0, ds, meta, tier="host",
                                    codec="delta_int8")
        # ~2.5 bytes/param/step (int8 residual + base amortized over K=16)
        assert h.nbytes() < 0.45 * h32.nbytes()


# --------------------------------------------------------------------------
# Streamed replay: encoded windows, kernel-vs-fetch, python oracle
# --------------------------------------------------------------------------


class TestDeltaStreamedReplay:
    @pytest.mark.parametrize("codec", ["delta_int8", "delta_bf16"])
    def test_kernel_vs_fetch_bitwise(self, codec):
        """Keeping windows encoded on device and decoding in-scan must be
        BITWISE identical to decode-on-fetch: both decode paths run the
        same `q*scale + base` under jit, so XLA contracts the multiply-add
        identically in both programs."""
        ds, obj, meta, p0 = _problem()
        changed = np.arange(6)
        _, h = sgd_train_with_cache(obj, p0, ds, meta, tier="host",
                                    codec=codec)
        cfg_k = dataclasses.replace(CFG, stream_window=8,
                                    stream_decode="kernel")
        w_k, st_k = deltagrad_retrain(obj, h, ds, changed, cfg_k)
        assert st_k.extra["stream_decode"] == "kernel"
        assert st_k.extra["encoded_bytes_high"] > 0
        # the tiny logreg leaves carry proportionally large scale/kidx/base
        # overhead, so only require strictly-smaller-than-decoded here; the
        # shard bench (64x64 MLP leaves) gates the real ratio
        assert st_k.extra["compression_ratio"] > 1.2
        cfg_f = dataclasses.replace(CFG, stream_window=8,
                                    stream_decode="fetch")
        w_f, st_f = deltagrad_retrain(obj, h, ds, changed, cfg_f)
        assert st_f.extra["stream_decode"] == "fetch"
        assert _dist(w_k, w_f) == 0.0
        # encoded windows keep the device high-water below decoded windows
        assert st_k.extra["hbm_high_water"] < st_f.extra["hbm_high_water"]

    @pytest.mark.parametrize("codec", ["delta_int8", "int8", "bf16"])
    def test_kernel_mode_matches_python_oracle(self, codec):
        ds, obj, meta, p0 = _problem()
        changed = np.arange(6)
        _, h = sgd_train_with_cache(obj, p0, ds, meta, tier="host",
                                    codec=codec)
        cfg = dataclasses.replace(CFG, stream_window=8,
                                  stream_decode="kernel")
        w_k, _ = deltagrad_retrain(obj, h, ds, changed, cfg)
        w_p, _ = deltagrad_retrain(obj, h, ds, changed,
                                   dataclasses.replace(CFG, impl="python"))
        assert _dist(w_k, w_p) <= TOL

    def test_f32_forces_fetch(self):
        ds, obj, meta, p0 = _problem()
        _, h = sgd_train_with_cache(obj, p0, ds, meta, tier="host")
        store = SegmentStreamer(h, window=8)  # decode="auto"
        assert store.decode_mode == "fetch"
        W, _, off = store.window(0, 8)
        assert not is_encoded_window(W)

    def test_unknown_decode_mode_raises(self):
        ds, obj, meta, p0 = _problem()
        _, h = sgd_train_with_cache(obj, p0, ds, meta, tier="host")
        with pytest.raises(ValueError, match="kernel"):
            SegmentStreamer(h, window=8, decode="gpu")

    def test_encoded_window_slice_decode_matches_entry(self):
        """`entry_at` on an ENCODED window (the in-scan decode the engine
        uses outside the Pallas route) agrees with the store's own decoded
        entry bitwise — both run the decode expression under jit."""
        ds, obj, meta, p0 = _problem()
        _, h = sgd_train_with_cache(obj, p0, ds, meta, tier="host",
                                    codec="delta_int8")
        store = SegmentStreamer(h, window=8, decode="kernel")
        W, G, off = store.window(8, 16)
        assert is_encoded_window(W)
        slice_jit = jax.jit(lambda w, t: entry_at(w, t, off))
        for t in (8, 12, 15):
            w_ref, g_ref = store.entry(t)
            assert _dist(slice_jit(W, t), w_ref) == 0.0
            assert _dist(slice_jit(G, t), g_ref) == 0.0

    def test_interpret_kernel_replay_matches_ref(self):
        """The fused dequant Pallas kernels (interpret mode on CPU) take
        over the encoded-window update and agree with the jnp path."""
        ds, obj, meta, p0 = _problem()
        changed = np.arange(6)
        _, h = sgd_train_with_cache(obj, p0, ds, meta, tier="host",
                                    codec="delta_int8")
        cfg = dataclasses.replace(CFG, stream_window=8,
                                  stream_decode="kernel")
        w_ref, _ = deltagrad_retrain(obj, h, ds, changed, cfg)
        w_pl, st = deltagrad_retrain(
            obj, h, ds, changed,
            dataclasses.replace(cfg, fused="interpret"))
        assert st.extra["fused"] == "interpret"
        assert _dist(w_pl, w_ref) <= TOL

    def test_momentum_replay_falls_back_to_jnp_decode(self):
        """Momentum replays have no dequant kernel; encoded windows still
        work via the in-scan slice decode."""
        ds = binary_classification(n=META["n"], d=16, seed=0)
        obj = logreg_objective(l2=META["l2"])
        meta = HistoryMeta(**{**META, "momentum": 0.9})
        _, h = sgd_train_with_cache(obj, logreg_init(16, seed=1), ds, meta,
                                    tier="host", codec="delta_int8")
        cfg = dataclasses.replace(CFG, stream_window=8,
                                  stream_decode="kernel")
        w_k, _ = deltagrad_retrain(obj, h, ds, np.arange(6), cfg)
        w_f, _ = deltagrad_retrain(
            obj, h, ds, np.arange(6),
            dataclasses.replace(cfg, stream_decode="fetch"))
        assert _dist(w_k, w_f) == 0.0
        # vs the eager python oracle the momentum recursion compounds the
        # per-decode 1-ulp FMA difference, so the envelope is looser
        w_p, _ = deltagrad_retrain(obj, h, ds, np.arange(6),
                                   dataclasses.replace(CFG, impl="python"))
        assert _dist(w_k, w_p) <= 4 * TOL

    def test_online_rewrites_committed_through_delta(self):
        """Streamed online requests under the delta codec: rewrites commit
        back through encode_delta against the ORIGINAL keyframes, and a
        fresh engine resumes bit-identically to the uninterrupted run."""
        reqs_all = [("delete", 3), ("delete", 17)]

        def mk():
            ds = binary_classification(n=META["n"], d=16, seed=0)
            obj = logreg_objective(l2=META["l2"])
            _, h = sgd_train_with_cache(obj, logreg_init(16, seed=1), ds,
                                        HistoryMeta(**META), tier="host",
                                        codec="delta_int8")
            return ds, obj, h

        ds1, obj1, h1 = mk()
        w_ref, _ = online_deltagrad(obj1, h1, ds1, reqs_all, CFG)
        ds2, obj2, h2 = mk()
        online_deltagrad(obj2, h2, ds2, reqs_all[:1], CFG)
        ds2.removed[3] = True
        w_resume, _ = online_deltagrad(obj2, h2, ds2, reqs_all[1:], CFG)
        assert _dist(w_resume, w_ref) <= TOL


# --------------------------------------------------------------------------
# Windowed disk spill
# --------------------------------------------------------------------------


class TestWindowedSpill:
    def _train(self, tmp_path, codec="f32", spill_window=None, sub="d"):
        ds, obj, meta, p0 = _problem()
        d = tmp_path / sub
        w, h = sgd_train_with_cache(obj, p0, ds, meta, tier="disk",
                                    codec=codec, spill_dir=str(d),
                                    spill_window=spill_window)
        return ds, obj, meta, w, h, d

    def test_one_npz_per_stream_window(self, tmp_path):
        _, _, meta, _, h, d = self._train(tmp_path, spill_window=8)
        wins = sorted(f for f in os.listdir(d) if f.startswith("win_"))
        assert len(wins) == -(-meta.steps // 8)
        assert not [f for f in os.listdir(d) if f.startswith("step_")]
        assert h.io_write_s > 0.0

    def test_windowed_matches_host_tier_bitwise(self, tmp_path):
        ds, obj, meta, _, h, _ = self._train(tmp_path, spill_window=8)
        _, h_host = sgd_train_with_cache(obj, logreg_init(16, seed=1), ds,
                                         meta, tier="host")
        for t in (0, 7, 8, 15, meta.steps - 1):
            assert _dist(h.entry(t)[0], h_host.entry(t)[0]) == 0.0
            assert _dist(h.entry(t)[1], h_host.entry(t)[1]) == 0.0
        assert h.io_read_s >= 0.0

    def test_legacy_per_step_layout_still_written_and_read(self, tmp_path):
        """spill_window=1 keeps the old step_*.npz files; entries agree
        with the windowed layout bitwise."""
        _, _, meta, _, h1, d1 = self._train(tmp_path, spill_window=1,
                                            sub="legacy")
        _, _, _, _, h8, _ = self._train(tmp_path, spill_window=8, sub="win")
        steps = [f for f in os.listdir(d1) if f.startswith("step_")]
        assert len(steps) == meta.steps
        for t in (0, 13, meta.steps - 1):
            assert _dist(h1.entry(t)[0], h8.entry(t)[0]) == 0.0

    def test_disk_default_spill_window_matches_stream_window(self, tmp_path):
        _, _, meta, _, h, d = self._train(tmp_path)  # spill_window=None
        assert h.spill_window > 1
        assert [f for f in os.listdir(d) if f.startswith("win_")]

    def test_replay_from_windowed_delta_spill(self, tmp_path):
        ds, obj, meta, _, h, _ = self._train(tmp_path, codec="delta_int8",
                                             spill_window=8)
        cfg = dataclasses.replace(CFG, stream_window=8,
                                  stream_decode="kernel")
        w_k, st = deltagrad_retrain(obj, h, ds, np.arange(6), cfg)
        assert st.extra["spill_io_read_s"] >= 0.0
        w_p, _ = deltagrad_retrain(obj, h, ds, np.arange(6),
                                   dataclasses.replace(CFG, impl="python"))
        assert _dist(w_k, w_p) <= TOL

    def test_state_dict_roundtrip_windowed_delta(self, tmp_path):
        ds, obj, meta, _, h, d = self._train(tmp_path, codec="delta_int8",
                                             spill_window=8)
        state = h.state_dict()
        h2 = TrainingHistory.from_state_dict(state, spill_dir=str(d))
        for t in (0, 9, meta.steps - 1):
            assert _dist(h.entry(t)[0], h2.entry(t)[0]) == 0.0
            assert _dist(h.entry(t)[1], h2.entry(t)[1]) == 0.0

    def test_overwrite_through_windowed_spill(self, tmp_path):
        ds, obj, meta, _, h, _ = self._train(tmp_path, codec="delta_int8",
                                             spill_window=8)
        before = [h.entry(t) for t in range(meta.steps)]
        new_w = jax.tree.map(lambda x: x * 1.5, before[9][0])
        h.overwrite(9, new_w, before[9][1])
        for t in range(meta.steps):
            if t == 9:
                continue
            assert _dist(h.entry(t)[0], before[t][0]) == 0.0

    def test_delta_disk_bytes_reported(self, tmp_path):
        _, _, _, _, h, _ = self._train(tmp_path, codec="delta_int8",
                                       spill_window=8)
        assert h.disk_nbytes() > 0
