"""repro.obs — span tracer, shared metrics registry, roofline accounting.

The observability layer's contract (see `repro.obs`'s docstring tables):

  * disabled tracing is near-free and allocation-shared (`NOOP_SPAN`);
  * spans nest per thread, record on any thread, and export as
    Chrome/Perfetto trace-event JSON — deterministic under an injected
    virtual clock;
  * a span opened with ``pred_s`` closes with ``measured_s`` and
    ``roofline_ratio`` (the predicted-vs-measured hook the replay engine
    uses);
  * `Histogram` quantiles track `np.percentile` within one log-bucket
    width, and `ServeMonitor` + `launch/serve.py` both serve their
    percentiles from it — the repo's ONE quantile code path;
  * JSONL and Prometheus exports round-trip the registry;
  * a real scan replay under a live tracer emits roofline-annotated
    ``replay.scan`` spans (the BENCH_obs acceptance invariant).
"""

import json
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.metrics import Histogram, MetricsRegistry, read_jsonl
from repro.obs.trace import NOOP_SPAN, Tracer

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _tracer_clean():
    """Never leak an enabled tracer into other tests (or from them)."""
    obs_trace.disable()
    yield
    obs_trace.disable()


class _VirtualClock:
    """Monotonic fake: every read advances by `step` seconds."""

    def __init__(self, start=100.0, step=1.0):
        self.t = start
        self.step = step

    def __call__(self):
        self.t += self.step
        return self.t


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


class TestTracer:
    def test_disabled_span_is_shared_noop(self):
        assert not obs_trace.enabled()
        s = obs_trace.span("x", a=1)
        assert s is NOOP_SPAN
        with s as inner:
            assert inner.set(b=2) is NOOP_SPAN

    def test_disabled_overhead_bound(self):
        """The disabled call is an attr load + None check; bound it VERY
        loosely (20µs vs the ~0.2µs measured) so slow CI never flakes."""
        obs_trace.disable()
        iters = 50_000
        t0 = time.perf_counter()
        for _ in range(iters):
            obs_trace.span("replay.scan", t0=0, t1=8)
        per_call = (time.perf_counter() - t0) / iters
        assert per_call < 20e-6

    def test_enable_disable_roundtrip(self):
        tr = obs_trace.enable()
        assert obs_trace.enabled() and obs_trace.get_tracer() is tr
        assert obs_trace.enable() is tr  # idempotent reuse
        assert obs_trace.disable() is tr
        assert not obs_trace.enabled()
        assert obs_trace.disable() is None

    def test_virtual_clock_deterministic_export(self):
        """Nested spans under a +1s-per-read clock: exact ts/dur/parent."""
        tr = obs_trace.enable(Tracer(clock=_VirtualClock()))
        # epoch read = 101; outer enter = 102, inner enter = 103,
        # inner exit = 104, outer exit = 105
        with obs_trace.span("outer", k=1):
            with obs_trace.span("inner"):
                pass
        obs_trace.disable()
        inner, outer = tr.events()
        assert inner["name"] == "inner" and outer["name"] == "outer"
        assert inner["ts"] == pytest.approx(2e6)
        assert inner["dur"] == pytest.approx(1e6)
        assert inner["args"]["parent"] == "outer"
        assert outer["ts"] == pytest.approx(1e6)
        assert outer["dur"] == pytest.approx(3e6)
        assert "parent" not in outer["args"]

    def test_roofline_hook_on_exit(self):
        tr = obs_trace.enable(Tracer(clock=_VirtualClock()))
        with obs_trace.span("replay.scan", pred_s=2.0):
            pass  # dur = exactly 1.0s of virtual time
        obs_trace.disable()
        (ev,) = tr.events()
        assert ev["args"]["measured_s"] == pytest.approx(1.0)
        assert ev["args"]["roofline_ratio"] == pytest.approx(0.5)

    def test_cross_thread_spans_get_own_track(self):
        """A span on a worker thread must not nest under the main thread's
        open span — stacks are per-thread, tids are distinct."""
        tr = obs_trace.enable(Tracer())
        started, release = threading.Event(), threading.Event()

        def worker():
            with obs_trace.span("store.window_stage", wid=3):
                started.set()
                release.wait(timeout=5)

        th = threading.Thread(target=worker, name="staging-0")
        with obs_trace.span("replay.scan"):
            th.start()
            assert started.wait(timeout=5)
            release.set()
            th.join(timeout=5)
        obs_trace.disable()
        by_name = {e["name"]: e for e in tr.events()}
        stage = by_name["store.window_stage"]
        scan = by_name["replay.scan"]
        assert stage["tid"] != scan["tid"]
        assert "parent" not in stage["args"]
        names = {m["args"]["name"]
                 for m in tr.to_chrome()["traceEvents"]
                 if m.get("ph") == "M"}
        assert "staging-0" in names

    def test_chrome_export_roundtrip(self, tmp_path):
        tr = obs_trace.enable(Tracer(clock=_VirtualClock()))
        with obs_trace.span("serve.batch", size=4,
                            dtype=np.float32(1.5), err=ValueError("x")):
            pass
        obs_trace.disable()
        path = tr.export_chrome(str(tmp_path / "trace.json"))
        with open(path) as f:
            doc = json.load(f)  # must be strictly valid JSON
        xs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
        assert len(xs) == 1 and xs[0]["name"] == "serve.batch"
        # non-JSON arg values fall back to float/str, never crash export
        assert xs[0]["args"]["dtype"] == pytest.approx(1.5)
        assert "x" in xs[0]["args"]["err"]
        assert doc["displayTimeUnit"] == "ms"

    def test_max_events_drops_not_grows(self):
        tr = obs_trace.enable(Tracer(max_events=3))
        for i in range(5):
            with obs_trace.span(f"s{i}"):
                pass
        obs_trace.disable()
        assert len(tr.events()) == 3
        assert tr.dropped == 2


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


class TestMetrics:
    def test_counter_gauge_basics(self):
        reg = MetricsRegistry()
        c = reg.counter("engine.replays", owner="core.engine")
        c.inc()
        c.inc(3)
        assert reg.counter("engine.replays").value == 4
        g = reg.gauge("store.hbm_high_water_bytes", unit="B")
        g.set_max(100)
        g.set_max(40)  # raise-only
        assert g.value == 100 and g.high == 100
        g.set(10)
        assert g.value == 10 and g.high == 100

    def test_kind_mismatch_raises(self):
        reg = MetricsRegistry()
        reg.counter("m")
        with pytest.raises(TypeError):
            reg.histogram("m")

    def test_labels_key_distinct_metrics(self):
        reg = MetricsRegistry()
        a = reg.counter("serve.served", labels={"class": "interactive"})
        b = reg.counter("serve.served", labels={"class": "batch"})
        a.inc()
        assert b.value == 0
        assert len(reg.metrics()) == 2

    def test_histogram_tracks_np_percentile(self):
        """Quantile error is bounded by one 4% log bucket; exact fields
        (count/mean/min/max) are exact."""
        rng = np.random.default_rng(0)
        sample = rng.lognormal(mean=2.0, sigma=1.2, size=5000)
        h = Histogram("lat", unit="ms")
        for v in sample:
            h.observe(float(v))
        s = h.summary()
        assert s["count"] == 5000
        assert s["mean"] == pytest.approx(float(np.mean(sample)))
        assert s["max"] == pytest.approx(float(np.max(sample)))
        for key, q in (("p50", 50), ("p95", 95), ("p99", 99)):
            exact = float(np.percentile(sample, q))
            assert abs(s[key] - exact) / exact < 0.05, (key, s[key], exact)

    def test_histogram_clamps_and_edges(self):
        h = Histogram("x")
        for v in (0.0, 1e-9, 5.0, 1e12):  # underflow, tiny, mid, overflow
            h.observe(v)
        assert h.min == 0.0 and h.max == 1e12
        assert 0.0 <= h.quantile(0.01) <= 1e12
        assert h.quantile(0.999) <= h.max  # clamped to observed max

    def test_empty_histogram_summary(self):
        assert Histogram("x").summary() == {"count": 0}

    def test_jsonl_roundtrip(self, tmp_path):
        reg = MetricsRegistry()
        reg.counter("queue.admitted", owner="serve.queue").inc(7)
        reg.gauge("online.compile_time_s", unit="s").set(1.25)
        h = reg.histogram("launch.dispatch_ms", unit="ms")
        for v in (1.0, 2.0, 3.0):
            h.observe(v)
        path = reg.to_jsonl(str(tmp_path / "metrics.jsonl"))
        snaps = read_jsonl(path)
        assert snaps == reg.snapshot()
        by_name = {s["name"]: s for s in snaps}
        assert by_name["queue.admitted"]["value"] == 7
        assert by_name["launch.dispatch_ms"]["count"] == 3

    def test_prometheus_exposition(self):
        reg = MetricsRegistry()
        reg.counter("queue.admitted", owner="serve.queue").inc(2)
        reg.histogram("serve.e2e_ms", unit="ms",
                      labels={"class": "interactive"}).observe(10.0)
        text = reg.to_prometheus()
        assert "# TYPE queue_admitted counter" in text
        assert "queue_admitted 2" in text
        assert "# TYPE serve_e2e_ms summary" in text
        assert 'serve_e2e_ms{class="interactive",quantile="0.5"}' in text
        assert 'serve_e2e_ms_count{class="interactive"} 1' in text
        assert text.endswith("\n")

    def test_default_registry_swap(self):
        old = obs_metrics.get_registry()
        try:
            fresh = obs_metrics.set_registry(MetricsRegistry())
            assert obs_metrics.get_registry() is fresh
        finally:
            obs_metrics.set_registry(old)


# ---------------------------------------------------------------------------
# one quantile code path (the dedup satellite)
# ---------------------------------------------------------------------------


class TestOneQuantilePath:
    SAMPLE = [3.0, 1.0, 40.0, 7.5, 0.4, 12.0, 12.0, 95.0, 2.2, 6.1]

    def test_monitor_quantiles_equal_shared_histogram(self):
        """ServeMonitor's per-class dispatch quantiles are EXACTLY the
        shared Histogram's on the same sample — same code, same buckets."""
        from repro.serve.monitor import ServeMonitor
        from repro.serve.queue import QueuedRequest

        mon = ServeMonitor()
        for i, ms in enumerate(self.SAMPLE):
            q = QueuedRequest(tenant="t0", sla_class="interactive",
                              op="delete", rows=[1], data=None,
                              coalesce=True, t_enqueue=0.0, deadline=1e9,
                              seq=i, t_dispatch=ms / 1e3, t_done=ms / 1e3)
            mon.observe_request(q)
        ref = Histogram("ref", unit="ms")
        for ms in self.SAMPLE:
            ref.observe(ms)
        got = mon.snapshot()["per_class"]["interactive"]["dispatch_ms"]
        want = ref.summary()
        assert got == want

    def test_no_private_percentile_helpers_remain(self):
        """The two pre-obs `_pcts` implementations are gone for good."""
        import repro.launch.serve as launch_serve
        import repro.serve.monitor as serve_monitor

        assert not hasattr(serve_monitor, "_pcts")
        assert not hasattr(launch_serve, "_pcts")


# ---------------------------------------------------------------------------
# the instrumented replay path + the CI gate
# ---------------------------------------------------------------------------


class TestReplayInstrumentation:
    def test_scan_replay_emits_roofline_spans(self):
        """A real (tiny) online delete under a live tracer produces
        ``replay.scan`` spans whose args carry the roofline annotations —
        the BENCH_obs acceptance invariant, in-process."""
        import dataclasses

        from repro.core.deltagrad import (DeltaGradConfig,
                                          sgd_train_with_cache)
        from repro.core.history import HistoryMeta
        from repro.core.online import online_deltagrad
        from repro.data.synthetic import binary_classification
        from repro.models.simple import logreg_init, logreg_objective

        n, d, steps = 200, 8, 30
        ds = binary_classification(n=n, d=d, seed=0)
        obj = logreg_objective(l2=5e-3)
        meta = HistoryMeta(n=n, batch_size=32, seed=7, steps=steps,
                           lr_schedule=((0, 0.3),))
        _, hist = sgd_train_with_cache(obj, logreg_init(d, seed=1), ds,
                                       meta, impl="scan")
        cfg = dataclasses.replace(
            DeltaGradConfig(period=5, burn_in=5, history_size=2),
            impl="scan")
        tr = obs_trace.enable(Tracer())
        try:
            online_deltagrad(obj, hist, ds, [3, 11], cfg, mode="delete")
        finally:
            obs_trace.disable()
        scans = [e for e in tr.events() if e["name"] == "replay.scan"]
        assert scans, "no replay.scan spans recorded"
        for ev in scans:
            args = ev["args"]
            assert args["pred_s"] > 0.0
            assert args["measured_s"] >= 0.0
            assert args["roofline_ratio"] == pytest.approx(
                args["measured_s"] / args["pred_s"])
        # the commit span closes out every online replay
        assert any(e["name"] == "replay.commit" for e in tr.events())

    def test_committed_obs_baseline_passes_against_itself(self):
        """`check_bench --suite obs` must accept its own committed
        baseline, or the first CI run after merge is red by
        construction."""
        path = os.path.join(REPO, "benchmarks", "baselines",
                            "BENCH_obs.ci.json")
        tool = os.path.join(REPO, "tools", "check_bench.py")
        proc = subprocess.run(
            [sys.executable, tool, "--suite", "obs", "--current", path,
             "--baseline", path],
            capture_output=True, text=True,
            env={k: v for k, v in os.environ.items()
                 if k != "GITHUB_STEP_SUMMARY"}, cwd=REPO)
        assert proc.returncode == 0, proc.stdout + proc.stderr
