"""L-BFGS compact representation: algebraic identities + paper lemmas."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.lbfgs import (
    LbfgsBuffer,
    bfgs_matrix_recursive,
    lbfgs_hvp_pytree,
    lbfgs_hvp_stacked,
    lbfgs_hvp_stacked_pytree,
)


def make_history(m, p, seed=0, mu=1.0):
    """Curvature-consistent pairs: dg = H dw with H spd (so D_ii > 0)."""
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(p, p)).astype(np.float32)
    H = A @ A.T / p + mu * np.eye(p, dtype=np.float32)
    dW = rng.normal(size=(m, p)).astype(np.float32)
    dG = (dW @ H.T).astype(np.float32)
    v = rng.normal(size=(p,)).astype(np.float32)
    return jnp.asarray(dW), jnp.asarray(dG), jnp.asarray(v), H


@pytest.mark.parametrize("m,p", [(1, 8), (2, 17), (3, 40), (5, 64), (8, 128)])
def test_compact_matches_recursive(m, p):
    dW, dG, v, _ = make_history(m, p)
    compact = lbfgs_hvp_stacked(dW, dG, v)
    B = bfgs_matrix_recursive(dW, dG)
    np.testing.assert_allclose(np.asarray(compact), np.asarray(B @ v),
                               rtol=2e-4, atol=2e-4)


def test_secant_equation():
    """B dw_last == dg_last — the defining quasi-Newton property."""
    dW, dG, v, _ = make_history(3, 32, seed=1)
    out = lbfgs_hvp_stacked(dW, dG, dW[-1])
    np.testing.assert_allclose(np.asarray(out), np.asarray(dG[-1]),
                               rtol=1e-4, atol=1e-4)


def test_quasi_hessian_positive_definite():
    """Lemma 6: z^T B z > 0 for curvature-consistent history."""
    dW, dG, _, _ = make_history(4, 24, seed=2)
    B = bfgs_matrix_recursive(dW, dG)
    eig = np.linalg.eigvalsh(np.asarray(B))
    assert eig.min() > 0


def test_pytree_and_stacked_pytree_agree_with_flat():
    m, p = 3, 30
    dW, dG, v, _ = make_history(m, p, seed=3)
    cut = 13
    tw = [{"a": dW[i, :cut], "b": dW[i, cut:]} for i in range(m)]
    tg = [{"a": dG[i, :cut], "b": dG[i, cut:]} for i in range(m)]
    tv = {"a": v[:cut], "b": v[cut:]}
    flat = np.asarray(lbfgs_hvp_stacked(dW, dG, v))
    out1 = lbfgs_hvp_pytree(tw, tg, tv)
    got1 = np.concatenate([np.asarray(out1["a"]), np.asarray(out1["b"])])
    np.testing.assert_allclose(got1, flat, rtol=1e-4, atol=1e-4)
    dWs = jax.tree.map(lambda *xs: jnp.stack(xs), *tw)
    dGs = jax.tree.map(lambda *xs: jnp.stack(xs), *tg)
    out2 = lbfgs_hvp_stacked_pytree(dWs, dGs, tv)
    got2 = np.concatenate([np.asarray(out2["a"]), np.asarray(out2["b"])])
    np.testing.assert_allclose(got2, flat, rtol=1e-4, atol=1e-4)


def test_buffer_admission_and_ring():
    buf = LbfgsBuffer(capacity=2, curvature_eps=0.0)
    dW, dG, v, _ = make_history(4, 16, seed=4)
    assert not buf.add(jnp.zeros(16), jnp.zeros(16))  # zero dw rejected
    for i in range(4):
        assert buf.add(dW[i], dG[i])
    assert len(buf) == 2  # ring keeps the last m
    out = buf.hvp(v)
    ref = lbfgs_hvp_stacked(dW[2:], dG[2:], v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4,
                               atol=1e-4)


def test_buffer_rejects_negative_curvature():
    buf = LbfgsBuffer(capacity=2, curvature_eps=0.0)
    dw = jnp.ones(8)
    assert not buf.add(dw, -dw)  # <dg, dw> < 0 — Algorithm-4 guard
    assert buf.rejected == 1


def test_stacked_cache_invalidation():
    buf = LbfgsBuffer(capacity=2)
    dW, dG, v, _ = make_history(3, 16, seed=5)
    buf.add(dW[0], dG[0])
    s1 = buf.stacked()
    assert buf.stacked() is s1  # cached
    buf.add(dW[1], dG[1])
    assert buf.stacked() is not s1  # invalidated


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 6), p=st.integers(4, 48), seed=st.integers(0, 10**6))
def test_hvp_linear_in_v(m, p, seed):
    """B(av1 + v2) == a Bv1 + Bv2 (hypothesis)."""
    dW, dG, _, _ = make_history(m, p, seed=seed)
    rng = np.random.default_rng(seed + 1)
    v1 = jnp.asarray(rng.normal(size=(p,)).astype(np.float32))
    v2 = jnp.asarray(rng.normal(size=(p,)).astype(np.float32))
    a = 1.7
    lhs = lbfgs_hvp_stacked(dW, dG, a * v1 + v2)
    rhs = a * lbfgs_hvp_stacked(dW, dG, v1) + lbfgs_hvp_stacked(dW, dG, v2)
    scale = float(jnp.max(jnp.abs(rhs))) + 1.0
    np.testing.assert_allclose(np.asarray(lhs) / scale,
                               np.asarray(rhs) / scale, atol=5e-4)
