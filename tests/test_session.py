"""UnlearnerSession: request-plan serving — coalescing, laziness,
interleaved batch/stream semantics, snapshot/restore, capacity bucketing."""

import dataclasses

import numpy as np
import pytest

import jax

from repro.core.deltagrad import DeltaGradConfig
from repro.core.session import (UnlearnerConfig, UnlearnerSession,
                                UnlearnRequest, plan_requests)
from repro.data.synthetic import binary_classification
from repro.models.simple import logreg_init, logreg_objective
from repro.utils.tree import tree_norm, tree_sub

PARITY_TOL = 1.5e-7


def make_session(n=800, d=10, steps=50, batch=256, impl="scan", seed=0):
    ds = binary_classification(n=n, d=d, seed=seed)
    obj = logreg_objective(l2=5e-3)
    cfg = UnlearnerConfig(
        steps=steps, batch_size=batch, lr=0.4, seed=seed,
        deltagrad=DeltaGradConfig(period=5, burn_in=8, history_size=2,
                                  impl=impl))
    sess = UnlearnerSession(obj, logreg_init(d, seed=seed + 1), ds, cfg)
    sess.fit()
    return sess, ds


def leaves_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


# -- coalescing ------------------------------------------------------------


def test_coalesced_burst_parity_vs_python_oracle():
    """The acceptance bar: a K=8 coalesced delete replay must match the
    per-step python oracle serving the SAME group schedule to <= 1.5e-7."""
    rows = np.random.default_rng(5).choice(800, 8, replace=False).tolist()
    sess_scan, _ = make_session(impl="scan")
    sess_py, _ = make_session(impl="python")
    w_scan = sess_scan.delete(rows).params
    w_py = sess_py.delete(rows).params
    d = float(tree_norm(tree_sub(w_scan, w_py)))
    assert d <= PARITY_TOL, d


def test_coalesced_burst_tracks_baseline_and_serial():
    """Serving-semantics contract (core.session docstring): the coalesced
    group correction approximates the same leave-K-out model as the serial
    Algorithm-3 stream — both must land far closer to exact retraining
    than the original model, and close to each other."""
    rows = np.random.default_rng(6).choice(800, 8, replace=False).tolist()
    sess_c, _ = make_session()
    w_star = sess_c.params
    w_u, _ = sess_c.baseline(rows)

    w_coal = sess_c.delete(rows).params
    sess_s, _ = make_session()
    sess_s.stream_delete(rows)
    w_serial = sess_s.params

    d_cu = float(tree_norm(tree_sub(w_coal, w_u)))
    d_su = float(tree_norm(tree_sub(w_serial, w_u)))
    d_0u = float(tree_norm(tree_sub(w_star, w_u)))
    assert d_cu < 0.3 * d_0u, (d_cu, d_0u)
    assert d_su < 0.3 * d_0u, (d_su, d_0u)
    d_cs = float(tree_norm(tree_sub(w_coal, w_serial)))
    assert d_cs < 0.5 * d_0u, (d_cs, d_0u)


def test_planner_groups_adjacent_same_op_requests():
    reqs = [
        (0, UnlearnRequest("delete", [1])),
        (1, UnlearnRequest("delete", [2, 3])),
        (2, UnlearnRequest("add", [800])),
        (3, UnlearnRequest("delete", [4])),
        (4, UnlearnRequest("delete", [5], coalesce=False)),  # breaks the run
        (5, UnlearnRequest("delete", [6])),
    ]
    groups = plan_requests(reqs)
    shape = [[t for t, _ in g] for g in groups]
    assert shape == [[0, 1], [2], [3], [4], [5]]


def test_handles_are_lazy_and_share_one_group_replay():
    sess, ds = make_session(steps=40)
    h1 = sess.delete([1, 2, 3])
    h2 = sess.delete([10, 11])
    h3 = sess.add(data={k: v[:2] for k, v in ds.columns.items()})
    # nothing executed yet: no engine, no responses
    assert sess._engine is None and not h1.done and not h3.done
    r1 = h1.result()
    # forcing ONE handle flushes the whole plan
    assert h2.done and h3.done
    # the two delete requests coalesced into one 5-row replay
    assert r1.group_size == 5 and len(r1.stats) == 1
    assert h2.result().stats[0] is r1.stats[0]
    assert h3.result().group_size == 2
    assert ds.removed[[1, 2, 3, 10, 11]].all()
    assert sess._engine.added == [800, 801]


def test_submit_validates_rows():
    sess, _ = make_session(steps=40)
    sess.delete([7]).result()
    with pytest.raises(ValueError, match="already deleted"):
        sess.delete([7])
    sess.delete([8])  # pending
    with pytest.raises(ValueError, match="already deleted"):
        sess.delete([8])
    with pytest.raises(ValueError, match="out of range"):
        sess.delete([10_000])
    with pytest.raises(ValueError, match="duplicate"):
        sess.delete([9, 9])
    with pytest.raises(ValueError, match="names no rows"):
        sess.delete([])


def test_submit_validates_add_rows():
    sess, ds = make_session(steps=40)
    with pytest.raises(ValueError, match="appended AFTER"):
        sess.add(rows=[3])  # an original row would be double-counted
    new = ds.append({k: v[:1] for k, v in ds.columns.items()})
    h = sess.add(rows=new.tolist())
    with pytest.raises(ValueError, match="pending add"):
        sess.add(rows=new.tolist())
    h.result()
    with pytest.raises(ValueError, match="already added"):
        sess.add(rows=new.tolist())


def test_flush_failure_keeps_later_requests_servable(monkeypatch):
    """A group that dies mid-plan must not strand the rest of the plan:
    later groups go back on the queue, and the failed group's handles
    resolve to a clear error instead of a bare KeyError."""
    sess, ds = make_session(steps=40)
    h1 = sess.delete([1])
    h2 = sess.delete([2], coalesce=False)  # this group will fail
    h3 = sess.delete([3])

    from repro.core import online
    orig = online.OnlineEngine.request_group

    def boom(self, op, rows):
        if rows == [2]:
            raise RuntimeError("boom")
        return orig(self, op, rows)

    monkeypatch.setattr(online.OnlineEngine, "request_group", boom)
    with pytest.raises(RuntimeError, match="boom"):
        h1.result()  # forces the flush that hits the failure
    monkeypatch.undo()

    assert h1.result().group_size == 1  # served before the failure
    with pytest.raises(RuntimeError, match="not served"):
        h2.result()
    r3 = h3.result()  # re-queued and served on the next flush
    assert r3.group_size == 1 and ds.removed[3] and not ds.removed[2]


def test_group_delete_r_pad_capped_at_batch_size():
    """A K >> B delete group must not widen every step's changed block to
    K: the pad caps at the minibatch bound (like the batch path)."""
    sess, _ = make_session(n=800, batch=64, steps=30)
    eng = sess.engine()
    rows = list(range(100))
    sched = eng._schedule("delete", rows)
    assert sched.changed_idx.shape[1] == 64  # pow2(min(100, B=64))
    assert sess.delete(rows).result().group_size == 100


def test_response_eviction_bounds_memory():
    sess, _ = make_session(steps=40)
    sess.max_responses = 2
    handles = [sess.delete([r], coalesce=False) for r in (1, 2, 3)]
    sess.flush()
    # oldest response evicted (3 singleton groups, cap 2)
    with pytest.raises(RuntimeError, match="evicted"):
        handles[0].result()
    assert handles[2].result().group_size == 1


# -- interleaved batch/stream semantics ------------------------------------


def _interleaved_plan(sess, ds):
    """delete (coalesced batch) -> stream_add (serial) -> delete again —
    the interleaving the pre-session API silently corrupted."""
    sess.delete([3, 17]).result()
    sess.stream_add({k: v[:2] for k, v in ds.columns.items()})
    sess.delete([40, 41]).result()
    return sess.params


def test_interleaved_batch_stream_parity_vs_python_oracle():
    sess_a, ds_a = make_session(impl="scan", steps=40)
    sess_b, ds_b = make_session(impl="python", steps=40)
    w_a = _interleaved_plan(sess_a, ds_a)
    w_b = _interleaved_plan(sess_b, ds_b)
    d = float(tree_norm(tree_sub(w_a, w_b)))
    assert d <= PARITY_TOL, d
    # both engines kept the full stream state across the interleaving
    for sess in (sess_a, sess_b):
        eng = sess._engine
        assert eng.added == [800, 801]
        assert not eng.live[[3, 17, 40, 41]].any()


# -- snapshot / restore ----------------------------------------------------


def test_snapshot_restore_roundtrip_mid_stream(tmp_path):
    """save() mid-stream; the restored session must serve the next request
    IDENTICALLY: bitwise-equal params and equal OnlineStats counters."""
    obj = logreg_objective(l2=5e-3)
    ds = binary_classification(n=800, d=10, seed=0)
    cfg = UnlearnerConfig(steps=50, batch_size=256, lr=0.4, seed=0,
                          deltagrad=DeltaGradConfig(period=5, burn_in=8))
    sess = UnlearnerSession(obj, logreg_init(10, seed=1), ds, cfg)
    sess.fit()
    sess.delete([1, 2, 3]).result()
    sess.stream_add({k: v[:2] for k, v in ds.columns.items()})
    sess.save(str(tmp_path))

    restored = UnlearnerSession.restore(str(tmp_path), obj)
    assert leaves_equal(sess.params, restored.params)
    assert restored._engine.added == sess._engine.added
    assert np.array_equal(restored._engine.live, sess._engine.live)
    assert restored._engine.last_ring is not None

    st_a = sess.stream_delete([30])
    st_b = restored.stream_delete([30])
    assert leaves_equal(sess.params, restored.params)  # bitwise
    a, b = st_a.per_request[0], st_b.per_request[0]
    for f in ("explicit_steps", "approx_steps", "guard_fallbacks",
              "skipped_steps", "grad_examples", "grad_examples_baseline"):
        assert getattr(a, f) == getattr(b, f), f
    # restored history keeps rewriting (next request also matches)
    assert leaves_equal(sess.history.final_params,
                        restored.history.final_params)


def test_restore_missing_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        UnlearnerSession.restore(str(tmp_path / "nope"),
                                 logreg_objective(l2=5e-3))


# -- pow2-bucketed add capacity --------------------------------------------


def test_device_columns_capacity_keeps_shapes_stable():
    ds = binary_classification(n=100, d=4, seed=0)
    cols = ds.device_columns(capacity=128)
    assert all(v.shape[0] == 128 for v in cols.values())
    ds.append({k: v[:5] for k, v in ds.columns.items()})
    cols2 = ds.device_columns(capacity=128)
    # re-uploaded (new rows) but the SHAPE — what compiled programs key on
    # — is unchanged, so nothing retraces
    assert all(v.shape[0] == 128 for v in cols2.values())
    with pytest.raises(AssertionError):
        ds.device_columns(capacity=64)  # below n


def test_engine_row_capacity_grows_pow2():
    sess, ds = make_session(steps=40)
    eng = sess.engine()
    base = eng._base_n
    assert eng._row_cap == base
    widths = set()
    for i in range(5):
        sess.stream_add({k: v[i:i + 1] for k, v in ds.columns.items()})
        widths.add(eng._cols()["x"].shape[0])
        assert eng._row_cap - base == 1 << max(
            0, (ds.n - base - 1).bit_length()), (eng._row_cap, ds.n)
    # 5 appends landed in O(log) distinct shapes: caps 1, 2, 4, 8
    assert len(widths) <= 4, widths


# -- unlearner shim over the session ---------------------------------------


def test_unlearner_shim_batch_after_stream_keeps_state():
    """The silent-state-loss footgun: batch delete()/add() after stream_*
    must reuse the session engine (added rows + liveness survive), never
    silently rebuild from a stale cache."""
    from repro.core.api import Unlearner

    ds = binary_classification(n=400, d=8, seed=3)
    unl = Unlearner(logreg_objective(l2=5e-3), logreg_init(8, seed=4), ds,
                    UnlearnerConfig(steps=30, batch_size=64, lr=0.3,
                                    deltagrad=DeltaGradConfig(period=5,
                                                              burn_in=4)))
    unl.fit()
    unl.stream_add({k: v[:2] for k, v in ds.columns.items()})
    eng = unl._online
    assert eng is not None and eng.added == [400, 401]
    stats = unl.delete([5, 6])  # batch request on the SAME engine
    assert unl._online is eng
    assert eng.added == [400, 401]  # join columns survived
    assert not eng.live[[5, 6]].any()
    assert stats.approx_steps > 0
    # deleting a previously-added row still works after the batch call
    unl.stream_delete([400])
    assert unl._online is eng and not eng.live[400]


def test_partial_ring_parity_scan_vs_python():
    """burn_in < history_size: the first approx steps run the masked
    compact solve over a PARTIALLY-filled device ring (1..m pairs, no
    host-side burn-in) — scan must still match the python oracle."""
    rows = np.random.default_rng(11).choice(800, 6, replace=False).tolist()
    ws = {}
    for impl in ("scan", "python"):
        ds = binary_classification(n=800, d=10, seed=0)
        cfg = UnlearnerConfig(
            steps=50, batch_size=256, lr=0.4, seed=0,
            deltagrad=DeltaGradConfig(period=3, burn_in=2, history_size=4,
                                      impl=impl))
        sess = UnlearnerSession(logreg_objective(l2=5e-3),
                                logreg_init(10, seed=1), ds, cfg)
        sess.fit()
        ws[impl] = sess.delete(rows).params
    d = float(tree_norm(tree_sub(ws["scan"], ws["python"])))
    assert d <= PARITY_TOL, d
