"""tools/check_bench.py — the CI bench-regression gate.

The contract under test (and the PR's acceptance criterion): CI FAILS —
nonzero exit — when a bench metric regresses past its ratio threshold or
a parity field changes, passes when the run matches its committed
baseline, and writes the per-metric comparison table to
$GITHUB_STEP_SUMMARY.
"""

import copy
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TOOL = os.path.join(REPO, "tools", "check_bench.py")


def shard_doc():
    """A minimal-but-complete shard bench JSON covering every gated path."""
    return {
        "config": {"n": 800, "steps": 48, "window": 16, "devices": 8},
        "variants": [
            {"variant": "resident", "wall_s": 0.03,
             "hbm_high_water_bytes": 1_600_000,
             "approx_steps": 33, "explicit_steps": 15},
            {"variant": "streamed", "wall_s": 0.05,
             "hbm_high_water_bytes": 800_000,
             "approx_steps": 33, "explicit_steps": 15,
             "parity_vs_resident": 0.0},
            {"variant": "mesh", "wall_s": 0.4,
             "hbm_high_water_bytes": 228_000,
             "approx_steps": 33, "explicit_steps": 15,
             "parity_vs_resident": 2.6e-08},
            {"variant": "sharded_streamed", "wall_s": 0.8,
             "hbm_high_water_bytes": 228_000,
             "approx_steps": 33, "explicit_steps": 15,
             "parity_vs_resident": 2.6e-08,
             "parity_vs_mesh_resident": 0.0},
        ],
        "hbm_reduction_mesh": 7.0,
        "hbm_reduction_streamed": 2.0,
        "hbm_reduction_sharded_streamed": 7.0,
        "sharded_streamed_shard_windows": 3.0,
        "wall_ratio_streamed": 1.7,
        "wall_ratio_mesh": 13.0,
        "wall_ratio_sharded_streamed": 27.0,
        "delta_int8": {
            "host_ram_reduction": 3.1,
            "disk_bytes_reduction": 3.9,
            "compression_ratio": 3.2,
            "wall_ratio_vs_sharded_streamed": 1.0,
            "kernel_vs_fetch": 0.0,
            "parity_vs_python": 3.3e-08,
            "sharded_vs_streamed": 3.9e-08,
        },
    }


def run_gate(tmp_path, current, baseline, env_extra=None, rolling=None):
    cur = tmp_path / "current.json"
    base = tmp_path / "baseline.json"
    cur.write_text(json.dumps(current))
    base.write_text(json.dumps(baseline))
    env = dict(os.environ)
    env.pop("GITHUB_STEP_SUMMARY", None)
    if env_extra:
        env.update(env_extra)
    cmd = [sys.executable, TOOL, "--suite", "shard", "--current", str(cur),
           "--baseline", str(base)]
    if rolling is not None:
        roll = tmp_path / "rolling.json"
        if isinstance(rolling, dict):
            roll.write_text(json.dumps(rolling))
        cmd += ["--rolling", str(roll)]
    return subprocess.run(cmd, capture_output=True, text=True, env=env,
                          cwd=REPO)


class TestCheckBenchGate:
    def test_identical_run_passes(self, tmp_path):
        doc = shard_doc()
        proc = run_gate(tmp_path, doc, doc)
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "PASS" in proc.stdout

    def test_wall_regression_past_threshold_fails(self, tmp_path):
        base = shard_doc()
        cur = copy.deepcopy(base)
        cur["wall_ratio_streamed"] = base["wall_ratio_streamed"] * 5
        proc = run_gate(tmp_path, cur, base)
        assert proc.returncode == 1
        assert "wall_ratio_streamed" in proc.stderr

    def test_wobble_within_threshold_passes(self, tmp_path):
        base = shard_doc()
        cur = copy.deepcopy(base)
        cur["wall_ratio_streamed"] = base["wall_ratio_streamed"] * 1.5
        cur["hbm_reduction_streamed"] = base["hbm_reduction_streamed"] * 0.9
        proc = run_gate(tmp_path, cur, base)
        assert proc.returncode == 0, proc.stdout + proc.stderr

    def test_exact_parity_field_change_fails(self, tmp_path):
        """A 0.0 parity baseline is an invariant, not a measurement: ANY
        nonzero value fails, however small."""
        base = shard_doc()
        cur = copy.deepcopy(base)
        cur["variants"][1]["parity_vs_resident"] = 1e-9  # streamed
        proc = run_gate(tmp_path, cur, base)
        assert proc.returncode == 1
        assert "parity_vs_resident" in proc.stderr

    def test_nonzero_parity_may_wobble_not_drift(self, tmp_path):
        base = shard_doc()
        cur = copy.deepcopy(base)
        cur["variants"][2]["parity_vs_resident"] = 5e-08  # < 1.5e-7 floor
        assert run_gate(tmp_path, cur, base).returncode == 0
        cur["variants"][2]["parity_vs_resident"] = 5e-06  # real drift
        assert run_gate(tmp_path, cur, base).returncode == 1

    def test_counter_change_fails(self, tmp_path):
        base = shard_doc()
        cur = copy.deepcopy(base)
        cur["variants"][3]["approx_steps"] += 1
        proc = run_gate(tmp_path, cur, base)
        assert proc.returncode == 1

    def test_config_mismatch_demands_new_baseline(self, tmp_path):
        base = shard_doc()
        cur = copy.deepcopy(base)
        cur["config"]["steps"] = 96
        proc = run_gate(tmp_path, cur, base)
        assert proc.returncode == 1
        assert "commit the new baseline" in proc.stdout

    def test_missing_metric_fails(self, tmp_path):
        base = shard_doc()
        cur = copy.deepcopy(base)
        del cur["sharded_streamed_shard_windows"]
        proc = run_gate(tmp_path, cur, base)
        assert proc.returncode == 1
        assert "disappeared" in proc.stdout

    def test_step_summary_table_written(self, tmp_path):
        doc = shard_doc()
        summary = tmp_path / "summary.md"
        proc = run_gate(tmp_path, doc, doc,
                        env_extra={"GITHUB_STEP_SUMMARY": str(summary)})
        assert proc.returncode == 0
        text = summary.read_text()
        assert "| metric | baseline | current |" in text
        assert "sharded_streamed_shard_windows" in text

    def test_rolling_missing_file_skipped(self, tmp_path):
        """No artifact from a last green main (first run, or the artifact
        expired) must not fail the gate."""
        doc = shard_doc()
        proc = run_gate(tmp_path, doc, doc, rolling="missing")
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "skipped (first run or expired artifact)" in proc.stdout

    def test_rolling_stale_config_skipped(self, tmp_path):
        doc = shard_doc()
        rolling = copy.deepcopy(doc)
        rolling["config"]["steps"] = 96
        proc = run_gate(tmp_path, doc, doc, rolling=rolling)
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "skipped as stale" in proc.stdout

    def test_rolling_regression_fails(self, tmp_path):
        """Slow drift: each run passes the loose committed thresholds but
        regresses vs the LAST run — the rolling compare catches it."""
        doc = shard_doc()
        rolling = copy.deepcopy(doc)
        rolling["delta_int8"]["host_ram_reduction"] = (
            doc["delta_int8"]["host_ram_reduction"] * 2)
        proc = run_gate(tmp_path, doc, doc, rolling=rolling)
        assert proc.returncode == 1
        assert "host_ram_reduction" in proc.stderr
        assert "rolling, last green main" in proc.stdout

    def test_rolling_identical_passes(self, tmp_path):
        doc = shard_doc()
        proc = run_gate(tmp_path, doc, doc, rolling=doc)
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "rolling, last green main" in proc.stdout

    def test_committed_shard_baseline_passes_against_itself(self):
        """The committed CI baseline must satisfy its own gate — otherwise
        the first CI run after merge is red by construction."""
        path = os.path.join(REPO, "benchmarks", "baselines",
                            "BENCH_shard.ci.json")
        proc = subprocess.run(
            [sys.executable, TOOL, "--suite", "shard", "--current", path,
             "--baseline", path],
            capture_output=True, text=True,
            env={k: v for k, v in os.environ.items()
                 if k != "GITHUB_STEP_SUMMARY"}, cwd=REPO)
        assert proc.returncode == 0, proc.stdout + proc.stderr

    def test_committed_serve_baseline_passes_against_itself(self):
        path = os.path.join(REPO, "benchmarks", "baselines",
                            "BENCH_serve.ci.json")
        proc = subprocess.run(
            [sys.executable, TOOL, "--suite", "serve", "--current", path,
             "--baseline", path],
            capture_output=True, text=True,
            env={k: v for k, v in os.environ.items()
                 if k != "GITHUB_STEP_SUMMARY"}, cwd=REPO)
        assert proc.returncode == 0, proc.stdout + proc.stderr
