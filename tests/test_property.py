"""Hypothesis property tests on system invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.deltagrad import (
    DeltaGradConfig,
    baseline_retrain,
    deltagrad_retrain,
    sgd_train_with_cache,
)
from repro.core.history import HistoryMeta
from repro.data.dataset import Dataset
from repro.data.synthetic import binary_classification
from repro.models.simple import logreg_init, logreg_objective
from repro.utils.tree import tree_norm, tree_sub


def _fit(n=300, d=6, steps=25, batch=64, seed=0):
    ds = binary_classification(n=n, d=d, seed=seed)
    obj = logreg_objective(l2=5e-3)
    meta = HistoryMeta(n=n, batch_size=batch, seed=5, steps=steps,
                       lr_schedule=((0, 0.3),))
    p0 = logreg_init(d, seed=seed + 1)
    w, h = sgd_train_with_cache(obj, p0, ds, meta)
    return ds, obj, meta, p0, w, h


DS, OBJ, META, P0, W_STAR, HIST = _fit()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_removal_set_order_invariance(seed):
    """DeltaGrad output depends on the removal SET, not its order."""
    rng = np.random.default_rng(seed)
    r = rng.choice(DS.n, size=5, replace=False)
    cfg = DeltaGradConfig(period=5, burn_in=5)
    w1, _ = deltagrad_retrain(OBJ, HIST, DS, r, cfg)
    w2, _ = deltagrad_retrain(OBJ, HIST, DS, r[::-1].copy(), cfg)
    assert float(tree_norm(tree_sub(w1, w2))) < 1e-6


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10**6), r=st.integers(1, 12))
def test_error_bounded_by_trivial_bound(seed, r):
    """||w^I - w^U|| stays below ||w^* - w^U|| (DeltaGrad never worse than
    not retraining at all)."""
    rng = np.random.default_rng(seed)
    rem = rng.choice(DS.n, size=r, replace=False)
    cfg = DeltaGradConfig(period=5, burn_in=5)
    w_u, _ = baseline_retrain(OBJ, DS, META, P0, rem)
    w_i, _ = deltagrad_retrain(OBJ, HIST, DS, rem, cfg)
    d_ui = float(tree_norm(tree_sub(w_u, w_i)))
    d_us = float(tree_norm(tree_sub(w_u, W_STAR)))
    assert d_ui <= d_us + 1e-6


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_period1_equals_baseline(seed):
    """T0 == 1 with burn_in covering everything == exact retraining."""
    rng = np.random.default_rng(seed)
    rem = rng.choice(DS.n, size=4, replace=False)
    cfg = DeltaGradConfig(period=1, burn_in=META.steps)
    w_u, _ = baseline_retrain(OBJ, DS, META, P0, rem)
    w_i, stats = deltagrad_retrain(OBJ, HIST, DS, rem, cfg)
    assert stats.approx_steps == 0
    assert float(tree_norm(tree_sub(w_u, w_i))) < 1e-6


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10**6), m=st.integers(1, 5))
def test_dataset_delete_undelete_roundtrip(seed, m):
    rng = np.random.default_rng(seed)
    ds = Dataset({"x": rng.normal(size=(50, 3)).astype(np.float32)})
    idx = rng.choice(50, size=m, replace=False)
    ds.delete(idx)
    assert ds.n_remaining == 50 - m
    ds.undelete(idx)
    assert ds.n_remaining == 50
    assert not ds.removed.any()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_gradient_compression_error_feedback_bounded(seed):
    """int8 + EF: per-step dequant error never exceeds one quantization
    step of the corrected gradient."""
    from repro.dist.compress import compress_grads, decompress_grads, init_error
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(32,)).astype(np.float32))}
    e = init_error(g)
    q, e2 = compress_grads(g, e)
    deq = decompress_grads(q)
    corrected = np.asarray(g["w"])  # error was zero
    scale = np.abs(corrected).max() / 127.0
    err = np.abs(np.asarray(deq["w"]) - corrected)
    assert err.max() <= scale / 2 + 1e-6
    np.testing.assert_allclose(np.asarray(e2["w"]),
                               corrected - np.asarray(deq["w"]), atol=1e-6)
