"""DeltaGrad engine vs exact retraining — the paper's central claims.

Theorem 1/7: ||w^U - w^I|| = o(r/n), an order below ||w^U - w^*|| = O(r/n).
Complexity §2.4: DeltaGrad evaluates ~(1/T0) of BaseL's per-sample gradients.
"""

import numpy as np
import pytest

from repro.core.deltagrad import (
    DeltaGradConfig,
    baseline_retrain,
    deltagrad_retrain,
    sgd_train_with_cache,
)
from repro.core.history import HistoryMeta
from repro.data.synthetic import binary_classification, multiclass_classification
from repro.models.simple import (
    logreg_init,
    logreg_objective,
    mlp_init,
    mlp_objective,
    multiclass_init,
    multiclass_objective,
)
from repro.utils.tree import tree_norm, tree_sub


def run_case(mode, batch_size, r, steps=80, n=2000, d=20, seed=0,
             cfg=None, objective=None, params0=None, ds=None):
    ds = ds or binary_classification(n=n, d=d, seed=seed)
    objective = objective or logreg_objective(l2=5e-3)
    params0 = params0 or logreg_init(d, seed=seed + 1)
    meta = HistoryMeta(n=ds.n, batch_size=batch_size, seed=7, steps=steps,
                       lr_schedule=((0, 0.5),))
    w_star, hist = sgd_train_with_cache(objective, params0, ds, meta)
    changed = np.random.default_rng(seed + 2).choice(
        ds.n if mode == "delete" else ds.n, size=r, replace=False)
    if mode == "add":
        rows = {k: v[changed] for k, v in ds.columns.items()}
        changed = ds.append(rows)
    cfg = cfg or DeltaGradConfig(period=5, burn_in=10, history_size=2)
    w_u, _ = baseline_retrain(objective, ds, meta, params0, changed, mode=mode)
    w_i, stats = deltagrad_retrain(objective, hist, ds, changed, cfg, mode=mode)
    d_ui = float(tree_norm(tree_sub(w_u, w_i)))
    d_us = float(tree_norm(tree_sub(w_u, w_star)))
    return d_ui, d_us, stats


class TestBatchDeletion:
    def test_sgd_delete_is_order_better_than_full_model(self):
        d_ui, d_us, stats = run_case("delete", batch_size=512, r=20)
        assert d_ui < 0.25 * d_us, (d_ui, d_us)
        assert stats.approx_steps > stats.explicit_steps

    def test_gd_delete(self):
        d_ui, d_us, _ = run_case("delete", batch_size=1 << 30, r=20)
        assert d_ui < 0.25 * d_us, (d_ui, d_us)

    def test_gradient_eval_speedup_close_to_period(self):
        cfg = DeltaGradConfig(period=10, burn_in=5, history_size=2)
        _, _, stats = run_case("delete", batch_size=1 << 30, r=10, cfg=cfg)
        # §2.4: speedup ~ T0 when j0 << T and r << n
        assert stats.theoretical_speedup > 4.0

    def test_zero_rate_matches_exact_replay(self):
        """r == 0: every step is the exact leave-0-out update -> w^I == w^U
        up to fp noise."""
        d_ui, _, _ = run_case("delete", batch_size=512, r=0)
        assert d_ui < 1e-5

    def test_multiclass(self):
        ds = multiclass_classification(n=1500, d=16, num_classes=5, seed=3)
        d_ui, d_us, _ = run_case(
            "delete", batch_size=512, r=15, ds=ds,
            objective=multiclass_objective(l2=5e-3),
            params0=multiclass_init(16, 5, seed=4))
        assert d_ui < 0.3 * d_us


class TestBatchAddition:
    def test_sgd_add(self):
        d_ui, d_us, _ = run_case("add", batch_size=512, r=20)
        assert d_ui < 0.3 * d_us, (d_ui, d_us)

    def test_gd_add(self):
        d_ui, d_us, _ = run_case("add", batch_size=1 << 30, r=20)
        assert d_ui < 0.3 * d_us, (d_ui, d_us)


class TestNonConvexGuard:
    def test_mlp_with_algorithm4_guard(self):
        """Paper §4.1 MNIST^n recipe: T0=2, quarter burn-in, guard on."""
        ds = multiclass_classification(n=1200, d=20, num_classes=4, seed=5)
        steps = 60
        cfg = DeltaGradConfig(period=2, burn_in=steps // 4, history_size=2,
                              guard=True, curvature_eps=1e-8)
        d_ui, d_us, stats = run_case(
            "delete", batch_size=1 << 30, r=12, steps=steps, ds=ds,
            objective=mlp_objective(l2=1e-3),
            params0=mlp_init(20, 32, 4, seed=6), cfg=cfg)
        assert d_ui < 0.5 * d_us, (d_ui, d_us)
        assert np.isfinite(d_ui)

    def test_guard_counts_fallbacks(self):
        cfg = DeltaGradConfig(period=5, burn_in=5, guard=True,
                              guard_norm_clip=0.0)  # force fallbacks
        _, _, stats = run_case("delete", batch_size=512, r=10, cfg=cfg)
        assert stats.guard_fallbacks > 0
        assert stats.approx_steps == 0  # everything fell back to explicit


class TestEdgeCases:
    def test_whole_batch_removed_skips_update(self):
        ds = binary_classification(n=40, d=5, seed=9)
        meta = HistoryMeta(n=40, batch_size=8, seed=1, steps=10,
                           lr_schedule=((0, 0.1),))
        obj = logreg_objective()
        p0 = logreg_init(5)
        _, hist = sgd_train_with_cache(obj, p0, ds, meta)
        # remove ALL rows of some step's batch: r/n is large, just exercise
        from repro.data.sampler import batch_indices
        batch0 = batch_indices(1, 0, 40, 8)
        cfg = DeltaGradConfig(period=3, burn_in=2)
        w_i, stats = deltagrad_retrain(obj, hist, ds, batch0, cfg)
        assert stats.skipped_steps >= 1
        assert np.isfinite(float(tree_norm(w_i)))


class TestMomentumExtension:
    """Beyond-paper: DeltaGrad under heavy-ball momentum (the paper's stated
    future work).  The retraining path maintains its own velocity from the
    corrected gradients; the o(r/n) behaviour empirically persists."""

    def test_momentum_delete(self):
        from repro.core.history import HistoryMeta
        from repro.data.synthetic import binary_classification
        from repro.models.simple import logreg_init, logreg_objective

        ds = binary_classification(n=2000, d=20, seed=0)
        obj = logreg_objective(l2=5e-3)
        meta = HistoryMeta(n=ds.n, batch_size=512, seed=7, steps=80,
                           lr_schedule=((0, 0.2),), momentum=0.9)
        p0 = logreg_init(20, seed=1)
        w_star, hist = sgd_train_with_cache(obj, p0, ds, meta)
        removed = np.random.default_rng(3).choice(ds.n, 20, replace=False)
        w_u, _ = baseline_retrain(obj, ds, meta, p0, removed)
        cfg = DeltaGradConfig(period=5, burn_in=10)
        w_i, stats = deltagrad_retrain(obj, hist, ds, removed, cfg)
        d_ui = float(tree_norm(tree_sub(w_u, w_i)))
        d_us = float(tree_norm(tree_sub(w_u, w_star)))
        assert d_ui < 0.35 * d_us, (d_ui, d_us)
        assert stats.approx_steps > 0

    def test_momentum_zero_rate_exact(self):
        from repro.core.history import HistoryMeta
        from repro.data.synthetic import binary_classification
        from repro.models.simple import logreg_init, logreg_objective

        ds = binary_classification(n=500, d=8, seed=2)
        obj = logreg_objective(l2=5e-3)
        meta = HistoryMeta(n=ds.n, batch_size=128, seed=3, steps=40,
                           lr_schedule=((0, 0.2),), momentum=0.9)
        p0 = logreg_init(8, seed=4)
        _, hist = sgd_train_with_cache(obj, p0, ds, meta)
        w_u, _ = baseline_retrain(obj, ds, meta, p0, np.array([], np.int64))
        cfg = DeltaGradConfig(period=5, burn_in=5)
        w_i, _ = deltagrad_retrain(obj, hist, ds, np.array([], np.int64), cfg)
        assert float(tree_norm(tree_sub(w_u, w_i))) < 1e-5
