"""Variable-count L-BFGS on device: the masked compact solve over the
zeros-initialized ring (1..m admitted pairs, no host-side burn-in)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lbfgs import (
    lbfgs_hvp_stacked_pytree,
    ring_valid_mask,
    valid_pair_mask,
)


def make_history(c, p, seed=0, mu=1.0):
    """Curvature-consistent pairs: dg = H dw with H spd (so D_ii > 0)."""
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(p, p)).astype(np.float32)
    H = A @ A.T / p + mu * np.eye(p, dtype=np.float32)
    dW = rng.normal(size=(c, p)).astype(np.float32)
    dG = (dW @ H.T).astype(np.float32)
    v = rng.normal(size=(p,)).astype(np.float32)
    return jnp.asarray(dW), jnp.asarray(dG), jnp.asarray(v)


def ring_with(dW, dG, m):
    """Embed c pairs newest-last in a zeros-initialized m-slot ring."""
    c, p = dW.shape
    rW = jnp.zeros((m, p), dtype=dW.dtype).at[m - c:].set(dW)
    rG = jnp.zeros((m, p), dtype=dG.dtype).at[m - c:].set(dG)
    return rW, rG


def test_ring_valid_mask_from_occupancy():
    dW, dG, _ = make_history(2, 12, seed=4)
    rW, _ = ring_with(dW, dG, 5)
    # any-leaf occupancy: the second leaf is all zeros and must not mask
    # out slots the first leaf occupies
    ring = {"a": rW, "b": jnp.zeros((5, 3), dtype=jnp.float32)}
    mask = np.asarray(ring_valid_mask(ring))
    assert mask.tolist() == [False, False, False, True, True]


def test_valid_pair_mask_matches_ring_derivation():
    dW, dG, _ = make_history(3, 8, seed=1)
    rW, _ = ring_with(dW, dG, 5)
    np.testing.assert_array_equal(np.asarray(valid_pair_mask(3, 5)),
                                  np.asarray(ring_valid_mask(rW)))
    assert np.asarray(valid_pair_mask(9, 5)).all()  # saturates at m


@pytest.mark.parametrize("c,m", [(1, 4), (2, 4), (3, 4), (2, 3)])
def test_masked_partial_ring_matches_compact_subsystem(c, m):
    """The masked 2m x 2m solve over a c-pair ring must equal the plain
    compact solve on just those c pairs (the satellite's contract: the
    device ring serves 1..m pairs with no separate count state)."""
    dW, dG, v = make_history(c, 24, seed=c * 10 + m)
    rW, rG = ring_with(dW, dG, m)
    got = lbfgs_hvp_stacked_pytree(rW, rG, v, masked=True)
    want = lbfgs_hvp_stacked_pytree(dW, dG, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_masked_full_ring_bitwise_equals_unmasked():
    """With every slot occupied the mask is inert: the masked solve must
    return the unmasked result EXACTLY (the engine's bitwise invariant —
    full-ring replays are unchanged by the refactor)."""
    dW, dG, v = make_history(4, 32, seed=9)
    got = lbfgs_hvp_stacked_pytree(dW, dG, v, masked=True)
    want = lbfgs_hvp_stacked_pytree(dW, dG, v)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_masked_empty_ring_is_zero_operator():
    """count == 0 degenerates to B v = 0 (sigma = 0/1 from zero slots)."""
    m, p = 3, 16
    rW = jnp.zeros((m, p), dtype=jnp.float32)
    v = jnp.asarray(np.random.default_rng(0).normal(size=(p,)),
                    dtype=jnp.float32)
    out = lbfgs_hvp_stacked_pytree(rW, rW, v, masked=True)
    np.testing.assert_array_equal(np.asarray(out), np.zeros(p, np.float32))
