"""Mesh-sharded replay parity: N-device shard_map scan vs single device.

Two layers of coverage:

  * `TestShardedReplayMesh` / `TestShardedOnlineMesh` /
    `TestShardedSession` run DIRECTLY when the process already has >= 8
    devices — the CI multi-device job sets
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before pytest —
    and skip on the normal 1-device tier-1 run.
  * `test_sharded_parity_subprocess_smoke` always runs: it spawns a fresh
    interpreter with the forced device count so the sharding seam is
    exercised by the tier-1 suite too (same idiom as
    tests/test_sharding_dryrun.py).
"""

import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

TOL = 1.5e-7
N_DEV = 8


def _devices() -> int:
    import jax
    return jax.local_device_count()


multi = pytest.mark.skipif(
    _devices() < N_DEV,
    reason=f"needs {N_DEV} devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _problem(d=16, steps=30):
    from repro.core.history import HistoryMeta
    from repro.data.synthetic import binary_classification
    from repro.models.simple import logreg_init, logreg_objective
    ds = binary_classification(n=200, d=d, seed=0)
    obj = logreg_objective(l2=1e-3)
    meta = HistoryMeta(n=200, batch_size=64, seed=0, steps=steps,
                       lr_schedule=((0, 0.2),), l2=1e-3)
    return ds, obj, meta, logreg_init(d, seed=1)


def _dist(a, b):
    from repro.utils.tree import tree_norm, tree_sub
    return float(tree_norm(tree_sub(a, b)))


def _cfg(**kw):
    from repro.core.deltagrad import DeltaGradConfig
    return DeltaGradConfig(period=5, burn_in=10, history_size=2, **kw)


@multi
class TestShardedReplayMesh:
    def test_replay_parity_and_stats(self):
        from repro.core.deltagrad import (deltagrad_retrain,
                                          sgd_train_with_cache)
        from repro.core.store import PlacementPolicy
        ds, obj, meta, p0 = _problem()
        _, h = sgd_train_with_cache(obj, p0, ds, meta, tier="stacked")
        changed = np.arange(6)
        w1, s1 = deltagrad_retrain(obj, h, ds, changed, _cfg())
        w8, s8 = deltagrad_retrain(obj, h, ds, changed, _cfg(),
                                   placement=PlacementPolicy.local(N_DEV))
        assert s8.extra["mesh"]["mesh_shape"] == [N_DEV]
        assert _dist(w1, w8) <= TOL
        assert (s1.approx_steps, s1.explicit_steps, s1.grad_examples) == \
            (s8.approx_steps, s8.explicit_steps, s8.grad_examples)

    def test_sharded_leaves_cut_per_device_hbm(self):
        """An MLP whose (d, hidden) leaves divide the data axis must store
        the path sharded: per-device history bytes drop by ~the mesh
        factor, and the all-gather-per-step replay still matches."""
        from repro.core.deltagrad import (deltagrad_retrain,
                                          sgd_train_with_cache)
        from repro.core.history import HistoryMeta
        from repro.core.store import PlacementPolicy
        from repro.data.synthetic import binary_classification
        from repro.models.simple import mlp_init, mlp_objective
        from repro.utils.tree import tree_norm
        ds = binary_classification(n=240, d=32, seed=0)
        ds.columns["y"] = ds.columns["y"].astype(np.int32)
        obj = mlp_objective(l2=1e-3)
        meta = HistoryMeta(n=240, batch_size=80, seed=0, steps=24,
                           lr_schedule=((0, 0.1),), l2=1e-3)
        _, h = sgd_train_with_cache(obj, mlp_init(32, 24, 2, seed=1), ds,
                                    meta, tier="stacked")
        cfg = _cfg(guard=True, curvature_eps=1e-8)
        w1, s1 = deltagrad_retrain(obj, h, ds, np.arange(5), cfg)
        w8, s8 = deltagrad_retrain(obj, h, ds, np.arange(5), cfg,
                                   placement=PlacementPolicy.local(N_DEV))
        assert s8.extra["hbm_high_water"] < s1.extra["hbm_high_water"] / 3
        rel = _dist(w1, w8) / max(1e-12, float(tree_norm(w1)))
        assert rel <= TOL
        assert (s1.approx_steps, s1.explicit_steps, s1.guard_fallbacks) == \
            (s8.approx_steps, s8.explicit_steps, s8.guard_fallbacks)

    def test_add_mode_parity(self):
        from repro.core.deltagrad import (deltagrad_retrain,
                                          sgd_train_with_cache)
        from repro.core.store import PlacementPolicy
        ds, obj, meta, p0 = _problem()
        _, h = sgd_train_with_cache(obj, p0, ds, meta, tier="stacked")
        new = ds.append({k: v[:3] for k, v in ds.columns.items()})
        w1, _ = deltagrad_retrain(obj, h, ds, new, _cfg(), mode="add")
        w8, _ = deltagrad_retrain(obj, h, ds, new, _cfg(), mode="add",
                                  placement=PlacementPolicy.local(N_DEV))
        assert _dist(w1, w8) <= TOL


@multi
class TestShardedOnlineMesh:
    def test_online_request_stats_parity(self):
        from repro.core.deltagrad import sgd_train_with_cache
        from repro.core.online import online_deltagrad
        from repro.core.store import PlacementPolicy

        def run(placement=None):
            ds, obj, meta, p0 = _problem()
            _, h = sgd_train_with_cache(obj, p0, ds, meta, tier="stacked")
            add = ds.append({k: v[:1] for k, v in ds.columns.items()})
            reqs = [("delete", 3), ("add", int(add[0])), ("delete", 17)]
            return online_deltagrad(obj, h, ds, reqs, _cfg(),
                                    placement=placement)

        w1, s1 = run()
        w8, s8 = run(PlacementPolicy.local(N_DEV))
        assert _dist(w1, w8) <= TOL
        for a, b in zip(s1.per_request, s8.per_request):
            assert (a.approx_steps, a.explicit_steps, a.grad_examples,
                    a.skipped_steps) == \
                (b.approx_steps, b.explicit_steps, b.grad_examples,
                 b.skipped_steps)


@multi
class TestShardedStreamedMesh:
    """The composed store: host/disk tier + mesh placement
    (`core.store.ShardedStreamer`) — the configuration `HistoryStore.create`
    used to refuse."""

    def _mlp_problem(self):
        from repro.core.history import HistoryMeta
        from repro.data.synthetic import binary_classification
        from repro.models.simple import mlp_init, mlp_objective
        ds = binary_classification(n=240, d=32, seed=0)
        ds.columns["y"] = ds.columns["y"].astype(np.int32)
        obj = mlp_objective(l2=1e-3)
        meta = HistoryMeta(n=240, batch_size=80, seed=0, steps=24,
                           lr_schedule=((0, 0.1),), l2=1e-3)
        return ds, obj, meta, mlp_init(32, 24, 2, seed=1)

    def test_replay_parity_and_shard_window_hbm(self, tmp_path):
        """Host-tier sharded-streamed replay: ≤ TOL vs the single-device
        resident run, EXACTLY 0.0 vs the sharded-resident run (identical
        shard_map programs step for step), and per-device high-water
        bounded by ~2 windows of the SHARD, not the full leaf."""
        import dataclasses

        from repro.core.deltagrad import (deltagrad_retrain,
                                          sgd_train_with_cache)
        from repro.core.store import PlacementPolicy
        from repro.utils.tree import tree_norm
        ds, obj, meta, p0 = self._mlp_problem()
        window = 8
        cfg = dataclasses.replace(_cfg(), stream_window=window)
        pol = PlacementPolicy.local(N_DEV)
        changed = np.arange(5)
        _, h_res = sgd_train_with_cache(obj, p0, ds, meta, tier="stacked")
        w1, s1 = deltagrad_retrain(obj, h_res, ds, changed, cfg)
        w8r, s8r = deltagrad_retrain(obj, h_res, ds, changed, cfg,
                                     placement=pol)
        _, h_host = sgd_train_with_cache(obj, p0, ds, meta, tier="host")
        w8s, s8s = deltagrad_retrain(obj, h_host, ds, changed, cfg,
                                     placement=pol)
        assert s8s.extra["store"] == "sharded_streamed"
        assert _dist(w8s, w8r) == 0.0
        rel = _dist(w8s, w1) / max(1e-12, float(tree_norm(w1)))
        assert rel <= TOL
        assert (s1.approx_steps, s1.explicit_steps, s1.grad_examples) == \
            (s8s.approx_steps, s8s.explicit_steps, s8s.grad_examples)
        # per-device high-water: ≤ ~2 windows of the SHARD (decoded window
        # + one in-flight encoded window), far below the full sharded path
        shard_window = s8r.extra["hbm_high_water"] * window / meta.steps
        assert s8s.extra["hbm_high_water"] <= 3.1 * shard_window
        assert s8s.extra["hbm_high_water"] < s1.extra["hbm_high_water"] / 6

    def test_guard_on_disk_tier_parity(self, tmp_path):
        import dataclasses

        from repro.core.deltagrad import (deltagrad_retrain,
                                          sgd_train_with_cache)
        from repro.core.store import PlacementPolicy
        ds, obj, meta, p0 = self._mlp_problem()
        cfg = dataclasses.replace(_cfg(guard=True, curvature_eps=1e-8),
                                  stream_window=8)
        pol = PlacementPolicy.local(N_DEV)
        _, h_res = sgd_train_with_cache(obj, p0, ds, meta, tier="stacked")
        w8r, s8r = deltagrad_retrain(obj, h_res, ds, np.arange(5), cfg,
                                     placement=pol)
        _, h_disk = sgd_train_with_cache(obj, p0, ds, meta, tier="disk",
                                         spill_dir=str(tmp_path))
        w8s, s8s = deltagrad_retrain(obj, h_disk, ds, np.arange(5), cfg,
                                     placement=pol)
        assert _dist(w8s, w8r) == 0.0
        assert s8s.guard_fallbacks == s8r.guard_fallbacks

    def test_online_mixed_stream_parity_vs_oracle(self):
        import dataclasses

        from repro.core.deltagrad import sgd_train_with_cache
        from repro.core.online import online_deltagrad
        from repro.core.store import PlacementPolicy

        def run(cfg, placement=None):
            ds, obj, meta, p0 = _problem()
            _, h = sgd_train_with_cache(obj, p0, ds, meta, tier="host")
            add = ds.append({k: v[:1] for k, v in ds.columns.items()})
            reqs = [("delete", 3), ("add", int(add[0])), ("delete", 17)]
            return online_deltagrad(obj, h, ds, reqs, cfg,
                                    placement=placement)

        cfg = dataclasses.replace(_cfg(), stream_window=8)
        w8, s8 = run(cfg, PlacementPolicy.local(N_DEV))
        assert s8.per_request[0].extra["store"] == "sharded_streamed"
        w_py, s_py = run(dataclasses.replace(cfg, impl="python"))
        assert _dist(w8, w_py) <= TOL
        for a, b in zip(s8.per_request, s_py.per_request):
            assert (a.approx_steps, a.explicit_steps, a.grad_examples,
                    a.skipped_steps) == \
                (b.approx_steps, b.explicit_steps, b.grad_examples,
                 b.skipped_steps)

    def test_lossy_codec_write_back_sharded_stream(self):
        """int8 rewrites on the composed store land through the codec into
        the owning HISTORY entries (not just the device windows): a fresh
        sharded engine rebuilt from the rewritten history serves the next
        request exactly like the uninterrupted sharded stream."""
        import dataclasses

        from repro.core.deltagrad import sgd_train_with_cache
        from repro.core.online import online_deltagrad
        from repro.core.store import PlacementPolicy

        cfg = dataclasses.replace(_cfg(), stream_window=8)
        pol = PlacementPolicy.local(N_DEV)
        reqs_all = [("delete", 3), ("delete", 17), ("delete", 40)]

        def mk():
            ds, obj, meta, p0 = _problem()
            _, h = sgd_train_with_cache(obj, p0, ds, meta, tier="host",
                                        codec="int8")
            return ds, obj, h

        ds1, obj1, h1 = mk()
        w_ref, st = online_deltagrad(obj1, h1, ds1, reqs_all, cfg,
                                     placement=pol)
        assert st.per_request[0].extra["store"] == "sharded_streamed"
        ds2, obj2, h2 = mk()
        online_deltagrad(obj2, h2, ds2, reqs_all[:2], cfg, placement=pol)
        # a NEW engine decodes the committed entries back off the host tier
        w_resume, _ = online_deltagrad(obj2, h2, ds2, reqs_all[2:], cfg,
                                       placement=pol)
        assert _dist(w_resume, w_ref) == 0.0

    def test_delta_codec_sharded_stream_parity(self):
        """delta_int8 on the composed store: per-shard windows ship
        ENCODED (residual + keyframe shards), decode in-scan, and match
        the single-device streamed replay of the same history."""
        import dataclasses

        from repro.core.deltagrad import (deltagrad_retrain,
                                          sgd_train_with_cache)
        from repro.core.store import PlacementPolicy
        from repro.utils.tree import tree_norm
        ds, obj, meta, p0 = self._mlp_problem()
        cfg = dataclasses.replace(_cfg(), stream_window=8,
                                  stream_decode="kernel")
        changed = np.arange(5)
        _, h = sgd_train_with_cache(obj, p0, ds, meta, tier="host",
                                    codec="delta_int8")
        w1, s1 = deltagrad_retrain(obj, h, ds, changed, cfg)
        w8, s8 = deltagrad_retrain(obj, h, ds, changed, cfg,
                                   placement=PlacementPolicy.local(N_DEV))
        assert s8.extra["store"] == "sharded_streamed"
        assert s8.extra["stream_decode"] == "kernel"
        assert s8.extra["compression_ratio"] > 1.2
        rel = _dist(w8, w1) / max(1e-12, float(tree_norm(w1)))
        assert rel <= TOL
        assert (s1.approx_steps, s1.explicit_steps) == \
            (s8.approx_steps, s8.explicit_steps)
        # encoded per-shard windows undercut the decoded-fetch high-water
        w8f, s8f = deltagrad_retrain(
            obj, h, ds, changed,
            dataclasses.replace(cfg, stream_decode="fetch"),
            placement=PlacementPolicy.local(N_DEV))
        assert _dist(w8, w8f) == 0.0
        assert s8.extra["hbm_high_water"] < s8f.extra["hbm_high_water"]

    def test_delta_write_back_sharded_stream(self):
        """Online rewrites through the composed store under delta_int8:
        residuals re-encode against the original keyframes and a fresh
        sharded engine resumes exactly."""
        import dataclasses

        from repro.core.deltagrad import sgd_train_with_cache
        from repro.core.online import online_deltagrad
        from repro.core.store import PlacementPolicy

        cfg = dataclasses.replace(_cfg(), stream_window=8)
        pol = PlacementPolicy.local(N_DEV)
        reqs_all = [("delete", 3), ("delete", 17), ("delete", 40)]

        def mk():
            ds, obj, meta, p0 = _problem()
            _, h = sgd_train_with_cache(obj, p0, ds, meta, tier="host",
                                        codec="delta_int8")
            return ds, obj, h

        ds1, obj1, h1 = mk()
        w_ref, st = online_deltagrad(obj1, h1, ds1, reqs_all, cfg,
                                     placement=pol)
        assert st.per_request[0].extra["store"] == "sharded_streamed"
        ds2, obj2, h2 = mk()
        online_deltagrad(obj2, h2, ds2, reqs_all[:2], cfg, placement=pol)
        w_resume, _ = online_deltagrad(obj2, h2, ds2, reqs_all[2:], cfg,
                                       placement=pol)
        assert _dist(w_resume, w_ref) == 0.0

    def test_session_save_restore_composed_descriptor(self, tmp_path):
        """save()/restore() round-trips the COMPOSED placement: host tier +
        mesh descriptor + stream window rebuild a `ShardedStreamer`."""
        import dataclasses

        from repro.core.session import UnlearnerConfig, UnlearnerSession
        from repro.core.store import PlacementPolicy
        from repro.data.synthetic import binary_classification
        from repro.models.simple import logreg_init, logreg_objective
        obj = logreg_objective(l2=1e-3)
        cfg = UnlearnerConfig(steps=30, batch_size=64, lr=0.2, seed=0,
                              history_tier="host",
                              deltagrad=dataclasses.replace(
                                  _cfg(), stream_window=8),
                              placement=PlacementPolicy.local(N_DEV))
        ds = binary_classification(n=200, d=16, seed=0)
        sess = UnlearnerSession(obj, logreg_init(16, seed=1), ds, cfg)
        sess.fit()
        sess.delete([3, 17]).result()
        assert sess.engine().store.kind == "sharded_streamed"
        sess.save(str(tmp_path))
        restored = UnlearnerSession.restore(str(tmp_path), obj)
        assert restored.config.placement.mesh_shape == (N_DEV,)
        assert restored.config.history_tier == "host"
        assert restored.engine().store.kind == "sharded_streamed"
        a = sess.delete([40]).params
        b = restored.delete([40]).params
        assert _dist(a, b) == 0.0


@multi
class TestShardedSession:
    def test_save_restore_under_sharded_placement(self, tmp_path):
        from repro.core.session import UnlearnerConfig, UnlearnerSession
        from repro.core.store import PlacementPolicy
        from repro.data.synthetic import binary_classification
        from repro.models.simple import logreg_init, logreg_objective
        obj = logreg_objective(l2=1e-3)
        cfg = UnlearnerConfig(steps=30, batch_size=64, lr=0.2, seed=0,
                              deltagrad=_cfg(),
                              placement=PlacementPolicy.local(N_DEV))
        ds = binary_classification(n=200, d=16, seed=0)
        sess = UnlearnerSession(obj, logreg_init(16, seed=1), ds, cfg)
        sess.fit()
        sess.delete([3, 17]).result()
        assert sess.engine().store.sharded_replay() is not None
        sess.save(str(tmp_path))
        restored = UnlearnerSession.restore(str(tmp_path), obj)
        # the placement descriptor round-tripped; the restored engine
        # serves on the same mesh shape
        assert restored.config.placement.mesh_shape == (N_DEV,)
        a = sess.delete([40]).params
        b = restored.delete([40]).params
        assert _dist(a, b) <= TOL


def test_sharded_parity_subprocess_smoke():
    """Always-on tier-1 coverage: run a tiny sharded-vs-single replay in a
    subprocess with 8 forced host devices (the main process stays at 1)."""
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
            " --xla_force_host_platform_device_count={N_DEV}")
        import numpy as np, jax
        assert jax.local_device_count() == {N_DEV}
        from repro.core.deltagrad import (DeltaGradConfig,
            deltagrad_retrain, sgd_train_with_cache)
        from repro.core.history import HistoryMeta
        from repro.core.store import PlacementPolicy
        from repro.data.synthetic import binary_classification
        from repro.models.simple import logreg_init, logreg_objective
        from repro.utils.tree import tree_norm, tree_sub
        ds = binary_classification(n=120, d=16, seed=0)
        obj = logreg_objective(l2=1e-3)
        meta = HistoryMeta(n=120, batch_size=48, seed=0, steps=18,
                           lr_schedule=((0, 0.2),), l2=1e-3)
        _, h = sgd_train_with_cache(obj, logreg_init(16, seed=1), ds, meta,
                                    tier="stacked")
        cfg = DeltaGradConfig(period=5, burn_in=6, history_size=2)
        w1, s1 = deltagrad_retrain(obj, h, ds, np.arange(4), cfg)
        w8, s8 = deltagrad_retrain(obj, h, ds, np.arange(4), cfg,
                                   placement=PlacementPolicy.local({N_DEV}))
        d = float(tree_norm(tree_sub(w1, w8)))
        assert d <= {TOL}, d
        assert s1.approx_steps == s8.approx_steps
        print("SHARD_OK", d)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], text=True,
                          capture_output=True, env=env,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "SHARD_OK" in proc.stdout


def test_sharded_streamed_subprocess_smoke():
    """Always-on tier-1 coverage for the COMPOSED store: a host-tier
    history placed on an 8-way forced-host mesh must stream per-shard
    windows and match the sharded-RESIDENT replay exactly."""
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
            " --xla_force_host_platform_device_count={N_DEV}")
        import dataclasses
        import numpy as np, jax
        assert jax.local_device_count() == {N_DEV}
        from repro.core.deltagrad import (DeltaGradConfig,
            deltagrad_retrain, sgd_train_with_cache)
        from repro.core.history import HistoryMeta
        from repro.core.store import PlacementPolicy
        from repro.data.synthetic import binary_classification
        from repro.models.simple import logreg_init, logreg_objective
        from repro.utils.tree import tree_norm, tree_sub
        ds = binary_classification(n=120, d=16, seed=0)
        obj = logreg_objective(l2=1e-3)
        meta = HistoryMeta(n=120, batch_size=48, seed=0, steps=18,
                           lr_schedule=((0, 0.2),), l2=1e-3)
        p0 = logreg_init(16, seed=1)
        cfg = DeltaGradConfig(period=5, burn_in=6, history_size=2,
                              stream_window=6)
        pol = PlacementPolicy.local({N_DEV})
        _, h_res = sgd_train_with_cache(obj, p0, ds, meta, tier="stacked")
        w_res, _ = deltagrad_retrain(obj, h_res, ds, np.arange(4), cfg,
                                     placement=pol)
        _, h = sgd_train_with_cache(obj, p0, ds, meta, tier="host")
        w_str, st = deltagrad_retrain(obj, h, ds, np.arange(4), cfg,
                                      placement=pol)
        assert st.extra["store"] == "sharded_streamed", st.extra["store"]
        assert st.extra["windows"] > 1, st.extra
        d = float(tree_norm(tree_sub(w_str, w_res)))
        assert d == 0.0, d
        print("SHARD_STREAM_OK", d)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], text=True,
                          capture_output=True, env=env,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "SHARD_STREAM_OK" in proc.stdout
