"""TrainingHistory tiers/codecs + the deterministic data pipeline."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.history import HistoryMeta, TrainingHistory
from repro.data.dataset import Dataset
from repro.data.sampler import addition_mask, batch_indices


META = HistoryMeta(n=100, batch_size=10, seed=3, steps=5,
                   lr_schedule=((0, 0.1), (3, 0.05)))


def tree(seed):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(3,)).astype(np.float32))}


@pytest.mark.parametrize("tier", ["device", "host"])
@pytest.mark.parametrize("codec", ["f32", "bf16", "int8"])
def test_history_roundtrip(tier, codec):
    h = TrainingHistory(META, tier=tier, codec=codec)
    for t in range(3):
        h.append(tree(t), tree(100 + t))
    p, g = h.entry(1)
    tol = {"f32": 1e-7, "bf16": 1e-2, "int8": 5e-2}[codec]
    np.testing.assert_allclose(np.asarray(p["w"]),
                               np.asarray(tree(1)["w"]), atol=tol)
    np.testing.assert_allclose(np.asarray(g["b"]),
                               np.asarray(tree(101)["b"]), atol=tol)


def test_history_disk_tier(tmp_path):
    h = TrainingHistory(META, tier="disk", codec="f32",
                        spill_dir=str(tmp_path))
    for t in range(4):
        h.append(tree(t), tree(100 + t))
    assert len(os.listdir(tmp_path)) == 4
    p, _ = h.entry(2)
    np.testing.assert_allclose(np.asarray(p["w"]), np.asarray(tree(2)["w"]))
    h.overwrite(2, tree(55), tree(66))
    p2, g2 = h.entry(2)
    np.testing.assert_allclose(np.asarray(p2["w"]), np.asarray(tree(55)["w"]))


def test_history_state_dict_roundtrip():
    h = TrainingHistory(META, tier="host")
    for t in range(3):
        h.append(tree(t), tree(100 + t))
    h.finalize(tree(999))
    h2 = TrainingHistory.from_state_dict(h.state_dict())
    p, g = h2.entry(0)
    np.testing.assert_allclose(np.asarray(p["w"]), np.asarray(tree(0)["w"]))
    np.testing.assert_allclose(np.asarray(h2.final_params["b"]),
                               np.asarray(tree(999)["b"]))


def test_lr_schedule():
    assert META.lr_at(0) == 0.1
    assert META.lr_at(2) == 0.1
    assert META.lr_at(3) == 0.05
    assert META.lr_at(4) == 0.05


# -- sampler ------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10**6), step=st.integers(0, 10**4),
       n=st.integers(10, 5000))
def test_sampler_is_pure_and_in_range(seed, step, n):
    b = min(n // 2 + 1, 128)
    i1 = batch_indices(seed, step, n, b)
    i2 = batch_indices(seed, step, n, b)
    np.testing.assert_array_equal(i1, i2)
    assert len(np.unique(i1)) == len(i1)  # without replacement
    assert i1.min() >= 0 and i1.max() < n


def test_sampler_full_batch_is_identity():
    np.testing.assert_array_equal(batch_indices(0, 7, 10, 10**9),
                                  np.arange(10))


def test_addition_mask_prefix_consistency():
    """Adding more samples never changes earlier samples' join pattern."""
    m3 = addition_mask(5, 11, 1000, 100, 3)
    m7 = addition_mask(5, 11, 1000, 100, 7)
    np.testing.assert_array_equal(m3, m7[:3])


# -- dataset ------------------------------------------------------------------


def test_dataset_delete_append_roundtrip():
    ds = Dataset({"x": np.arange(12).reshape(6, 2).astype(np.float32),
                  "y": np.arange(6)})
    ds.delete([1, 4])
    assert ds.n_remaining == 4
    with pytest.raises(ValueError):
        ds.delete([1])
    new = ds.append({"x": np.ones((2, 2), np.float32), "y": np.array([7, 8])})
    np.testing.assert_array_equal(new, [6, 7])
    assert ds.n == 8
    kept, removed = ds.split_batch(np.array([0, 1, 4, 6]))
    np.testing.assert_array_equal(kept, [0, 6])
    np.testing.assert_array_equal(removed, [1, 4])


def test_padded_batch_weights():
    ds = Dataset({"x": np.arange(10).astype(np.float32)})
    batch, w = ds.padded_batch(np.array([3, 7]), pad_to=5)
    assert batch["x"].shape == (5,)
    np.testing.assert_array_equal(w, [1, 1, 0, 0, 0])
